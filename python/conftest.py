"""Make `pytest python/tests/` work from the repo root: the test suite
imports the `compile` package, which lives in this directory."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
