"""AOT export: train the serve CNN once, lower every precision
configuration to HLO text, and write the artifact manifest.

This is the *only* Python entry point in the deployment story. It runs at
build time (``make artifacts``) and produces:

* ``artifacts/serve_cnn_<config>_b<batch>.hlo.txt`` — one AOT-lowered
  quantized forward graph per (precision config, batch size). Weights are
  baked in as constants; the graph's single parameter is the input image
  batch ``f32[batch, 32, 32, 3]`` and its output is the logits tuple
  ``(f32[batch, 10],)``.
* ``artifacts/weights.npz`` — the trained float parameters (reproducible
  re-export without retraining).
* ``artifacts/manifest.json`` — configs, average bitwidths, held-out
  accuracies, batch sizes, loss curve, and artifact file names. The rust
  coordinator reads this to discover what it can serve.

Interchange format is HLO **text**, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the ``xla`` crate's backend) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

#: Batch sizes compiled ahead of time. The coordinator's dynamic batcher
#: packs requests into the largest compiled batch (padding the remainder).
BATCH_SIZES = (1, 4, 8)

#: Training seed — fixed for reproducible artifacts.
TRAIN_SEED = 0
EVAL_SEED = 99


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (the rust-side format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_config(params, cfg_name: str, batch: int, out_dir: Path) -> dict:
    """Lower one (config, batch) serving graph to HLO text; returns its
    manifest entry."""
    spec = jax.ShapeDtypeStruct((batch, *model.INPUT_SHAPE), jnp.float32)
    if cfg_name == "float":
        fn = lambda x: (model.float_forward(params, x),)  # noqa: E731
        bits = 32.0
    else:
        cfg = model.PRECISION_CONFIGS[cfg_name]
        fn = lambda x: (model.quant_forward(params, x, cfg, use_kernel=True),)  # noqa: E731
        bits = model.avg_bits(cfg)
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    name = f"serve_cnn_{cfg_name}_b{batch}.hlo.txt"
    (out_dir / name).write_text(text)
    return {
        "config": cfg_name,
        "batch": batch,
        "file": name,
        "avg_bits": bits,
        "hlo_bytes": len(text),
    }


def flatten_params(params) -> dict[str, np.ndarray]:
    """Nested params -> flat dict for npz storage."""
    return {
        f"{layer}/{leaf}": np.asarray(v)
        for layer, sub in params.items()
        for leaf, v in sub.items()
    }


def unflatten_params(flat: dict[str, np.ndarray]):
    """Inverse of :func:`flatten_params`."""
    params: dict[str, dict[str, jnp.ndarray]] = {}
    for key, v in flat.items():
        layer, leaf = key.split("/")
        params.setdefault(layer, {})[leaf] = jnp.asarray(v)
    return params


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--steps", type=int, default=400, help="training steps")
    ap.add_argument("--batch", type=int, default=32, help="training batch size")
    ap.add_argument(
        "--retrain", action="store_true", help="retrain even if weights.npz exists"
    )
    args = ap.parse_args()
    out_dir = Path(args.out)
    # `--out path/model.hlo.txt` style (Makefile sentinel) -> parent dir.
    if out_dir.suffix:
        out_dir = out_dir.parent
    out_dir.mkdir(parents=True, exist_ok=True)

    weights_path = out_dir / "weights.npz"
    curve: list[tuple[int, float]] = []
    if weights_path.exists() and not args.retrain:
        print(f"loading cached weights from {weights_path}")
        params = unflatten_params(dict(np.load(weights_path)))
    else:
        print(f"training serve_cnn for {args.steps} steps (batch {args.batch}) ...")
        t0 = time.time()
        params, curve = model.train(
            jax.random.PRNGKey(TRAIN_SEED), steps=args.steps, batch=args.batch
        )
        print(f"trained in {time.time() - t0:.1f}s")
        np.savez(weights_path, **flatten_params(params))

    # Held-out eval set exported raw for the rust serving driver: inputs as
    # little-endian f32, labels as u8 (no npz parser needed on the rust side).
    eval_n = 128
    ex, ey = model.make_dataset(jax.random.PRNGKey(EVAL_SEED + 1), eval_n)
    np.asarray(ex, dtype="<f4").tofile(out_dir / "eval_inputs.f32")
    np.asarray(ey, dtype=np.uint8).tofile(out_dir / "eval_labels.u8")
    # Cross-language numerics check: expected float logits of the first 8
    # eval samples; rust/tests/runtime_e2e.rs compares PJRT output to these.
    exp = model.float_forward(params, ex[:8])
    np.asarray(exp, dtype="<f4").tofile(out_dir / "eval_logits_float_b8.f32")

    eval_key = jax.random.PRNGKey(EVAL_SEED)
    accuracies = {"float": model.eval_accuracy(params, None, eval_key)}
    for cfg_name in model.PRECISION_CONFIGS:
        accuracies[cfg_name] = model.eval_accuracy(params, cfg_name, eval_key)
        print(f"  accuracy[{cfg_name}] = {accuracies[cfg_name]:.4f}")
    print(f"  accuracy[float] = {accuracies['float']:.4f}")

    entries = []
    for cfg_name in ["float", *model.PRECISION_CONFIGS]:
        for batch in BATCH_SIZES:
            t0 = time.time()
            entry = export_config(params, cfg_name, batch, out_dir)
            entry["accuracy"] = accuracies[cfg_name]
            entries.append(entry)
            print(
                f"  exported {entry['file']}  ({entry['hlo_bytes'] / 1e3:.0f} kB, "
                f"{time.time() - t0:.1f}s)"
            )

    manifest = {
        "model": "serve_cnn",
        "input_shape": list(model.INPUT_SHAPE),
        "num_classes": model.NUM_CLASSES,
        "param_count": model.param_count(params),
        "batch_sizes": list(BATCH_SIZES),
        "train_steps": args.steps,
        "loss_curve": curve,
        "configs": {
            name: {"per_layer": [list(p) for p in cfg], "avg_bits": model.avg_bits(cfg)}
            for name, cfg in model.PRECISION_CONFIGS.items()
        },
        "accuracies": accuracies,
        "eval_set": {"n": eval_n, "inputs": "eval_inputs.f32", "labels": "eval_labels.u8"},
        "artifacts": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'} with {len(entries)} artifacts")


if __name__ == "__main__":
    main()
