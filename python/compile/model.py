"""Layer-2 JAX model: the quantized CNN served by the BF-IMNA coordinator.

This is the build-time half of the serving demo. It defines ``SERVE_CNN``
— the small CNN that `rust/src/model/zoo.rs::serve_cnn` mirrors layer for
layer — plus:

* a float forward pass (training path),
* a **bit-fluid quantized forward pass** with per-layer weight/activation
  bitwidths, where every convolution / fully-connected layer lowers to the
  Layer-1 Pallas bit-plane GEMM (`kernels.bitserial_gemm`) through im2col —
  exactly how BF-IMNA maps convolutions onto CAPs (§II-C),
* a tiny synthetic 10-class image dataset and a training loop, so the
  exported artifacts carry *real trained weights* and the accuracy-vs-
  precision trade-off of Table VII is measurable end to end.

Python never runs at serve time: `aot.py` lowers `quant_forward` once per
precision configuration to HLO text; the rust coordinator loads and
executes the artifacts via PJRT.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.bitserial_gemm import bitplane_gemm

# ---------------------------------------------------------------------------
# Architecture (must mirror rust/src/model/zoo.rs::serve_cnn).
# ---------------------------------------------------------------------------

INPUT_SHAPE = (32, 32, 3)
NUM_CLASSES = 10

# (name, kind, c_in, c_out) — conv kernels are 3x3, stride 1, pad 1.
SERVE_CNN = (
    ("conv1", "conv", 3, 16),
    ("conv2", "conv", 16, 16),
    ("pool1", "maxpool", 2, None),
    ("conv3", "conv", 16, 32),
    ("conv4", "conv", 32, 32),
    ("pool2", "maxpool", 2, None),
    ("conv5", "conv", 32, 64),
    ("gap", "avgpool", None, None),
    ("fc", "fc", 64, NUM_CLASSES),
)

#: Names of the weight-carrying layers, in order (6 of them). A precision
#: configuration assigns one (w_bits, a_bits) pair per entry.
WEIGHT_LAYERS = tuple(n for n, k, *_ in SERVE_CNN if k in ("conv", "fc"))

#: The precision configurations the coordinator can switch between at run
#: time (serve-CNN analogue of Table VII's rows: fixed INT8 / INT4 plus
#: three HAWQ-style mixed configs under loosening latency budgets).
PRECISION_CONFIGS: dict[str, tuple[tuple[int, int], ...]] = {
    "int8": tuple((8, 8) for _ in WEIGHT_LAYERS),
    "mixed_high": ((8, 8), (8, 8), (8, 8), (4, 4), (8, 8), (8, 8)),
    "mixed_medium": ((8, 8), (8, 8), (4, 4), (4, 4), (8, 8), (8, 8)),
    "mixed_low": ((8, 8), (4, 4), (4, 4), (4, 4), (4, 4), (8, 8)),
    "int4": tuple((4, 4) for _ in WEIGHT_LAYERS),
}


def avg_bits(cfg: tuple[tuple[int, int], ...]) -> float:
    """Average bitwidth of a configuration (Table VII convention)."""
    return sum((w + a) / 2 for w, a in cfg) / len(cfg)


# ---------------------------------------------------------------------------
# Parameters.
# ---------------------------------------------------------------------------


def init_params(key: jax.Array) -> dict[str, Any]:
    """He-initialized parameters for SERVE_CNN."""
    params: dict[str, Any] = {}
    for name, kind, c_in, c_out in SERVE_CNN:
        if kind == "conv":
            key, sub = jax.random.split(key)
            fan_in = 9 * c_in
            params[name] = {
                "w": jax.random.normal(sub, (3, 3, c_in, c_out), jnp.float32)
                * jnp.sqrt(2.0 / fan_in),
                "b": jnp.zeros((c_out,), jnp.float32),
            }
        elif kind == "fc":
            key, sub = jax.random.split(key)
            params[name] = {
                "w": jax.random.normal(sub, (c_in, c_out), jnp.float32)
                * jnp.sqrt(2.0 / c_in),
                "b": jnp.zeros((c_out,), jnp.float32),
            }
    return params


def param_count(params: dict[str, Any]) -> int:
    """Total trainable parameter count."""
    return sum(int(v.size) for layer in params.values() for v in layer.values())


# ---------------------------------------------------------------------------
# im2col convolution (§II-C) — shared by the float and quantized paths.
# ---------------------------------------------------------------------------


def im2col(x: jnp.ndarray, k: int = 3, pad: int = 1) -> jnp.ndarray:
    """Unroll 3x3 stride-1 patches: (B, H, W, C) -> (B*H*W, k*k*C).

    Column order is (di, dj, c) — the same unrolling the rust mapper and
    Fig. 2 use, so the kernel matrix reshape below matches.
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = [xp[:, di : di + h, dj : dj + w, :] for di in range(k) for dj in range(k)]
    patches = jnp.concatenate(cols, axis=-1)  # (B, H, W, k*k*C)
    return patches.reshape(b * h * w, k * k * c)


def _conv_via_gemm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, gemm) -> jnp.ndarray:
    """3x3 same-conv through im2col + a caller-supplied GEMM."""
    bsz, h, wdt, _ = x.shape
    c_out = w.shape[-1]
    # (3,3,C_in,C_out) -> (9*C_in, C_out), matching im2col's (di,dj,c) order.
    wm = w.reshape(-1, c_out)
    out = gemm(im2col(x), wm)
    return out.reshape(bsz, h, wdt, c_out) + b


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-2 max pooling."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def global_avgpool(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pooling to (B, C)."""
    return x.mean(axis=(1, 2))


# ---------------------------------------------------------------------------
# Float forward (training path).
# ---------------------------------------------------------------------------


def float_forward(params: dict[str, Any], x: jnp.ndarray) -> jnp.ndarray:
    """Float32 forward pass, logits of shape (B, NUM_CLASSES)."""
    gemm = lambda a, w: a @ w  # noqa: E731
    for name, kind, *_ in SERVE_CNN:
        if kind == "conv":
            p = params[name]
            x = jax.nn.relu(_conv_via_gemm(x, p["w"], p["b"], gemm))
        elif kind == "maxpool":
            x = maxpool2(x)
        elif kind == "avgpool":
            x = global_avgpool(x)
        elif kind == "fc":
            p = params[name]
            x = x @ p["w"] + p["b"]
    return x


# ---------------------------------------------------------------------------
# Quantized forward (the exported serving graph).
# ---------------------------------------------------------------------------


def _quant_gemm(
    a_f: jnp.ndarray, w_f: jnp.ndarray, a_bits: int, w_bits: int, use_kernel: bool
) -> jnp.ndarray:
    """Quantize both operands, multiply in integers (Pallas bit-plane GEMM
    or the jnp oracle), dequantize."""
    s_a = ref.scale_for(a_f, a_bits)
    s_w = ref.scale_for(w_f, w_bits)
    qa = ref.quantize(a_f, a_bits, s_a)
    qw = ref.quantize(w_f, w_bits, s_w)
    if use_kernel:
        qo = bitplane_gemm(qa, qw, a_bits=a_bits, w_bits=w_bits)
    else:
        qo = ref.gemm_ref(qa, qw)
    return qo.astype(jnp.float32) * (s_a * s_w)


def quant_forward(
    params: dict[str, Any],
    x: jnp.ndarray,
    cfg: tuple[tuple[int, int], ...],
    *,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Bit-fluid quantized forward pass.

    Args:
      params: trained float parameters.
      x: (B, 32, 32, 3) float32 inputs.
      cfg: one (w_bits, a_bits) pair per weight layer (see
        ``PRECISION_CONFIGS``). Lower precision simply shortens the Pallas
        kernel's bit-plane loops — the software analogue of BF-IMNA
        deactivating MSB columns, with zero reconfiguration.
      use_kernel: route GEMMs through the Pallas kernel (True, the exported
        path) or the pure-jnp oracle (False, the test oracle).
    """
    if len(cfg) != len(WEIGHT_LAYERS):
        raise ValueError(f"cfg has {len(cfg)} entries, need {len(WEIGHT_LAYERS)}")
    slot = 0
    for name, kind, *_ in SERVE_CNN:
        if kind == "conv":
            w_bits, a_bits = cfg[slot]
            slot += 1
            p = params[name]
            gemm = functools.partial(
                _quant_gemm, a_bits=a_bits, w_bits=w_bits, use_kernel=use_kernel
            )
            x = jax.nn.relu(_conv_via_gemm(x, p["w"], p["b"], gemm))
        elif kind == "maxpool":
            x = maxpool2(x)
        elif kind == "avgpool":
            x = global_avgpool(x)
        elif kind == "fc":
            w_bits, a_bits = cfg[slot]
            slot += 1
            p = params[name]
            x = _quant_gemm(x, p["w"], a_bits, w_bits, use_kernel) + p["b"]
    return x


# ---------------------------------------------------------------------------
# Synthetic 10-class dataset + training loop.
# ---------------------------------------------------------------------------


def _class_gratings() -> jnp.ndarray:
    """One oriented sinusoidal grating per class — texture classes a CNN
    with global average pooling learns from local filters."""
    h, w, c = INPUT_SHAPE
    ii, jj = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    tpl = []
    for k in range(NUM_CLASSES):
        theta = jnp.pi * k / NUM_CLASSES
        freq = 2.0 + 0.7 * k
        phase = 2.0 * jnp.pi * freq * (jnp.cos(theta) * ii + jnp.sin(theta) * jj) / h
        img = jnp.stack([jnp.sin(phase + ch) for ch in range(c)], axis=-1)
        tpl.append(img)
    return jnp.stack(tpl).astype(jnp.float32)  # (classes, H, W, C)


def make_dataset(key: jax.Array, n: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Synthetic texture classification set: each class is an oriented
    grating; a sample is its class grating under a random gain, a random
    spatial shift (gratings are shift-covariant, so class identity
    survives) plus Gaussian noise. Non-trivial but learnable in a few
    hundred steps."""
    k_lbl, k_gain, k_shift, k_noise = jax.random.split(key, 4)
    templates = _class_gratings()
    labels = jax.random.randint(k_lbl, (n,), 0, NUM_CLASSES)
    gains = 0.7 + 0.6 * jax.random.uniform(k_gain, (n, 1, 1, 1))
    shifts = jax.random.randint(k_shift, (n, 2), 0, INPUT_SHAPE[0])
    noise = jax.random.normal(k_noise, (n, *INPUT_SHAPE), jnp.float32)
    base = templates[labels]
    rolled = jax.vmap(lambda img, s: jnp.roll(img, (s[0], s[1]), axis=(0, 1)))(
        base, shifts
    )
    x = gains * rolled + 0.7 * noise
    return x, labels


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Top-1 accuracy."""
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("lr", "momentum"))
def _sgd_step(params, velocity, x, y, lr: float = 0.015, momentum: float = 0.9):
    loss, grads = jax.value_and_grad(
        lambda p: cross_entropy(float_forward(p, x), y)
    )(params)
    velocity = jax.tree.map(lambda v, g: momentum * v - lr * g, velocity, grads)
    params = jax.tree.map(lambda p, v: p + v, params, velocity)
    return params, velocity, loss


def train(
    key: jax.Array,
    steps: int = 300,
    batch: int = 64,
    log_every: int = 50,
    verbose: bool = True,
) -> tuple[dict[str, Any], list[tuple[int, float]]]:
    """Train SERVE_CNN on the synthetic set; returns (params, loss curve)."""
    k_data, k_init = jax.random.split(key)
    x_all, y_all = make_dataset(k_data, steps * batch // 4 + batch)
    params = init_params(k_init)
    velocity = jax.tree.map(jnp.zeros_like, params)
    n = x_all.shape[0]
    curve = []
    for step in range(steps):
        lo = (step * batch) % (n - batch)
        xb, yb = x_all[lo : lo + batch], y_all[lo : lo + batch]
        params, velocity, loss = _sgd_step(params, velocity, xb, yb)
        if step % log_every == 0 or step == steps - 1:
            curve.append((step, float(loss)))
            if verbose:
                print(f"  step {step:4d}  loss {float(loss):.4f}")
    return params, curve


def eval_accuracy(
    params: dict[str, Any],
    cfg_name: str | None,
    key: jax.Array,
    n: int = 512,
) -> float:
    """Held-out accuracy of the float model (cfg_name=None) or a quantized
    configuration (routed through the pure-jnp oracle for speed)."""
    x, y = make_dataset(key, n)
    if cfg_name is None:
        logits = float_forward(params, x)
    else:
        logits = quant_forward(params, x, PRECISION_CONFIGS[cfg_name], use_kernel=False)
    return float(accuracy(logits, y))
