"""Layer-1 Pallas kernel: bit-plane (bit-serial) integer GEMM.

BF-IMNA's compute hot-spot is the bit-serial multiply-accumulate of the
2D Associative Processor: a ``b_w x b_a``-bit multiply is ``b_w * b_a``
compare/write LUT pass groups applied to *all* CAM rows at once
(word-parallel). A TPU has no CAM, but the insight — **precision is a loop
bound over bit planes, with full parallelism across words** — maps onto
the MXU directly:

====================================  =====================================
BF-IMNA (paper)                       this kernel (TPU-shaped Pallas)
====================================  =====================================
word-parallel CAM rows                the (M, N) tile dims of an MXU matmul
one bit-column LUT pass group         one bit-plane matmul (0/1 matrices)
``b_a x b_w`` compare/write groups    ``b_a x b_w`` plane matmuls, shifted
MSB deactivation at low precision     fewer planes in the static unroll
MAP -> CAP mesh streaming             HBM -> VMEM streaming via BlockSpec
CAP capacity (4800 x 16 cells)        VMEM tile budget per grid step
====================================  =====================================

The kernel computes ``out[m, n] = sum_k a[m, k] * w[k, n]`` for signed
integers carried in int32, where ``a`` holds ``a_bits``-bit values and
``w`` holds ``w_bits``-bit values (two's complement). Each operand is
decomposed into bit planes; plane ``i`` of ``a`` against plane ``j`` of
``w`` contributes ``s_i * s_j * 2^(i+j) * (A_i @ W_j)`` where the sign
``s`` is negative for the MSB plane (two's-complement weight). Plane
matmuls run in float32 — planes are 0/1 so f32 accumulation is exact far
beyond any precision this kernel accepts (< 2^24).

Performance notes (structure, not interpret-mode wallclock):

* **VMEM footprint** per grid step = ``TILE_M*K + K*TILE_N + TILE_M*TILE_N``
  int32 words; with the default 128x128 tiles and K <= 2304 that is
  ~2.4 MB, inside a TPU core's ~16 MB VMEM with double-buffering room.
* **MXU utilization**: each of the ``a_bits*w_bits`` plane matmuls is a
  dense ``TILE_M x K x TILE_N`` contraction — MXU-shaped; the bit-serial
  loop multiplies arithmetic intensity by ``a_bits*w_bits`` while traffic
  stays one plane-extract per operand load, so the kernel is compute-bound
  for b >= 2 (the paper's regime: APs win at low precision).
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; real-TPU numbers are estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes (8x128 lanes; 128x128 keeps the systolic
# array full while fitting VMEM, see module docstring).
TILE_M = 128
TILE_N = 128

# Largest operand precision the kernel accepts (Table V: "Supported
# Bitwidth: up to 8" for the LR chip; 16 covers the Table VIII peak rows).
MAX_BITS = 16


def _plane_signs(bits: int) -> list[int]:
    """Two's-complement plane weights: +1 for all planes except the MSB."""
    return [1] * (bits - 1) + [-1] if bits > 1 else [1]


def _bitplane_kernel(a_ref, w_ref, o_ref, *, a_bits: int, w_bits: int):
    """One (TILE_M, TILE_N) output tile: unrolled bit-plane accumulation.

    The ``a_bits * w_bits`` plane matmuls mirror the AP's compare/write
    pass groups; the shift-accumulate mirrors the carry columns.
    """
    a = a_ref[...]  # (tile_m, K) int32
    w = w_ref[...]  # (K, tile_n) int32
    # Bias to unsigned so plane extraction is a plain shift-and-mask, then
    # fold the bias back: a = ua - 2^(b-1)  with ua = a + 2^(b-1) >= 0.
    # Simpler and branch-free: extract planes from the two's-complement
    # pattern directly (mask the value to b bits first).
    a_u = a & ((1 << a_bits) - 1)
    w_u = w & ((1 << w_bits) - 1)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    sa = _plane_signs(a_bits)
    sw = _plane_signs(w_bits)
    for i in range(a_bits):
        a_plane = ((a_u >> i) & 1).astype(jnp.float32)
        for j in range(w_bits):
            w_plane = ((w_u >> j) & 1).astype(jnp.float32)
            plane = jax.lax.dot_general(
                a_plane,
                w_plane,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            acc = acc + float(sa[i] * sw[j] * (1 << (i + j))) * plane
    o_ref[...] = acc.astype(jnp.int32)


def _pad_to(x: jnp.ndarray, m: int, axis: int) -> jnp.ndarray:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("a_bits", "w_bits", "tile_m", "tile_n"))
def bitplane_gemm(
    a: jnp.ndarray,
    w: jnp.ndarray,
    *,
    a_bits: int,
    w_bits: int,
    tile_m: int = TILE_M,
    tile_n: int = TILE_N,
) -> jnp.ndarray:
    """Bit-serial integer GEMM ``a @ w`` via the Pallas bit-plane kernel.

    Args:
      a: ``(M, K)`` int32, values in ``[-2^(a_bits-1), 2^(a_bits-1))``.
      w: ``(K, N)`` int32, values in ``[-2^(w_bits-1), 2^(w_bits-1))``.
      a_bits / w_bits: operand precisions (the bit-fluid loop bounds).
      tile_m / tile_n: output tile shape (BlockSpec grid).

    Returns:
      ``(M, N)`` int32 exact product.
    """
    if not (1 <= a_bits <= MAX_BITS and 1 <= w_bits <= MAX_BITS):
        raise ValueError(f"bits out of range: a_bits={a_bits} w_bits={w_bits}")
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"shape mismatch: {a.shape} @ {w.shape}")
    m, k = a.shape
    n = w.shape[1]
    a = _pad_to(a.astype(jnp.int32), tile_m, 0)
    w = _pad_to(w.astype(jnp.int32), tile_n, 1)
    mp, np_ = a.shape[0], w.shape[1]
    grid = (mp // tile_m, np_ // tile_n)
    out = pl.pallas_call(
        functools.partial(_bitplane_kernel, a_bits=a_bits, w_bits=w_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(a, w)
    return out[:m, :n]


def vmem_bytes(tile_m: int, k: int, tile_n: int) -> int:
    """Static VMEM footprint estimate of one grid step (int32 words)."""
    return 4 * (tile_m * k + k * tile_n + tile_m * tile_n)


def plane_matmuls(a_bits: int, w_bits: int) -> int:
    """Number of MXU plane matmuls per tile — the bit-serial cost knob."""
    return a_bits * w_bits
