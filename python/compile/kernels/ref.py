"""Pure-jnp oracles for the Layer-1 kernel and the quantization helpers.

Everything here is deliberately naive — it is the correctness reference
the Pallas kernel and the Layer-2 model are tested against, never the
deployed path.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Exact integer GEMM reference: plain int32 matmul."""
    return (a.astype(jnp.int32) @ w.astype(jnp.int32)).astype(jnp.int32)


def qrange(bits: int) -> tuple[int, int]:
    """Symmetric signed integer range of a ``bits``-bit operand.

    The negative end is clipped to ``-(2^(b-1) - 1)`` (symmetric
    quantization, HAWQ-V3 convention) so scales invert cleanly.
    """
    hi = (1 << (bits - 1)) - 1
    return -hi, hi


def quantize(x: jnp.ndarray, bits: int, scale: jnp.ndarray | float) -> jnp.ndarray:
    """Uniform symmetric quantization to ``bits``-bit signed ints."""
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray | float) -> jnp.ndarray:
    """Inverse of :func:`quantize`."""
    return q.astype(jnp.float32) * scale


def scale_for(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Max-abs calibration scale so that ``x`` spans the ``bits`` range."""
    _, hi = qrange(bits)
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / hi


def fake_quant(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize-dequantize round trip (the quantization *error* injector)."""
    s = scale_for(x, bits)
    return dequantize(quantize(x, bits, s), s)


def bitplane_gemm_ref(
    a: jnp.ndarray, w: jnp.ndarray, a_bits: int, w_bits: int
) -> jnp.ndarray:
    """Bit-plane accumulation spelled out in pure jnp (mirrors the AP LUT
    schedule one plane pair at a time) — a second, structurally different
    oracle for the Pallas kernel."""
    a = a.astype(jnp.int32) & ((1 << a_bits) - 1)
    w = w.astype(jnp.int32) & ((1 << w_bits) - 1)
    out = jnp.zeros((a.shape[0], w.shape[1]), jnp.int32)
    for i in range(a_bits):
        sa = -1 if (a_bits > 1 and i == a_bits - 1) else 1
        ap = ((a >> i) & 1).astype(jnp.int32)
        for j in range(w_bits):
            sw = -1 if (w_bits > 1 and j == w_bits - 1) else 1
            wp = ((w >> j) & 1).astype(jnp.int32)
            out = out + sa * sw * (1 << (i + j)) * (ap @ wp)
    return out
