"""AOT export path: HLO text generation and the weights round trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


def test_hlo_text_is_valid_entry(params):
    spec = jax.ShapeDtypeStruct((1, *model.INPUT_SHAPE), jnp.float32)
    fn = lambda x: (  # noqa: E731
        model.quant_forward(params, x, model.PRECISION_CONFIGS["int4"], use_kernel=True),
    )
    text = aot.to_hlo_text(jax.jit(fn).lower(spec))
    assert "ENTRY" in text
    assert "f32[1,32,32,3]" in text  # the input parameter
    assert "f32[1,10]" in text  # the logits
    assert len(text) > 10_000


def test_export_config_writes_file(tmp_path, params):
    entry = aot.export_config(params, "int4", 1, tmp_path)
    assert (tmp_path / entry["file"]).exists()
    assert entry["avg_bits"] == 4.0
    assert entry["batch"] == 1
    text = (tmp_path / entry["file"]).read_text()
    assert "ENTRY" in text


def test_export_float_reference(tmp_path, params):
    entry = aot.export_config(params, "float", 2, tmp_path)
    assert entry["avg_bits"] == 32.0
    assert "f32[2,32,32,3]" in (tmp_path / entry["file"]).read_text()


def test_weights_roundtrip(tmp_path, params):
    flat = aot.flatten_params(params)
    np.savez(tmp_path / "w.npz", **flat)
    loaded = aot.unflatten_params(dict(np.load(tmp_path / "w.npz")))
    for layer in params:
        for leaf in params[layer]:
            np.testing.assert_array_equal(
                np.asarray(params[layer][leaf]), np.asarray(loaded[layer][leaf])
            )


def test_quantized_and_float_exports_differ(tmp_path, params):
    a = aot.export_config(params, "int8", 1, tmp_path)
    b = aot.export_config(params, "int4", 1, tmp_path)
    ta = (tmp_path / a["file"]).read_text()
    tb = (tmp_path / b["file"]).read_text()
    # int8 unrolls 4x the bit-plane matmuls of int4.
    assert len(ta) > len(tb)
