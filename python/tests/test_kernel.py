"""Layer-1 correctness: the Pallas bit-plane GEMM vs the pure-jnp oracles.

`hypothesis` sweeps shapes and precisions; deterministic cases pin the
edge behaviour (1-bit planes, MSB signs, padding remainders).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bitserial_gemm import (
    MAX_BITS,
    bitplane_gemm,
    plane_matmuls,
    vmem_bytes,
)
from compile.kernels import ref


def rand_operand(rng, rows, cols, bits):
    """Random signed ints exactly spanning the two's-complement range."""
    if bits == 1:
        return rng.integers(0, 2, size=(rows, cols)).astype(np.int32)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return rng.integers(lo, hi + 1, size=(rows, cols)).astype(np.int32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 48),
    n=st.integers(1, 40),
    a_bits=st.integers(1, 8),
    w_bits=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_exact_gemm(m, k, n, a_bits, w_bits, seed):
    rng = np.random.default_rng(seed)
    a = rand_operand(rng, m, k, a_bits)
    w = rand_operand(rng, k, n, w_bits)
    got = np.asarray(bitplane_gemm(jnp.asarray(a), jnp.asarray(w), a_bits=a_bits, w_bits=w_bits))
    np.testing.assert_array_equal(got, a.astype(np.int64) @ w.astype(np.int64))


@settings(max_examples=10, deadline=None)
@given(
    a_bits=st.integers(2, 8),
    w_bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_bitplane_oracle(a_bits, w_bits, seed):
    """Second oracle: the explicit plane-by-plane jnp accumulation."""
    rng = np.random.default_rng(seed)
    a = rand_operand(rng, 9, 13, a_bits)
    w = rand_operand(rng, 13, 7, w_bits)
    got = np.asarray(bitplane_gemm(jnp.asarray(a), jnp.asarray(w), a_bits=a_bits, w_bits=w_bits))
    want = np.asarray(ref.bitplane_gemm_ref(jnp.asarray(a), jnp.asarray(w), a_bits, w_bits))
    np.testing.assert_array_equal(got, want)


def test_mixed_widths():
    """Asymmetric (a_bits, w_bits) pairs — the bit-fluid case."""
    rng = np.random.default_rng(7)
    for a_bits, w_bits in [(2, 8), (8, 2), (4, 8), (8, 4), (3, 5)]:
        a = rand_operand(rng, 17, 23, a_bits)
        w = rand_operand(rng, 23, 11, w_bits)
        got = np.asarray(
            bitplane_gemm(jnp.asarray(a), jnp.asarray(w), a_bits=a_bits, w_bits=w_bits)
        )
        np.testing.assert_array_equal(got, a @ w)


def test_tile_padding_remainders():
    """Shapes straddling the tile grid exercise the pad/crop path."""
    rng = np.random.default_rng(3)
    for m, n in [(127, 129), (128, 128), (129, 127), (1, 257)]:
        a = rand_operand(rng, m, 16, 4)
        w = rand_operand(rng, 16, n, 4)
        got = np.asarray(bitplane_gemm(jnp.asarray(a), jnp.asarray(w), a_bits=4, w_bits=4))
        assert got.shape == (m, n)
        np.testing.assert_array_equal(got, a @ w)


def test_custom_tile_sizes():
    rng = np.random.default_rng(5)
    a = rand_operand(rng, 64, 32, 4)
    w = rand_operand(rng, 32, 64, 4)
    for tm, tn in [(16, 16), (64, 64), (32, 8)]:
        got = np.asarray(
            bitplane_gemm(jnp.asarray(a), jnp.asarray(w), a_bits=4, w_bits=4, tile_m=tm, tile_n=tn)
        )
        np.testing.assert_array_equal(got, a @ w)


def test_extreme_values_hit_range_ends():
    """MSB sign handling: operands pinned to range endpoints."""
    for bits in [2, 4, 8]:
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        a = np.array([[lo, hi], [hi, lo]], np.int32)
        w = np.array([[lo, hi], [hi, lo]], np.int32)
        got = np.asarray(bitplane_gemm(jnp.asarray(a), jnp.asarray(w), a_bits=bits, w_bits=bits))
        np.testing.assert_array_equal(got, a @ w)


def test_one_bit_operands_are_unsigned():
    """bits == 1 has a single, positive plane (no sign plane)."""
    a = np.array([[0, 1, 1], [1, 0, 1]], np.int32)
    w = np.array([[1, 0], [1, 1], [0, 1]], np.int32)
    got = np.asarray(bitplane_gemm(jnp.asarray(a), jnp.asarray(w), a_bits=1, w_bits=1))
    np.testing.assert_array_equal(got, a @ w)


def test_zero_inputs():
    a = np.zeros((8, 8), np.int32)
    w = np.zeros((8, 8), np.int32)
    got = np.asarray(bitplane_gemm(jnp.asarray(a), jnp.asarray(w), a_bits=8, w_bits=8))
    np.testing.assert_array_equal(got, np.zeros((8, 8)))


def test_rejects_bad_bits_and_shapes():
    a = jnp.zeros((4, 4), jnp.int32)
    with pytest.raises(ValueError):
        bitplane_gemm(a, a, a_bits=0, w_bits=4)
    with pytest.raises(ValueError):
        bitplane_gemm(a, a, a_bits=4, w_bits=MAX_BITS + 1)
    with pytest.raises(ValueError):
        bitplane_gemm(a, jnp.zeros((5, 4), jnp.int32), a_bits=4, w_bits=4)


def test_cost_helpers():
    """Static cost knobs used by the perf notes in DESIGN.md."""
    assert plane_matmuls(8, 8) == 64
    assert plane_matmuls(4, 8) == 32
    # 128x128 tiles, K = 2304: ~2.4 MB (inside a TPU core's VMEM).
    assert vmem_bytes(128, 2304, 128) < 16 * 2**20 / 2


def test_quantize_roundtrip():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    for bits in [2, 4, 8]:
        s = ref.scale_for(x, bits)
        q = ref.quantize(x, bits, s)
        lo, hi = ref.qrange(bits)
        assert int(q.min()) >= lo and int(q.max()) <= hi
        err = np.abs(np.asarray(ref.dequantize(q, s)) - np.asarray(x)).max()
        assert err <= float(s) * 0.5 + 1e-6


def test_fake_quant_error_shrinks_with_bits():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    errs = [
        float(jnp.abs(ref.fake_quant(x, b) - x).mean()) for b in [2, 4, 6, 8]
    ]
    assert all(e1 > e2 for e1, e2 in zip(errs, errs[1:])), errs
