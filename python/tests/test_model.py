"""Layer-2 correctness: im2col convolution, quantized forward, training.

The quantized forward routed through the Pallas kernel must agree exactly
with the pure-jnp oracle path (same quantization, oracle GEMM); the float
im2col convolution must match `jax.lax.conv_general_dilated`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(42))


@pytest.fixture(scope="module")
def batch():
    x, y = model.make_dataset(jax.random.PRNGKey(3), 4)
    return x, y


def test_im2col_matches_lax_conv(params, batch):
    """Float conv-via-GEMM == XLA's native convolution."""
    x, _ = batch
    p = params["conv1"]
    got = model._conv_via_gemm(x, p["w"], p["b"], lambda a, w: a @ w)
    want = (
        jax.lax.conv_general_dilated(
            x,
            p["w"],
            window_strides=(1, 1),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        + p["b"]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_im2col_shape_and_order():
    """Patch layout: (di, dj, c) unrolling, B*H*W rows."""
    x = jnp.arange(2 * 4 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 4, 3)
    cols = model.im2col(x)
    assert cols.shape == (2 * 4 * 4, 9 * 3)
    # Center tap (di=1, dj=1) of the first pixel is the pixel itself.
    center = cols[0, (3 * 1 + 1) * 3 : (3 * 1 + 1) * 3 + 3]
    np.testing.assert_array_equal(np.asarray(center), np.asarray(x[0, 0, 0]))


def test_pooling_ops():
    x = jnp.arange(1 * 4 * 4 * 1, dtype=jnp.float32).reshape(1, 4, 4, 1)
    mp = model.maxpool2(x)
    assert mp.shape == (1, 2, 2, 1)
    np.testing.assert_array_equal(np.asarray(mp)[0, :, :, 0], [[5, 7], [13, 15]])
    gap = model.global_avgpool(x)
    assert gap.shape == (1, 1)
    assert float(gap[0, 0]) == pytest.approx(7.5)


def test_float_forward_shapes(params, batch):
    x, _ = batch
    logits = model.float_forward(params, x)
    assert logits.shape == (x.shape[0], model.NUM_CLASSES)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("cfg_name", list(model.PRECISION_CONFIGS))
def test_quant_forward_kernel_matches_oracle(params, batch, cfg_name):
    """The Pallas-kernel path and the jnp-oracle path share quantization,
    so their logits must agree to float32 tolerance."""
    x, _ = batch
    cfg = model.PRECISION_CONFIGS[cfg_name]
    a = model.quant_forward(params, x, cfg, use_kernel=True)
    b = model.quant_forward(params, x, cfg, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_int8_close_to_float(params, batch):
    x, _ = batch
    f = model.float_forward(params, x)
    q = model.quant_forward(params, x, model.PRECISION_CONFIGS["int8"], use_kernel=False)
    # 8-bit symmetric quantization stays within a few percent of float.
    rel = float(jnp.abs(f - q).max() / (jnp.abs(f).max() + 1e-9))
    assert rel < 0.15, rel


def test_int4_error_exceeds_int8(params, batch):
    x, _ = batch
    f = model.float_forward(params, x)
    e8 = float(
        jnp.abs(f - model.quant_forward(params, x, model.PRECISION_CONFIGS["int8"], use_kernel=False)).mean()
    )
    e4 = float(
        jnp.abs(f - model.quant_forward(params, x, model.PRECISION_CONFIGS["int4"], use_kernel=False)).mean()
    )
    assert e4 > e8, (e4, e8)


def test_quant_forward_rejects_bad_cfg(params, batch):
    x, _ = batch
    with pytest.raises(ValueError):
        model.quant_forward(params, x, ((8, 8),))


def test_config_table():
    assert len(model.WEIGHT_LAYERS) == 6
    assert model.avg_bits(model.PRECISION_CONFIGS["int8"]) == 8.0
    assert model.avg_bits(model.PRECISION_CONFIGS["int4"]) == 4.0
    mixed = model.avg_bits(model.PRECISION_CONFIGS["mixed_medium"])
    assert 4.0 < mixed < 8.0
    # Budgets order by average bits: high > medium > low.
    assert (
        model.avg_bits(model.PRECISION_CONFIGS["mixed_high"])
        > mixed
        > model.avg_bits(model.PRECISION_CONFIGS["mixed_low"])
    )


def test_dataset_is_class_consistent():
    """Same labels from different keys share the grating structure."""
    x1, y1 = model.make_dataset(jax.random.PRNGKey(1), 64)
    x2, y2 = model.make_dataset(jax.random.PRNGKey(2), 64)
    assert x1.shape == (64, *model.INPUT_SHAPE)
    assert int(y1.min()) >= 0 and int(y1.max()) < model.NUM_CLASSES
    # Different keys -> different samples.
    assert not np.array_equal(np.asarray(x1), np.asarray(x2))


def test_short_training_reduces_loss():
    """A handful of SGD steps must cut the loss — the training loop works."""
    params, curve = model.train(
        jax.random.PRNGKey(0), steps=30, batch=16, log_every=29, verbose=False
    )
    assert curve[0][1] > curve[-1][1], curve
    assert model.param_count(params) > 30_000


def test_cross_entropy_and_accuracy():
    logits = jnp.array([[10.0, 0.0], [0.0, 10.0]])
    labels = jnp.array([0, 1])
    assert float(model.cross_entropy(logits, labels)) < 0.01
    assert float(model.accuracy(logits, labels)) == 1.0
    assert float(model.accuracy(logits, jnp.array([1, 0]))) == 0.0
