//! Bit-fluidity demo (paper §V-B, Table VII): run HAWQ-V3's per-layer
//! INT4/INT8 ResNet18 configurations for three latency budgets on the
//! BF-IMNA simulator and reproduce the accuracy-vs-EDP trade-off.
//!
//! ```bash
//! cargo run --release --example hawq_bitfluid
//! ```

use bf_imna::sim::{artifacts, SweepEngine};

fn main() {
    // Table VII is the `table7` catalog artifact: the five HAWQ-V3
    // configurations are an explicit precision grid in a serializable
    // SweepSpec, and this render is byte-identical to rendering the same
    // spec's sharded (`sweep`/`merge`) or dispatched document.
    println!("chip: Table V LR (8x8 clusters x 8x8 CAPs), SRAM, 1 GHz\n");
    let table7 = artifacts::by_name("table7").expect("table7 in catalog");
    print!("{}", table7.run_and_render(&SweepEngine::new(), false).expect("table7 renders"));

    println!(
        "\nTrade-off (as in the paper): the low-latency-budget config lands the\n\
         EDP closest to fixed INT4 while giving up the least accuracy that the\n\
         budget allows; the high-budget config tracks INT8 accuracy at 1.13x\n\
         better EDP. BF-IMNA switches between these configurations at run time\n\
         with zero hardware reconfiguration — see examples/e2e_serving.rs for\n\
         the live version over PJRT."
    );
}
