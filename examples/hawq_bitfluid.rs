//! Bit-fluidity demo (paper §V-B, Table VII): run HAWQ-V3's per-layer
//! INT4/INT8 ResNet18 configurations for three latency budgets on the
//! BF-IMNA simulator and reproduce the accuracy-vs-EDP trade-off.
//!
//! ```bash
//! cargo run --release --example hawq_bitfluid
//! ```

use bf_imna::model::zoo;
use bf_imna::precision::hawq;
use bf_imna::sim::{simulate, SimParams};
use bf_imna::util::table::{fmt_eng, Table};

fn main() {
    let net = zoo::resnet18();
    let params = SimParams::lr_sram();

    // INT8 is the normalization anchor (Table VII convention).
    let int8_cfg = hawq::config_for_resnet18(&net, &hawq::row(hawq::LatencyBudget::FixedInt8));
    let int8 = simulate(&net, &int8_cfg, &params);

    println!("Table VII — bit-fluid mixed-precision ResNet18 (HAWQ-V3 configs)");
    println!("chip: Table V LR (8x8 clusters x 8x8 CAPs), SRAM, 1 GHz\n");
    let mut t = Table::new(vec![
        "constraint",
        "avg bits",
        "norm energy (ours)",
        "norm energy (paper)",
        "norm latency (ours)",
        "EDP J.s (ours)",
        "size MB",
        "top-1 % (paper)",
    ]);
    for row in hawq::table_vii_rows() {
        let cfg = hawq::config_for_resnet18(&net, &row);
        let r = simulate(&net, &cfg, &params);
        t.row(vec![
            row.budget.label().to_string(),
            format!("{:.2}", row.paper_avg_bits),
            format!("{:.2}", int8.energy_j() / r.energy_j()),
            format!("{:.2}", row.paper_norm_energy),
            format!("{:.3}", int8.latency_s() / r.latency_s()),
            fmt_eng(r.edp_js(), 3),
            format!("{:.1}", cfg.model_size_bytes(&net) as f64 / 1e6),
            format!("{:.2}", row.paper_top1_acc),
        ]);
    }
    print!("{}", t.render());

    println!(
        "\nTrade-off (as in the paper): the low-latency-budget config lands the\n\
         EDP closest to fixed INT4 while giving up the least accuracy that the\n\
         budget allows; the high-budget config tracks INT8 accuracy at 1.13x\n\
         better EDP. BF-IMNA switches between these configurations at run time\n\
         with zero hardware reconfiguration — see examples/e2e_serving.rs for\n\
         the live version over PJRT."
    );
}
