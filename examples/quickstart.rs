//! Quickstart — the 60-second tour of the BF-IMNA library.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the three things the crate does:
//! 1. cost a single AP operation with the Table I runtime models,
//! 2. simulate end-to-end CNN inference on the LR chip,
//! 3. show the bit-fluid knob: the *same* hardware runs any per-layer
//!    precision configuration with zero reconfiguration.

use bf_imna::ap::{runtime_model as rt, ApKind};
use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{simulate, SimParams};
use bf_imna::util::table::{fmt_eng, Table};

fn main() {
    // --- 1. One AP operation, three organizations (Table I). -----------
    println!("1) Table I runtime of an 8-bit, 1024-element reduction:\n");
    let mut t = Table::new(vec!["AP kind", "time units", "result bits"]);
    for kind in ApKind::ALL {
        let cost = rt::reduce(8, 1024, kind);
        t.row(vec![
            kind.label().to_string(),
            cost.events.time_units().to_string(),
            cost.result_bits.to_string(),
        ]);
    }
    print!("{}", t.render());

    // --- 2. End-to-end inference simulation. ---------------------------
    println!("\n2) AlexNet ImageNet inference on the Table V LR chip (SRAM, INT8):\n");
    let net = zoo::alexnet();
    let cfg = PrecisionConfig::fixed(8, net.weight_layers());
    let r = simulate(&net, &cfg, &SimParams::lr_sram());
    println!("   latency  {} s", fmt_eng(r.latency_s(), 3));
    println!("   energy   {} J", fmt_eng(r.energy_j(), 3));
    println!("   GOPS     {}", fmt_eng(r.gops(), 3));
    println!("   GOPS/W   {}", fmt_eng(r.gops_per_w(), 3));
    println!("   area     {:.2} mm2", r.area_mm2);

    // --- 3. Bit fluidity: per-layer precision is just a config. --------
    println!("\n3) Bit fluidity — same chip, three precision configs:\n");
    let mut t = Table::new(vec!["config", "avg bits", "energy (J)", "latency (s)", "EDP (J.s)"]);
    let n = net.weight_layers();
    let mut mixed_bits = vec![8u32; n];
    for b in mixed_bits.iter_mut().skip(n / 2) {
        *b = 4;
    }
    let configs = vec![
        PrecisionConfig::fixed(8, n),
        PrecisionConfig::from_bits("mixed-8/4", &mixed_bits),
        PrecisionConfig::fixed(4, n),
    ];
    for cfg in configs {
        let r = simulate(&net, &cfg, &SimParams::lr_sram());
        t.row(vec![
            cfg.name.clone(),
            format!("{:.2}", cfg.avg_bits()),
            fmt_eng(r.energy_j(), 3),
            fmt_eng(r.latency_s(), 3),
            fmt_eng(r.edp_js(), 3),
        ]);
    }
    print!("{}", t.render());
    println!("\nNote how energy tracks precision while latency barely moves —");
    println!("the AP's bit-serial loops shrink, but reduction (row-bound) dominates.");
}
