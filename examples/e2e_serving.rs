//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```
//!
//! The build-time Python side trained the serve CNN on the synthetic
//! texture dataset and AOT-lowered one quantized forward graph per
//! precision configuration (L2 model calling the L1 Pallas bit-plane GEMM)
//! to HLO text. This driver is pure rust on the request path:
//!
//! 1. start the bit-fluid coordinator (loads + compiles every artifact on
//!    the PJRT CPU client),
//! 2. replay the held-out eval set as serving requests under the three
//!    latency budgets,
//! 3. report per-budget accuracy (real labels!), p50/p99 latency,
//!    throughput, which precision configs served each budget, and the
//!    BF-IMNA hardware cost the simulator attributes to each config —
//!    the live version of Table VII's accuracy-vs-EDP trade-off.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use bf_imna::coordinator::{Budget, Coordinator, CoordinatorConfig};
use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::runtime::Manifest;
use bf_imna::sim::{simulate, SimParams};
use bf_imna::util::stats;
use bf_imna::util::table::{fmt_eng, Table};

fn read_eval_set(dir: &Path, elems: usize) -> (Vec<Vec<f32>>, Vec<u8>) {
    let raw = std::fs::read(dir.join("eval_inputs.f32")).expect("eval_inputs.f32 (make artifacts)");
    let labels = std::fs::read(dir.join("eval_labels.u8")).expect("eval_labels.u8");
    let floats: Vec<f32> = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let inputs: Vec<Vec<f32>> = floats.chunks_exact(elems).map(|c| c.to_vec()).collect();
    assert_eq!(inputs.len(), labels.len(), "eval set size mismatch");
    (inputs, labels)
}

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // ---- Simulator-side cost estimates per config (the L3 tie-in). ----
    let manifest = Manifest::load(dir).expect("manifest");
    let serve_net = zoo::serve_cnn();
    let mut sim_cost: BTreeMap<String, (f64, f64)> = BTreeMap::new(); // (energy J, EDP J.s)
    for (name, info) in &manifest.configs {
        let bits: Vec<u32> = info.per_layer.iter().map(|&(w, _)| w).collect();
        let cfg = PrecisionConfig::from_bits(name, &bits);
        let r = simulate(&serve_net, &cfg, &SimParams::lr_sram());
        sim_cost.insert(name.clone(), (r.energy_j(), r.edp_js()));
    }

    // ---- Start the coordinator (compiles the quantized artifacts). ----
    // Budgets pin configs the way HAWQ-V3 names one configuration per
    // latency budget (Table VII); on real BF-IMNA hardware the
    // measured-latency controller would pick the same ladder because fewer
    // bits are genuinely faster there (on this CPU testbed, interpret-mode
    // bit-plane kernels invert that ordering, hence the pinning).
    println!("compiling artifacts on the PJRT CPU client ...");
    let t0 = Instant::now();
    let coord = Coordinator::start(
        dir,
        CoordinatorConfig {
            configs: vec![
                "int8".into(),
                "mixed_high".into(),
                "mixed_medium".into(),
                "mixed_low".into(),
                "int4".into(),
            ],
            pinned: [
                (Budget::Low, "mixed_low".to_string()),
                (Budget::Medium, "mixed_medium".to_string()),
                (Budget::High, "int8".to_string()),
            ]
            .into(),
            ..CoordinatorConfig::default()
        },
    )
    .expect("coordinator");
    println!(
        "ready in {:.1}s: configs [{}]\n",
        t0.elapsed().as_secs_f64(),
        coord.configs().join(", ")
    );

    let (inputs, labels) = read_eval_set(dir, coord.sample_elems());
    let classes = coord.num_classes();
    println!("replaying {} held-out samples per budget ...\n", inputs.len());

    let mut rows = Vec::new();
    for budget in [Budget::Low, Budget::Medium, Budget::High] {
        let t0 = Instant::now();
        let pendings: Vec<_> = inputs
            .iter()
            .map(|x| coord.submit(x.clone(), budget).expect("submit"))
            .collect();
        let mut correct = 0usize;
        let mut lat = Vec::new();
        let mut served_by: BTreeMap<String, u64> = BTreeMap::new();
        for (p, &label) in pendings.into_iter().zip(&labels) {
            let r = p.wait().expect("response");
            let pred = r
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred == label as usize {
                correct += 1;
            }
            lat.push(r.latency_s);
            *served_by.entry(r.config).or_default() += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        let dominant = served_by
            .iter()
            .max_by_key(|(_, &c)| c)
            .map(|(k, _)| k.clone())
            .unwrap_or_default();
        let (sim_e, sim_edp) = sim_cost.get(&dominant).copied().unwrap_or((0.0, 0.0));
        rows.push((
            budget,
            correct as f64 / inputs.len() as f64,
            stats::percentile(&lat, 0.5),
            stats::percentile(&lat, 0.99),
            inputs.len() as f64 / wall,
            served_by,
            dominant,
            sim_e,
            sim_edp,
        ));
    }

    let mut t = Table::new(vec![
        "budget",
        "accuracy",
        "p50 (s)",
        "p99 (s)",
        "req/s",
        "served by",
        "sim energy (J)",
        "sim EDP (J.s)",
    ]);
    for (budget, acc, p50, p99, rps, served_by, _dom, sim_e, sim_edp) in &rows {
        let served: Vec<String> =
            served_by.iter().map(|(k, v)| format!("{k}:{v}")).collect();
        t.row(vec![
            budget.label().to_string(),
            format!("{:.3}", acc),
            fmt_eng(*p50, 3),
            fmt_eng(*p99, 3),
            format!("{:.1}", rps),
            served.join(" "),
            fmt_eng(*sim_e, 3),
            fmt_eng(*sim_edp, 3),
        ]);
    }
    print!("{}", t.render());
    assert_eq!(classes, 10);

    let m = coord.metrics();
    println!(
        "\ntotals: {} requests, {} batches, occupancy {:.0}%, 0 python calls on the request path",
        m.completed,
        m.batches,
        100.0 * m.batch_occupancy()
    );
    println!(
        "\nThe tight budget rides low-precision artifacts (lower simulated BF-IMNA\n\
         energy/EDP, slightly lower accuracy); the loose budget keeps INT8/float\n\
         quality — Table VII's trade-off, live, with precision switched per batch\n\
         at zero reconfiguration cost."
    );
}
