//! Design-space exploration (paper §V-A, Figs. 6–8): ReRAM vs SRAM,
//! mixed-precision sweeps on the ImageNet benchmarks, breakdowns and
//! voltage scaling — the full DSE in one run.
//!
//! ```bash
//! cargo run --release --example dse_sweep
//! ```

use bf_imna::arch::HwConfig;
use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{breakdown, dse, shard, simulate, SimParams, SweepEngine};
use bf_imna::util::json::Json;
use bf_imna::util::table::{fmt_eng, fmt_ratio, Table};

fn main() {
    // ---- Fig. 6: technology ratios on VGG16. ---------------------------
    let vgg = zoo::vgg16();
    println!("Fig. 6 — ReRAM/SRAM ratios, end-to-end VGG16 inference (LR):\n");
    let mut t = Table::new(vec!["precision", "energy ratio", "latency ratio", "area savings"]);
    for row in dse::fig6_tech_ratios(&vgg) {
        t.row(vec![
            row.bits.to_string(),
            fmt_ratio(row.energy_ratio),
            fmt_ratio(row.latency_ratio),
            fmt_ratio(row.area_savings),
        ]);
    }
    print!("{}", t.render());
    println!("(paper: energy ratio decreasing 80.9x -> 63.1x, latency ~flat, area 4.4x)\n");

    // ---- Fig. 7: mixed-precision sweeps. --------------------------------
    println!("Fig. 7 — mean metrics vs average precision (SRAM):\n");
    for net in zoo::imagenet_benchmarks() {
        for hw in [HwConfig::Lr, HwConfig::Ir] {
            let series = dse::fig7_series(&net, hw, 7);
            let mut t = Table::new(vec!["avg bits", "energy (J)", "latency (s)", "GOPS/W/mm2"]);
            for p in &series {
                t.row(vec![
                    format!("{:.0}", p.avg_bits),
                    fmt_eng(p.energy_j, 3),
                    fmt_eng(p.latency_s, 3),
                    fmt_eng(p.gops_per_w_mm2, 3),
                ]);
            }
            println!("{} | {}:", net.name, hw.label());
            print!("{}", t.render());
            println!();
        }
    }

    // ---- Fig. 8: breakdowns (INT8, LR, SRAM). ---------------------------
    println!("Fig. 8 — energy & GEMM-latency breakdowns (INT8, LR, SRAM):\n");
    for net in zoo::imagenet_benchmarks() {
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let r = simulate(&net, &cfg, &SimParams::lr_sram());
        let e: Vec<String> = breakdown::energy_by_kind(&r)
            .iter()
            .map(|s| format!("{} {:.1}%", s.label, 100.0 * s.fraction))
            .collect();
        let l: Vec<String> = breakdown::gemm_latency_by_phase(&r)
            .iter()
            .map(|s| format!("{} {:.1}%", s.label, 100.0 * s.fraction))
            .collect();
        println!("{:9} energy: {}", r.net_name, e.join(", "));
        println!("{:9} gemm latency: {}", "", l.join(", "));
    }

    // ---- Voltage scaling (§V-A). ----------------------------------------
    println!("\nVoltage scaling (1.0 V -> 0.5 V write energy, §V-A):\n");
    for net in zoo::imagenet_benchmarks() {
        let saving = dse::voltage_scaling_saving(&net, 8);
        println!("  {:9} energy saving: {:.3}% (paper: <= 0.06%)", net.name, 100.0 * saving);
    }

    // ---- Sweep service: spec -> shards -> merge (sim::shard). -----------
    // The same Fig. 7 sweep as a serializable spec: two "workers" each run
    // a contiguous half of the point index space on their own engine, and
    // the merger reassembles a byte-identical copy of the single-process
    // document. On real deployments each worker is a separate
    // `bf-imna sweep --shards N --shard-id K` process.
    println!("\nSweep service (sim::shard) — AlexNet LR, 2 shards:\n");
    let spec = dse::fig7_spec(&zoo::alexnet(), HwConfig::Lr, 7);
    println!("  spec: {}", spec.to_json());
    let full = shard::run_full(&spec, &SweepEngine::new()).unwrap();
    let docs: Vec<Json> = (0..2)
        .map(|k| shard::run_shard(&spec, 2, k, &SweepEngine::new()).unwrap().to_json())
        .collect();
    let merged = shard::merge(&docs).unwrap();
    assert_eq!(merged.to_string(), full.to_string());
    println!(
        "  2-shard merge == single-process sweep, byte for byte ({} points, {} bytes).",
        merged.get("n_points").and_then(Json::as_i64).unwrap_or(0),
        full.to_string().len()
    );
}
