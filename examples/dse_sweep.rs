//! Design-space exploration (paper §V-A, Figs. 6–8): ReRAM vs SRAM,
//! mixed-precision sweeps on the ImageNet benchmarks, breakdowns and
//! voltage scaling — the full DSE in one run.
//!
//! ```bash
//! cargo run --release --example dse_sweep
//! ```

use bf_imna::arch::HwConfig;
use bf_imna::model::zoo;
use bf_imna::sim::{artifacts, dse, shard, SweepEngine};
use bf_imna::util::json::Json;

fn main() {
    // One engine (shared plan cache) for every artifact of the DSE.
    let engine = SweepEngine::new();

    // ---- Figs. 6–8 straight from the artifact catalog: each is a named
    // SweepSpec run through spec -> run -> render, byte-identical to what
    // a sharded or dispatched run of the same spec renders. ------------
    for (name, note) in [
        ("fig6", "(paper: energy ratio decreasing 80.9x -> 63.1x, latency ~flat, area 4.4x)"),
        ("fig7", "(paper: energy rises with precision; latency nearly flat)"),
        ("fig8", "(paper: GEMM dominates energy; reduction dominates GEMM latency)"),
    ] {
        let artifact = artifacts::by_name(name).expect("catalog artifact");
        print!("{}", artifact.run_and_render(&engine, false).expect("artifact renders"));
        println!("{note}\n");
    }

    // ---- Voltage scaling (§V-A). ----------------------------------------
    println!("\nVoltage scaling (1.0 V -> 0.5 V write energy, §V-A):\n");
    for net in zoo::imagenet_benchmarks() {
        let saving = dse::voltage_scaling_saving(&net, 8);
        println!("  {:9} energy saving: {:.3}% (paper: <= 0.06%)", net.name, 100.0 * saving);
    }

    // ---- Sweep service: spec -> shards -> merge (sim::shard). -----------
    // The same Fig. 7 sweep as a serializable spec: two "workers" each run
    // a contiguous half of the point index space on their own engine, and
    // the merger reassembles a byte-identical copy of the single-process
    // document. On real deployments each worker is a separate
    // `bf-imna sweep --shards N --shard-id K` process.
    println!("\nSweep service (sim::shard) — AlexNet LR, 2 shards:\n");
    let spec = dse::fig7_spec(&zoo::alexnet(), HwConfig::Lr, 7);
    println!("  spec: {}", spec.to_json());
    let full = shard::run_full(&spec, &SweepEngine::new()).unwrap();
    let docs: Vec<Json> = (0..2)
        .map(|k| shard::run_shard(&spec, 2, k, &SweepEngine::new()).unwrap().to_json())
        .collect();
    let merged = shard::merge(&docs).unwrap();
    assert_eq!(merged.to_string(), full.to_string());
    println!(
        "  2-shard merge == single-process sweep, byte for byte ({} points, {} bytes).",
        merged.get("n_points").and_then(Json::as_i64).unwrap_or(0),
        full.to_string().len()
    );
}
