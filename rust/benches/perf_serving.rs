//! Bench: the serving request path end to end on the sim backend — the
//! default-build coordinator under a mixed class/deadline request load.
//!
//! Measures what the serving redesign makes measurable without PJRT:
//! submit→batch→pick→execute→reply wall-clock throughput and latency
//! percentiles, the config mix the bit-fluid controller produces, and the
//! deadline met fraction. Results are exported to `BENCH_serving.json` at
//! the repo root so CI tracks the serving trajectory PR-over-PR (the
//! serving counterpart of `perf_hotpath`'s `BENCH_dse.json`).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use bf_imna::coordinator::{Budget, Coordinator, CoordinatorConfig};
use bf_imna::util::benchkit::banner;
use bf_imna::util::json::Json;
use bf_imna::util::rng::Rng;
use bf_imna::util::table::{fmt_eng, Table};

const REQUESTS: usize = 256;

fn main() {
    banner("Serving request path (sim backend, mixed budgets + deadlines)");
    let coord = Coordinator::start_sim(CoordinatorConfig::default(), 0.0)
        .expect("sim-backed coordinator starts in the default build");
    println!(
        "configs (descending quality): [{}]; sending {REQUESTS} requests",
        coord.configs().join(", ")
    );

    let elems = coord.sample_elems();
    let mut rng = Rng::new(42);
    let budgets = [Budget::Low, Budget::Medium, Budget::High];
    let t0 = Instant::now();
    let pendings: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let x: Vec<f32> = (0..elems).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
            if i % 4 == 3 {
                // Every fourth request carries an explicit deadline drawn
                // from a deterministic ladder of targets.
                coord
                    .request(x)
                    .deadline(Duration::from_micros(50 + 200 * (i % 5) as u64))
                    .submit()
                    .expect("submit")
            } else {
                coord.submit(x, budgets[i % 3]).expect("submit")
            }
        })
        .collect();

    let mut per_config: BTreeMap<String, u64> = BTreeMap::new();
    let mut met = 0usize;
    for p in pendings {
        let r = p.wait().expect("response");
        met += usize::from(r.met_deadline);
        *per_config.entry(r.config).or_default() += 1;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    assert_eq!(m.completed as usize, REQUESTS, "every request must complete");
    assert_eq!(m.failed, 0, "sim backend must not fail executions");

    let rps = REQUESTS as f64 / wall_s;
    let p50 = m.latency_p(0.5);
    let p99 = m.latency_p(0.99);
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests".to_string(), REQUESTS.to_string()]);
    t.row(vec!["wall".to_string(), format!("{} s", fmt_eng(wall_s, 3))]);
    t.row(vec!["throughput".to_string(), format!("{rps:.0} req/s")]);
    t.row(vec!["batches".to_string(), m.batches.to_string()]);
    t.row(vec!["batch occupancy".to_string(), format!("{:.0}%", 100.0 * m.batch_occupancy())]);
    t.row(vec!["p50 latency".to_string(), format!("{} s", fmt_eng(p50, 3))]);
    t.row(vec!["p99 latency".to_string(), format!("{} s", fmt_eng(p99, 3))]);
    t.row(vec!["deadlines met".to_string(), format!("{met}/{REQUESTS}")]);
    for (cfg, count) in &per_config {
        t.row(vec![format!("served by {cfg}"), count.to_string()]);
    }
    print!("{}", t.render());

    write_bench_json(wall_s, rps, p50, p99, met, &m, &per_config);
}

/// Export the serving timings as canonical JSON at the repo root so CI can
/// archive the serving-perf trajectory PR-over-PR.
fn write_bench_json(
    wall_s: f64,
    rps: f64,
    p50: f64,
    p99: f64,
    met: usize,
    m: &bf_imna::coordinator::Metrics,
    per_config: &BTreeMap<String, u64>,
) {
    let doc = Json::obj([
        ("bench", Json::str("perf_serving/request_path")),
        ("backend", Json::str("sim")),
        ("requests", Json::num(REQUESTS as f64)),
        ("wall_s", Json::num(wall_s)),
        ("throughput_rps", Json::num(rps)),
        ("latency_p50_s", Json::num(p50)),
        ("latency_p99_s", Json::num(p99)),
        ("batches", Json::num(m.batches as f64)),
        ("batch_occupancy", Json::num(m.batch_occupancy())),
        ("deadline_met_frac", Json::num(met as f64 / REQUESTS as f64)),
        (
            "per_config",
            Json::obj(per_config.iter().map(|(k, &v)| (k.clone(), Json::num(v as f64)))),
        ),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_serving.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
