//! Bench: the serving request path end to end on the sim backend — the
//! default-build coordinator under a mixed class/deadline request load —
//! plus the wire: per-connect vs pooled vs multi-sample exchange rates
//! against a live `ServingServer` (the `perf_transport` section).
//!
//! Measures what the serving redesign makes measurable without PJRT:
//! submit→batch→pick→execute→reply wall-clock throughput and latency
//! percentiles, the config mix the bit-fluid controller produces, the
//! deadline met fraction, and how much the connection-oriented transport
//! (keep-alive + `ConnPool`) buys over one-connect-per-request. Results
//! are exported to `BENCH_serving.json` at the repo root so CI tracks the
//! serving trajectory PR-over-PR (the serving counterpart of
//! `perf_hotpath`'s `BENCH_dse.json`); CI's smoke step asserts the pooled
//! rates beat the per-connect rates on the same run. The `hotpath`
//! section A/Bs the lock-free serving path: mutex- vs sharded-atomic
//! metrics recording, spawn-per-connection vs pooled handler churn, and
//! a multi-core loadgen probe — CI gates sharded ≥ mutex and pooled ≥
//! spawn.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use bf_imna::coordinator::server::{self as serving, BatchInferRequest, InferRequest};
use bf_imna::coordinator::{
    Budget, BudgetSpec, Coordinator, CoordinatorConfig, Metrics, Priority, RequestSpec,
    ServingServer, ShardedMetrics,
};
use bf_imna::sim::transport::ConnPool;
use bf_imna::util::benchkit::banner;
use bf_imna::util::json::Json;
use bf_imna::util::rng::Rng;
use bf_imna::util::table::{fmt_eng, Table};

const REQUESTS: usize = 256;
/// `GET /stats` exchanges per transport mode — pure wire overhead, no
/// coordinator latency in the loop, so the connect cost dominates.
const STATS_EXCHANGES: usize = 200;
/// `POST /infer` exchanges per transport mode (end-to-end over the wire).
const INFER_EXCHANGES: usize = 64;
/// Multi-sample mode: framed requests sent × samples packed into each.
const MS_EXCHANGES: usize = 4;
/// Samples per multi-sample framed request.
const MS_BATCH: usize = 16;
/// Client-side exchange deadline for the transport section.
const WIRE_TIMEOUT: Duration = Duration::from_secs(30);
/// Contending writer threads for the hotpath metrics A/B — at least 4 so
/// the mutex path actually contends, even on small CI runners.
const HOTPATH_MIN_THREADS: usize = 4;
/// `record_request` calls per writer thread in the metrics A/B.
const HOTPATH_OPS: usize = 50_000;
/// Fresh connections per churn mode (spawn-per-conn vs pooled handlers).
const CHURN_CONNS: usize = 300;
/// Timed rounds per churn mode; the best round is reported (standard
/// noise-floor practice for a ratio gate).
const CHURN_ROUNDS: usize = 2;

fn main() {
    banner("Serving request path (sim backend, mixed budgets + deadlines)");
    let coord = Coordinator::start_sim(CoordinatorConfig::default(), 0.0)
        .expect("sim-backed coordinator starts in the default build");
    println!(
        "configs (descending quality): [{}]; sending {REQUESTS} requests",
        coord.configs().join(", ")
    );

    let elems = coord.sample_elems();
    let mut rng = Rng::new(42);
    let budgets = [Budget::Low, Budget::Medium, Budget::High];
    let t0 = Instant::now();
    let pendings: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let x: Vec<f32> = (0..elems).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
            if i % 4 == 3 {
                // Every fourth request carries an explicit deadline drawn
                // from a deterministic ladder of targets.
                coord
                    .request(x)
                    .deadline(Duration::from_micros(50 + 200 * (i % 5) as u64))
                    .submit()
                    .expect("submit")
            } else {
                coord.submit(x, budgets[i % 3]).expect("submit")
            }
        })
        .collect();

    let mut per_config: BTreeMap<String, u64> = BTreeMap::new();
    let mut met = 0usize;
    for p in pendings {
        let r = p.wait().expect("response");
        met += usize::from(r.met_deadline);
        *per_config.entry(r.config).or_default() += 1;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    assert_eq!(m.completed as usize, REQUESTS, "every request must complete");
    assert_eq!(m.failed, 0, "sim backend must not fail executions");

    let rps = REQUESTS as f64 / wall_s;
    let p50 = m.latency_p(0.5);
    let p99 = m.latency_p(0.99);
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests".to_string(), REQUESTS.to_string()]);
    t.row(vec!["wall".to_string(), format!("{} s", fmt_eng(wall_s, 3))]);
    t.row(vec!["throughput".to_string(), format!("{rps:.0} req/s")]);
    t.row(vec!["batches".to_string(), m.batches.to_string()]);
    t.row(vec!["batch occupancy".to_string(), format!("{:.0}%", 100.0 * m.batch_occupancy())]);
    t.row(vec!["p50 latency".to_string(), format!("{} s", fmt_eng(p50, 3))]);
    t.row(vec!["p99 latency".to_string(), format!("{} s", fmt_eng(p99, 3))]);
    t.row(vec!["deadlines met".to_string(), format!("{met}/{REQUESTS}")]);
    for (cfg, count) in &per_config {
        t.row(vec![format!("served by {cfg}"), count.to_string()]);
    }
    print!("{}", t.render());

    let transport = bench_transport();
    let loadgen = bench_loadgen();
    let hotpath = bench_hotpath();
    write_bench_json(wall_s, rps, p50, p99, met, &m, &per_config, transport, loadgen, hotpath);
}

/// The `hotpath` section: the lock-free serving-path A/Bs. (a) Metrics:
/// the same `record_request` load hammered through one `Mutex<Metrics>`
/// vs per-thread [`ShardedMetrics`] recorders. (b) Connection churn:
/// fresh connect + `GET /healthz` against a front end in legacy
/// spawn-per-connection mode (`serve_threads: 0`) vs the pooled default.
/// (c) A multi-core loadgen probe at the `available_parallelism` sender
/// default. CI gates on sharded ≥ mutex and pooled ≥ spawn.
fn bench_hotpath() -> Json {
    banner("Hot path (mutex vs sharded metrics; spawn vs pooled connections)");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .max(HOTPATH_MIN_THREADS);

    // (a) Metrics A/B. Every writer records the identical sequence in
    // both arms, so the two snapshots must agree exactly — the A/B is a
    // semantics check as well as a stopwatch.
    let mutex = std::sync::Arc::new(std::sync::Mutex::new(Metrics::default()));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..threads {
            let mutex = std::sync::Arc::clone(&mutex);
            scope.spawn(move || {
                let class = if w % 2 == 0 { "low" } else { "high" };
                for i in 0..HOTPATH_OPS {
                    let mut m = mutex.lock().unwrap();
                    m.record_request(class, 1e-4 * ((i % 17) + 1) as f64, i % 7 != 0);
                }
            });
        }
    });
    let mutex_ops_per_s = (threads * HOTPATH_OPS) as f64 / t0.elapsed().as_secs_f64();

    let sharded = std::sync::Arc::new(ShardedMetrics::new(threads));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..threads {
            let recorder = sharded.recorder();
            scope.spawn(move || {
                let class = if w % 2 == 0 { "low" } else { "high" };
                for i in 0..HOTPATH_OPS {
                    recorder.record_request(class, 1e-4 * ((i % 17) + 1) as f64, i % 7 != 0);
                }
            });
        }
    });
    let sharded_ops_per_s = (threads * HOTPATH_OPS) as f64 / t0.elapsed().as_secs_f64();
    let snap = sharded.snapshot();
    let plain = mutex.lock().unwrap();
    assert_eq!(snap.completed, plain.completed, "both arms recorded the same load");
    assert_eq!(snap.deadline_met, plain.deadline_met, "same verdicts in both arms");
    drop(plain);

    // (b) Connection churn A/B: a fresh connection per `/healthz` probe,
    // against the same front end in both handler modes. Best-of-N rounds
    // per mode keeps a single noisy round from deciding the ratio.
    let churn = |serve_threads: usize| -> f64 {
        let coord = Coordinator::start_sim(CoordinatorConfig::default(), 0.0)
            .expect("sim-backed coordinator starts in the default build");
        let server = ServingServer::spawn_with(
            "127.0.0.1:0",
            coord,
            serving::ServeOpts { serve_threads, ..Default::default() },
        )
        .expect("bind ephemeral port");
        let addr = server.addr().to_string();
        // Warm up: listener + first handler ready before the stopwatch.
        serving::fetch_health(&addr, WIRE_TIMEOUT).expect("warmup /healthz");
        let mut best = 0.0f64;
        for _ in 0..CHURN_ROUNDS {
            let t0 = Instant::now();
            for _ in 0..CHURN_CONNS {
                serving::fetch_health(&addr, WIRE_TIMEOUT).expect("churn /healthz");
            }
            best = best.max(CHURN_CONNS as f64 / t0.elapsed().as_secs_f64());
        }
        server.shutdown();
        best
    };
    let spawn_rps = churn(0);
    let pooled_rps = churn(serving::ServeOpts::default().serve_threads);

    // (c) Multi-core loadgen probe at the default (available_parallelism)
    // sender count.
    let coord = Coordinator::start_sim(CoordinatorConfig::default(), 0.0)
        .expect("sim-backed coordinator starts in the default build");
    let server = ServingServer::spawn("127.0.0.1:0", coord).expect("bind ephemeral port");
    let addr = server.addr().to_string();
    let spec = bf_imna::coordinator::loadgen::WorkloadSpec::builtin("constant", 200.0, 1.0, 7)
        .expect("builtin workload");
    let lopts = bf_imna::coordinator::loadgen::LoadgenOpts {
        timeout: WIRE_TIMEOUT,
        ..Default::default()
    };
    let report = bf_imna::coordinator::loadgen::run_loadgen(&addr, &spec, &lopts)
        .expect("hotpath loadgen run");
    server.shutdown();
    let lg_p99 = report.total.latency.percentile(0.99);

    let mut t = Table::new(vec!["probe", "value"]);
    t.row(vec![
        format!("metrics mutex ({threads} threads)"),
        format!("{} ops/s", fmt_eng(mutex_ops_per_s, 3)),
    ]);
    t.row(vec![
        format!("metrics sharded ({threads} threads)"),
        format!("{} ops/s", fmt_eng(sharded_ops_per_s, 3)),
    ]);
    t.row(vec![
        "metrics speedup".to_string(),
        format!("{:.2}x", sharded_ops_per_s / mutex_ops_per_s),
    ]);
    t.row(vec!["churn spawn-per-conn".to_string(), format!("{spawn_rps:.0} conn/s")]);
    t.row(vec!["churn pooled".to_string(), format!("{pooled_rps:.0} conn/s")]);
    t.row(vec![
        "churn speedup".to_string(),
        format!("{:.2}x", pooled_rps / spawn_rps),
    ]);
    t.row(vec![
        format!("loadgen ({} senders)", report.senders),
        format!(
            "{:.0} req/s achieved | p99 {} s | {:.0}% sender util",
            report.achieved_rps(),
            fmt_eng(lg_p99, 3),
            100.0 * report.sender_utilization()
        ),
    ]);
    print!("{}", t.render());

    Json::obj([
        (
            "metrics",
            Json::obj([
                ("threads", Json::num(threads as f64)),
                ("ops_per_thread", Json::num(HOTPATH_OPS as f64)),
                ("mutex_ops_per_s", Json::num(mutex_ops_per_s)),
                ("sharded_ops_per_s", Json::num(sharded_ops_per_s)),
                ("speedup", Json::num(sharded_ops_per_s / mutex_ops_per_s)),
            ]),
        ),
        (
            "churn",
            Json::obj([
                ("conns", Json::num(CHURN_CONNS as f64)),
                ("spawn_rps", Json::num(spawn_rps)),
                ("pooled_rps", Json::num(pooled_rps)),
                ("speedup", Json::num(pooled_rps / spawn_rps)),
            ]),
        ),
        (
            "loadgen",
            Json::obj([
                ("workers", Json::num(report.senders as f64)),
                ("achieved_rps", Json::num(report.achieved_rps())),
                ("latency_p99_s", Json::num(lg_p99)),
                ("sender_utilization", Json::num(report.sender_utilization())),
            ]),
        ),
    ])
}

/// The `perf_loadgen` section: a short seeded open-loop run through the
/// real loadgen driver against a live front end, reporting offered vs
/// achieved rate and the client-measured tail.
fn bench_loadgen() -> Json {
    banner("Loadgen (open-loop constant profile, mixed classes)");
    let coord = Coordinator::start_sim(CoordinatorConfig::default(), 0.0)
        .expect("sim-backed coordinator starts in the default build");
    let server = ServingServer::spawn("127.0.0.1:0", coord).expect("bind ephemeral port");
    let addr = server.addr().to_string();
    let spec = bf_imna::coordinator::loadgen::WorkloadSpec::builtin("constant", 150.0, 1.0, 42)
        .expect("builtin workload");
    let opts = bf_imna::coordinator::loadgen::LoadgenOpts {
        workers: 4,
        timeout: WIRE_TIMEOUT,
    };
    let report =
        bf_imna::coordinator::loadgen::run_loadgen(&addr, &spec, &opts).expect("loadgen run");
    server.shutdown();

    let p99 = report.total.latency.percentile(0.99);
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["offered".to_string(), format!("{:.0} req/s", report.offered_rps())]);
    t.row(vec!["achieved".to_string(), format!("{:.0} req/s", report.achieved_rps())]);
    t.row(vec![
        "sent / ok / busy / errors".to_string(),
        format!(
            "{} / {} / {} / {}",
            report.total.sent, report.total.ok, report.total.rejected_busy, report.total.errors
        ),
    ]);
    t.row(vec!["met deadline".to_string(), format!("{:.0}%", 100.0 * report.total.met_frac())]);
    t.row(vec!["client p99".to_string(), format!("{} s", fmt_eng(p99, 3))]);
    print!("{}", t.render());

    Json::obj([
        ("offered_rps", Json::num(report.offered_rps())),
        ("achieved_rps", Json::num(report.achieved_rps())),
        ("sent", Json::num(report.total.sent as f64)),
        ("ok", Json::num(report.total.ok as f64)),
        ("rejected_busy", Json::num(report.total.rejected_busy as f64)),
        ("errors", Json::num(report.total.errors as f64)),
        ("met_frac", Json::num(report.total.met_frac())),
        ("latency_p99_s", Json::num(p99)),
    ])
}

/// The `perf_transport` section: the same serving coordinator behind a
/// live HTTP front end, measuring exchanges/second in three wire modes —
/// one fresh connection per request, a pooled keep-alive connection, and
/// multi-sample framed requests over the pooled connection.
fn bench_transport() -> Json {
    banner("Transport (per-connect vs pooled vs multi-sample)");
    let coord = Coordinator::start_sim(CoordinatorConfig::default(), 0.0)
        .expect("sim-backed coordinator starts in the default build");
    let elems = coord.sample_elems();
    let server = ServingServer::spawn("127.0.0.1:0", coord).expect("bind ephemeral port");
    let addr = server.addr().to_string();
    let pool = ConnPool::new(2);
    let spec = RequestSpec {
        budget: BudgetSpec::Class(Budget::High),
        priority: Priority::Normal,
        batch_hint: None,
    };
    let mut rng = Rng::new(7);
    let mut sample = || -> Vec<f32> { (0..elems).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect() };

    // GET /stats — the wire-overhead probe: no batching latency in the
    // loop, so this isolates connect + frame cost.
    let t0 = Instant::now();
    for _ in 0..STATS_EXCHANGES {
        serving::fetch_stats(&addr, WIRE_TIMEOUT).expect("per-connect /stats");
    }
    let stats_per_connect_rps = STATS_EXCHANGES as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..STATS_EXCHANGES {
        serving::fetch_stats_pooled(&pool, &addr, WIRE_TIMEOUT).expect("pooled /stats");
    }
    let stats_pooled_rps = STATS_EXCHANGES as f64 / t0.elapsed().as_secs_f64();

    // POST /infer — end to end over the wire, one sample per exchange.
    let t0 = Instant::now();
    for _ in 0..INFER_EXCHANGES {
        let req = InferRequest { input: sample(), spec: spec.clone() };
        serving::infer_remote(&addr, &req, WIRE_TIMEOUT).expect("per-connect /infer");
    }
    let infer_per_connect_rps = INFER_EXCHANGES as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..INFER_EXCHANGES {
        let req = InferRequest { input: sample(), spec: spec.clone() };
        serving::infer_remote_pooled(&pool, &addr, &req, WIRE_TIMEOUT).expect("pooled /infer");
    }
    let infer_pooled_rps = INFER_EXCHANGES as f64 / t0.elapsed().as_secs_f64();

    // Multi-sample POST /infer — many samples per framed request over the
    // pooled connection; the rate is samples/second, comparable to the
    // single-sample rates above.
    let t0 = Instant::now();
    for _ in 0..MS_EXCHANGES {
        let req = BatchInferRequest {
            inputs: (0..MS_BATCH).map(|_| sample()).collect(),
            spec: spec.clone(),
        };
        let rs = serving::infer_remote_many(&pool, &addr, &req, WIRE_TIMEOUT)
            .expect("multi-sample /infer");
        assert_eq!(rs.len(), MS_BATCH, "one verdict per sample");
    }
    let ms_rps = (MS_EXCHANGES * MS_BATCH) as f64 / t0.elapsed().as_secs_f64();

    let ps = pool.stats();
    server.shutdown();

    let mut t = Table::new(vec!["mode", "exchanges", "rate"]);
    t.row(vec![
        "/stats per-connect".to_string(),
        STATS_EXCHANGES.to_string(),
        format!("{stats_per_connect_rps:.0} req/s"),
    ]);
    t.row(vec![
        "/stats pooled".to_string(),
        STATS_EXCHANGES.to_string(),
        format!("{stats_pooled_rps:.0} req/s"),
    ]);
    t.row(vec![
        "/infer per-connect".to_string(),
        INFER_EXCHANGES.to_string(),
        format!("{infer_per_connect_rps:.0} req/s"),
    ]);
    t.row(vec![
        "/infer pooled".to_string(),
        INFER_EXCHANGES.to_string(),
        format!("{infer_pooled_rps:.0} req/s"),
    ]);
    t.row(vec![
        format!("/infer multi-sample {MS_EXCHANGES}x{MS_BATCH}"),
        (MS_EXCHANGES * MS_BATCH).to_string(),
        format!("{ms_rps:.0} samples/s"),
    ]);
    t.row(vec![
        "pool".to_string(),
        String::new(),
        format!("{} fresh, {} reused, {} stale retries", ps.fresh_connects, ps.reuses, ps.stale_retries),
    ]);
    print!("{}", t.render());

    Json::obj([
        ("stats_exchanges", Json::num(STATS_EXCHANGES as f64)),
        ("stats_per_connect_rps", Json::num(stats_per_connect_rps)),
        ("stats_pooled_rps", Json::num(stats_pooled_rps)),
        ("infer_exchanges", Json::num(INFER_EXCHANGES as f64)),
        ("infer_per_connect_rps", Json::num(infer_per_connect_rps)),
        ("infer_pooled_rps", Json::num(infer_pooled_rps)),
        ("multi_sample_exchanges", Json::num(MS_EXCHANGES as f64)),
        ("multi_sample_batch", Json::num(MS_BATCH as f64)),
        ("multi_sample_rps", Json::num(ms_rps)),
        ("pool_fresh_connects", Json::num(ps.fresh_connects as f64)),
        ("pool_reuses", Json::num(ps.reuses as f64)),
        ("pool_stale_retries", Json::num(ps.stale_retries as f64)),
    ])
}

/// Export the serving timings as canonical JSON at the repo root so CI can
/// archive the serving-perf trajectory PR-over-PR. The `transport` object
/// carries the per-connect/pooled/multi-sample wire rates; CI's smoke step
/// asserts the pooled rates beat the per-connect rates.
#[allow(clippy::too_many_arguments)]
fn write_bench_json(
    wall_s: f64,
    rps: f64,
    p50: f64,
    p99: f64,
    met: usize,
    m: &bf_imna::coordinator::Metrics,
    per_config: &BTreeMap<String, u64>,
    transport: Json,
    loadgen: Json,
    hotpath: Json,
) {
    let doc = Json::obj([
        ("bench", Json::str("perf_serving/request_path")),
        ("backend", Json::str("sim")),
        ("requests", Json::num(REQUESTS as f64)),
        ("wall_s", Json::num(wall_s)),
        ("throughput_rps", Json::num(rps)),
        ("latency_p50_s", Json::num(p50)),
        ("latency_p99_s", Json::num(p99)),
        ("batches", Json::num(m.batches as f64)),
        ("batch_occupancy", Json::num(m.batch_occupancy())),
        ("deadline_met_frac", Json::num(met as f64 / REQUESTS as f64)),
        (
            "per_config",
            Json::obj(per_config.iter().map(|(k, &v)| (k.clone(), Json::num(v as f64)))),
        ),
        ("transport", transport),
        ("loadgen", loadgen),
        ("hotpath", hotpath),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_serving.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
