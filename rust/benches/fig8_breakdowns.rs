//! Bench: regenerate Fig. 8 — (a) total-energy breakdown by work category
//! and (b) GEMM-latency breakdown by phase, for the three ImageNet
//! benchmarks on the LR chip. All three networks fan through one
//! [`SweepEngine`] batch; both figures come from the same reports.

use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{artifacts, breakdown, shard, SimParams, SweepEngine, SweepPoint};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::table::{fmt_eng, Table};

fn main() {
    let params = SimParams::lr_sram();
    let engine = SweepEngine::new();

    banner("Fig. 8 — breakdowns (INT8, LR, SRAM), via the artifact catalog");
    // Both share tables come from the `fig8` catalog artifact: the spec's
    // records carry the breakdown values, so the rendered figure is
    // byte-identical whether the document was computed here, by shards,
    // or by a worker fleet.
    let fig8 = artifacts::by_name("fig8").expect("fig8 in catalog");
    let spec = fig8.spec();
    let resolved = spec.resolve().expect("fig8 spec resolves");
    let result = shard::run_shard(&spec, 1, 0, &engine).expect("fig8 sweep runs");
    print!(
        "{}",
        fig8.render_records(&spec, &resolved, &result.points).expect("fig8 renders")
    );
    println!("(paper: reduction dominates GEMM latency; multiplication is bit-serial\n\
              column-parallel and nearly precision-flat in total latency)");

    // Paper shape assertions straight off the records the renderer used.
    for rec in &result.points {
        let energy = breakdown::shares(&breakdown::ENERGY_KIND_LABELS, &rec.energy_kinds);
        assert!(
            breakdown::fraction_of(&energy, "GEMM") > 0.4,
            "{}: GEMM share too small",
            rec.net
        );
        let phases = breakdown::shares(&breakdown::GEMM_PHASE_LABELS, &rec.gemm_phases);
        let red = breakdown::fraction_of(&phases, "Reduce");
        let mul = breakdown::fraction_of(&phases, "Multiply");
        assert!(red > mul && red > 0.5, "{}: reduce {red:.2} vs multiply {mul:.2}", rec.net);
    }

    banner("Per-layer detail (VGG16, 5 most expensive layers)");
    let vgg = zoo::vgg16();
    let cfg = PrecisionConfig::fixed(8, vgg.weight_layers());
    let r = engine.run(&[SweepPoint::new(&vgg, &cfg, &params)]).remove(0);
    let mut layers: Vec<_> = r.layers.iter().collect();
    layers.sort_by(|a, b| b.energy_j().partial_cmp(&a.energy_j()).unwrap());
    let mut t = Table::new(vec!["layer", "steps", "energy (J)", "latency (s)", "mesh (s)"]);
    for l in layers.iter().take(5) {
        t.row(vec![
            l.name.to_string(),
            l.steps.to_string(),
            fmt_eng(l.energy_j(), 3),
            fmt_eng(l.latency_s, 3),
            fmt_eng(l.mesh_s, 3),
        ]);
    }
    print!("{}", t.render());

    banner("Timing");
    let nets = zoo::imagenet_benchmarks();
    let cfgs: Vec<PrecisionConfig> =
        nets.iter().map(|n| PrecisionConfig::fixed(8, n.weight_layers())).collect();
    let points: Vec<SweepPoint> =
        nets.iter().zip(&cfgs).map(|(n, c)| SweepPoint::new(n, c, &params)).collect();
    let bench = Bencher::new().samples(10);
    let r = bench.run("engine sweep + both breakdowns (3 nets)", || {
        let bds = breakdown::breakdowns_many(&engine, &points);
        bds.iter()
            .map(|b| b.energy_by_kind[0].fraction + b.gemm_latency_by_phase[0].fraction)
            .sum::<f64>()
    });
    println!("{}", r.report_line());
}
