//! Bench: regenerate Fig. 8 — (a) total-energy breakdown by work category
//! and (b) GEMM-latency breakdown by phase, for the three ImageNet
//! benchmarks on the LR chip. All three networks fan through one
//! [`SweepEngine`] batch; both figures come from the same reports.

use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{breakdown, SimParams, SweepEngine, SweepPoint};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::table::{fmt_eng, Table};

fn main() {
    let params = SimParams::lr_sram();
    let engine = SweepEngine::new();
    let nets = zoo::imagenet_benchmarks();
    let cfgs: Vec<PrecisionConfig> =
        nets.iter().map(|n| PrecisionConfig::fixed(8, n.weight_layers())).collect();
    let points: Vec<SweepPoint> =
        nets.iter().zip(&cfgs).map(|(n, c)| SweepPoint::new(n, c, &params)).collect();
    let bds = breakdown::breakdowns_many(&engine, &points);

    banner("Fig. 8a — energy breakdown (INT8, LR, SRAM)");
    let mut t = Table::new(vec!["network", "GEMM", "Pooling", "Residual/ReLU", "Interconnect"]);
    for (net, bd) in nets.iter().zip(&bds) {
        let shares = &bd.energy_by_kind;
        let pct = |l: &str| format!("{:.1}%", 100.0 * breakdown::fraction_of(shares, l));
        t.row(vec![
            net.name.clone(),
            pct("GEMM"),
            pct("Pooling"),
            pct("Residual/ReLU"),
            pct("Interconnect"),
        ]);
        // Paper: "GEMM and pooling are the main energy bottlenecks" — GEMM
        // must dominate the AP-side energy.
        assert!(
            breakdown::fraction_of(shares, "GEMM") > 0.4,
            "{}: GEMM share too small",
            net.name
        );
    }
    print!("{}", t.render());

    banner("Fig. 8b — GEMM latency breakdown by phase (INT8, LR, SRAM)");
    let mut t = Table::new(vec!["network", "Populate", "Multiply", "Reduce", "Readout", "ReLU"]);
    for (net, bd) in nets.iter().zip(&bds) {
        let shares = &bd.gemm_latency_by_phase;
        let pct = |l: &str| format!("{:.1}%", 100.0 * breakdown::fraction_of(shares, l));
        t.row(vec![
            net.name.clone(),
            pct("Populate"),
            pct("Multiply"),
            pct("Reduce"),
            pct("Readout"),
            pct("ReLU"),
        ]);
        // The paper's headline: reduction, not multiplication, bottlenecks
        // GEMM latency.
        let red = breakdown::fraction_of(shares, "Reduce");
        let mul = breakdown::fraction_of(shares, "Multiply");
        assert!(red > mul && red > 0.5, "{}: reduce {red:.2} vs multiply {mul:.2}", net.name);
    }
    print!("{}", t.render());
    println!("(paper: reduction dominates GEMM latency; multiplication is bit-serial\n\
              column-parallel and nearly precision-flat in total latency)");

    banner("Per-layer detail (VGG16, 5 most expensive layers)");
    let vgg = zoo::vgg16();
    let cfg = PrecisionConfig::fixed(8, vgg.weight_layers());
    let r = engine.run(&[SweepPoint::new(&vgg, &cfg, &params)]).remove(0);
    let mut layers: Vec<_> = r.layers.iter().collect();
    layers.sort_by(|a, b| b.energy_j().partial_cmp(&a.energy_j()).unwrap());
    let mut t = Table::new(vec!["layer", "steps", "energy (J)", "latency (s)", "mesh (s)"]);
    for l in layers.iter().take(5) {
        t.row(vec![
            l.name.to_string(),
            l.steps.to_string(),
            fmt_eng(l.energy_j(), 3),
            fmt_eng(l.latency_s, 3),
            fmt_eng(l.mesh_s, 3),
        ]);
    }
    print!("{}", t.render());

    banner("Timing");
    let bench = Bencher::new().samples(10);
    let r = bench.run("engine sweep + both breakdowns (3 nets)", || {
        let bds = breakdown::breakdowns_many(&engine, &points);
        bds.iter()
            .map(|b| b.energy_by_kind[0].fraction + b.gemm_latency_by_phase[0].fraction)
            .sum::<f64>()
    });
    println!("{}", r.report_line());
}
