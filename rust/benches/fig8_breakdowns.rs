//! Bench: regenerate Fig. 8 — (a) total-energy breakdown by work category
//! and (b) GEMM-latency breakdown by phase, for the three ImageNet
//! benchmarks on the LR chip.

use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{breakdown, simulate, SimParams};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::table::{fmt_eng, Table};

fn main() {
    banner("Fig. 8a — energy breakdown (INT8, LR, SRAM)");
    let params = SimParams::lr_sram();
    let mut t = Table::new(vec!["network", "GEMM", "Pooling", "Residual/ReLU", "Interconnect"]);
    for net in zoo::imagenet_benchmarks() {
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let r = simulate(&net, &cfg, &params);
        let shares = breakdown::energy_by_kind(&r);
        let pct = |l: &str| format!("{:.1}%", 100.0 * breakdown::fraction_of(&shares, l));
        t.row(vec![
            net.name.clone(),
            pct("GEMM"),
            pct("Pooling"),
            pct("Residual/ReLU"),
            pct("Interconnect"),
        ]);
        // Paper: "GEMM and pooling are the main energy bottlenecks" — GEMM
        // must dominate the AP-side energy.
        assert!(
            breakdown::fraction_of(&shares, "GEMM") > 0.4,
            "{}: GEMM share too small",
            net.name
        );
    }
    print!("{}", t.render());

    banner("Fig. 8b — GEMM latency breakdown by phase (INT8, LR, SRAM)");
    let mut t = Table::new(vec!["network", "Populate", "Multiply", "Reduce", "Readout", "ReLU"]);
    for net in zoo::imagenet_benchmarks() {
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let r = simulate(&net, &cfg, &params);
        let shares = breakdown::gemm_latency_by_phase(&r);
        let pct = |l: &str| format!("{:.1}%", 100.0 * breakdown::fraction_of(&shares, l));
        t.row(vec![
            net.name.clone(),
            pct("Populate"),
            pct("Multiply"),
            pct("Reduce"),
            pct("Readout"),
            pct("ReLU"),
        ]);
        // The paper's headline: reduction, not multiplication, bottlenecks
        // GEMM latency.
        let red = breakdown::fraction_of(&shares, "Reduce");
        let mul = breakdown::fraction_of(&shares, "Multiply");
        assert!(red > mul && red > 0.5, "{}: reduce {red:.2} vs multiply {mul:.2}", net.name);
    }
    print!("{}", t.render());
    println!("(paper: reduction dominates GEMM latency; multiplication is bit-serial\n\
              column-parallel and nearly precision-flat in total latency)");

    banner("Per-layer detail (VGG16, 5 most expensive layers)");
    let vgg = zoo::vgg16();
    let cfg = PrecisionConfig::fixed(8, vgg.weight_layers());
    let r = simulate(&vgg, &cfg, &params);
    let mut layers: Vec<_> = r.layers.iter().collect();
    layers.sort_by(|a, b| b.energy_j().partial_cmp(&a.energy_j()).unwrap());
    let mut t = Table::new(vec!["layer", "steps", "energy (J)", "latency (s)", "mesh (s)"]);
    for l in layers.iter().take(5) {
        t.row(vec![
            l.name.clone(),
            l.steps.to_string(),
            fmt_eng(l.energy_j(), 3),
            fmt_eng(l.latency_s, 3),
            fmt_eng(l.mesh_s, 3),
        ]);
    }
    print!("{}", t.render());

    banner("Timing");
    let bench = Bencher::new().samples(10);
    let r = bench.run("simulate + both breakdowns (3 nets)", || {
        let mut acc = 0.0;
        for net in zoo::imagenet_benchmarks() {
            let cfg = PrecisionConfig::fixed(8, net.weight_layers());
            let rep = simulate(&net, &cfg, &params);
            acc += breakdown::energy_by_kind(&rep)[0].fraction;
            acc += breakdown::gemm_latency_by_phase(&rep)[0].fraction;
        }
        acc
    });
    println!("{}", r.report_line());
}
