//! Bench: regenerate Fig. 6 — ReRAM/SRAM energy and latency ratios for
//! fixed precisions 2..8, end-to-end VGG16 inference — plus the §V-A
//! voltage-scaling experiment and a mesh-energy sensitivity ablation.

use bf_imna::arch::HwConfig;
use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{artifacts, dse, simulate, simulate_on, SimParams, SweepEngine};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::table::{fmt_ratio, Table};

fn main() {
    banner("Fig. 6 — ReRAM/SRAM ratios, end-to-end VGG16 (LR chip)");
    // The figure itself comes from the artifact catalog: spec -> run ->
    // render, byte-identical to rendering a sharded or dispatched run of
    // the same spec.
    let engine = SweepEngine::new();
    let fig6 = artifacts::by_name("fig6").expect("fig6 in catalog");
    print!("{}", fig6.run_and_render(&engine, false).expect("fig6 renders"));
    let vgg = zoo::vgg16();
    let rows = dse::fig6_tech_ratios_with(&engine, &vgg);
    println!(
        "paper: energy ratios decreasing 80.9x -> 63.1x; latency ~1.85x flat; area 4.4x.\n\
         measured shape: energy ratio decreasing {} -> {}; latency {}..{}; area {}.",
        fmt_ratio(rows.first().unwrap().energy_ratio),
        fmt_ratio(rows.last().unwrap().energy_ratio),
        fmt_ratio(rows.iter().map(|r| r.latency_ratio).fold(f64::MAX, f64::min)),
        fmt_ratio(rows.iter().map(|r| r.latency_ratio).fold(f64::MIN, f64::max)),
        fmt_ratio(rows[0].area_savings),
    );
    assert!(rows.windows(2).all(|w| w[1].energy_ratio < w[0].energy_ratio));

    banner("Voltage scaling (SRAM 1.0 V -> 0.5 V write energy, §V-A)");
    let mut t = Table::new(vec!["network", "energy saving", "paper"]);
    for net in zoo::imagenet_benchmarks() {
        let s = dse::voltage_scaling_saving(&net, 8);
        t.row(vec![net.name.clone(), format!("{:.3}%", 100.0 * s), "<= 0.06%".to_string()]);
    }
    print!("{}", t.render());

    banner("Ablation: mesh energy-per-bit sensitivity (undocumented in [6])");
    // The paper sources mesh pJ/bit/mm from Dally et al. without printing
    // the value; sweep it to show the headline results barely move.
    let cfg = PrecisionConfig::fixed(8, vgg.weight_layers());
    let params = SimParams::lr_sram();
    let mut t = Table::new(vec!["e_mesh (pJ/bit/mm)", "energy/inference (J)", "delta vs 0.05"]);
    let mut chip = bf_imna::arch::ChipConfig::for_network(HwConfig::Lr, &vgg);
    let base = simulate(&vgg, &cfg, &params).energy_j();
    for e in [0.01, 0.05, 0.1, 0.2] {
        chip.mesh.e_bit_mm = e * 1e-12;
        let r = simulate_on(&vgg, &cfg, &params, &chip);
        t.row(vec![
            format!("{e}"),
            format!("{:.4}", r.energy_j()),
            format!("{:+.1}%", 100.0 * (r.energy_j() - base) / base),
        ]);
    }
    print!("{}", t.render());

    banner("Extension: PCM / FeFET technologies (§V-A 'easy to extend')");
    let mut t = Table::new(vec![
        "technology",
        "energy/inf (J)",
        "latency/inf (s)",
        "area (mm2)",
        "energy vs SRAM",
    ]);
    let techs = [
        bf_imna::ap::tech::Tech::sram(),
        bf_imna::ap::tech::Tech::reram(),
        bf_imna::ap::tech::Tech::pcm(),
        bf_imna::ap::tech::Tech::fefet(),
    ];
    let sram_e = simulate(&vgg, &cfg, &SimParams::new(HwConfig::Lr, techs[0])).energy_j();
    for tech in techs {
        let r = simulate(&vgg, &cfg, &SimParams::new(HwConfig::Lr, tech));
        t.row(vec![
            tech.cell.label().to_string(),
            format!("{:.4}", r.energy_j()),
            format!("{:.5}", r.latency_s()),
            format!("{:.1}", r.area_mm2),
            fmt_ratio(r.energy_j() / sram_e),
        ]);
    }
    print!("{}", t.render());

    banner("Extension: inter-batch pipelining + chiplet scale-out (§V-B)");
    let r8 = simulate(&vgg, &cfg, &params);
    println!(
        "VGG16 LR INT8: batch-1 {:.0} GOPS -> pipelined {:.0} GOPS ({} speedup)",
        r8.gops(),
        r8.pipelined_gops(),
        fmt_ratio(r8.pipeline_speedup())
    );
    for chips in [1u64, 2, 4, 8] {
        let s = bf_imna::sim::ScaleOut::new(r8.clone(), chips);
        println!(
            "  {chips} chip(s): {:.0} GOPS pipelined, {:.0} mm2, {:.0} GOPS/W (scale-invariant)",
            s.pipelined_gops(),
            s.area_mm2(),
            s.gops_per_w()
        );
    }

    banner("Timing");
    let bench = Bencher::new().samples(10);
    let r = bench.run("fig6 full sweep (7 precisions x 2 techs, VGG16)", || {
        dse::fig6_tech_ratios(&vgg).len()
    });
    println!("{}", r.report_line());
}
