//! Bench: the §Perf hot paths — the end-to-end timings the performance
//! pass optimizes and EXPERIMENTS.md §Perf records.
//!
//! Three layers, three hot paths:
//! * **L3 simulator** — map_network + simulate for every benchmark network
//!   (this is what every DSE point pays, thousands of times per sweep);
//! * **L3 emulator** — the bit-exact CAM inner loop (pass application);
//! * **Runtime** — PJRT execute of the serving artifacts (request-path
//!   latency floor), when `make artifacts` output is present.

use std::path::Path;

use bf_imna::ap::emulator;
use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{simulate, SimParams};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::rng::Rng;

fn main() {
    banner("L3 simulator hot path (map + cost every layer)");
    let bench = Bencher::new().samples(30);
    let params = SimParams::lr_sram();
    for net in [zoo::alexnet(), zoo::resnet18(), zoo::vgg16(), zoo::resnet50()] {
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let name = format!("simulate {} (LR, INT8, {} layers)", net.name, net.layers.len());
        let r = bench.run(&name, || simulate(&net, &cfg, &params).energy_j());
        println!("{}", r.report_line());
    }
    // A full Fig. 7-style sweep point: 5 configs x 3 nets.
    let nets = zoo::imagenet_benchmarks();
    let r = bench.run("DSE point (3 nets x 5 random configs)", || {
        let mut rng = Rng::new(9);
        let mut acc = 0.0;
        for net in &nets {
            for _ in 0..5 {
                let bits: Vec<u32> =
                    (0..net.weight_layers()).map(|_| 2 + rng.below(7) as u32).collect();
                let cfg = PrecisionConfig::from_bits("r", &bits);
                acc += simulate(net, &cfg, &params).energy_j();
            }
        }
        acc
    });
    println!("{}", r.report_line());

    banner("L3 emulator hot path (bit-exact CAM pass application)");
    let mut rng = Rng::new(3);
    let a = rng.vec_below(1024, 256);
    let b = rng.vec_below(1024, 256);
    let r = bench.run("emulate_add 8b x 1024 words", || emulator::emulate_add(&a, &b, 8).0.len());
    println!("{}", r.report_line());
    let r = bench
        .run("emulate_multiply 8b x 1024 words", || emulator::emulate_multiply(&a, &b, 8, 8).0.len());
    println!("{}", r.report_line());
    let r = bench.run("emulate_reduce_2d 8b x 1024 words", || {
        emulator::emulate_reduce_2d(&a, 8).0
    });
    println!("{}", r.report_line());

    banner("Runtime hot path (PJRT execute, request-path floor)");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` to include PJRT timings");
        return;
    }
    use bf_imna::runtime::Runtime;
    let rt = Runtime::load_configs(&dir, &["int8", "int4"]).expect("runtime");
    let elems = rt.manifest().sample_elems();
    let exec_bench = Bencher::new().samples(10).warmup(2);
    for (config, batch) in [("int8", 1u64), ("int8", 8), ("int4", 1), ("int4", 8)] {
        let input = vec![0.25f32; batch as usize * elems];
        let name = format!("pjrt execute {config} b{batch}");
        let r = exec_bench.run(&name, || rt.infer(config, batch, &input).unwrap().len());
        println!(
            "{}   ({:.1} samples/s)",
            r.report_line(),
            batch as f64 * r.throughput()
        );
    }
}
