//! Bench: the §Perf hot paths — the end-to-end timings the performance
//! pass optimizes and EXPERIMENTS.md §Perf records.
//!
//! Three layers, three hot paths:
//! * **L3 simulator** — map_network + simulate for every benchmark network,
//!   then the headline: a Fig. 7-style **DSE point** (3 nets x 5 random
//!   configs) run three ways — serial uncached (the seed baseline),
//!   through a cold [`SweepEngine`], and through a warm one (sweep steady
//!   state). The engine results are asserted **bit-identical** to direct
//!   `simulate()` output, and the DSE-point timings are exported to
//!   `BENCH_dse.json` at the repo root so CI tracks the perf trajectory
//!   PR-over-PR.
//! * **L3 emulator** — the bit-exact CAM inner loop (pass application);
//! * **Runtime** — PJRT execute of the serving artifacts (request-path
//!   latency floor), when `make artifacts` output is present.

use std::path::Path;

use bf_imna::ap::emulator;
use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{dse, simulate, SimParams, SweepEngine, SweepPoint};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::rng::Rng;

fn main() {
    banner("L3 simulator hot path (map + cost every layer)");
    let bench = Bencher::new().samples(30);
    let params = SimParams::lr_sram();
    for net in [zoo::alexnet(), zoo::resnet18(), zoo::vgg16(), zoo::resnet50()] {
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let name = format!("simulate {} (LR, INT8, {} layers)", net.name, net.layers.len());
        let r = bench.run(&name, || simulate(&net, &cfg, &params).energy_j());
        println!("{}", r.report_line());
    }

    banner("DSE point (3 nets x 5 random configs) — serial uncached vs SweepEngine");
    // The same 15 (net, config) points for every variant — the shared,
    // seed-stable workload (also timed by ablations' Ablation 5).
    let (nets, cfgs) = dse::perf_dse_batch();
    let points: Vec<SweepPoint> =
        cfgs.iter().map(|(i, c)| SweepPoint::new(&nets[*i], c, &params)).collect();

    // Baseline: what the seed paid per DSE point — fresh mapping for every
    // layer of every config, single-threaded.
    let serial = bench.run("DSE point, serial uncached (seed baseline)", || {
        let mut acc = 0.0;
        for (i, cfg) in &cfgs {
            acc += simulate(&nets[*i], cfg, &params).energy_j();
        }
        acc
    });
    println!("{}", serial.report_line());

    // Engine, cold: a fresh plan cache every iteration — isolates the
    // parallel fan-out win.
    let cold = bench.run("DSE point, SweepEngine (cold cache)", || {
        SweepEngine::new().run(&points).iter().map(|r| r.energy_j()).sum::<f64>()
    });
    println!("{}", cold.report_line());

    // Engine, prewarmed: the sweep-service discipline (see sim::shard) —
    // a batch-level `prewarm` populates the cache up front, so even the
    // *first* run never maps cold and workers cannot race on cold keys.
    let prewarmed_engine = SweepEngine::new();
    prewarmed_engine.prewarm(&points);
    let prewarmed = bench.run("DSE point, SweepEngine (prewarmed cache)", || {
        prewarmed_engine.run(&points).iter().map(|r| r.energy_j()).sum::<f64>()
    });
    println!("{}", prewarmed.report_line());

    // Engine, warm: one cache across iterations — the steady state every
    // sweep after its first few configs runs in.
    let engine = SweepEngine::new();
    let warm = bench.run("DSE point, SweepEngine (warm cache)", || {
        engine.run(&points).iter().map(|r| r.energy_j()).sum::<f64>()
    });
    println!("{}", warm.report_line());
    let stats = engine.cache_stats();
    println!(
        "engine: {} worker threads; plan cache {} entries, hit rate {:.1}%",
        engine.threads(),
        stats.entries,
        100.0 * stats.hit_rate()
    );
    // Timing thresholds would flake across machines, but cache behaviour is
    // deterministic: after 30+ warm iterations of the same 15 points, the
    // hit rate must be near 1. This is the CI canary for the speedup claim —
    // a PlanKey regression that misses on every lookup fails here, loudly.
    assert!(
        stats.hit_rate() > 0.9,
        "plan cache ineffective on the warm DSE sweep: {stats:?}"
    );

    // Bit-identity: the whole point of the cache is that it cannot change
    // a single output bit.
    let engine_reports = engine.run(&points);
    for ((i, cfg), er) in cfgs.iter().zip(&engine_reports) {
        let dr = simulate(&nets[*i], cfg, &params);
        assert_eq!(
            er.energy_j().to_bits(),
            dr.energy_j().to_bits(),
            "energy diverged on {} / {}",
            dr.net_name,
            dr.cfg_name
        );
        assert_eq!(
            er.latency_s().to_bits(),
            dr.latency_s().to_bits(),
            "latency diverged on {} / {}",
            dr.net_name,
            dr.cfg_name
        );
    }
    println!("bit-identity: engine results == direct simulate() on all {} points.", points.len());

    let serial_mean = serial.summary().mean;
    let cold_mean = cold.summary().mean;
    let warm_mean = warm.summary().mean;
    let prewarmed_mean = prewarmed.summary().mean;
    println!(
        "speedup vs serial uncached: {:.1}x cold, {:.1}x prewarmed, {:.1}x warm \
         (acceptance target: >= 5x warm)",
        serial_mean / cold_mean,
        serial_mean / prewarmed_mean,
        serial_mean / warm_mean
    );
    write_bench_json(serial_mean, cold_mean, prewarmed_mean, warm_mean, engine.threads());

    banner("L3 emulator hot path (bit-exact CAM pass application)");
    let mut rng = Rng::new(3);
    let a = rng.vec_below(1024, 256);
    let b = rng.vec_below(1024, 256);
    let r = bench.run("emulate_add 8b x 1024 words", || emulator::emulate_add(&a, &b, 8).0.len());
    println!("{}", r.report_line());
    let r = bench
        .run("emulate_multiply 8b x 1024 words", || emulator::emulate_multiply(&a, &b, 8, 8).0.len());
    println!("{}", r.report_line());
    let r = bench.run("emulate_reduce_2d 8b x 1024 words", || {
        emulator::emulate_reduce_2d(&a, 8).0
    });
    println!("{}", r.report_line());

    banner("Runtime hot path (PJRT execute, request-path floor)");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` to include PJRT timings");
        return;
    }
    use bf_imna::runtime::Runtime;
    let rt = match Runtime::load_configs(&dir, &["int8", "int4"]) {
        Ok(rt) => rt,
        Err(e) => {
            println!("runtime unavailable ({e}) — skipping PJRT timings");
            return;
        }
    };
    let elems = rt.manifest().sample_elems();
    let exec_bench = Bencher::new().samples(10).warmup(2);
    for (config, batch) in [("int8", 1u64), ("int8", 8), ("int4", 1), ("int4", 8)] {
        let input = vec![0.25f32; batch as usize * elems];
        let name = format!("pjrt execute {config} b{batch}");
        let r = exec_bench.run(&name, || rt.infer(config, batch, &input).unwrap().len());
        println!(
            "{}   ({:.1} samples/s)",
            r.report_line(),
            batch as f64 * r.throughput()
        );
    }
}

/// Export the DSE-point timings as JSON at the repo root so CI can archive
/// the perf trajectory PR-over-PR.
fn write_bench_json(serial_s: f64, cold_s: f64, prewarmed_s: f64, warm_s: f64, threads: usize) {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_dse.json");
    let json = format!(
        "{{\n  \"bench\": \"perf_hotpath/dse_point\",\n  \"points\": 15,\n  \
         \"serial_uncached_mean_s\": {serial_s:.9},\n  \
         \"engine_cold_mean_s\": {cold_s:.9},\n  \
         \"engine_prewarmed_mean_s\": {prewarmed_s:.9},\n  \
         \"engine_warm_mean_s\": {warm_s:.9},\n  \
         \"speedup_cold\": {:.3},\n  \"speedup_prewarmed\": {:.3},\n  \
         \"speedup_warm\": {:.3},\n  \"threads\": {threads}\n}}\n",
        serial_s / cold_s,
        serial_s / prewarmed_s,
        serial_s / warm_s,
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
