//! Bench: the elastic-fleet layer — what the result store saves and what
//! the slice protocol costs over the wire.
//!
//! Two sections. The `store` section runs the same sweep twice through
//! `store::run_full_stored` against a fresh on-disk store: the first run
//! computes and persists every point, the second must replay all of them,
//! and the replay/compute wall-clock ratio is the store's payoff. The
//! `elastic` section drives `fleet::dispatch_elastic` over two live local
//! workers (static source, adaptive slice sizing on) and reports the
//! wall-clock and how the points split across the fleet. Both sections
//! assert byte-identity against `shard::run_full` — a bench that drifts
//! from the reference is measuring the wrong thing.
//!
//! Results are exported to `BENCH_fleet.json` at the repo root so CI can
//! track the store and slice-path trajectory PR-over-PR, alongside
//! `BENCH_dse.json` and `BENCH_serving.json`.

use std::path::Path;
use std::time::{Duration, Instant};

use bf_imna::sim::fleet::{dispatch_elastic, ElasticOpts, WorkerSource};
use bf_imna::sim::shard::{self, PrecisionGrid, SweepSpec};
use bf_imna::sim::store::{self, ResultStore};
use bf_imna::sim::transport::WorkerServer;
use bf_imna::sim::SweepEngine;
use bf_imna::util::benchkit::banner;
use bf_imna::util::json::Json;
use bf_imna::util::table::{fmt_eng, Table};

/// 2 technologies x 8 fixed widths = 16 DSE points: enough that the
/// store's replay speedup and the fleet's point split are visible, small
/// enough to keep the bench in CI-smoke territory.
fn bench_spec() -> SweepSpec {
    SweepSpec::single(
        "serve_cnn",
        vec!["lr".to_string()],
        vec!["sram".to_string(), "reram".to_string()],
        PrecisionGrid::Fixed { bits: vec![2, 3, 4, 5, 6, 7, 8, 9] },
    )
}

fn main() {
    let spec = bench_spec();
    let reference = shard::run_full(&spec, &SweepEngine::serial())
        .expect("reference sweep")
        .to_string();
    let n = spec.resolve().expect("resolve").num_points();

    let (cold_s, warm_s, replayed) = bench_store(&spec, &reference, n);
    let (elastic_s, per_worker) = bench_elastic(&spec, &reference, n);
    write_bench_json(n, cold_s, warm_s, replayed, elastic_s, &per_worker);
}

/// The `store` section: cold run computes + persists every point, warm
/// run replays every point from disk without touching the simulator.
fn bench_store(spec: &SweepSpec, reference: &str, n: usize) -> (f64, f64, usize) {
    banner("Result store (cold compute + persist vs warm replay)");
    let dir = std::env::temp_dir().join(format!("bf-imna-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let engine = SweepEngine::with_threads(2);
    let store = ResultStore::open(&dir).expect("open store");
    let t0 = Instant::now();
    let cold = store::run_full_stored(spec, &engine, &store).expect("cold stored sweep");
    let cold_s = t0.elapsed().as_secs_f64();
    assert_eq!(cold.doc.to_string(), reference, "cold stored sweep drifted from run_full");
    assert_eq!((cold.computed, cold.replayed), (n, 0), "cold run must compute everything");

    // A fresh engine for the warm run, so nothing is served from the
    // in-process plan cache — every replayed point comes off disk.
    let engine = SweepEngine::with_threads(2);
    let store = ResultStore::open(&dir).expect("reopen store");
    let t0 = Instant::now();
    let warm = store::run_full_stored(spec, &engine, &store).expect("warm stored sweep");
    let warm_s = t0.elapsed().as_secs_f64();
    assert_eq!(warm.doc.to_string(), reference, "replayed sweep drifted from run_full");
    assert_eq!((warm.computed, warm.replayed), (0, n), "warm run must replay everything");
    let _ = std::fs::remove_dir_all(&dir);

    let mut t = Table::new(vec!["run", "computed", "replayed", "wall"]);
    t.row(vec![
        "cold".to_string(),
        cold.computed.to_string(),
        cold.replayed.to_string(),
        format!("{} s", fmt_eng(cold_s, 3)),
    ]);
    t.row(vec![
        "warm".to_string(),
        warm.computed.to_string(),
        warm.replayed.to_string(),
        format!("{} s", fmt_eng(warm_s, 3)),
    ]);
    t.row(vec![
        "speedup".to_string(),
        String::new(),
        String::new(),
        format!("{:.1}x", cold_s / warm_s.max(1e-9)),
    ]);
    print!("{}", t.render());
    (cold_s, warm_s, warm.replayed)
}

/// The `elastic` section: the full sweep through `dispatch_elastic` over
/// two live local workers, with adaptive slice sizing in the loop.
fn bench_elastic(spec: &SweepSpec, reference: &str, n: usize) -> (f64, Vec<(String, usize)>) {
    banner("Elastic dispatch (2 local workers, adaptive slices)");
    let workers: Vec<WorkerServer> = (0..2)
        .map(|_| {
            WorkerServer::spawn("127.0.0.1:0", SweepEngine::with_threads(2)).expect("bind worker")
        })
        .collect();
    let source =
        WorkerSource::Static(workers.iter().map(|w| w.addr().to_string()).collect());
    let eopts = ElasticOpts {
        timeout: Duration::from_secs(60),
        poll: Duration::from_millis(20),
        max_slice: 4,
        ..ElasticOpts::default()
    };
    let t0 = Instant::now();
    let report = dispatch_elastic(spec, &source, &eopts).expect("elastic dispatch");
    let elastic_s = t0.elapsed().as_secs_f64();
    assert_eq!(report.doc.to_string(), reference, "elastic dispatch drifted from run_full");
    assert_eq!(report.computed_points, n, "no store in the loop: everything is computed");
    for w in workers {
        w.shutdown();
    }

    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["points".to_string(), n.to_string()]);
    t.row(vec!["wall".to_string(), format!("{} s", fmt_eng(elastic_s, 3))]);
    t.row(vec![
        "rate".to_string(),
        format!("{:.0} points/s", n as f64 / elastic_s.max(1e-9)),
    ]);
    for (addr, served) in &report.per_worker {
        t.row(vec![format!("served by {addr}"), format!("{served} point(s)")]);
    }
    t.row(vec![
        "retries / busy".to_string(),
        format!("{} / {}", report.retries, report.busy_retries),
    ]);
    print!("{}", t.render());
    (elastic_s, report.per_worker)
}

/// Export the fleet timings as canonical JSON at the repo root, the
/// `BENCH_dse.json` / `BENCH_serving.json` pattern.
fn write_bench_json(
    n: usize,
    cold_s: f64,
    warm_s: f64,
    replayed: usize,
    elastic_s: f64,
    per_worker: &[(String, usize)],
) {
    let doc = Json::obj([
        ("bench", Json::str("perf_fleet/store_and_elastic")),
        ("points", Json::num(n as f64)),
        (
            "store",
            Json::obj([
                ("cold_wall_s", Json::num(cold_s)),
                ("warm_wall_s", Json::num(warm_s)),
                ("replayed_points", Json::num(replayed as f64)),
                ("replay_speedup", Json::num(cold_s / warm_s.max(1e-9))),
            ]),
        ),
        (
            "elastic",
            Json::obj([
                ("workers", Json::num(per_worker.len() as f64)),
                ("wall_s", Json::num(elastic_s)),
                ("points_per_s", Json::num(n as f64 / elastic_s.max(1e-9))),
            ]),
        ),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_fleet.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
