//! Bench: regenerate Table I (AP runtime models) and validate the analytic
//! formulas against the functional bit-exact emulator (§IV's
//! "microbenchmark, consisting of random vectors/matrices, was used to
//! validate the proposed mathematical models").

use bf_imna::ap::{complexity::Function, emulator, runtime_model as rt, ApKind};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::rng::Rng;
use bf_imna::util::table::Table;

fn main() {
    banner("Table I — devised runtime of functions on APs (time units)");
    let (m, l, s, k, i, j, u) = (8u32, 256u64, 4u64, 16u64, 8u64, 64u64, 8u64);
    println!("M={m}, L={l}, S={s}, K={k}, matmul {i}x{j} by {j}x{u}\n");
    let mut t = Table::new(vec!["function", "1D AP", "2D AP (no seg)", "2D AP (seg)"]);
    let rows: Vec<(&str, Box<dyn Fn(ApKind) -> u64>)> = vec![
        ("Addition", Box::new(move |kd| rt::add(m, l, kd).events.time_units())),
        ("Multiplication", Box::new(move |kd| rt::multiply(m, m, l, kd).events.time_units())),
        ("Reduction", Box::new(move |kd| rt::reduce(m, l, kd).events.time_units())),
        (
            "Matrix-Matrix Mult.",
            Box::new(move |kd| rt::matmat(m, m, i, j, u, kd).events.time_units()),
        ),
        ("ReLU", Box::new(move |kd| rt::relu(m, l, kd).events.time_units())),
        ("Max Pooling", Box::new(move |kd| rt::maxpool(m, s, k, kd).events.time_units())),
        ("Average Pooling", Box::new(move |kd| rt::avgpool(m, s, k, kd).events.time_units())),
    ];
    for (name, f) in &rows {
        t.row(vec![
            name.to_string(),
            f(ApKind::OneD).to_string(),
            f(ApKind::TwoD).to_string(),
            f(ApKind::TwoDSeg).to_string(),
        ]);
    }
    print!("{}", t.render());

    banner("Emulator validation (bit-exact CAM vs analytic pass counts)");
    let mut rng = Rng::new(42);
    let mut t = Table::new(vec!["function", "M", "emulated", "analytic", "match"]);
    let mut all_ok = true;
    for m in [2usize, 4, 8] {
        let a = rng.vec_below(128, 1 << m);
        let b = rng.vec_below(128, 1 << m);
        let cases: Vec<(&str, u64, u64)> = vec![
            (
                "addition",
                emulator::emulate_add(&a, &b, m).1.events().compares,
                rt::add(m as u32, 256, ApKind::TwoD).events.compares,
            ),
            (
                "multiplication",
                emulator::emulate_multiply(&a, &b, m, m).1.events().compares,
                // +M: the emulator's explicit carry-flush passes.
                rt::multiply(m as u32, m as u32, 256, ApKind::TwoD).events.compares + m as u64,
            ),
            (
                "relu",
                {
                    let v: Vec<i64> = a.iter().map(|&x| x as i64 - (1 << (m - 1))).collect();
                    emulator::emulate_relu(&v, m).1.events().compares
                },
                rt::relu(m as u32, 128, ApKind::TwoD).events.compares,
            ),
        ];
        for (name, emu, model) in cases {
            let ok = emu == model;
            all_ok &= ok;
            t.row(vec![
                name.to_string(),
                m.to_string(),
                emu.to_string(),
                model.to_string(),
                if ok { "yes" } else { "NO" }.to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    assert!(all_ok, "emulator diverged from the analytic models");

    banner("Timing (model evaluation + emulator throughput)");
    let bench = Bencher::new().samples(20);
    let r = bench.run("analytic: all 7 functions x 3 kinds", || {
        let mut acc = 0u64;
        for f in Function::ALL {
            for kd in ApKind::ALL {
                acc = acc.wrapping_add(f.dominant_term(kd, 8, 256, 4, 16, 8, 8) as u64);
            }
        }
        acc
    });
    println!("{}", r.report_line());
    let a = rng.vec_below(256, 256);
    let b = rng.vec_below(256, 256);
    let r = bench.run("emulator: 8b x 8b multiply over 256 words", || {
        emulator::emulate_multiply(&a, &b, 8, 8).0.len()
    });
    println!("{}", r.report_line());
}
