//! Bench: regenerate Table I (AP runtime models) and validate the analytic
//! formulas against the functional bit-exact emulator (§IV's
//! "microbenchmark, consisting of random vectors/matrices, was used to
//! validate the proposed mathematical models").

use bf_imna::ap::{complexity::Function, emulator, runtime_model as rt, ApKind};
use bf_imna::sim::{artifacts, SweepEngine};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::rng::Rng;
use bf_imna::util::table::Table;

fn main() {
    banner("Table I — via the artifact catalog (devised models + emulator validation)");
    // The Table I artifact renders the devised runtime models and the
    // bit-exact emulator validation; it *errors* (failing this bench) if
    // the emulator diverges from the analytic pass counts.
    let table1 = artifacts::by_name("table1").expect("table1 in catalog");
    print!("{}", table1.run_and_render(&SweepEngine::serial(), false).expect("table1 validates"));

    banner("Extended emulator validation (seed 42, + ReLU, larger vectors)");
    let mut rng = Rng::new(42);
    let mut t = Table::new(vec!["function", "M", "emulated", "analytic", "match"]);
    let mut all_ok = true;
    for m in [2usize, 4, 8] {
        let a = rng.vec_below(128, 1 << m);
        let b = rng.vec_below(128, 1 << m);
        let cases: Vec<(&str, u64, u64)> = vec![
            (
                "addition",
                emulator::emulate_add(&a, &b, m).1.events().compares,
                rt::add(m as u32, 256, ApKind::TwoD).events.compares,
            ),
            (
                "multiplication",
                emulator::emulate_multiply(&a, &b, m, m).1.events().compares,
                // +M: the emulator's explicit carry-flush passes.
                rt::multiply(m as u32, m as u32, 256, ApKind::TwoD).events.compares + m as u64,
            ),
            (
                "relu",
                {
                    let v: Vec<i64> = a.iter().map(|&x| x as i64 - (1 << (m - 1))).collect();
                    emulator::emulate_relu(&v, m).1.events().compares
                },
                rt::relu(m as u32, 128, ApKind::TwoD).events.compares,
            ),
        ];
        for (name, emu, model) in cases {
            let ok = emu == model;
            all_ok &= ok;
            t.row(vec![
                name.to_string(),
                m.to_string(),
                emu.to_string(),
                model.to_string(),
                if ok { "yes" } else { "NO" }.to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    assert!(all_ok, "emulator diverged from the analytic models");

    banner("Timing (model evaluation + emulator throughput)");
    let bench = Bencher::new().samples(20);
    let r = bench.run("analytic: all 7 functions x 3 kinds", || {
        let mut acc = 0u64;
        for f in Function::ALL {
            for kd in ApKind::ALL {
                acc = acc.wrapping_add(f.dominant_term(kd, 8, 256, 4, 16, 8, 8) as u64);
            }
        }
        acc
    });
    println!("{}", r.report_line());
    let a = rng.vec_below(256, 256);
    let b = rng.vec_below(256, 256);
    let r = bench.run("emulator: 8b x 8b multiply over 256 words", || {
        emulator::emulate_multiply(&a, &b, 8, 8).0.len()
    });
    println!("{}", r.report_line());
}
