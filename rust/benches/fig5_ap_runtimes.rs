//! Bench: regenerate Fig. 5 — AP runtime of (a) reduction, (b) matrix-
//! matrix multiplication, (c) average pooling, (d) max pooling,
//! (e) addition, (f) multiplication, (g) ReLU, as a function of the
//! precision M for the three AP organizations.

use bf_imna::ap::{runtime_model as rt, ApKind};
use bf_imna::sim::{artifacts, SweepEngine};
use bf_imna::util::benchkit::{banner, Bencher};

fn main() {
    // The seven series tables come from the `fig5` catalog artifact — the
    // same renderer `bf-imna render --artifact fig5` uses.
    let fig5 = artifacts::by_name("fig5").expect("fig5 in catalog");
    print!("{}", fig5.run_and_render(&SweepEngine::serial(), false).expect("fig5 renders"));

    let l = 1024u64; // words for element-wise / reduction series
    let (i, j, u) = (16u64, 128u64, 16u64); // matmul shape (for the timing loop)
    let (s, k) = (4u64, 64u64); // pooling window + op count

    // Shape checks the paper's Fig. 5 narrative depends on.
    banner("Shape checks");
    let seg_speedup =
        rt::reduce(8, l, ApKind::TwoD).events.time_units() as f64
            / rt::reduce(8, l, ApKind::TwoDSeg).events.time_units() as f64;
    println!("reduction: 2D-seg speedup over 2D at L=1024: {seg_speedup:.1}x (tree vs linear)");
    let mul_quad = rt::multiply(16, 16, l, ApKind::TwoD).events.time_units() as f64
        / rt::multiply(8, 8, l, ApKind::TwoD).events.time_units() as f64;
    println!("multiplication: 16b/8b runtime ratio: {mul_quad:.2}x (expected ~4x, O(M^2))");
    let relu_lin = rt::relu(16, l, ApKind::TwoD).events.time_units() as f64
        / rt::relu(8, l, ApKind::TwoD).events.time_units() as f64;
    println!("relu: 16b/8b runtime ratio: {relu_lin:.2}x (expected ~2x, O(M))");
    assert!(mul_quad > 3.5 && mul_quad < 4.5);
    assert!(relu_lin > 1.8 && relu_lin < 2.2);

    banner("Timing");
    let bench = Bencher::new().samples(20);
    let r = bench.run("full Fig. 5 grid (7 fns x 8 widths x 3 kinds)", || {
        let mut acc = 0u64;
        for m in [2u32, 4, 6, 8, 10, 12, 14, 16] {
            for kd in ApKind::ALL {
                acc = acc.wrapping_add(rt::reduce(m, l, kd).events.time_units());
                acc = acc.wrapping_add(rt::matmat(m, m, i, j, u, kd).events.time_units());
                acc = acc.wrapping_add(rt::avgpool(m, s, k, kd).events.time_units());
                acc = acc.wrapping_add(rt::maxpool(m, s, k, kd).events.time_units());
                acc = acc.wrapping_add(rt::add(m, l, kd).events.time_units());
                acc = acc.wrapping_add(rt::multiply(m, m, l, kd).events.time_units());
                acc = acc.wrapping_add(rt::relu(m, l, kd).events.time_units());
            }
        }
        acc
    });
    println!("{}", r.report_line());
}
