//! Bench: ablations over the design choices ARCHITECTURE.md calls out —
//! the mapper's u/i split selection, the IR mesh-bandwidth scaling rule,
//! and the coordinator's batch window (compiled batch sizes).
//!
//! Each section shows what the headline results would look like with the
//! choice disabled, justifying why it is in the design.

use bf_imna::arch::ChipConfig;
use bf_imna::mapper;
use bf_imna::model::zoo;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{simulate, SimParams, SweepEngine, SweepPoint};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::table::{fmt_eng, fmt_ratio, Table};

fn main() {
    // ------------------------------------------------------------------
    banner("Ablation 1 — mapper split selection (u-split vs i-split)");
    // The mapper picks min(u-split, i-split) for the critical-path mesh
    // traffic. Show per-layer what each split would cost on AlexNet (whose
    // FC layers are the i-split's reason to exist).
    let net = zoo::alexnet();
    let chip = ChipConfig::lr();
    let cfg = PrecisionConfig::fixed(8, net.weight_layers());
    let plan = mapper::map_network(&net, &chip, &cfg);
    let mut t = Table::new(vec!["layer", "critical mesh bits", "total mesh bits", "ratio"]);
    for l in plan.layers.iter().filter(|l| l.kind == mapper::WorkKind::Gemm) {
        t.row(vec![
            l.name.to_string(),
            l.mesh_bits_critical.to_string(),
            l.mesh_bits.to_string(),
            format!("{:.3}", l.mesh_bits_critical as f64 / l.mesh_bits as f64),
        ]);
    }
    print!("{}", t.render());
    // The fc layers must ride the i-split: their critical traffic has to be
    // far below one full weight copy (i*j*8 bits).
    let fc6 = plan.layers.iter().find(|l| &*l.name == "fc6").unwrap();
    let full_copy = 4096u64 * 9216 * 8;
    println!(
        "\nfc6 critical {} bits vs one full weight copy {} bits ({}): the i-split\n\
         keeps Table VII's normalized latency at ~1.00 (serialized copies gave 1.55).",
        fc6.mesh_bits_critical,
        full_copy,
        fmt_ratio(full_copy as f64 / fc6.mesh_bits_critical as f64)
    );
    assert!(fc6.mesh_bits_critical < full_copy / 4);

    // ------------------------------------------------------------------
    banner("Ablation 2 — IR mesh bandwidth scaling (1 link per 64 CAPs)");
    // PR 1 could only express this ablation in-process via the
    // `SweepPoint::on_chip` override; it is now the `ablation-ir-mesh`
    // catalog artifact — the chip geometries are explicit coordinates of
    // a serializable SweepSpec, so the same table renders from sharded or
    // dispatched documents byte-identically.
    let params = SimParams::lr_sram();
    let engine = SweepEngine::new();
    let ablation = bf_imna::sim::artifacts::by_name("ablation-ir-mesh").expect("in catalog");
    print!("{}", ablation.run_and_render(&engine, false).expect("ablation renders"));

    // ------------------------------------------------------------------
    banner("Ablation 3 — compiled batch sizes (batcher amortization)");
    // With inter-batch pipelining, batching amortizes per-layer fill; show
    // the simulator's per-sample cost by batch via the pipeline model.
    let vgg = zoo::vgg16();
    let cfg8 = PrecisionConfig::fixed(8, vgg.weight_layers());
    let r = simulate(&vgg, &cfg8, &params);
    let mut t = Table::new(vec!["mode", "per-inference (s)", "throughput (GOPS)"]);
    t.row(vec![
        "batch-1 (no pipelining)".to_string(),
        fmt_eng(r.latency_s(), 3),
        format!("{:.0}", r.gops()),
    ]);
    t.row(vec![
        "pipelined steady state".to_string(),
        fmt_eng(r.pipeline_interval_s(), 3),
        format!("{:.0}", r.pipelined_gops()),
    ]);
    print!("{}", t.render());
    println!(
        "pipeline speedup {} — why the coordinator batches (and why §V-B says\n\
         'BF-IMNA readily enables inter-batch pipelining').",
        fmt_ratio(r.pipeline_speedup())
    );

    // ------------------------------------------------------------------
    banner("Ablation 4 — 2D AP without segmentation (the paper's choice)");
    // The paper picks the unsegmented 2D AP "to favor programmability".
    // Quantify what segmentation would buy on the dominant op (reduction)
    // at CAP scale.
    use bf_imna::ap::{runtime_model as rt, ApKind};
    let mut t = Table::new(vec!["L (words)", "2D (ours)", "2D seg", "seg speedup"]);
    for l in [64u64, 512, 4800] {
        let a = rt::reduce(8, l, ApKind::TwoD).events.time_units();
        let b = rt::reduce(8, l, ApKind::TwoDSeg).events.time_units();
        t.row(vec![
            l.to_string(),
            a.to_string(),
            b.to_string(),
            fmt_ratio(a as f64 / b as f64),
        ]);
    }
    print!("{}", t.render());
    println!(
        "segmentation would cut the reduce bottleneck ~20-40x at CAP scale, at the\n\
         cost of L/4 duplicated carry rows + fixed segment boundaries — the paper\n\
         (and this repo) trades that for programmability; Fig. 8b shows where the\n\
         time goes as a result."
    );

    // ------------------------------------------------------------------
    banner("Extension — fine-grained (per-channel) precision scheduling");
    // Intro granularity taxonomy: bit-serial hardware gets fine-grained
    // *energy* savings for free; latency needs width-sorted packing.
    use bf_imna::precision::granularity as gran;
    use bf_imna::util::rng::Rng;
    let mut rng = Rng::new(5);
    let mut t = Table::new(vec![
        "channel widths",
        "lockstep passes",
        "sorted passes",
        "ideal",
        "sorted efficiency",
    ]);
    let lanes = 64;
    for (label, cfg) in [
        ("uniform 8b x 512", gran::ChannelConfig::uniform(8, 8, 512)),
        ("half 8b / half 4b", {
            let mut w = vec![8u32; 256];
            w.extend(vec![4u32; 256]);
            gran::ChannelConfig { a_bits: 8, w_bits: w }
        }),
        ("random 2..8b x 512", gran::ChannelConfig::random(8, 2, 8, 512, &mut rng)),
    ] {
        let lock = gran::lockstep_passes(&cfg, lanes);
        let sorted = gran::sorted_packed_passes(&cfg, lanes);
        t.row(vec![
            label.to_string(),
            lock.to_string(),
            sorted.to_string(),
            format!("{:.0}", gran::ideal_passes(&cfg, lanes)),
            format!("{:.0}%", 100.0 * gran::schedule_efficiency(&cfg, lanes, sorted)),
        ]);
    }
    print!("{}", t.render());
    println!("width-sorted packing recovers (nearly) the ideal fine-grained latency;\nnaive lockstep wastes the fine granularity entirely (energy saves either way).");

    // ------------------------------------------------------------------
    banner("Extension — LLM workload (§V-D 'Supported Workloads')");
    use bf_imna::sim::breakdown;
    let llm = zoo::llm_block(128, 768);
    let cfg8 = PrecisionConfig::fixed(8, llm.weight_layers());
    let r = simulate(&llm, &cfg8, &params);
    let shares = breakdown::energy_by_kind(&r);
    let mut t = Table::new(vec!["category", "energy share"]);
    for s in &shares {
        t.row(vec![s.label.clone(), format!("{:.1}%", 100.0 * s.fraction)]);
    }
    print!("{}", t.render());
    println!(
        "transformer block (seq 128, d 768): {:.1} G MACs, all in GEMMs; energy is\n\
         matmul-dominated exactly as §V-D warns — the motivation for the paper's\n\
         future-work matmul engines.",
        llm.total_macs() as f64 / 1e9
    );

    // ------------------------------------------------------------------
    banner("Ablation 5 — sweep engine: what the cache and the fan-out each buy");
    // The same 15-point DSE batch as benches/perf_hotpath (shared via
    // dse::perf_dse_batch, so the two benches cannot drift apart), run
    // four ways to attribute the speedup: serial+uncached (seed
    // behaviour), serial with the plan cache, parallel cold, parallel warm.
    let (nets, dse_cfgs) = bf_imna::sim::dse::perf_dse_batch();
    let points: Vec<SweepPoint> =
        dse_cfgs.iter().map(|(i, c)| SweepPoint::new(&nets[*i], c, &params)).collect();
    let bench = Bencher::new().samples(10).warmup(2);
    let baseline = bench.run("serial uncached", || {
        dse_cfgs.iter().map(|(i, c)| simulate(&nets[*i], c, &params).energy_j()).sum::<f64>()
    });
    let serial_engine = SweepEngine::serial();
    let serial_cached = bench.run("serial + plan cache", || {
        serial_engine.run(&points).iter().map(|r| r.energy_j()).sum::<f64>()
    });
    let cold_parallel = bench.run("parallel, cold cache", || {
        SweepEngine::new().run(&points).iter().map(|r| r.energy_j()).sum::<f64>()
    });
    let warm_engine = SweepEngine::new();
    let warm_parallel = bench.run("parallel, warm cache", || {
        warm_engine.run(&points).iter().map(|r| r.energy_j()).sum::<f64>()
    });
    let base_mean = baseline.summary().mean;
    let mut t = Table::new(vec!["variant", "mean / DSE point", "speedup"]);
    for r in [&baseline, &serial_cached, &cold_parallel, &warm_parallel] {
        let s = r.summary();
        t.row(vec![
            r.name.clone(),
            bf_imna::util::benchkit::fmt_duration(s.mean),
            fmt_ratio(base_mean / s.mean),
        ]);
    }
    print!("{}", t.render());
    println!(
        "({} worker threads; both ingredients are needed — the cache removes the\n\
         O(configs x layers) mapping work, the fan-out spreads the cost conversion)",
        warm_engine.threads()
    );
}
