//! Bench: regenerate Fig. 7 — (a) energy/inference, (b) latency/inference
//! and (c) GOPS/W/mm² vs average precision for AlexNet / VGG16 / ResNet50
//! on the IR and LR configurations.

use bf_imna::arch::HwConfig;
use bf_imna::model::zoo;
use bf_imna::sim::{artifacts, dse, shard, SweepEngine};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::json::Json;

fn main() {
    banner("Fig. 7 — DSE vs average precision (SRAM, mean of sweep combos)");
    // One engine for the whole figure: every series fans its combination
    // points across the worker pool, and the plan cache carries over from
    // series to series (same nets, same 7 candidate widths per layer).
    // The figure itself is the `fig7` catalog artifact — one multi-network
    // SweepSpec (3 nets x {LR, IR}) run and rendered through the same path
    // a sharded or dispatched run would take.
    let engine = SweepEngine::new();
    let fig7 = artifacts::by_name("fig7").expect("fig7 in catalog");
    print!("{}", fig7.run_and_render(&engine, false).expect("fig7 renders"));
    // Paper shape assertions per series, from the same engine (cache warm).
    let nets = zoo::imagenet_benchmarks();
    for hw in [HwConfig::Lr, HwConfig::Ir] {
        for net in &nets {
            let series = dse::fig7_series_with(&engine, net, hw, 7);
            assert!(
                series.windows(2).all(|w| w[1].energy_j > w[0].energy_j),
                "{} {}: energy must increase with precision",
                net.name,
                hw.label()
            );
            let lat_ratio = series.last().unwrap().latency_s / series[0].latency_s;
            assert!(
                lat_ratio < 1.6,
                "{} {}: latency should be nearly flat, got {lat_ratio:.2}x",
                net.name,
                hw.label()
            );
        }
    }

    banner("Cross-checks (paper §V-A numbers)");
    // ResNet50 LR energy growth 2 -> 8 bits (paper: 0.009 -> 0.095 J, 10.5x).
    let resnet = zoo::resnet50();
    let series = dse::fig7_series_with(&engine, &resnet, HwConfig::Lr, 7);
    let growth = series.last().unwrap().energy_j / series[0].energy_j;
    println!(
        "ResNet50 LR energy 2b -> 8b: {:.4} J -> {:.4} J ({growth:.1}x; paper 0.009 -> 0.095, 10.5x)",
        series[0].energy_j,
        series.last().unwrap().energy_j
    );
    // Energy ordering VGG16 > ResNet50 > AlexNet at every precision.
    let vgg = dse::fig7_series_with(&engine, &zoo::vgg16(), HwConfig::Lr, 7);
    let alex = dse::fig7_series_with(&engine, &zoo::alexnet(), HwConfig::Lr, 7);
    for ((v, r), a) in vgg.iter().zip(&series).zip(&alex) {
        assert!(
            v.energy_j > r.energy_j && r.energy_j > a.energy_j,
            "energy ordering broke at avg bits {}",
            v.avg_bits
        );
    }
    println!("energy ordering VGG16 > ResNet50 > AlexNet holds at every avg precision.");
    // LR vs IR energy-area efficiency gap.
    let ir = dse::fig7_series_with(&engine, &resnet, HwConfig::Ir, 7);
    let gap = series[3].gops_per_w_mm2 / ir[3].gops_per_w_mm2;
    println!("ResNet50 GOPS/W/mm2 LR/IR gap at 5 avg bits: {gap:.0}x (paper: up to 4 orders).");

    banner("Timing");
    let bench = Bencher::new().samples(3).warmup(1);
    let alexnet = zoo::alexnet();
    let r = bench.run("fig7 series, fresh engine (AlexNet LR, 7x5 combos)", || {
        dse::fig7_series(&alexnet, HwConfig::Lr, 7).len()
    });
    println!("{}", r.report_line());
    let r = bench.run("fig7 series, shared warm engine (AlexNet LR)", || {
        dse::fig7_series_with(&engine, &alexnet, HwConfig::Lr, 7).len()
    });
    println!("{}", r.report_line());
    let stats = engine.cache_stats();
    println!(
        "shared engine after full figure: {} plan entries, {:.1}% hit rate, {} threads",
        stats.entries,
        100.0 * stats.hit_rate(),
        engine.threads()
    );

    banner("Sweep service: spec -> shards -> merge (sim::shard)");
    // The same AlexNet LR figure as a serializable spec, run as 4
    // independent shard "workers" (fresh engine each, as separate
    // processes would be) and reassembled — the merge must be
    // byte-identical to the single-process document.
    let spec = dse::fig7_spec(&alexnet, HwConfig::Lr, 7);
    let full = shard::run_full(&spec, &SweepEngine::new()).unwrap().to_string();
    const SHARDS: usize = 4;
    let docs: Vec<Json> = (0..SHARDS)
        .map(|k| shard::run_shard(&spec, SHARDS, k, &SweepEngine::new()).unwrap().to_json())
        .collect();
    let merged = shard::merge(&docs).unwrap().to_string();
    assert_eq!(merged, full, "sharded merge diverged from the single-process sweep");
    println!(
        "{SHARDS}-shard merge is byte-identical to the single-process sweep ({} points, {} bytes).",
        spec.resolve().unwrap().num_points(),
        full.len()
    );

    // Prewarm ablation: one coordinator prewarms a cache, snapshots it,
    // and a "worker" absorbs the snapshot — its run never maps cold.
    let resolved = spec.resolve().unwrap();
    let points = resolved.points(0..resolved.num_points());
    let donor = SweepEngine::new();
    donor.prewarm(&points);
    let snapshot = donor.cache().snapshot();
    let worker = SweepEngine::new();
    worker.cache().absorb(&snapshot);
    let r = bench.run("fig7 spec sweep, snapshot-prewarmed engine (AlexNet LR)", || {
        worker.run(&points).len()
    });
    println!("{}", r.report_line());
    let r = bench.run("fig7 spec sweep, cold engine per run (AlexNet LR)", || {
        SweepEngine::new().run(&points).len()
    });
    println!("{}", r.report_line());
    println!(
        "snapshot: {} plans; worker misses after absorb+runs: {}",
        snapshot.len(),
        worker.cache_stats().misses
    );
    assert_eq!(worker.cache_stats().misses, 0, "snapshot-prewarmed worker mapped cold");
}
