//! Bench: regenerate Table VII — bit-fluid mixed-precision inference of
//! ResNet18 on BF-IMNA using HAWQ-V3's per-layer configurations under
//! three latency budgets, vs fixed INT4 / INT8.

use bf_imna::model::zoo;
use bf_imna::precision::hawq::{self, LatencyBudget};
use bf_imna::sim::{artifacts, shard, simulate, SimParams, SweepEngine};
use bf_imna::util::benchkit::{banner, Bencher};

fn main() {
    banner("Table VII — bit-fluid BF-IMNA, ResNet18 + HAWQ-V3 configs (LR, SRAM)");
    // The table comes from the `table7` catalog artifact: the five HAWQ
    // configurations are an *explicit precision grid* in a serializable
    // SweepSpec, so the same table renders from sharded or dispatched
    // documents byte-identically.
    let engine = SweepEngine::new();
    let table7 = artifacts::by_name("table7").expect("table7 in catalog");
    let spec = table7.spec();
    let resolved = spec.resolve().expect("table7 spec resolves");
    let result = shard::run_shard(&spec, 1, 0, &engine).expect("table7 sweep runs");
    print!(
        "{}",
        table7.render_records(&spec, &resolved, &result.points).expect("table7 renders")
    );

    // Shape assertions straight off the records the renderer used.
    let rec_for = |budget: LatencyBudget| {
        let name = format!("hawq-{}", hawq::row(budget).budget.label());
        result
            .points
            .iter()
            .find(|r| r.cfg == name)
            .unwrap_or_else(|| panic!("no record for {name}"))
    };
    let int8 = rec_for(LatencyBudget::FixedInt8);
    for row in hawq::table_vii_rows() {
        let rec = rec_for(row.budget);
        // The normalized-energy ranking must match the paper even where
        // the absolute factor differs.
        assert!(
            int8.energy_j / rec.energy_j >= 0.99,
            "{}: worse than INT8?",
            row.budget.label()
        );
    }
    // Paper EDP ordering: INT4 < Low < Medium < High < INT8.
    let edp = |b: LatencyBudget| rec_for(b).edp_js;
    assert!(edp(LatencyBudget::FixedInt4) < edp(LatencyBudget::Low));
    assert!(edp(LatencyBudget::Low) < edp(LatencyBudget::Medium));
    assert!(edp(LatencyBudget::Medium) < edp(LatencyBudget::High));
    assert!(edp(LatencyBudget::High) < edp(LatencyBudget::FixedInt8));
    println!("\nEDP ordering INT4 < Low < Medium < High < INT8 reproduces the paper.");
    println!("Accuracy column is HAWQ-V3's published ImageNet top-1 (the paper adopts");
    println!("it verbatim; our simulator models hardware cost, not accuracy — the live");
    println!("accuracy/EDP trade-off runs in examples/e2e_serving.rs).");

    banner("Timing");
    let net = zoo::resnet18();
    let params = SimParams::lr_sram();
    let bench = Bencher::new().samples(10);
    let r = bench.run("table7 (5 configs x ResNet18 LR sim)", || {
        hawq::table_vii_rows()
            .iter()
            .map(|row| {
                let cfg = hawq::config_for_resnet18(&net, row);
                simulate(&net, &cfg, &params).edp_js()
            })
            .sum::<f64>()
    });
    println!("{}", r.report_line());
}
