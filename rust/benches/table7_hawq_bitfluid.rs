//! Bench: regenerate Table VII — bit-fluid mixed-precision inference of
//! ResNet18 on BF-IMNA using HAWQ-V3's per-layer configurations under
//! three latency budgets, vs fixed INT4 / INT8.

use bf_imna::model::zoo;
use bf_imna::precision::hawq::{self, LatencyBudget};
use bf_imna::sim::{simulate, SimParams};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::table::{fmt_eng, Table};

fn main() {
    banner("Table VII — bit-fluid BF-IMNA, ResNet18 + HAWQ-V3 configs (LR, SRAM)");
    let net = zoo::resnet18();
    let params = SimParams::lr_sram();
    let int8 = {
        let cfg = hawq::config_for_resnet18(&net, &hawq::row(LatencyBudget::FixedInt8));
        simulate(&net, &cfg, &params)
    };

    let mut t = Table::new(vec![
        "constraint",
        "avg bits",
        "norm E ours",
        "norm E paper",
        "norm L ours",
        "norm L paper",
        "EDP ours (J.s)",
        "size MB",
        "top-1 % (paper)",
    ]);
    let mut edps = Vec::new();
    for row in hawq::table_vii_rows() {
        let cfg = hawq::config_for_resnet18(&net, &row);
        let r = simulate(&net, &cfg, &params);
        let norm_e = int8.energy_j() / r.energy_j();
        let norm_l = int8.latency_s() / r.latency_s();
        edps.push((row.budget, r.edp_js()));
        t.row(vec![
            row.budget.label().to_string(),
            format!("{:.2}", row.paper_avg_bits),
            format!("{:.2}", norm_e),
            format!("{:.2}", row.paper_norm_energy),
            format!("{:.3}", norm_l),
            format!("{:.3}", row.paper_norm_latency),
            fmt_eng(r.edp_js(), 3),
            format!("{:.1}", cfg.model_size_bytes(&net) as f64 / 1e6),
            format!("{:.2}", row.paper_top1_acc),
        ]);
        // Shape: the normalized-energy ranking must match the paper even
        // where the absolute factor differs.
        assert!(norm_e >= 0.99, "{}: worse than INT8?", row.budget.label());
    }
    print!("{}", t.render());

    // Paper EDP ordering: INT4 < Low < Medium < High < INT8.
    let edp = |b: LatencyBudget| edps.iter().find(|(x, _)| *x == b).unwrap().1;
    assert!(edp(LatencyBudget::FixedInt4) < edp(LatencyBudget::Low));
    assert!(edp(LatencyBudget::Low) < edp(LatencyBudget::Medium));
    assert!(edp(LatencyBudget::Medium) < edp(LatencyBudget::High));
    assert!(edp(LatencyBudget::High) < edp(LatencyBudget::FixedInt8));
    println!("\nEDP ordering INT4 < Low < Medium < High < INT8 reproduces the paper.");
    println!("Accuracy column is HAWQ-V3's published ImageNet top-1 (the paper adopts");
    println!("it verbatim; our simulator models hardware cost, not accuracy — the live");
    println!("accuracy/EDP trade-off runs in examples/e2e_serving.rs).");

    banner("Timing");
    let bench = Bencher::new().samples(10);
    let r = bench.run("table7 (5 configs x ResNet18 LR sim)", || {
        hawq::table_vii_rows()
            .iter()
            .map(|row| {
                let cfg = hawq::config_for_resnet18(&net, row);
                simulate(&net, &cfg, &params).edp_js()
            })
            .sum::<f64>()
    });
    println!("{}", r.report_line());
}
