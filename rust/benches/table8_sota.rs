//! Bench: regenerate Table VIII + Fig. 9 — BF-IMNA peak rows (modeled from
//! the AP cost model) against the published SOTA accelerator records, with
//! the §V-C headline comparisons.

use bf_imna::ap::tech::Tech;
use bf_imna::baselines::{peak, record, sota_records, PAPER_BF_ROWS};
use bf_imna::sim::{artifacts, SweepEngine};
use bf_imna::util::benchkit::{banner, Bencher};
use bf_imna::util::table::{fmt_eng, fmt_ratio, Table};

fn main() {
    banner("Table VIII — performance comparison with SOTA frameworks");
    // The table + §V-C headlines come from the `table8` catalog artifact.
    let table8 = artifacts::by_name("table8").expect("table8 in catalog");
    print!("{}", table8.run_and_render(&SweepEngine::serial(), false).expect("table8 renders"));

    banner("Model vs published BF-IMNA rows");
    let mut t = Table::new(vec!["bits", "GOPS model", "GOPS paper", "err", "GOPS/W model", "GOPS/W paper", "err"]);
    for (modeled, paper) in peak::bf_imna_rows().iter().zip(PAPER_BF_ROWS.iter()) {
        let (eg, ee) = peak::relative_error(modeled, paper);
        t.row(vec![
            modeled.precision.to_string(),
            fmt_eng(modeled.gops, 4),
            fmt_eng(paper.gops, 4),
            format!("{:+.0}%", 100.0 * eg),
            fmt_eng(modeled.gops_per_w, 4),
            fmt_eng(paper.gops_per_w, 4),
            format!("{:+.0}%", 100.0 * ee),
        ]);
    }
    print!("{}", t.render());

    banner("§V-C headline comparisons");
    let bf16 = peak::peak_row(16, &Tech::sram());
    let bf8 = peak::peak_row(8, &Tech::sram());
    let isaac = record("ISAAC");
    let pipe = record("PipeLayer");
    let puma = record("PUMA");
    let h100 = record("H100 GPU");
    println!(
        "16b vs ISAAC:     {} throughput (paper 1.02x), {} lower efficiency (paper 3.66x)",
        fmt_ratio(bf16.gops / isaac.gops),
        fmt_ratio(isaac.gops_per_w / bf16.gops_per_w)
    );
    println!(
        "16b vs PipeLayer: {} lower throughput (paper 2.95x), {} higher efficiency (paper 1.19x)",
        fmt_ratio(pipe.gops / bf16.gops),
        fmt_ratio(bf16.gops_per_w / pipe.gops_per_w)
    );
    println!(
        "16b vs PUMA:      {} lower throughput (paper 1.26x), {} lower efficiency (paper 4.95x)",
        fmt_ratio(puma.gops / bf16.gops),
        fmt_ratio(puma.gops_per_w / bf16.gops_per_w)
    );
    let h100_eamm = h100.gops_per_w / h100.area_mm2.unwrap();
    println!(
        "8b vs H100:       {} better GOPS/W/mm2 (paper ~2.7x: 8 vs 3)",
        fmt_ratio(bf8.gops_per_w_mm2() / h100_eamm)
    );
    assert!(bf8.gops > isaac.gops && bf8.gops_per_w > isaac.gops_per_w);
    assert!(bf8.gops > pipe.gops && bf8.gops_per_w > pipe.gops_per_w);

    banner("Fig. 9 — GOPS vs GOPS/W scatter (all frameworks)");
    let mut t = Table::new(vec!["framework", "GOPS", "GOPS/W"]);
    let mut points: Vec<(String, f64, f64)> = sota_records()
        .iter()
        .map(|r| (r.name.to_string(), r.gops, r.gops_per_w))
        .collect();
    for row in peak::bf_imna_rows() {
        points.push((format!("BF-IMNA_{}b", row.precision), row.gops, row.gops_per_w));
    }
    points.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (name, gops, gpw) in points {
        t.row(vec![name, fmt_eng(gops, 4), fmt_eng(gpw, 4)]);
    }
    print!("{}", t.render());

    banner("Timing");
    let bench = Bencher::new().samples(30);
    let r = bench.run("peak model (3 rows)", || peak::bf_imna_rows().len());
    println!("{}", r.report_line());
}
