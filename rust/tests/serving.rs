//! Default-build serving tests: the coordinator end to end on the sim
//! backend — no artifacts, no `--features pjrt` — plus the HTTP serving
//! front end over real sockets.
//!
//! What the serving API redesign must guarantee:
//! * the coordinator runs (and replies) in the default build;
//! * every response carries a met-or-flagged deadline verdict consistent
//!   with its own latency and target;
//! * config choices are deterministic given a fixed request trace (the
//!   sim backend feeds the controller modeled, not wall-clock, latencies);
//! * `POST /infer` / `GET /healthz` / `GET /stats` round-trip over TCP.
//!
//! The keep-alive additions: pooled clients ride one connection across
//! exchanges (and transparently reconnect when the server idle-times the
//! socket out or the per-connection request cap closes it), and the
//! multi-sample `POST /infer` returns logits byte-identical to the same
//! inputs sent one at a time.

use std::thread;
use std::time::Duration;

use bf_imna::coordinator::loadgen::{self, LoadgenOpts, WorkloadSpec};
use bf_imna::coordinator::server::{self as serving, BatchInferRequest, InferRequest, ServeOpts};
use bf_imna::coordinator::{
    Budget, BudgetSpec, Coordinator, CoordinatorConfig, Priority, RequestSpec, ServingServer,
};
use bf_imna::runtime::SimBackend;
use bf_imna::sim::transport::{http_request, ConnPool};
use bf_imna::util::json::Json;
use bf_imna::util::rng::Rng;

fn start(calibrate: bool) -> Coordinator {
    Coordinator::start_sim(
        CoordinatorConfig {
            calibrate,
            batch_window: Duration::from_millis(1),
            ..CoordinatorConfig::default()
        },
        0.0,
    )
    .expect("sim-backed coordinator starts in the default build")
}

fn sample(elems: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..elems).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect()
}

#[test]
fn coordinator_serves_in_the_default_build() {
    let c = start(true);
    assert_eq!(c.configs(), ["int8", "mixed", "int4"], "descending-quality ladder");
    let r = c.infer(sample(c.sample_elems(), 1), Budget::High).expect("infer");
    assert_eq!(r.logits.len(), c.num_classes());
    assert!(r.logits.iter().all(|x| x.is_finite()));
    assert!(r.latency_s > 0.0);
    assert!(r.target_s > 0.0);
    let m = c.metrics();
    assert_eq!(m.completed, 1);
    assert_eq!(m.failed, 0);
    assert_eq!(m.deadline_met + m.deadline_missed, 1);
}

#[test]
fn deadlines_walk_the_ladder_and_are_flagged() {
    let c = start(false);
    let elems = c.sample_elems();

    // A generous deadline keeps full quality and is met.
    let r = c
        .request(sample(elems, 2))
        .deadline(Duration::from_secs(10))
        .submit()
        .expect("submit")
        .wait()
        .expect("response");
    assert_eq!(r.config, "int8", "a 10s deadline affords the ladder top");
    assert!(r.met_deadline, "a 10s deadline must be met (latency {})", r.latency_s);
    assert!((r.target_s - 10.0).abs() < 1e-9);

    // An impossible deadline degrades to the cheapest config and is
    // flagged as missed — never dropped.
    let r = c
        .request(sample(elems, 3))
        .deadline(Duration::from_nanos(1))
        .submit()
        .expect("submit")
        .wait()
        .expect("response");
    assert_eq!(r.config, "int4", "nothing fits 1ns; the controller falls back to cheapest");
    assert!(!r.met_deadline, "a 1ns deadline cannot be met");
    assert_eq!(r.logits.len(), c.num_classes(), "flagged responses still carry logits");
}

#[test]
fn config_choices_are_deterministic_given_a_fixed_trace() {
    // The sim backend feeds the controller its modeled latencies, so with
    // calibration off (wall-clock free) the pick sequence is a pure
    // function of the request trace.
    let backend = SimBackend::serve_cnn(0.0);
    let l4 = backend.modeled_latency_s("int4", 1).expect("int4 modeled");
    let l8 = backend.modeled_latency_s("int8", 1).expect("int8 modeled");
    let trace: Vec<BudgetSpec> = vec![
        BudgetSpec::Class(Budget::High),
        BudgetSpec::Deadline(Duration::from_secs_f64(l8 * 3.0)),
        BudgetSpec::Class(Budget::Low),
        BudgetSpec::Deadline(Duration::from_secs_f64(l4 * 1.05)),
        BudgetSpec::Deadline(Duration::from_secs_f64((l4 + l8) * 0.6)),
        BudgetSpec::Class(Budget::Medium),
        BudgetSpec::Deadline(Duration::from_secs_f64(l4 * 0.5)),
        BudgetSpec::Deadline(Duration::from_secs_f64(l8 * 10.0)),
    ];
    let run = |trace: &[BudgetSpec]| -> Vec<String> {
        let c = start(false);
        let elems = c.sample_elems();
        trace
            .iter()
            .enumerate()
            .map(|(i, &budget)| {
                // Sequential submits: each request rides its own batch, so
                // the trace fixes the controller's entire input.
                c.submit_spec(
                    sample(elems, 100 + i as u64),
                    RequestSpec { budget, ..RequestSpec::default() },
                )
                .expect("submit")
                .wait()
                .expect("response")
                .config
            })
            .collect()
    };
    let first = run(&trace);
    let second = run(&trace);
    assert_eq!(first, second, "same trace, same coordinator build, different configs");
    // And the extremes are pinned regardless of the ladder's exact shape.
    assert_eq!(first[1], "int8", "3x the int8 latency affords full quality");
    assert_eq!(first[6], "int4", "half the int4 latency fits nothing; cheapest fallback");
}

#[test]
fn concurrent_submitters_all_get_consistent_verdicts() {
    let c = start(true);
    let elems = c.sample_elems();
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let c = c.clone();
        handles.push(thread::spawn(move || {
            let budgets = [Budget::Low, Budget::Medium, Budget::High];
            (0..8u64)
                .map(|i| {
                    let x = sample(elems, 1000 + 100 * t + i);
                    let pending = if i % 2 == 0 {
                        c.submit(x, budgets[(i % 3) as usize]).expect("submit")
                    } else {
                        c.request(x)
                            .deadline(Duration::from_millis(1 + 20 * i))
                            .priority(if i % 4 == 1 { Priority::High } else { Priority::Normal })
                            .submit()
                            .expect("submit")
                    };
                    pending.wait().expect("response")
                })
                .collect::<Vec<_>>()
        }));
    }
    let mut total = 0;
    for h in handles {
        for r in h.join().expect("submitter thread") {
            total += 1;
            assert!(c.configs().contains(&r.config), "unknown config {}", r.config);
            assert!(r.target_s > 0.0);
            // The verdict is exactly the latency-vs-target comparison.
            assert_eq!(r.met_deadline, r.latency_s <= r.target_s);
            assert_eq!(r.logits.len(), c.num_classes());
        }
    }
    assert_eq!(total, 32);
    let m = c.metrics();
    assert_eq!(m.completed, 32);
    assert_eq!(m.deadline_met + m.deadline_missed, 32);
    assert_eq!(m.failed, 0);
}

#[test]
fn batch_hints_keep_requests_in_small_batches() {
    let c = start(true);
    let elems = c.sample_elems();
    // A burst of hint-1 requests: whatever batches form, every response
    // must have ridden a batch of exactly 1.
    let pendings: Vec<_> = (0..8)
        .map(|i| {
            c.request(sample(elems, 2000 + i))
                .class(Budget::High)
                .batch_hint(1)
                .submit()
                .expect("submit")
        })
        .collect();
    for p in pendings {
        let r = p.wait().expect("response");
        assert_eq!(r.batch, 1, "a hint-1 request rode a batch of {}", r.batch);
    }
    assert_eq!(c.metrics().completed, 8);
}

#[test]
fn rejects_wrong_input_size() {
    let c = start(false);
    assert!(c.submit(vec![0.0; 7], Budget::High).is_err());
    assert!(c.request(vec![0.0; 7]).deadline(Duration::from_millis(5)).submit().is_err());
}

#[test]
fn http_front_end_round_trips_over_real_sockets() {
    let c = start(true);
    let server = ServingServer::spawn("127.0.0.1:0", c.clone()).expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(30);

    // The health document carries the model contract.
    let health = serving::fetch_health(&addr, timeout).expect("GET /healthz");
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    let elems = health.get("sample_elems").and_then(Json::as_i64).expect("sample_elems") as usize;
    assert_eq!(elems, c.sample_elems());
    assert!(health.get("configs").and_then(Json::as_arr).is_some_and(|a| !a.is_empty()));

    // A class request and a deadline request both round-trip.
    let r = serving::infer_remote(
        &addr,
        &InferRequest {
            input: sample(elems, 1),
            spec: RequestSpec { budget: BudgetSpec::Class(Budget::Low), ..RequestSpec::default() },
        },
        timeout,
    )
    .expect("class infer");
    assert_eq!(r.logits.len(), c.num_classes());
    assert!(c.configs().contains(&r.config));
    let r = serving::infer_remote(
        &addr,
        &InferRequest {
            input: sample(elems, 2),
            spec: RequestSpec {
                budget: BudgetSpec::Deadline(Duration::from_secs(5)),
                priority: Priority::High,
                batch_hint: Some(1),
            },
        },
        timeout,
    )
    .expect("deadline infer");
    assert!(r.met_deadline, "a 5s deadline over loopback must be met");
    assert_eq!(r.batch, 1, "the batch hint survives the wire");

    // The wire responses and the local metrics agree.
    let stats = serving::fetch_stats(&addr, timeout).expect("GET /stats");
    assert_eq!(stats.get("completed").and_then(Json::as_i64), Some(2), "{stats}");
    assert_eq!(stats.get("failed").and_then(Json::as_i64), Some(0));

    // Hostile and invalid requests get clean 4xx, and the server survives.
    let (status, _) =
        http_request(&addr, "POST", "/infer", b"this is not json", timeout).expect("bad body");
    assert_eq!(status, 400);
    let wrong_size = InferRequest {
        input: vec![0.5; 3],
        spec: RequestSpec::default(),
    };
    let (status, body) = http_request(
        &addr,
        "POST",
        "/infer",
        wrong_size.to_json().to_string().as_bytes(),
        timeout,
    )
    .expect("wrong-size request");
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    let (status, _) = http_request(&addr, "GET", "/no-such", b"", timeout).expect("404 path");
    assert_eq!(status, 404);

    // Still alive after the abuse.
    let health = serving::fetch_health(&addr, timeout).expect("final healthz");
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    server.shutdown();
}

#[test]
fn connection_budget_bounces_overflow_with_machine_readable_503() {
    use std::net::TcpStream;

    let c = start(false);
    let server = ServingServer::spawn_with(
        "127.0.0.1:0",
        c.clone(),
        ServeOpts { max_concurrent_requests: 1, ..ServeOpts::default() },
    )
    .expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(10);

    // Occupy the single connection slot with an idle connection (its
    // handler blocks reading it under the exchange deadline).
    let hog = TcpStream::connect(&addr).expect("hog connection");
    thread::sleep(Duration::from_millis(200)); // let the accept loop admit it

    // Every further connection is bounced with the server-busy code.
    let (status, body) = http_request(&addr, "GET", "/healthz", b"", timeout)
        .expect("over-budget request still gets an HTTP reply");
    assert_eq!(status, 503, "{}", String::from_utf8_lossy(&body));
    let reply = Json::parse_bytes(&body).expect("503 body is JSON");
    assert_eq!(reply.get("code").and_then(Json::as_str), Some("server-busy"), "{reply}");

    // Releasing the slot restores service.
    drop(hog);
    let mut ok = false;
    for _ in 0..100 {
        if let Ok((200, _)) = http_request(&addr, "GET", "/healthz", b"", timeout) {
            ok = true;
            break;
        }
        thread::sleep(Duration::from_millis(20));
    }
    assert!(ok, "server did not recover after the hog connection closed");
    server.shutdown();
}

#[test]
fn sim_backend_numerics_agree_between_local_and_wire_paths() {
    // The same input through the library path and the HTTP path must
    // produce the same logits (the sim backend is deterministic, and the
    // wire round-trips f32 losslessly through shortest-round-trip JSON).
    let c = start(false);
    let server = ServingServer::spawn("127.0.0.1:0", c.clone()).expect("bind serving server");
    let addr = server.addr().to_string();
    let x = sample(c.sample_elems(), 9);
    let local = c.infer(x.clone(), Budget::High).expect("local infer");
    let wire = serving::infer_remote(
        &addr,
        &InferRequest { input: x, spec: RequestSpec::default() },
        Duration::from_secs(30),
    )
    .expect("wire infer");
    assert_eq!(local.config, wire.config, "same trace position, same pick");
    assert_eq!(local.logits, wire.logits, "wire transport perturbed the logits");
    server.shutdown();
}

/// A coordinator pinned to one loaded config: every request is served by
/// `int8`, so per-sample logits are a pure function of the input — the
/// precondition for byte-identity across batch compositions and wire
/// modes.
fn start_pinned() -> Coordinator {
    Coordinator::start_sim(
        CoordinatorConfig {
            configs: vec!["int8".to_string()],
            calibrate: false,
            batch_window: Duration::from_millis(1),
            ..CoordinatorConfig::default()
        },
        0.0,
    )
    .expect("single-config coordinator starts in the default build")
}

#[test]
fn pooled_client_reuses_the_serving_connection() {
    let c = start(true);
    let server = ServingServer::spawn("127.0.0.1:0", c.clone()).expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(30);
    let pool = ConnPool::new(2);

    let elems = c.sample_elems();
    for i in 0..3 {
        let r = serving::infer_remote_pooled(
            &pool,
            &addr,
            &InferRequest { input: sample(elems, 40 + i), spec: RequestSpec::default() },
            timeout,
        )
        .expect("pooled infer");
        assert_eq!(r.logits.len(), c.num_classes());
    }
    let stats = serving::fetch_stats_pooled(&pool, &addr, timeout).expect("pooled /stats");
    assert_eq!(stats.get("completed").and_then(Json::as_i64), Some(3), "{stats}");

    let ps = pool.stats();
    assert_eq!(ps.fresh_connects, 1, "all four exchanges ride one socket: {ps:?}");
    assert_eq!(ps.reuses, 3, "{ps:?}");
    server.shutdown();
}

#[test]
fn stats_reports_tail_latency_and_met_rate_over_the_wire() {
    let c = start(true);
    let server = ServingServer::spawn("127.0.0.1:0", c.clone()).expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(30);
    let elems = c.sample_elems();
    for i in 0..4 {
        serving::infer_remote(
            &addr,
            &InferRequest { input: sample(elems, 50 + i), spec: RequestSpec::default() },
            timeout,
        )
        .expect("infer");
    }
    let stats = serving::fetch_stats(&addr, timeout).expect("GET /stats");
    let p50 = stats.get("latency_p50_s").and_then(Json::as_f64).expect("latency_p50_s");
    let p99 = stats.get("latency_p99_s").and_then(Json::as_f64).expect("latency_p99_s");
    let p999 = stats.get("latency_p999_s").and_then(Json::as_f64).expect("latency_p999_s");
    assert!(p50 > 0.0 && p50 <= p99 && p99 <= p999, "tail order: {p50} {p99} {p999}");
    let met = stats.get("deadline_met_frac").and_then(Json::as_f64).expect("deadline_met_frac");
    assert!((0.0..=1.0).contains(&met), "{met}");
    server.shutdown();
}

#[test]
fn multi_sample_infer_is_byte_identical_to_single_sample_requests() {
    // The same 5 inputs through (a) one-at-a-time wire requests against a
    // pinned coordinator and (b) one multi-sample framed request against
    // a second pinned coordinator must produce identical logits, sample
    // for sample — framing and batching are transparent to the numerics.
    let inputs: Vec<Vec<f32>> = {
        let c = start_pinned();
        (0..5).map(|i| sample(c.sample_elems(), 60 + i)).collect()
    };

    let singles: Vec<Vec<f32>> = {
        let c = start_pinned();
        let server = ServingServer::spawn("127.0.0.1:0", c).expect("bind serving server");
        let addr = server.addr().to_string();
        let out = inputs
            .iter()
            .map(|x| {
                serving::infer_remote(
                    &addr,
                    &InferRequest { input: x.clone(), spec: RequestSpec::default() },
                    Duration::from_secs(30),
                )
                .expect("single infer")
                .logits
            })
            .collect();
        server.shutdown();
        out
    };

    let c = start_pinned();
    let server = ServingServer::spawn("127.0.0.1:0", c).expect("bind serving server");
    let addr = server.addr().to_string();
    let pool = ConnPool::new(2);
    let many = serving::infer_remote_many(
        &pool,
        &addr,
        &BatchInferRequest { inputs: inputs.clone(), spec: RequestSpec::default() },
        Duration::from_secs(30),
    )
    .expect("multi-sample infer");
    assert_eq!(many.len(), inputs.len(), "one verdict per sample");
    for (i, (single, batched)) in singles.iter().zip(&many).enumerate() {
        assert_eq!(batched.config, "int8", "pinned coordinator must serve int8");
        assert_eq!(
            single, &batched.logits,
            "sample {i}: multi-sample framing perturbed the logits"
        );
    }
    server.shutdown();
}

#[test]
fn multi_sample_requests_reject_bad_shapes_cleanly() {
    let c = start(false);
    let server = ServingServer::spawn("127.0.0.1:0", c.clone()).expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(10);

    // An empty inputs array and a mis-sized sample both get a 400 — and a
    // mixed batch is rejected before any sample is submitted (no partial
    // work, so completed stays 0).
    let (status, body) =
        http_request(&addr, "POST", "/infer", b"{\"inputs\": [], \"budget\": \"high\"}", timeout)
            .expect("empty batch");
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    let good = sample(c.sample_elems(), 70);
    let bad_batch = BatchInferRequest {
        inputs: vec![good, vec![0.5; 3]],
        spec: RequestSpec::default(),
    };
    let (status, body) = http_request(
        &addr,
        "POST",
        "/infer",
        bad_batch.to_json().to_string().as_bytes(),
        timeout,
    )
    .expect("mis-sized batch");
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    let stats = serving::fetch_stats(&addr, timeout).expect("GET /stats");
    assert_eq!(
        stats.get("completed").and_then(Json::as_i64),
        Some(0),
        "a rejected batch must not submit partial work: {stats}"
    );
    server.shutdown();
}

#[test]
fn idle_timeout_recycles_pooled_serving_connections() {
    // A server that idle-times sockets out quickly: the pool's second
    // exchange finds its cached connection closed and transparently opens
    // a fresh one — the caller never sees a failure.
    let c = start(false);
    let server = ServingServer::spawn_with(
        "127.0.0.1:0",
        c,
        ServeOpts { idle_timeout: Duration::from_millis(50), ..ServeOpts::default() },
    )
    .expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(10);
    let pool = ConnPool::new(2);

    let s1 = serving::fetch_stats_pooled(&pool, &addr, timeout).expect("first exchange");
    assert!(s1.get("completed").and_then(Json::as_i64).is_some(), "{s1}");
    thread::sleep(Duration::from_millis(300)); // let the server idle the socket out
    let s2 = serving::fetch_stats_pooled(&pool, &addr, timeout).expect("exchange after idle close");
    assert!(s2.get("completed").and_then(Json::as_i64).is_some(), "{s2}");
    let ps = pool.stats();
    assert_eq!(ps.fresh_connects, 2, "the idled socket must not be reused: {ps:?}");
    server.shutdown();
}

#[test]
fn serving_request_cap_closes_cleanly_under_a_pooled_client() {
    // Cap at 2 requests per connection: the pool sees the `connection:
    // close` on every second reply and reconnects — all exchanges succeed.
    let c = start(true);
    let server = ServingServer::spawn_with(
        "127.0.0.1:0",
        c.clone(),
        ServeOpts { max_requests_per_conn: 2, ..ServeOpts::default() },
    )
    .expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(30);
    let pool = ConnPool::new(2);
    let elems = c.sample_elems();
    for i in 0..6 {
        serving::infer_remote_pooled(
            &pool,
            &addr,
            &InferRequest { input: sample(elems, 80 + i), spec: RequestSpec::default() },
            timeout,
        )
        .expect("pooled infer under a request cap");
    }
    let ps = pool.stats();
    assert_eq!(ps.fresh_connects, 3, "6 exchanges at 2 per connection: {ps:?}");
    assert_eq!(ps.reuses, 3, "{ps:?}");
    assert_eq!(c.metrics().completed, 6);
    server.shutdown();
}

/// Read a numeric leaf out of a metrics/stats document by dotted path.
fn num(doc: &Json, path: &str) -> f64 {
    let mut cur = doc.clone();
    for part in path.split('.') {
        cur = cur.get(part).cloned().unwrap_or(Json::Null);
    }
    cur.as_f64().unwrap_or_else(|| panic!("no numeric '{path}' in {doc}"))
}

#[test]
fn loadgen_replay_is_byte_identical_client_side() {
    // The same seeded WorkloadSpec against two fresh servers: the
    // client-side plan (request sequence, classes, budgets, digest) must
    // be byte-identical — what the servers did with it may differ, but
    // the offered load never does.
    let spec = WorkloadSpec::builtin("constant", 60.0, 0.5, 9).expect("builtin spec");
    let opts = LoadgenOpts { workers: 4, timeout: Duration::from_secs(10) };
    let run = || {
        let c = start(false);
        let server = ServingServer::spawn("127.0.0.1:0", c).expect("bind serving server");
        let report =
            loadgen::run_loadgen(&server.addr().to_string(), &spec, &opts).expect("loadgen run");
        server.shutdown();
        report
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.plan.to_string(),
        b.plan.to_string(),
        "same spec + seed must replay a byte-identical plan"
    );
    assert!(a.plan.get("digest").and_then(Json::as_str).is_some(), "plan carries its digest");
    let planned = num(&a.plan, "arrivals") as u64;
    assert!(planned > 0, "an 0.5 s x 60 rps run plans arrivals");
    assert_eq!(a.total.sent, planned, "every planned arrival is dispatched");
    assert_eq!(b.total.sent, planned);
    assert!(a.total.ok > 0, "a healthy server answers offered load: {:?}", a.total);
    // The observed half may legitimately differ run to run; the class
    // populations (a pure function of the plan) may not.
    let classes = |r: &loadgen::LoadReport| -> Vec<(String, u64)> {
        r.per_class.iter().map(|(k, v)| (k.clone(), v.sent)).collect()
    };
    assert_eq!(classes(&a), classes(&b), "class draws are part of the deterministic plan");
}

#[test]
fn overloaded_loadgen_counts_rejections_without_stalling_or_leaking() {
    // One admitted connection, six senders, well over capacity: admission
    // control must bounce the overflow (visible on both ends), and once
    // the run's pool drops its sockets the server must drain back to a
    // lone connection — nothing stalls, nothing leaks.
    let c = start(false);
    let server = ServingServer::spawn_with(
        "127.0.0.1:0",
        c,
        ServeOpts { max_concurrent_requests: 1, ..ServeOpts::default() },
    )
    .expect("bind serving server");
    let addr = server.addr().to_string();
    let spec = WorkloadSpec::builtin("constant", 300.0, 0.6, 5).expect("builtin spec");
    let opts = LoadgenOpts { workers: 6, timeout: Duration::from_secs(10) };
    let report = loadgen::run_loadgen(&addr, &spec, &opts).expect("overloaded run still reports");

    assert!(
        report.total.rejected_busy > 0,
        "an over-capacity run must see 503 rejections: {:?}",
        report.total
    );
    assert_eq!(
        report.total.sent,
        report.total.ok + report.total.rejected_busy + report.total.errors,
        "every dispatched request has exactly one outcome: {:?}",
        report.total
    );

    // The server's own count of bounced connections agrees that admission
    // control fired, and the server is still live and drained.
    let timeout = Duration::from_secs(10);
    let mut drained = false;
    for _ in 0..100 {
        if let Ok(m) = serving::fetch_metrics(&addr, timeout) {
            assert!(num(&m, "connections.rejected_busy") > 0.0, "{m}");
            // Our own /metrics fetch holds the one slot while it is served.
            if num(&m, "connections.open") <= 1.0 {
                drained = true;
                break;
            }
        }
        thread::sleep(Duration::from_millis(20));
    }
    assert!(drained, "connections leaked after the loadgen pool closed");
    let health = serving::fetch_health(&addr, timeout).expect("healthz after overload");
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    server.shutdown();
}

#[test]
fn metrics_endpoint_reconciles_with_stats_over_the_wire() {
    let c = start(true);
    let server = ServingServer::spawn("127.0.0.1:0", c.clone()).expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(30);
    let elems = c.sample_elems();
    // A mixed population so per-class metrics have several rows.
    for (i, budget) in [
        BudgetSpec::Class(Budget::Low),
        BudgetSpec::Class(Budget::High),
        BudgetSpec::Deadline(Duration::from_secs(5)),
        BudgetSpec::Class(Budget::Low),
        BudgetSpec::Deadline(Duration::from_secs(5)),
    ]
    .into_iter()
    .enumerate()
    {
        serving::infer_remote(
            &addr,
            &InferRequest {
                input: sample(elems, 90 + i as u64),
                spec: RequestSpec { budget, ..RequestSpec::default() },
            },
            timeout,
        )
        .expect("infer");
    }

    let metrics = serving::fetch_metrics(&addr, timeout).expect("GET /metrics");
    let stats = serving::fetch_stats(&addr, timeout).expect("GET /stats");

    // Shared counters agree between the two documents.
    for key in ["completed", "failed", "deadline_met", "deadline_missed"] {
        assert_eq!(num(&metrics, key), num(&stats, key), "'{key}' disagrees:\n{metrics}\n{stats}");
    }
    // Both percentile sets route through the same histogram.
    assert_eq!(num(&metrics, "latency.p50_s"), num(&stats, "latency_p50_s"));
    assert_eq!(num(&metrics, "latency.p99_s"), num(&stats, "latency_p99_s"));
    assert_eq!(num(&metrics, "latency.p999_s"), num(&stats, "latency_p999_s"));

    // The metrics document reconciles with itself: met + missed ==
    // completed, in total and per class.
    assert_eq!(num(&metrics, "completed"), 5.0, "{metrics}");
    assert_eq!(
        num(&metrics, "deadline_met") + num(&metrics, "deadline_missed"),
        num(&metrics, "completed")
    );
    let per_class = metrics.get("per_class").and_then(Json::as_obj).expect("per_class");
    assert!(per_class.len() >= 2, "mixed budgets must yield several classes: {metrics}");
    let mut class_completed = 0.0;
    for (name, cm) in per_class {
        class_completed += num(cm, "completed");
        assert_eq!(
            num(cm, "deadline_met") + num(cm, "deadline_missed"),
            num(cm, "completed"),
            "class {name} does not reconcile"
        );
        let met_frac = num(cm, "met_frac");
        assert!((0.0..=1.0).contains(&met_frac), "class {name}: {met_frac}");
    }
    assert_eq!(class_completed, num(&metrics, "completed"), "classes partition the requests");
    assert_eq!(num(&metrics, "queue_depth"), 0.0, "idle server, empty queue");

    // Connection counters only ever move forward.
    let later = serving::fetch_metrics(&addr, timeout).expect("second GET /metrics");
    assert!(
        num(&later, "connections.accepted") > num(&metrics, "connections.accepted"),
        "accepted connections must be monotone:\n{metrics}\n{later}"
    );
    server.shutdown();
}

#[test]
fn racing_scrapes_stay_monotone_and_internally_consistent() {
    // Loadgen traffic races `/metrics` and `/stats` scrapes. The sharded
    // atomic metrics promise (all stores Relaxed, merged at scrape time):
    // every mid-flight document is internally consistent — ordered
    // percentiles, clamped per-class counters — and shared counters only
    // ever move forward across scrapes. Cross-counter identities like
    // `met + missed == completed` are only owed at quiescence, so those
    // are checked after the traffic thread joins.
    let c = start(false);
    let server = ServingServer::spawn("127.0.0.1:0", c).expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(10);

    let spec = WorkloadSpec::builtin("constant", 150.0, 0.8, 11).expect("builtin spec");
    let opts = LoadgenOpts { workers: 3, timeout };
    let gen_addr = addr.clone();
    let traffic = thread::spawn(move || {
        loadgen::run_loadgen(&gen_addr, &spec, &opts).expect("loadgen run under scrapes")
    });

    let mut last_completed = -1.0;
    let mut last_accepted = -1.0;
    let mut scrapes = 0u32;
    while scrapes < 3 || !traffic.is_finished() {
        let m = serving::fetch_metrics(&addr, timeout).expect("GET /metrics mid-load");
        let s = serving::fetch_stats(&addr, timeout).expect("GET /stats mid-load");
        scrapes += 1;

        // Counters never run backwards, within or across documents (the
        // /stats scrape happens strictly after the /metrics scrape).
        let completed = num(&m, "completed");
        assert!(completed >= last_completed, "completed went backwards:\n{m}");
        assert!(num(&s, "completed") >= completed, "later scrape saw fewer:\n{m}\n{s}");
        last_completed = num(&s, "completed");
        let accepted = num(&m, "connections.accepted");
        assert!(accepted >= last_accepted, "accepted went backwards:\n{m}");
        last_accepted = accepted;
        assert_eq!(num(&m, "connections.accept_errors"), 0.0, "{m}");

        // Every document is internally ordered, even mid-merge.
        let (p50, p99, p999) =
            (num(&m, "latency.p50_s"), num(&m, "latency.p99_s"), num(&m, "latency.p999_s"));
        assert!(p50 <= p99 && p99 <= p999, "tail order: {p50} {p99} {p999}\n{m}");
        let (p50, p99, p999) =
            (num(&s, "latency_p50_s"), num(&s, "latency_p99_s"), num(&s, "latency_p999_s"));
        assert!(p50 <= p99 && p99 <= p999, "tail order: {p50} {p99} {p999}\n{s}");

        // Per-class rows are clamped: met never outruns completions.
        if let Some(per_class) = m.get("per_class").and_then(Json::as_obj) {
            for (name, cm) in per_class {
                assert!(
                    num(cm, "deadline_met") <= num(cm, "completed"),
                    "class {name} met > completed:\n{m}"
                );
                let met_frac = num(cm, "met_frac");
                assert!((0.0..=1.0).contains(&met_frac), "class {name}: {met_frac}");
            }
        }
        thread::sleep(Duration::from_millis(10));
    }

    // Quiesced: every reply the loadgen received synchronized with the
    // recorder that produced it, so the final documents reconcile exactly.
    let report = traffic.join().expect("traffic thread");
    assert!(report.total.ok > 0, "a healthy server answers offered load: {:?}", report.total);
    assert!(scrapes >= 3, "the run must actually race some scrapes");
    let m = serving::fetch_metrics(&addr, timeout).expect("final /metrics");
    let completed = num(&m, "completed");
    assert!(completed >= report.total.ok as f64, "server completed fewer than client oks:\n{m}");
    assert!(completed <= report.total.sent as f64, "more completions than dispatches:\n{m}");
    assert_eq!(
        num(&m, "deadline_met") + num(&m, "deadline_missed"),
        completed,
        "quiesced verdicts must partition completions:\n{m}"
    );
    let per_class = m.get("per_class").and_then(Json::as_obj).expect("per_class");
    let class_completed: f64 = per_class.values().map(|cm| num(cm, "completed")).sum();
    assert_eq!(class_completed, completed, "classes partition the requests:\n{m}");
    server.shutdown();
}

#[test]
fn legacy_spawn_per_connection_mode_still_serves() {
    // `serve_threads == 0` keeps the historical thread-per-connection
    // accept loop as the A/B baseline for the pooled hot path; it must
    // stay fully functional.
    let c = start(false);
    let server = ServingServer::spawn_with(
        "127.0.0.1:0",
        c.clone(),
        ServeOpts { serve_threads: 0, ..ServeOpts::default() },
    )
    .expect("bind serving server");
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(10);

    let health = serving::fetch_health(&addr, timeout).expect("GET /healthz");
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    let r = serving::infer_remote(
        &addr,
        &InferRequest { input: sample(c.sample_elems(), 7), spec: RequestSpec::default() },
        timeout,
    )
    .expect("legacy-mode infer");
    assert_eq!(r.logits.len(), c.num_classes());
    let stats = serving::fetch_stats(&addr, timeout).expect("GET /stats");
    assert_eq!(stats.get("completed").and_then(Json::as_i64), Some(1), "{stats}");
    server.shutdown();
}
