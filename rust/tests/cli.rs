//! CLI smoke tests: the `bf-imna` binary's help must cover every command
//! and sweep-service flag it actually accepts, and the sharded sweep +
//! merge path must reproduce the single-process sweep byte for byte.

use std::path::PathBuf;
use std::process::{Command, Output};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_bf-imna")
}

fn run(args: &[&str]) -> Output {
    Command::new(bin()).args(args).output().expect("spawn bf-imna")
}

fn stdout(o: &Output) -> String {
    String::from_utf8_lossy(&o.stdout).to_string()
}

/// A unique scratch directory per test (removed at the end, best effort).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bf_imna_cli_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

#[test]
fn help_covers_every_command_and_sweep_service_flag() {
    let out = run(&["help"]);
    assert!(out.status.success());
    let text = stdout(&out);
    for cmd in [
        "simulate", "sweep", "merge", "serve-worker", "fleet", "dispatch", "artifacts", "render",
        "hawq", "compare", "validate", "serve", "infer", "loadgen", "costs", "calibrate",
    ] {
        assert!(text.contains(cmd), "help does not mention command '{cmd}'");
    }
    // The sweep-service + transport + catalog + serving flags the binary
    // accepts must all be documented.
    for flag in [
        "--net", "--bits", "--hw", "--tech", "--breakdown", "--out", "--shards", "--shard-id",
        "--combos", "--seed", "--cache-in", "--cache-out", "--artifacts", "--requests", "--addr",
        "--workers", "--spec", "--timeout-s", "--artifact", "--doc", "--tiny", "--names",
        "--max-shards", "--queue-depth", "--budget", "--deadline-ms", "--priority",
        "--batch-hint", "--time-scale", "--stats", "--max-requests", "--idle-timeout-s",
        "--conn-requests", "--pool", "--count", "--batch", "--rps", "--duration-s", "--profile",
        "--fleet", "--store", "--advertise", "--heartbeat-s", "--expiry-s", "--max-slice",
        "--grace-s", "--serve-threads", "--worker-threads", "--costs", "--csv", "--list",
        "--show", "--fleet-priors",
    ] {
        assert!(text.contains(flag), "help does not mention flag '{flag}'");
    }
    // The worker's and serving front end's endpoints are operator-facing
    // API; keep them in help.
    for endpoint in
        ["/shard", "/slice", "/cache", "/healthz", "/stats", "/infer", "/metrics", "/register",
         "/workers"]
    {
        assert!(text.contains(endpoint), "help does not mention endpoint '{endpoint}'");
    }
    // No args behaves like help.
    assert_eq!(stdout(&run(&[])), text);
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = run(&["frobnicate"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("unknown command"), "{err}");
    assert!(err.contains("USAGE"), "{err}");
}

#[test]
fn simulate_prints_the_metric_table() {
    let out = run(&["simulate", "--net", "serve_cnn", "--bits", "4"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = stdout(&out);
    for needle in ["INT4", "latency / inference", "energy / inference", "throughput"] {
        assert!(text.contains(needle), "missing '{needle}' in:\n{text}");
    }
    // Bad flags fail loudly.
    assert!(!run(&["simulate", "--net", "lenet"]).status.success());
    assert!(!run(&["simulate", "--tech", "dram"]).status.success());
    assert!(!run(&["simulate", "--hw", "mr"]).status.success());
}

#[test]
fn sweep_table_mode_prints_the_series() {
    let out = run(&["sweep", "--net", "serve_cnn"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = stdout(&out);
    assert!(text.contains("Fig. 7 series"), "{text}");
    assert!(text.contains("avg bits"), "{text}");
}

#[test]
fn sweep_service_flags_are_honored_not_silently_dropped() {
    // Any sweep-service flag must switch to JSON mode and actually take
    // effect — `--tech reram` used to fall through to the SRAM table.
    let out = run(&["sweep", "--net", "serve_cnn", "--tech", "reram", "--combos", "1"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = stdout(&out);
    assert!(text.starts_with('{'), "expected a JSON document, got:\n{text}");
    assert!(text.contains(r#""tech":["reram"]"#), "spec does not carry reram:\n{text}");
    assert!(text.contains(r#""tech":"reram""#), "points do not carry reram:\n{text}");
    // Bad values fail instead of being ignored.
    assert!(!run(&["sweep", "--net", "serve_cnn", "--tech", "dram"]).status.success());
    assert!(!run(&["sweep", "--net", "serve_cnn", "--combos", "0"]).status.success());
}

#[test]
fn sharded_sweep_plus_merge_matches_single_process_byte_for_byte() {
    let dir = scratch("shard");
    let path = |name: &str| dir.join(name).to_string_lossy().to_string();

    // Single-process reference document.
    let full = path("full.json");
    let out = run(&["sweep", "--net", "serve_cnn", "--combos", "1", "--out", &full]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Four shard worker processes + the merger (the acceptance shape:
    // `bf-imna sweep --shards 4 --shard-id {0..3}` + `bf-imna merge`).
    let mut shard_files = Vec::new();
    for k in 0..4 {
        let f = path(&format!("shard{k}.json"));
        let out = run(&[
            "sweep", "--net", "serve_cnn", "--combos", "1", "--shards", "4", "--shard-id",
            &k.to_string(), "--out", &f,
        ]);
        assert!(out.status.success(), "shard {k}: {}", String::from_utf8_lossy(&out.stderr));
        shard_files.push(f);
    }
    let merged = path("merged.json");
    // Deliberately out of order: merge sorts by the recorded slice starts.
    let out = run(&[
        "merge", &shard_files[1], &shard_files[3], &shard_files[0], &shard_files[2], "--out",
        &merged,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let full_bytes = std::fs::read(&full).unwrap();
    let merged_bytes = std::fs::read(&merged).unwrap();
    assert!(!full_bytes.is_empty());
    assert_eq!(merged_bytes, full_bytes, "merged document differs from the unsharded sweep");

    // Merging an incomplete shard set must fail.
    assert!(!run(&["merge", &shard_files[0], "--out", &path("bad.json")]).status.success());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn artifact_catalog_lists_and_specs_round_trip() {
    // The table listing and the scripting-friendly name list agree.
    let out = run(&["artifacts"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let listing = stdout(&out);
    let out = run(&["artifacts", "--names"]);
    assert!(out.status.success());
    let names: Vec<String> = stdout(&out).lines().map(str::to_string).collect();
    assert!(names.len() >= 8, "catalog too small: {names:?}");
    for name in &names {
        assert!(listing.contains(name.as_str()), "listing misses '{name}'");
        // Every artifact's tiny spec is printable, parseable JSON.
        let out = run(&["artifacts", "--spec", name, "--tiny"]);
        assert!(out.status.success(), "{name}: {}", String::from_utf8_lossy(&out.stderr));
        assert!(stdout(&out).trim_start().starts_with('{'), "{name}: not JSON");
    }
    // Unknown artifacts fail loudly everywhere they can be named.
    assert!(!run(&["artifacts", "--spec", "fig99"]).status.success());
    assert!(!run(&["render", "--artifact", "fig99"]).status.success());
    assert!(!run(&["render"]).status.success(), "render without --artifact must fail");
}

#[test]
fn artifact_spec_shard_merge_render_matches_local_render() {
    // The full acceptance pipeline through the real binary:
    //   artifacts --spec NAME --tiny -> sweep --spec --shards 2 -> merge
    //   -> render --doc    must equal    render (local in-process run).
    let dir = scratch("artifact_pipeline");
    let path = |name: &str| dir.join(name).to_string_lossy().to_string();
    let name = "fig6";

    let spec = path("spec.json");
    let out = run(&["artifacts", "--spec", name, "--tiny", "--out", &spec]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let mut shard_files = Vec::new();
    for k in 0..2 {
        let f = path(&format!("s{k}.json"));
        let out = run(&[
            "sweep", "--spec", &spec, "--shards", "2", "--shard-id", &k.to_string(), "--out", &f,
        ]);
        assert!(out.status.success(), "shard {k}: {}", String::from_utf8_lossy(&out.stderr));
        shard_files.push(f);
    }
    let merged = path("merged.json");
    let out = run(&["merge", &shard_files[0], &shard_files[1], "--out", &merged]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let from_doc = path("from_doc.txt");
    let out = run(&["render", "--artifact", name, "--doc", &merged, "--out", &from_doc]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let local = path("local.txt");
    let out = run(&["render", "--artifact", name, "--tiny", "--out", &local]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let doc_bytes = std::fs::read(&from_doc).unwrap();
    assert!(!doc_bytes.is_empty());
    assert_eq!(
        doc_bytes,
        std::fs::read(&local).unwrap(),
        "document render differs from the local in-process render"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn merge_with_zero_input_files_fails_cleanly_and_writes_nothing() {
    let dir = scratch("merge_empty");
    let out_path = dir.join("never-written.json");

    // Bare `merge` and `merge --out F` both have zero positional files.
    let out = run(&["merge"]);
    assert!(!out.status.success(), "merge with no files must fail");
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("no shard files"), "unclear zero-files error: {err}");

    let out = run(&["merge", "--out", &out_path.to_string_lossy()]);
    assert!(!out.status.success(), "merge --out with no files must fail");
    assert!(!out_path.exists(), "merge must not write output on the zero-files path");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dispatch_through_worker_binaries_matches_sweep_byte_for_byte() {
    use std::io::BufRead;
    let dir = scratch("dispatch");
    let path = |name: &str| dir.join(name).to_string_lossy().to_string();

    // Single-process reference document.
    let full = path("full.json");
    let out = run(&["sweep", "--net", "serve_cnn", "--combos", "1", "--out", &full]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Two real `serve-worker` processes on ephemeral ports; the bound
    // address is announced on stderr.
    let mut children = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let mut child = Command::new(bin())
            .args(["serve-worker", "--addr", "127.0.0.1:0"])
            .stderr(std::process::Stdio::piped())
            .spawn()
            .expect("spawn serve-worker");
        let stderr = child.stderr.take().unwrap();
        let mut line = String::new();
        std::io::BufReader::new(stderr).read_line(&mut line).expect("read worker banner");
        let addr = line
            .split("http://")
            .nth(1)
            .and_then(|rest| rest.split_whitespace().next())
            .unwrap_or_else(|| panic!("no address in worker banner: {line:?}"))
            .to_string();
        children.push(child);
        addrs.push(addr);
    }

    let merged = path("merged.json");
    let out = run(&[
        "dispatch", "--workers", &addrs.join(","), "--net", "serve_cnn", "--combos", "1",
        "--shards", "3", "--out", &merged,
    ]);
    for mut child in children {
        let _ = child.kill();
        let _ = child.wait();
    }
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        std::fs::read(&merged).unwrap(),
        std::fs::read(&full).unwrap(),
        "dispatch output differs from the single-process sweep"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_and_infer_round_trip_through_the_real_binary() {
    use std::io::BufRead;

    // `bf-imna serve` on an ephemeral port, sim backend (no artifacts, no
    // pjrt feature) — the acceptance shape for the serving redesign. The
    // bound address is announced on stderr.
    let mut child = Command::new(bin())
        .args(["serve", "--addr", "127.0.0.1:0"])
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let stderr = child.stderr.take().unwrap();
    let mut reader = std::io::BufReader::new(stderr);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read serve banner");
        assert!(n > 0, "serve exited before announcing its address");
        if let Some(rest) = line.split("http://").nth(1) {
            break rest.split_whitespace().next().expect("address in banner").to_string();
        }
    };

    // Mixed-budget `bf-imna infer` calls against the live server.
    let out = run(&["infer", "--addr", &addr, "--requests", "3", "--budget", "low"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = stdout(&out);
    assert!(text.contains("config"), "{text}");
    assert!(text.contains("summary:"), "{text}");

    let out = run(&["infer", "--addr", &addr, "--deadline-ms", "5000", "--priority", "high"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout(&out).contains("met"), "{}", stdout(&out));

    // Contradictory budget flags fail loudly on the client.
    let out = run(&["infer", "--addr", &addr, "--budget", "low", "--deadline-ms", "5"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("not both"));

    // The stats document reflects the served requests.
    let out = run(&["infer", "--addr", &addr, "--stats"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stats = stdout(&out);
    assert!(stats.contains("\"completed\":4"), "{stats}");
    assert!(stats.contains("deadline_met"), "{stats}");

    // The pooled keep-alive client: 3 framed requests of 2 samples each
    // over one connection, with per-request verdicts and an aggregate
    // throughput line naming the connection reuse.
    let out = run(&["infer", "--addr", &addr, "--count", "3", "--batch", "2", "--budget", "high"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = stdout(&out);
    assert!(text.contains("request 2.1"), "3x2 pooled requests missing verdicts:\n{text}");
    assert!(text.contains("pooled:"), "{text}");
    assert!(text.contains("req/s"), "{text}");

    let _ = child.kill();
    let _ = child.wait();
}

#[test]
fn serve_loadgen_slo_report_round_trip_through_the_real_binary() {
    use std::io::BufRead;
    let dir = scratch("loadgen");
    let report_path = dir.join("slo-report.json").to_string_lossy().to_string();

    // `bf-imna serve` on an ephemeral port (sim backend), then a seeded
    // burst-profile `bf-imna loadgen` against it, writing the SLO report.
    let mut child = Command::new(bin())
        .args(["serve", "--addr", "127.0.0.1:0"])
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let stderr = child.stderr.take().unwrap();
    let mut reader = std::io::BufReader::new(stderr);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read serve banner");
        assert!(n > 0, "serve exited before announcing its address");
        if let Some(rest) = line.split("http://").nth(1) {
            break rest.split_whitespace().next().expect("address in banner").to_string();
        }
    };

    let out = run(&[
        "loadgen", "--addr", &addr, "--profile", "burst", "--rps", "80", "--duration-s", "1",
        "--seed", "7", "--workers", "4", "--out", &report_path,
    ]);
    let _ = child.kill();
    let _ = child.wait();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // The report is a parseable SLO document joining both sides.
    let text = std::fs::read_to_string(&report_path).expect("slo report written");
    let report = bf_imna::util::json::Json::parse(&text).expect("slo report parses");
    assert_eq!(report.get("kind").and_then(|k| k.as_str()), Some("slo-report"), "{report}");
    let met = report
        .get("client")
        .and_then(|c| c.get("met_frac"))
        .and_then(|m| m.as_f64())
        .expect("client met_frac");
    assert!((0.0..=1.0).contains(&met), "{met}");
    let arrivals = report
        .get("offered")
        .and_then(|o| o.get("arrivals"))
        .and_then(|a| a.as_f64())
        .expect("offered arrivals");
    assert!(arrivals > 0.0, "{report}");
    assert!(
        report.get("server").and_then(|s| s.get("completed_delta")).is_some(),
        "server join half present: {report}"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn costs_presets_list_show_and_file_round_trip() {
    let dir = scratch("costs");
    let path = |name: &str| dir.join(name).to_string_lossy().to_string();

    // The preset catalog names every preset with its version.
    let out = run(&["costs", "--list"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let listing = stdout(&out);
    for needle in ["default", "scaled-0v5", "envm-optimistic", "jia-65nm", "cost_version"] {
        assert!(listing.contains(needle), "costs listing misses '{needle}':\n{listing}");
    }
    // Bare `costs` is the listing too.
    assert_eq!(stdout(&run(&["costs"])), listing);

    // --show prints the canonical serialization; --out writes the same.
    let out = run(&["costs", "--show", "jia-65nm"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let shown = stdout(&out);
    assert!(shown.starts_with('{'), "{shown}");
    assert!(shown.contains(r#""name":"jia-65nm""#), "{shown}");
    let table_file = path("jia.json");
    let out = run(&["costs", "--show", "jia-65nm", "--out", &table_file]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(std::fs::read_to_string(&table_file).unwrap(), shown);

    // A sweep under the exported file equals a sweep under the preset
    // name, and both echo the table name on spec and points.
    let by_name = path("by_name.json");
    let out = run(&[
        "sweep", "--net", "serve_cnn", "--combos", "1", "--costs", "jia-65nm", "--out", &by_name,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let by_file = path("by_file.json");
    let out = run(&[
        "sweep", "--net", "serve_cnn", "--combos", "1", "--costs", &table_file, "--out", &by_file,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let name_bytes = std::fs::read(&by_name).unwrap();
    assert_eq!(std::fs::read(&by_file).unwrap(), name_bytes);
    let text = String::from_utf8(name_bytes).unwrap();
    // The spec embeds the full table (self-contained documents); the
    // points echo its name as their coordinate.
    assert!(text.contains(r#""costs":[{"cost_version""#), "spec misses the axis:\n{text}");
    assert!(text.contains(r#""name":"jia-65nm""#), "spec misses the table:\n{text}");
    assert!(text.contains(r#""costs":"jia-65nm""#), "points miss the coordinate:\n{text}");

    // Unknown presets fail loudly everywhere they can be named.
    assert!(!run(&["costs", "--show", "nope"]).status.success());
    assert!(!run(&["sweep", "--net", "serve_cnn", "--costs", "nope"]).status.success());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_costs_axis_shards_and_merges_byte_for_byte() {
    let dir = scratch("costs_shard");
    let path = |name: &str| dir.join(name).to_string_lossy().to_string();

    // Single-process reference under a non-default cost table.
    let full = path("full.json");
    let out = run(&[
        "sweep", "--net", "serve_cnn", "--combos", "1", "--costs", "scaled-0v5", "--out", &full,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Two shard processes + merge must reproduce it byte for byte.
    let mut shard_files = Vec::new();
    for k in 0..2 {
        let f = path(&format!("s{k}.json"));
        let out = run(&[
            "sweep", "--net", "serve_cnn", "--combos", "1", "--costs", "scaled-0v5", "--shards",
            "2", "--shard-id", &k.to_string(), "--out", &f,
        ]);
        assert!(out.status.success(), "shard {k}: {}", String::from_utf8_lossy(&out.stderr));
        shard_files.push(f);
    }
    let merged = path("merged.json");
    let out = run(&["merge", &shard_files[0], &shard_files[1], "--out", &merged]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let full_bytes = std::fs::read(&full).unwrap();
    assert_eq!(std::fs::read(&merged).unwrap(), full_bytes);
    assert!(String::from_utf8(full_bytes).unwrap().contains(r#""costs":"scaled-0v5""#));

    // The default table stays invisible: a plain sweep document never
    // mentions costs at all (the seed byte-identity contract).
    let plain = path("plain.json");
    let out = run(&["sweep", "--net", "serve_cnn", "--combos", "1", "--out", &plain]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&plain).unwrap();
    assert!(!text.contains("costs"), "default sweep document mentions costs:\n{text}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn render_csv_writes_the_flat_table_alongside_the_text() {
    let dir = scratch("render_csv");
    let path = |name: &str| dir.join(name).to_string_lossy().to_string();

    let txt = path("fig6.txt");
    let csv = path("fig6.csv");
    let out = run(&["render", "--artifact", "fig6", "--tiny", "--out", &txt, "--csv", &csv]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(!std::fs::read(&txt).unwrap().is_empty());
    let table = std::fs::read_to_string(&csv).unwrap();
    let mut lines = table.lines();
    assert!(
        lines.next().unwrap().starts_with("index,net,cfg,hw,tech,chip,costs,"),
        "csv header:\n{table}"
    );
    assert!(lines.next().is_some(), "csv has no data rows:\n{table}");

    // --csv without a path must fail, not silently write a file
    // literally named "true".
    assert!(!run(&["render", "--artifact", "fig6", "--tiny", "--csv"]).status.success());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn calibrate_fits_a_versioned_table_that_feeds_back_into_sweeps() {
    let dir = scratch("calibrate");
    let path = |name: &str| dir.join(name).to_string_lossy().to_string();

    let fitted = path("fitted.json");
    let out = run(&["calibrate", "--out", &fitted]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = stdout(&out);
    assert!(report.contains("fitted cycles per op"), "{report}");
    assert!(report.contains("RMS relative residual"), "{report}");

    // The emitted table is a loadable cost table: a sweep runs under it
    // and echoes its name as the costs coordinate.
    let text = std::fs::read_to_string(&fitted).unwrap();
    assert!(text.contains(r#""name":"fitted-serve-cnn""#), "{text}");
    let doc = path("doc.json");
    let out = run(&[
        "sweep", "--net", "serve_cnn", "--combos", "1", "--costs", &fitted, "--out", &doc,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(std::fs::read_to_string(&doc).unwrap().contains(r#""costs":"fitted-serve-cnn""#));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_fleet_priors_harvest_measured_stats_through_the_real_binaries() {
    use std::io::BufRead;

    // A serving front end can announce its address before any banner
    // line we care about; collect every stderr line read on the way to
    // the http:// banner so earlier diagnostics stay assertable.
    fn spawn_serve(args: &[&str]) -> (std::process::Child, String, Vec<String>) {
        let mut child = Command::new(bin())
            .args(args)
            .stderr(std::process::Stdio::piped())
            .spawn()
            .expect("spawn bf-imna");
        let stderr = child.stderr.take().unwrap();
        let mut reader = std::io::BufReader::new(stderr);
        let mut seen = Vec::new();
        let addr = loop {
            let mut line = String::new();
            let n = reader.read_line(&mut line).expect("read banner");
            assert!(n > 0, "process exited before announcing an address: {seen:?}");
            if let Some(rest) = line.split("listening on http://").nth(1) {
                break rest.split_whitespace().next().expect("address in banner").to_string();
            }
            seen.push(line);
        };
        (child, addr, seen)
    }

    let (mut fleet, fleet_addr, _) = spawn_serve(&["fleet", "--addr", "127.0.0.1:0"]);

    // An empty fleet is not an error — the coordinator announces the
    // simulator-prior fallback and serves anyway. This first server also
    // registers itself, so its measured stats enter the listing.
    let (mut serve1, addr1, seen1) = spawn_serve(&[
        "serve", "--addr", "127.0.0.1:0", "--fleet-priors", &fleet_addr, "--fleet", &fleet_addr,
        "--heartbeat-s", "0.1",
    ]);
    assert!(
        seen1.iter().any(|l| l.contains("falling back to simulator priors")),
        "empty-fleet fallback not announced: {seen1:?}"
    );

    // Serve some traffic so the per-config execute stats are non-zero,
    // then give the heartbeat a couple of beats to carry them.
    let out = run(&["infer", "--addr", &addr1, "--requests", "4", "--budget", "low"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    std::thread::sleep(std::time::Duration::from_millis(800));

    // A fresh server now harvests measured priors from the fleet.
    let (mut serve2, addr2, seen2) = spawn_serve(&[
        "serve", "--addr", "127.0.0.1:0", "--fleet-priors", &fleet_addr,
    ]);
    assert!(
        seen2.iter().any(|l| l.contains("latency priors from fleet")),
        "measured priors not harvested: {seen2:?}"
    );
    // And it serves.
    let out = run(&["infer", "--addr", &addr2, "--requests", "2", "--budget", "high"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    for child in [&mut serve1, &mut serve2, &mut fleet] {
        let _ = child.kill();
        let _ = child.wait();
    }
}

#[test]
fn cache_snapshot_flags_round_trip_without_changing_bytes() {
    let dir = scratch("cache");
    let path = |name: &str| dir.join(name).to_string_lossy().to_string();

    let cold_out = path("cold.json");
    let snap = path("snap.json");
    let out = run(&[
        "sweep", "--net", "serve_cnn", "--combos", "1", "--out", &cold_out, "--cache-out", &snap,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(std::fs::metadata(&snap).unwrap().len() > 2, "snapshot is empty");

    let warm_out = path("warm.json");
    let out = run(&[
        "sweep", "--net", "serve_cnn", "--combos", "1", "--out", &warm_out, "--cache-in", &snap,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        std::fs::read(&warm_out).unwrap(),
        std::fs::read(&cold_out).unwrap(),
        "a shipped cache snapshot changed the sweep output"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
