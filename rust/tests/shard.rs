//! Property tests for the sharded sweep service (`sim::shard`): any shard
//! partition of a [`SweepSpec`], merged, must be **byte-identical** to the
//! unsharded run — across thread counts and across cold, prewarmed, and
//! snapshot-loaded plan caches. This is the invariant that lets shards run
//! as independent processes with no coordination.

use bf_imna::mapper::CacheSnapshot;
use bf_imna::sim::shard::{self, ChipGeom, MetricSet, PrecisionGrid, SweepSpec};
use bf_imna::sim::SweepEngine;
use bf_imna::util::json::Json;
use bf_imna::util::proptest::check;

fn mixed_spec(net: &str, combos: usize, seed: u64) -> SweepSpec {
    SweepSpec::single(
        net,
        vec!["lr".to_string()],
        vec!["sram".to_string()],
        PrecisionGrid::Mixed { targets: vec![2.0, 5.0, 8.0], combos, seed },
    )
}

#[test]
fn any_shard_partition_merges_bit_identical() {
    check("sharded merge == unsharded sweep", 10, |rng| {
        let net = ["serve_cnn", "alexnet"][rng.below(2) as usize];
        let spec = mixed_spec(net, 1 + rng.below(2) as usize, rng.below(1000));
        let full = shard::run_full(&spec, &SweepEngine::serial())?.to_string();
        let shards = 1 + rng.below(6) as usize;
        let mut docs = Vec::new();
        for k in 0..shards {
            // Every worker gets its own engine with a random thread count
            // and a randomly cold or prewarmed cache — none of which may
            // change a single output bit.
            let engine = SweepEngine::with_threads(1 + rng.below(4) as usize);
            if rng.bool() {
                let resolved = spec.resolve()?;
                let range = shard::shard_range(resolved.num_points(), shards, k);
                engine.prewarm(&resolved.points(range));
            }
            docs.push(shard::run_shard(&spec, shards, k, &engine)?.to_json());
        }
        let merged = shard::merge(&docs)?.to_string();
        if merged != full {
            return Err(format!("net={net} shards={shards}: merged != unsharded"));
        }
        Ok(())
    });
}

#[test]
fn snapshot_loaded_worker_never_maps_and_stays_bit_identical() {
    let spec = SweepSpec::single(
        "serve_cnn",
        vec!["lr".to_string()],
        vec!["sram".to_string(), "reram".to_string()],
        PrecisionGrid::Fixed { bits: vec![2, 5, 8] },
    );
    let resolved = spec.resolve().unwrap();
    let points = resolved.points(0..resolved.num_points());

    // Coordinator side: prewarm, snapshot, serialize to text (the wire).
    let donor = SweepEngine::serial();
    donor.prewarm(&points);
    let wire = donor.cache().snapshot().to_json().to_string();

    // Worker side: absorb the shipped snapshot, then sweep in parallel.
    let snap = CacheSnapshot::from_json(&Json::parse(&wire).unwrap()).unwrap();
    let worker = SweepEngine::with_threads(3);
    assert!(worker.cache().absorb(&snap) > 0);
    let from_snapshot = worker.run(&points);
    assert_eq!(worker.cache_stats().misses, 0, "worker mapped cold despite the snapshot");

    // A cold engine computes the same bits.
    let cold = SweepEngine::serial().run(&points);
    assert_eq!(from_snapshot.len(), cold.len());
    for (s, c) in from_snapshot.iter().zip(&cold) {
        assert_eq!(s.energy_j().to_bits(), c.energy_j().to_bits());
        assert_eq!(s.latency_s().to_bits(), c.latency_s().to_bits());
        assert_eq!(s.cfg_name, c.cfg_name);
    }
}

#[test]
fn spec_json_round_trip_random() {
    check("spec json round trip", 32, |rng| {
        let nets = ["alexnet", "vgg16", "resnet18", "resnet50", "serve_cnn"];
        let hw_all = ["lr", "ir"];
        let tech_all = ["sram", "reram", "pcm", "fefet"];
        let pick = |rng: &mut bf_imna::util::rng::Rng, all: &[&str]| -> Vec<String> {
            let n = 1 + rng.below(all.len() as u64) as usize;
            (0..n).map(|_| all[rng.below(all.len() as u64) as usize].to_string()).collect()
        };
        let grid = if rng.bool() {
            PrecisionGrid::Fixed {
                bits: (0..1 + rng.below(6)).map(|_| 2 + rng.below(7) as u32).collect(),
            }
        } else {
            PrecisionGrid::Mixed {
                targets: (0..1 + rng.below(6)).map(|_| 2.0 + rng.f64() * 6.0).collect(),
                combos: 1 + rng.below(8) as usize,
                seed: rng.next_u64(),
            }
        };
        // 1–2 chip geometries with unique names and random overrides: the
        // geometry axis must round-trip and merge like any other.
        let mut chips = vec![ChipGeom::default_chip()];
        if rng.bool() {
            chips.push(ChipGeom {
                mesh_bits_per_transfer: if rng.bool() { Some(256 + rng.below(2048)) } else { None },
                caps_x: if rng.bool() { Some(1 + rng.below(16)) } else { None },
                ..ChipGeom::named("variant")
            });
        }
        // Half the specs select a random metric subset — metric selection
        // must round-trip like every other spec axis.
        let metrics = if rng.bool() {
            MetricSet::Full
        } else {
            let picked: Vec<&str> = shard::METRIC_NAMES
                .iter()
                .filter(|_| rng.bool())
                .copied()
                .collect();
            if picked.is_empty() { MetricSet::Full } else { MetricSet::subset(&picked)? }
        };
        // Half the specs carry a non-default costs axis — cost tables
        // must round-trip inside the spec like every other axis.
        let mut costs = vec![bf_imna::costs::default_table().clone()];
        if rng.bool() {
            costs.push(bf_imna::costs::scaled_0v5_table().clone());
        }
        let spec = SweepSpec {
            nets: {
                let n = 1 + rng.below(2) as usize;
                (0..n).map(|_| nets[rng.below(nets.len() as u64) as usize].to_string()).collect()
            },
            hw: pick(rng, &hw_all),
            tech: pick(rng, &tech_all),
            chips,
            grid,
            batch: 1 + rng.below(8),
            metrics,
            costs,
        };
        let text = spec.to_json().to_string();
        let back = SweepSpec::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)?;
        if back != spec {
            return Err(format!("round trip changed the spec: {text}"));
        }
        if back.to_json().to_string() != text {
            return Err("re-serialization is not stable".to_string());
        }
        Ok(())
    });
}

#[test]
fn merge_validates_partition_shape() {
    let spec = mixed_spec("serve_cnn", 1, 7);
    let docs: Vec<Json> = (0..3)
        .map(|k| shard::run_shard(&spec, 3, k, &SweepEngine::serial()).unwrap().to_json())
        .collect();
    // Any strict subset, duplicate, or cross-spec mix must be rejected.
    assert!(shard::merge(&docs[..2]).is_err());
    assert!(shard::merge(&[docs[0].clone(), docs[0].clone(), docs[2].clone()]).is_err());
    let other = mixed_spec("serve_cnn", 1, 8);
    let alien = shard::run_shard(&other, 3, 1, &SweepEngine::serial()).unwrap().to_json();
    assert!(shard::merge(&[docs[0].clone(), alien, docs[2].clone()]).is_err());
    // The correct set, in any order, merges fine.
    let merged = shard::merge(&[docs[2].clone(), docs[0].clone(), docs[1].clone()]).unwrap();
    assert_eq!(
        merged.get("n_points").and_then(Json::as_i64).unwrap(),
        spec.resolve().unwrap().num_points() as i64
    );

    // A truncated *final* shard keeps ids, starts, and indices contiguous —
    // only the spec-coverage check can reject it.
    let mut truncated = docs.clone();
    if let Json::Obj(m) = &mut truncated[2] {
        if let Some(Json::Arr(points)) = m.get_mut("points") {
            assert!(points.pop().is_some(), "last shard should carry points");
        }
    }
    let err = shard::merge(&truncated).unwrap_err();
    assert!(err.contains("enumerates"), "{err}");
}

#[test]
fn shard_range_overpartition_gives_empty_trailing_ranges() {
    // More shards than points: the first n shards get one point each, the
    // rest are empty — and the whole family still partitions 0..n. The
    // transport now hits these ranges programmatically (a dispatcher may
    // be configured with more shards than the sweep has points), so the
    // edge cases deserve direct coverage.
    for n in [0usize, 1, 2, 4] {
        for shards in [n + 1, n + 3, 16] {
            let mut covered = Vec::new();
            for k in 0..shards {
                let r = shard::shard_range(n, shards, k);
                assert!(r.len() <= 1, "n={n} shards={shards} k={k}: range {r:?} too wide");
                if k >= n {
                    assert!(r.is_empty(), "n={n} shards={shards} k={k}: expected empty, got {r:?}");
                    assert_eq!(r.start, n, "empty ranges sit at the end of the point space");
                }
                covered.extend(r);
            }
            assert_eq!(covered, (0..n).collect::<Vec<_>>(), "n={n} shards={shards}");
        }
    }
}

#[test]
fn shard_range_single_point_spec_lands_in_shard_zero() {
    assert_eq!(shard::shard_range(1, 1, 0), 0..1);
    for shards in [2usize, 5, 9] {
        assert_eq!(shard::shard_range(1, shards, 0), 0..1);
        for k in 1..shards {
            assert!(shard::shard_range(1, shards, k).is_empty());
        }
    }
}

#[test]
fn shard_range_last_shard_carries_no_remainder_bias() {
    // The remainder spreads over the *first* `rem` shards; the last shard
    // gets the base size and always ends exactly at n.
    for (n, shards) in [(7usize, 3usize), (10, 3), (35, 8), (6, 4), (100, 7)] {
        let last = shard::shard_range(n, shards, shards - 1);
        assert_eq!(last.end, n, "n={n} shards={shards}: last range {last:?} misses the end");
        assert_eq!(last.len(), n / shards, "n={n} shards={shards}: last shard must be base-sized");
        let first = shard::shard_range(n, shards, 0);
        assert_eq!(first.len(), n / shards + usize::from(n % shards > 0));
    }
}

#[test]
fn empty_shards_run_and_merge_byte_identically() {
    // End-to-end over-partition: 4 points into 6 shards (two of them
    // empty) must still merge to the exact single-process bytes.
    let spec = SweepSpec::single(
        "serve_cnn",
        vec!["lr".to_string()],
        vec!["sram".to_string()],
        PrecisionGrid::Fixed { bits: vec![2, 4, 6, 8] },
    );
    let full = shard::run_full(&spec, &SweepEngine::serial()).unwrap().to_string();
    let docs: Vec<Json> = (0..6)
        .map(|k| shard::run_shard(&spec, 6, k, &SweepEngine::serial()).unwrap().to_json())
        .collect();
    for k in 4..6 {
        let pts = docs[k].get("points").and_then(Json::as_arr).unwrap();
        assert!(pts.is_empty(), "shard {k} of an overpartition should be empty");
    }
    assert_eq!(shard::merge(&docs).unwrap().to_string(), full);
}

#[test]
fn invalid_specs_fail_to_resolve_before_any_work() {
    // resolve() enforces the same validity rules from_json does, so specs
    // built in code (e.g. by the CLI) cannot smuggle in degenerate grids.
    let mut spec = mixed_spec("serve_cnn", 1, 7);
    spec.grid = PrecisionGrid::Mixed { targets: vec![2.0, 5.0], combos: 0, seed: 7 };
    assert!(spec.resolve().is_err());
    let mut spec = mixed_spec("serve_cnn", 1, 7);
    spec.grid = PrecisionGrid::Fixed { bits: vec![0] };
    assert!(spec.resolve().is_err());
    let mut spec = mixed_spec("serve_cnn", 1, 7);
    spec.grid = PrecisionGrid::Fixed { bits: vec![65] };
    assert!(spec.resolve().is_err());
    let mut spec = mixed_spec("serve_cnn", 1, 7);
    spec.batch = 0;
    assert!(spec.resolve().is_err());
}
