//! Failure-injection suite for the HTTP worker-pool transport
//! (`sim::transport`).
//!
//! The invariant under attack: a `dispatch` over a worker fleet must
//! produce a document **byte-identical** to the single-process
//! `shard::run_full`, no matter what the fleet does — workers dying before
//! or mid-request, workers replying garbage bytes, non-JSON HTTP, or
//! valid-but-wrong shard documents. Corruption must be retried elsewhere,
//! never merged.
//!
//! The byte-level protocol tests also hit a live worker socket with
//! malformed HTTP and assert clean 4xx replies (no panics, no hangs), and
//! the dead-worker test exports its merged + reference documents to
//! `CARGO_TARGET_TMPDIR` so CI can upload them as a debugging artifact.
//!
//! The keep-alive suite attacks the connection-oriented layer the same
//! way: pipelined exchanges on one socket, half-closed peers, hogs that
//! exceed the per-connection request cap, servers that restart under a
//! pooled client, and workers that die mid-pipeline — the merged bytes
//! must never change.
//!
//! The fleet-churn suite does the same to the elastic dispatcher
//! (`sim::fleet`): workers leaving mid-sweep, workers joining mid-sweep,
//! fingerprint-mismatched workers bounced at registration, and
//! store-backed re-runs that must compute only novel points — all
//! byte-identical to `shard::run_full`. It also pins the pooled-retry
//! contract: a non-idempotent request that fails *after* its bytes
//! reached a reused socket is never silently re-executed.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use bf_imna::sim::fleet::{
    dispatch_elastic, spawn_heartbeat, ElasticOpts, FleetOpts, FleetServer, WorkerSource,
};
use bf_imna::sim::shard::{self, PrecisionGrid, ShardRequest, ShardResult, SweepSpec};
use bf_imna::sim::store::ResultStore;
use bf_imna::sim::transport::{
    dispatch, http_request, http_request_json, read_response, write_request_conn, ConnPool,
    DispatchOpts, WorkerOpts, WorkerServer, CODE_FINGERPRINT_MISMATCH, CODE_WORKER_BUSY,
};
use bf_imna::sim::SweepEngine;
use bf_imna::util::json::Json;

/// A small but non-trivial sweep: 2 grid cells x 4 precision configs.
fn small_spec() -> SweepSpec {
    SweepSpec::single(
        "serve_cnn",
        vec!["lr".to_string()],
        vec!["sram".to_string(), "reram".to_string()],
        PrecisionGrid::Fixed { bits: vec![2, 3, 4, 5] },
    )
}

/// The single-process reference document (canonical text).
fn reference(spec: &SweepSpec) -> String {
    shard::run_full(spec, &SweepEngine::serial()).unwrap().to_string()
}

fn spawn_workers(n: usize) -> Vec<WorkerServer> {
    (0..n)
        .map(|_| WorkerServer::spawn("127.0.0.1:0", SweepEngine::with_threads(2)).expect("bind worker"))
        .collect()
}

fn addrs(workers: &[WorkerServer]) -> Vec<String> {
    workers.iter().map(|w| w.addr().to_string()).collect()
}

fn opts(shards: usize) -> DispatchOpts {
    DispatchOpts { shards, timeout: Duration::from_secs(30), ..DispatchOpts::default() }
}

/// A fake worker that accepts `accepts` connections, reading a bit of each
/// request and then dropping the connection without a reply (a worker
/// crashing mid-compute), after which its listener drops too and the port
/// refuses connections (a worker that is gone). The thread leaks if never
/// connected to; tests do not join it.
fn spawn_dying_worker(accepts: usize) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind dying worker");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        for _ in 0..accepts {
            let Ok((mut stream, _)) = listener.accept() else { return };
            let mut buf = [0u8; 256];
            let _ = stream.read(&mut buf);
            // Drop the stream mid-request: the dispatcher sees a reset.
        }
    });
    addr
}

/// A fake worker that answers every connection with a fixed byte string.
fn spawn_garbage_worker(reply: Vec<u8>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind garbage worker");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || loop {
        let Ok((mut stream, _)) = listener.accept() else { return };
        let mut buf = [0u8; 4096];
        let _ = stream.read(&mut buf);
        let _ = stream.write_all(&reply);
    });
    addr
}

fn http_200(body: &str) -> Vec<u8> {
    format!("HTTP/1.1 200 OK\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}", body.len())
        .into_bytes()
}

#[test]
fn dispatch_over_a_healthy_pool_is_byte_identical_to_run_full() {
    let spec = small_spec();
    let full = reference(&spec);
    let workers = spawn_workers(3);
    let report = dispatch(&spec, &addrs(&workers), &opts(5)).expect("dispatch");
    assert_eq!(report.doc.to_string(), full, "merged transport doc differs from run_full");
    assert_eq!(report.retries, 0, "healthy pool should not retry");
    let served: usize = report.per_worker.iter().map(|(_, n)| n).sum();
    assert_eq!(served, 5, "{:?}", report.per_worker);

    // The workers' own stats agree with the dispatch report.
    let mut stats_served = 0;
    for w in &workers {
        let (status, stats) =
            http_request_json(&w.addr().to_string(), "GET", "/stats", b"", Duration::from_secs(10))
                .expect("GET /stats");
        assert_eq!(status, 200);
        stats_served += stats.get("shards_served").and_then(Json::as_i64).unwrap_or(0) as usize;
        assert!(stats.get("cache").and_then(|c| c.get("entries")).is_some(), "{stats}");
    }
    assert_eq!(stats_served, 5);
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn dead_worker_range_is_reassigned_and_merge_stays_byte_identical() {
    let spec = small_spec();
    let full = reference(&spec);
    let mut workers = spawn_workers(3);
    let pool = addrs(&workers);

    // Kill worker 0: drop its listener so every request to it is refused.
    // Its shard range must be reassigned to the survivors.
    workers.remove(0).shutdown();

    let report = dispatch(&spec, &pool, &opts(6)).expect("dispatch over a pool with a dead worker");

    // Export the documents *before* asserting on them, so CI's artifact
    // upload has the merged-vs-reference pair to diff exactly when the
    // byte-identity assertion below fails.
    let tmp = std::path::Path::new(env!("CARGO_TARGET_TMPDIR"));
    std::fs::write(tmp.join("transport_failover_merged.json"), format!("{}\n", report.doc))
        .expect("write merged artifact");
    std::fs::write(tmp.join("transport_failover_reference.json"), format!("{full}\n"))
        .expect("write reference artifact");

    assert_eq!(report.doc.to_string(), full, "reassigned merge differs from run_full");
    assert!(report.retries >= 1, "dead worker produced no retries: {:?}", report.per_worker);
    assert_eq!(report.per_worker[0].1, 0, "a dead worker cannot serve shards");

    for w in workers {
        w.shutdown();
    }
}

#[test]
fn worker_dying_mid_request_is_retried_elsewhere() {
    let spec = small_spec();
    let full = reference(&spec);
    let workers = spawn_workers(2);
    // The dying worker resets its first two connections mid-request, then
    // refuses outright — both failure shapes feed the same reassignment.
    let mut pool = vec![spawn_dying_worker(2)];
    pool.extend(addrs(&workers));

    let report = dispatch(&spec, &pool, &opts(6)).expect("dispatch with a mid-request death");
    assert_eq!(report.doc.to_string(), full);
    assert_eq!(report.per_worker[0].1, 0, "the dying worker never completed a shard");
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn garbage_replies_are_never_merged() {
    let spec = small_spec();
    let full = reference(&spec);

    // Three corruption shapes: raw non-HTTP bytes, a 200 whose body is not
    // JSON, and — the subtle one — a well-formed ShardResult for the wrong
    // shard (it may only ever be accepted for the shard it truthfully
    // describes).
    let liar_doc =
        shard::run_shard(&spec, 6, 0, &SweepEngine::serial()).unwrap().to_json().to_string();
    let healthy = spawn_workers(1);
    let pool = vec![
        spawn_garbage_worker(b"\x16\x03\x01 utter garbage, not http".to_vec()),
        spawn_garbage_worker(http_200("this is not json {")),
        spawn_garbage_worker(http_200(&liar_doc)),
        addrs(&healthy)[0].clone(),
    ];

    let mut dopts = opts(6);
    // Garbage workers fail fast; allow a few strikes before retirement so
    // the validation path is exercised repeatedly.
    dopts.max_worker_failures = 2;
    let report = dispatch(&spec, &pool, &dopts).expect("dispatch across garbage workers");
    assert_eq!(report.doc.to_string(), full, "a corrupt reply leaked into the merge");
    assert!(report.retries >= 1, "garbage workers never got probed: {:?}", report.per_worker);
    // The raw-garbage and non-JSON workers can never complete a shard. (The
    // liar can — but only for the one shard where its reply is the truth.)
    assert_eq!(report.per_worker[0].1, 0);
    assert_eq!(report.per_worker[1].1, 0);
    for w in healthy {
        w.shutdown();
    }
}

#[test]
fn overpartitioned_dispatch_with_empty_shards_is_byte_identical() {
    // More shards than points: trailing shards are empty ranges, which the
    // workers compute (trivially) and merge accepts.
    let spec = SweepSpec::single(
        "serve_cnn",
        vec!["lr".to_string()],
        vec!["sram".to_string()],
        PrecisionGrid::Fixed { bits: vec![4, 8] },
    );
    let full = reference(&spec);
    let workers = spawn_workers(2);
    let report = dispatch(&spec, &addrs(&workers), &opts(5)).expect("overpartitioned dispatch");
    assert_eq!(report.doc.to_string(), full);
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn wire_prewarm_is_transparent_to_output_bytes() {
    let spec = small_spec();
    let full = reference(&spec);

    // Warm a donor engine locally, snapshot its plan cache, and ship it.
    let donor = SweepEngine::serial();
    shard::run_full(&spec, &donor).unwrap();
    let snap = donor.cache().snapshot();
    assert!(snap.len() > 0, "donor cache is empty");

    let workers = spawn_workers(2);
    let pool = addrs(&workers);

    // Shipping the snapshot directly reports absorbed plans...
    let (status, reply) = http_request_json(
        &pool[0],
        "POST",
        "/cache",
        snap.to_json().to_string().as_bytes(),
        Duration::from_secs(10),
    )
    .expect("POST /cache");
    assert_eq!(status, 200, "{reply}");
    assert!(reply.get("absorbed").and_then(Json::as_i64).unwrap_or(0) > 0, "{reply}");

    // ...and a prewarmed dispatch still produces identical bytes.
    let mut dopts = opts(4);
    dopts.prewarm = Some(snap);
    let report = dispatch(&spec, &pool, &dopts).expect("prewarmed dispatch");
    assert_eq!(report.doc.to_string(), full, "wire prewarm changed output bytes");
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn all_workers_dead_fails_with_a_clear_error_not_a_hang() {
    let spec = small_spec();
    let workers = spawn_workers(2);
    let pool = addrs(&workers);
    for w in workers {
        w.shutdown();
    }
    let err = dispatch(&spec, &pool, &opts(4)).expect_err("dispatch over a dead pool");
    assert!(err.contains("shards unassigned"), "{err}");
}

/// Send raw bytes to a live worker socket and return the full reply text.
fn raw_roundtrip(addr: &str, bytes: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(bytes).expect("send");
    let _ = s.shutdown(Shutdown::Write);
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    String::from_utf8_lossy(&out).to_string()
}

#[test]
fn protocol_abuse_gets_clean_4xx_and_the_worker_survives() {
    let worker = spawn_workers(1).remove(0);
    let addr = worker.addr().to_string();

    let cases: Vec<(Vec<u8>, &str)> = vec![
        (b"GARBAGE\r\n\r\n".to_vec(), "400"),
        (b"GET / HTTP/9.9\r\n\r\n".to_vec(), "505"),
        (b"POST /shard HTTP/1.1\r\n\r\n".to_vec(), "411"),
        (b"POST /shard HTTP/1.1\r\ncontent-length: nope\r\n\r\n".to_vec(), "400"),
        (
            format!("POST /shard HTTP/1.1\r\ncontent-length: {}\r\n\r\n", 1usize << 40).into_bytes(),
            "413",
        ),
        // Truncated body: declares 64 bytes, sends 9, closes.
        (b"POST /shard HTTP/1.1\r\ncontent-length: 64\r\n\r\ntruncated".to_vec(), "400"),
        // Valid HTTP, invalid shard request JSON.
        (b"POST /shard HTTP/1.1\r\ncontent-length: 8\r\n\r\nnot json".to_vec(), "400"),
        (b"GET /no-such-endpoint HTTP/1.1\r\n\r\n".to_vec(), "404"),
        (b"DELETE /healthz HTTP/1.1\r\ncontent-length: 0\r\n\r\n".to_vec(), "405"),
    ];
    for (bytes, expect) in cases {
        let reply = raw_roundtrip(&addr, &bytes);
        assert!(
            reply.starts_with(&format!("HTTP/1.1 {expect}")),
            "input {:?} expected {expect}, got reply {:?}",
            String::from_utf8_lossy(&bytes),
            reply.lines().next().unwrap_or("")
        );
    }

    // Garbage cache snapshots are rejected, not absorbed.
    let (status, _) =
        http_request(&addr, "POST", "/cache", b"{\"version\":99}", Duration::from_secs(10))
            .expect("POST /cache");
    assert_eq!(status, 400);

    // After all that abuse the worker still serves: health, then a real
    // shard whose document matches an in-process run exactly.
    let (status, health) =
        http_request_json(&addr, "GET", "/healthz", b"", Duration::from_secs(10)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));

    let spec = small_spec();
    let order = bf_imna::sim::shard::ShardRequest { spec: spec.clone(), shards: 2, shard_id: 1 };
    let (status, doc) = http_request_json(
        &addr,
        "POST",
        "/shard",
        order.to_json().to_string().as_bytes(),
        Duration::from_secs(30),
    )
    .unwrap();
    assert_eq!(status, 200);
    let got = ShardResult::from_json(&doc).expect("worker replied a valid shard document");
    let want = shard::run_shard(&spec, 2, 1, &SweepEngine::serial()).unwrap();
    assert_eq!(got.to_json().to_string(), want.to_json().to_string());

    // The stats endpoint recorded both the abuse and the served shard.
    let (status, stats) =
        http_request_json(&addr, "GET", "/stats", b"", Duration::from_secs(10)).unwrap();
    assert_eq!(status, 200);
    assert!(stats.get("protocol_errors").and_then(Json::as_i64).unwrap_or(0) >= 1, "{stats}");
    assert_eq!(stats.get("shards_served").and_then(Json::as_i64), Some(1), "{stats}");

    worker.shutdown();
}

/// A sweep heavy enough to keep a worker's single compute slot busy for a
/// while (two big ImageNet nets x two chips x 28 mixed configs).
fn heavy_spec() -> SweepSpec {
    let mut spec = SweepSpec::fig7("vgg16", "lr", 4, 7);
    spec.nets = vec!["vgg16".to_string(), "resnet50".to_string()];
    spec.hw = vec!["lr".to_string(), "ir".to_string()];
    spec
}

#[test]
fn over_limit_shard_requests_get_machine_readable_503_and_the_worker_survives() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    // One compute slot, no admission queue: any overlap must be bounced.
    let worker = WorkerServer::spawn_with(
        "127.0.0.1:0",
        SweepEngine::with_threads(2),
        WorkerOpts { max_concurrent_shards: 1, admission_queue: 0, ..WorkerOpts::default() },
    )
    .expect("bind worker");
    let addr = worker.addr().to_string();

    // Occupy the slot with a heavy shard from a background thread.
    let done = Arc::new(AtomicBool::new(false));
    let first = {
        let addr = addr.clone();
        let done = Arc::clone(&done);
        thread::spawn(move || {
            let order = ShardRequest { spec: heavy_spec(), shards: 1, shard_id: 0 };
            let out = http_request_json(
                &addr,
                "POST",
                "/shard",
                order.to_json().to_string().as_bytes(),
                Duration::from_secs(300),
            );
            done.store(true, Ordering::SeqCst);
            out
        })
    };

    // Wait until the worker reports the shard in flight (or the heavy
    // shard somehow finishes first — then the 503 leg is skipped rather
    // than made flaky).
    let mut saw_in_flight = false;
    while !done.load(Ordering::SeqCst) {
        let (status, stats) =
            http_request_json(&addr, "GET", "/stats", b"", Duration::from_secs(10))
                .expect("GET /stats");
        assert_eq!(status, 200);
        if stats.get("shards_in_flight").and_then(Json::as_i64).unwrap_or(0) >= 1 {
            saw_in_flight = true;
            break;
        }
        thread::sleep(Duration::from_millis(1));
    }

    if saw_in_flight {
        // The overlap request must bounce with the machine-readable code,
        // fast — the worker replies without waiting for the heavy shard.
        let order = ShardRequest { spec: small_spec(), shards: 1, shard_id: 0 };
        let (status, reply) = http_request_json(
            &addr,
            "POST",
            "/shard",
            order.to_json().to_string().as_bytes(),
            Duration::from_secs(30),
        )
        .expect("overlap request");
        if status == 503 {
            assert_eq!(
                reply.get("code").and_then(Json::as_str),
                Some(CODE_WORKER_BUSY),
                "{reply}"
            );
        } else {
            // Lost the race: the heavy shard finished between the stats
            // poll and this request — it must then have been served fully.
            assert_eq!(status, 200, "{reply}");
            ShardResult::from_json(&reply).expect("valid shard reply");
        }
    }

    // The occupied slot's own request completes with a valid document.
    let (status, doc) = first.join().expect("heavy-shard thread").expect("heavy shard reply");
    assert_eq!(status, 200);
    ShardResult::from_json(&doc).expect("heavy shard document is valid");

    // And after the backpressure episode the worker still serves.
    let (status, health) =
        http_request_json(&addr, "GET", "/healthz", b"", Duration::from_secs(10)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    let (_, stats) =
        http_request_json(&addr, "GET", "/stats", b"", Duration::from_secs(10)).unwrap();
    if saw_in_flight {
        // Either the bounce was recorded, or the race resolved to a serve.
        let bounced = stats.get("busy_rejections").and_then(Json::as_i64).unwrap_or(0);
        let served = stats.get("shards_served").and_then(Json::as_i64).unwrap_or(0);
        assert!(bounced + served >= 2, "{stats}");
    }
    worker.shutdown();
}

#[test]
fn busy_bounces_are_retried_not_counted_toward_retirement() {
    // One single-slot, zero-queue worker addressed twice: the dispatcher
    // runs two threads against the same socket, so overlapping requests
    // bounce with 503 worker-busy. With max_worker_failures = 1, a single
    // *counted* failure would retire a thread — so the dispatch can only
    // succeed if busy bounces are handled as backpressure, not failures.
    let spec = small_spec();
    let full = reference(&spec);
    let worker = WorkerServer::spawn_with(
        "127.0.0.1:0",
        SweepEngine::with_threads(2),
        WorkerOpts { max_concurrent_shards: 1, admission_queue: 0, ..WorkerOpts::default() },
    )
    .expect("bind worker");
    let pool = vec![worker.addr().to_string(), worker.addr().to_string()];

    let mut dopts = opts(6);
    dopts.max_worker_failures = 1;
    let report = dispatch(&spec, &pool, &dopts).expect("dispatch under backpressure");
    assert_eq!(report.doc.to_string(), full, "backpressure changed the merged bytes");
    assert_eq!(report.retries, 0, "busy bounces must not count as failures");
    let served: usize = report.per_worker.iter().map(|(_, n)| n).sum();
    assert_eq!(served, 6);
    worker.shutdown();
}

#[test]
fn admission_queue_serializes_instead_of_rejecting() {
    // With a queue, overlapping requests wait for the slot instead of
    // bouncing: a multi-shard dispatch against one single-slot worker
    // completes with zero retries of any kind.
    let spec = small_spec();
    let full = reference(&spec);
    let worker = WorkerServer::spawn_with(
        "127.0.0.1:0",
        SweepEngine::with_threads(2),
        WorkerOpts { max_concurrent_shards: 1, admission_queue: 8, ..WorkerOpts::default() },
    )
    .expect("bind worker");
    let pool = vec![worker.addr().to_string(), worker.addr().to_string()];
    let report = dispatch(&spec, &pool, &opts(5)).expect("queued dispatch");
    assert_eq!(report.doc.to_string(), full);
    assert_eq!(report.retries, 0);
    assert_eq!(report.busy_retries, 0, "the queue should absorb the overlap");
    worker.shutdown();
}

// ---- keep-alive and connection-pool failure injection ------------------

/// One keep-alive GET, as raw bytes (HTTP/1.1 defaults to keep-alive).
fn raw_get(path: &str) -> Vec<u8> {
    format!("GET {path} HTTP/1.1\r\nhost: test\r\ncontent-length: 0\r\n\r\n").into_bytes()
}

#[test]
fn pipelined_exchanges_ride_one_connection_until_close() {
    let worker = spawn_workers(1).remove(0);
    let addr = worker.addr().to_string();

    // Three requests pipelined onto one socket: two keep-alive, then an
    // explicit close. The server must answer all three in order on the
    // same connection and hang up only after the third.
    let mut bytes = raw_get("/healthz");
    bytes.extend(raw_get("/healthz"));
    bytes.extend(
        b"GET /healthz HTTP/1.1\r\nhost: test\r\ncontent-length: 0\r\nconnection: close\r\n\r\n"
            .to_vec(),
    );
    let reply = raw_roundtrip(&addr, &bytes);
    assert_eq!(reply.matches("HTTP/1.1 200").count(), 3, "{reply}");
    assert_eq!(reply.matches("connection: keep-alive").count(), 2, "{reply}");
    assert_eq!(reply.matches("connection: close").count(), 1, "{reply}");

    // The worker counted one connection for all three exchanges (the
    // stats probe below is the second).
    let (status, stats) =
        http_request_json(&addr, "GET", "/stats", b"", Duration::from_secs(10)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(stats.get("connections").and_then(Json::as_i64), Some(2), "{stats}");
    worker.shutdown();
}

#[test]
fn half_closed_peer_still_gets_its_reply() {
    // A client that sends its request and immediately shuts down its write
    // half (FIN) has not aborted — the server must still parse, serve, and
    // reply, then close cleanly on the EOF.
    let worker = spawn_workers(1).remove(0);
    let reply = raw_roundtrip(&worker.addr().to_string(), &raw_get("/healthz"));
    assert!(reply.starts_with("HTTP/1.1 200"), "{reply}");
    assert!(reply.contains("\"ok\":true"), "{reply}");
    worker.shutdown();
}

#[test]
fn hog_connection_exceeding_the_request_cap_gets_a_clean_close() {
    // Cap at 2 requests per connection: a hog asking for more gets its 2
    // replies and then a clean close — the third exchange yields EOF (or
    // a reset), never a third reply.
    let worker = WorkerServer::spawn_with(
        "127.0.0.1:0",
        SweepEngine::with_threads(2),
        WorkerOpts { max_requests_per_conn: 2, ..WorkerOpts::default() },
    )
    .expect("bind worker");
    let addr = worker.addr().to_string();

    let mut s = TcpStream::connect(&addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(30))).unwrap();
    for i in 0..2 {
        write_request_conn(&mut s, "GET", "/healthz", &addr, b"", false).expect("send");
        let (status, _) = read_response(&mut s).unwrap_or_else(|e| panic!("reply {i}: {e:?}"));
        assert_eq!(status, 200);
    }
    let _ = write_request_conn(&mut s, "GET", "/healthz", &addr, b"", false);
    let mut rest = Vec::new();
    let _ = s.read_to_end(&mut rest);
    assert!(
        rest.is_empty(),
        "bytes followed the capped close: {:?}",
        String::from_utf8_lossy(&rest)
    );

    // The cap recycles the connection; it does not wound the worker.
    let (status, health) =
        http_request_json(&addr, "GET", "/healthz", b"", Duration::from_secs(10)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    worker.shutdown();
}

#[test]
fn idle_connections_are_closed_after_the_idle_timeout() {
    let worker = WorkerServer::spawn_with(
        "127.0.0.1:0",
        SweepEngine::with_threads(2),
        WorkerOpts { idle_timeout: Duration::from_millis(100), ..WorkerOpts::default() },
    )
    .expect("bind worker");
    let addr = worker.addr().to_string();

    // One keep-alive exchange, then silence: the server must reply (with
    // keep-alive intent), wait out the idle budget, and close — so the
    // read below terminates with EOF instead of hanging.
    let mut s = TcpStream::connect(&addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(&raw_get("/healthz")).expect("send");
    let mut out = Vec::new();
    s.read_to_end(&mut out).expect("server closes the idle connection");
    let reply = String::from_utf8_lossy(&out);
    assert!(reply.starts_with("HTTP/1.1 200"), "{reply}");
    assert!(reply.contains("connection: keep-alive"), "{reply}");
    worker.shutdown();
}

#[test]
fn pooled_exchanges_reuse_the_worker_connection() {
    let worker = spawn_workers(1).remove(0);
    let addr = worker.addr().to_string();
    let pool = ConnPool::new(2);
    for _ in 0..3 {
        let (status, _) = pool
            .request(&addr, "GET", "/healthz", b"", Duration::from_secs(10))
            .expect("pooled /healthz");
        assert_eq!(status, 200);
    }
    let ps = pool.stats();
    assert_eq!(ps.fresh_connects, 1, "{ps:?}");
    assert_eq!(ps.reuses, 2, "{ps:?}");

    // The worker agrees: one connection from the pool, one from the
    // fresh stats probe itself.
    let (_, stats) =
        http_request_json(&addr, "GET", "/stats", b"", Duration::from_secs(10)).unwrap();
    assert_eq!(stats.get("connections").and_then(Json::as_i64), Some(2), "{stats}");
    worker.shutdown();
}

/// Minimal framed-HTTP peer for restart tests: read one request head off
/// `s` (requests in these tests carry no body), or `false` on EOF.
fn read_request_head(s: &mut TcpStream) -> bool {
    let mut tail = Vec::new();
    let mut b = [0u8; 1];
    loop {
        match s.read(&mut b) {
            Ok(1) => {
                tail.push(b[0]);
                if tail.ends_with(b"\r\n\r\n") {
                    return true;
                }
            }
            _ => return false,
        }
    }
}

const KEEPALIVE_200: &[u8] =
    b"HTTP/1.1 200 OK\r\ncontent-length: 2\r\nconnection: keep-alive\r\n\r\n{}";

#[test]
fn pooled_client_survives_a_server_restart_between_exchanges() {
    // A server that serves one exchange, closes the connection (restart),
    // then serves a second connection indefinitely. The pool's second
    // request must transparently land on a fresh connection — via the
    // health check or the one-shot stale retry, depending on whether the
    // FIN has arrived — and succeed either way.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind fake server");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            if read_request_head(&mut s) {
                let _ = s.write_all(KEEPALIVE_200);
            }
            // Dropping `s` here is the restart: the pooled socket dies.
        }
        if let Ok((mut s, _)) = listener.accept() {
            while read_request_head(&mut s) {
                if s.write_all(KEEPALIVE_200).is_err() {
                    return;
                }
            }
        }
    });

    let pool = ConnPool::new(2);
    let (status, _) =
        pool.request(&addr, "GET", "/ping", b"", Duration::from_secs(10)).expect("first exchange");
    assert_eq!(status, 200);
    // Let the server's FIN land (or not — both paths must work).
    thread::sleep(Duration::from_millis(50));
    let (status, _) = pool
        .request(&addr, "GET", "/ping", b"", Duration::from_secs(10))
        .expect("exchange after the restart");
    assert_eq!(status, 200);
    let ps = pool.stats();
    assert_eq!(ps.fresh_connects, 2, "both exchanges needed a connect: {ps:?}");
    assert_eq!(ps.reuses, 0, "{ps:?}");
}

#[test]
fn worker_dying_mid_pipeline_has_its_remaining_shards_reassigned() {
    // A worker that completes one keep-alive exchange (a valid busy
    // bounce), then dies mid-pipeline: it reads the next request off the
    // pooled connection and closes without replying, and its listener is
    // gone afterwards. The dispatcher must absorb the bounce, retry the
    // stale socket once, see the refusal, retire the worker, and reassign
    // everything — with merged bytes identical to the reference.
    let spec = small_spec();
    let full = reference(&spec);
    let busy_body = format!("{{\"code\":\"{CODE_WORKER_BUSY}\",\"error\":\"slot busy\"}}");
    let busy_reply = format!(
        "HTTP/1.1 503 Service Unavailable\r\ncontent-length: {}\r\nconnection: keep-alive\r\n\r\n{busy_body}",
        busy_body.len()
    );
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind dying worker");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let Ok((mut s, _)) = listener.accept() else { return };
        // Exchange 1 completes (so the connection is pooled)...
        let mut buf = [0u8; 4096];
        let _ = s.read(&mut buf);
        let _ = s.write_all(busy_reply.as_bytes());
        // ...exchange 2 dies mid-request, and the listener drops with the
        // thread: every later connect is refused.
        let _ = s.read(&mut buf);
    });

    let healthy = spawn_workers(2);
    let mut pool = vec![addr];
    pool.extend(addrs(&healthy));
    let report = dispatch(&spec, &pool, &opts(6)).expect("dispatch with a mid-pipeline death");
    assert_eq!(report.doc.to_string(), full, "mid-pipeline death changed the merged bytes");
    assert_eq!(report.per_worker[0].1, 0, "the dying worker never completed a shard");
    assert!(report.busy_retries >= 1, "the keep-alive bounce was not seen: {report:?}");
    assert!(report.retries >= 1, "the death was not retried elsewhere: {report:?}");
    for w in healthy {
        w.shutdown();
    }
}

#[test]
fn prewarm_retries_refused_connects_while_a_worker_binds() {
    // A worker launched in parallel with the dispatcher: its port is known
    // but its listener binds only after the dispatcher's first prewarm
    // connect has been refused. The backoff schedule must keep it in the
    // pool instead of retiring it (which, with no other worker, would fail
    // the whole dispatch).
    let spec = small_spec();
    let full = reference(&spec);
    let donor = SweepEngine::serial();
    shard::run_full(&spec, &donor).unwrap();
    let snap = donor.cache().snapshot();

    let placeholder = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    let addr = placeholder.local_addr().unwrap().to_string();
    drop(placeholder);
    let late = {
        let addr = addr.clone();
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(60));
            WorkerServer::spawn(&addr, SweepEngine::with_threads(2)).expect("late bind")
        })
    };

    let mut dopts = opts(3);
    dopts.prewarm = Some(snap);
    let report =
        dispatch(&spec, &[addr], &dopts).expect("dispatch with a late-binding worker");
    assert_eq!(report.doc.to_string(), full, "late-binding prewarm changed output bytes");
    let served: usize = report.per_worker.iter().map(|(_, n)| n).sum();
    assert_eq!(served, 3, "the late worker serves the whole sweep: {:?}", report.per_worker);
    late.join().expect("late-bind thread").shutdown();
}

// ---- pooled-retry safety (the double-execute regression) ---------------

#[test]
fn reused_connection_post_failure_after_write_is_not_retried() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    // A server that serves one keep-alive POST on its first connection,
    // reads the *second* request fully — the point where it may have
    // executed it — and drops the socket without a byte of reply. Every
    // request that reaches the server is counted, and later connections
    // are served normally: if the pool (incorrectly) replayed the failed
    // POST on a fresh connection, the count would reach 3.
    let executed = Arc::new(AtomicUsize::new(0));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind counting server");
    let addr = listener.local_addr().unwrap().to_string();
    {
        let executed = Arc::clone(&executed);
        thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                if read_request_head(&mut s) {
                    executed.fetch_add(1, Ordering::SeqCst);
                    let _ = s.write_all(KEEPALIVE_200);
                }
                if read_request_head(&mut s) {
                    executed.fetch_add(1, Ordering::SeqCst);
                    // Fully received, then dropped before any reply byte.
                }
            }
            while let Ok((mut s, _)) = listener.accept() {
                while read_request_head(&mut s) {
                    executed.fetch_add(1, Ordering::SeqCst);
                    if s.write_all(KEEPALIVE_200).is_err() {
                        break;
                    }
                }
            }
        });
    }

    let pool = ConnPool::new(2);
    let (status, _) =
        pool.request(&addr, "POST", "/task", b"", Duration::from_secs(10)).expect("first POST");
    assert_eq!(status, 200);
    // The reused connection dies after the request bytes are out: the
    // server cannot be proven innocent of executing it, so the pool must
    // surface the failure instead of replaying a non-idempotent request.
    let err = pool
        .request(&addr, "POST", "/task", b"", Duration::from_secs(10))
        .expect_err("a POST that failed after the write must error, not silently retry");
    assert!(!err.refused, "{err:?}");
    // Give a wrong implementation a moment to run the retry it shouldn't.
    thread::sleep(Duration::from_millis(100));
    assert_eq!(
        executed.load(Ordering::SeqCst),
        2,
        "the failed POST was re-executed on a fresh connection"
    );
    let ps = pool.stats();
    assert_eq!(ps.stale_retries, 0, "a post-write POST failure is not retry-safe: {ps:?}");
    assert_eq!(ps.fresh_connects, 1, "{ps:?}");
}

#[test]
fn reused_connection_get_clean_eof_is_retried_on_a_fresh_connection() {
    // The mirror image: the same stale-socket shape (full request read,
    // clean close, zero response bytes) on an idempotent GET *is* the
    // race the pool exists to absorb — one transparent retry on a fresh
    // connection.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind restarting server");
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            if read_request_head(&mut s) {
                let _ = s.write_all(KEEPALIVE_200);
            }
            let _ = read_request_head(&mut s);
            // Clean close mid-pipeline: the idle-timer race.
        }
        if let Ok((mut s, _)) = listener.accept() {
            while read_request_head(&mut s) {
                if s.write_all(KEEPALIVE_200).is_err() {
                    return;
                }
            }
        }
    });

    let pool = ConnPool::new(2);
    let (status, _) =
        pool.request(&addr, "GET", "/ping", b"", Duration::from_secs(10)).expect("first GET");
    assert_eq!(status, 200);
    let (status, _) = pool
        .request(&addr, "GET", "/ping", b"", Duration::from_secs(10))
        .expect("the stale GET retries transparently");
    assert_eq!(status, 200);
    let ps = pool.stats();
    assert_eq!(ps.stale_retries, 1, "{ps:?}");
    assert_eq!(ps.fresh_connects, 2, "{ps:?}");
}

// ---- elastic fleet: registration, heartbeats, churn, and the store -----

/// Wait (bounded) until the controller's `GET /workers` listing satisfies
/// `pred`, returning the workers array.
fn wait_for_listing(
    fleet_addr: &str,
    pred: impl Fn(&[Json]) -> bool,
    what: &str,
) -> Vec<Json> {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (status, listing) =
            http_request_json(fleet_addr, "GET", "/workers", b"", Duration::from_secs(10))
                .expect("GET /workers");
        assert_eq!(status, 200, "{listing}");
        let workers = listing.get("workers").and_then(Json::as_arr).unwrap_or(&[]).to_vec();
        if pred(&workers) {
            return workers;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}: {listing}");
        thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn fleet_controller_registers_heartbeats_and_expires_silent_workers() {
    let fleet = FleetServer::spawn_with(
        "127.0.0.1:0",
        FleetOpts { expiry: Duration::from_millis(400) },
    )
    .expect("bind fleet controller");
    let fleet_addr = fleet.addr().to_string();

    // A fingerprint-mismatched worker is rejected at the door with the
    // machine-readable code — it must never enter a listing a dispatcher
    // trusts.
    let bogus = Json::obj([
        ("addr", Json::str("127.0.0.1:9")),
        ("fingerprint", Json::str("not-this-binary")),
    ])
    .to_string();
    let (status, reply) = http_request_json(
        &fleet_addr,
        "POST",
        "/register",
        bogus.as_bytes(),
        Duration::from_secs(10),
    )
    .expect("bogus register");
    assert_eq!(status, 400, "{reply}");
    assert_eq!(
        reply.get("code").and_then(Json::as_str),
        Some(CODE_FINGERPRINT_MISMATCH),
        "{reply}"
    );

    // So is a registration without an address.
    let (status, _) =
        http_request_json(&fleet_addr, "POST", "/register", b"{}", Duration::from_secs(10))
            .expect("empty register");
    assert_eq!(status, 400);
    assert!(
        wait_for_listing(&fleet_addr, |ws| ws.is_empty(), "an empty listing").is_empty()
    );

    // A real worker heartbeating in appears, carrying its live stats.
    let worker = spawn_workers(1).remove(0);
    let advertise = worker.addr().to_string();
    let hb = spawn_heartbeat(
        &fleet_addr,
        &advertise,
        worker.stats_handle(),
        Duration::from_millis(50),
    );
    let listed = wait_for_listing(&fleet_addr, |ws| !ws.is_empty(), "the worker to register");
    assert_eq!(listed[0].get("addr").and_then(Json::as_str), Some(advertise.as_str()));
    assert!(
        listed[0].get("stats").and_then(|s| s.get("cache")).is_some(),
        "listing carries no stats: {:?}",
        listed[0]
    );

    // Silence the heartbeat: past the expiry the worker leaves the
    // listing (which is what pauses it at an elastic dispatcher)...
    hb.stop();
    wait_for_listing(&fleet_addr, |ws| ws.is_empty(), "the silent worker to expire");

    // ...and a resumed heartbeat brings the same address straight back —
    // the un-retire path.
    let hb = spawn_heartbeat(
        &fleet_addr,
        &advertise,
        worker.stats_handle(),
        Duration::from_millis(50),
    );
    wait_for_listing(&fleet_addr, |ws| !ws.is_empty(), "the worker to rejoin");
    hb.stop();
    worker.shutdown();
    fleet.shutdown();
}

/// A slightly wider sweep for churn tests: 2 techs x 6 widths = 12 points,
/// so a mid-sweep worker swap has points left to serve.
fn churn_spec() -> SweepSpec {
    SweepSpec::single(
        "serve_cnn",
        vec!["lr".to_string()],
        vec!["sram".to_string(), "reram".to_string()],
        PrecisionGrid::Fixed { bits: vec![2, 3, 4, 5, 6, 7] },
    )
}

#[test]
fn elastic_dispatch_survives_worker_death_and_admits_a_late_joiner() {
    let spec = churn_spec();
    let full = reference(&spec);
    let fleet = FleetServer::spawn_with(
        "127.0.0.1:0",
        FleetOpts { expiry: Duration::from_millis(400) },
    )
    .expect("bind fleet controller");
    let fleet_addr = fleet.addr().to_string();

    let worker_a = spawn_workers(1).remove(0);
    let hb_a = spawn_heartbeat(
        &fleet_addr,
        &worker_a.addr().to_string(),
        worker_a.stats_handle(),
        Duration::from_millis(50),
    );

    // One point per slice, so the sweep takes many round trips and the
    // churn below lands mid-flight.
    let dispatcher = {
        let spec = spec.clone();
        let fleet_addr = fleet_addr.clone();
        thread::spawn(move || {
            let eopts = ElasticOpts {
                timeout: Duration::from_secs(30),
                poll: Duration::from_millis(50),
                min_slice: 1,
                max_slice: 1,
                grace: Duration::from_secs(60),
                ..ElasticOpts::default()
            };
            dispatch_elastic(&spec, &WorkerSource::Fleet(fleet_addr), &eopts)
        })
    };

    // Mid-sweep churn: a second worker joins, then the first one dies —
    // its heartbeats stop and its listener drops.
    thread::sleep(Duration::from_millis(150));
    let worker_b = spawn_workers(1).remove(0);
    let hb_b = spawn_heartbeat(
        &fleet_addr,
        &worker_b.addr().to_string(),
        worker_b.stats_handle(),
        Duration::from_millis(50),
    );
    hb_a.stop();
    worker_a.shutdown();

    let report = dispatcher.join().expect("dispatcher thread").expect("elastic dispatch");
    assert_eq!(report.doc.to_string(), full, "fleet churn changed the assembled bytes");
    assert_eq!(report.computed_points, 12);
    assert_eq!(report.replayed_points, 0);
    hb_b.stop();
    worker_b.shutdown();
    fleet.shutdown();
}

#[test]
fn elastic_dispatch_waits_for_the_first_worker_to_join_an_empty_fleet() {
    let spec = small_spec();
    let full = reference(&spec);
    let fleet = FleetServer::spawn("127.0.0.1:0").expect("bind fleet controller");
    let fleet_addr = fleet.addr().to_string();

    // Nothing is registered when the dispatch starts; the worker arrives
    // ~150 ms in. The dispatcher must admit it mid-sweep instead of
    // failing on the empty listing.
    let late = {
        let fleet_addr = fleet_addr.clone();
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(150));
            let w = spawn_workers(1).remove(0);
            let hb = spawn_heartbeat(
                &fleet_addr,
                &w.addr().to_string(),
                w.stats_handle(),
                Duration::from_millis(50),
            );
            (w, hb)
        })
    };
    let eopts = ElasticOpts {
        timeout: Duration::from_secs(30),
        poll: Duration::from_millis(50),
        grace: Duration::from_secs(60),
        ..ElasticOpts::default()
    };
    let report = dispatch_elastic(&spec, &WorkerSource::Fleet(fleet_addr), &eopts)
        .expect("dispatch against an initially empty fleet");
    assert_eq!(report.doc.to_string(), full, "late join changed the assembled bytes");
    let (w, hb) = late.join().expect("late-join thread");
    hb.stop();
    w.shutdown();
    fleet.shutdown();
}

#[test]
fn elastic_prewarm_failure_pauses_and_retries_instead_of_retiring() {
    // The elastic sibling of the legacy late-bind prewarm test — but here
    // the contract is stronger: a failed wire prewarm pauses the worker
    // with backoff and retries; only a fingerprint mismatch is fatal.
    // With a single (initially absent) worker, permanent retirement would
    // fail the whole dispatch.
    let spec = small_spec();
    let full = reference(&spec);
    let donor = SweepEngine::serial();
    shard::run_full(&spec, &donor).unwrap();
    let snap = donor.cache().snapshot();

    let placeholder = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    let addr = placeholder.local_addr().unwrap().to_string();
    drop(placeholder);
    let late = {
        let addr = addr.clone();
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(150));
            WorkerServer::spawn(&addr, SweepEngine::with_threads(2)).expect("late bind")
        })
    };
    let eopts = ElasticOpts {
        timeout: Duration::from_secs(30),
        poll: Duration::from_millis(50),
        grace: Duration::from_secs(60),
        prewarm: Some(snap),
        ..ElasticOpts::default()
    };
    let report = dispatch_elastic(&spec, &WorkerSource::Static(vec![addr]), &eopts)
        .expect("elastic dispatch with a late-binding prewarmed worker");
    assert_eq!(report.doc.to_string(), full, "late prewarm changed the assembled bytes");
    assert_eq!(report.computed_points, 8);
    late.join().expect("late-bind thread").shutdown();
}

#[test]
fn store_backed_elastic_rerun_replays_every_point_without_workers() {
    let spec = small_spec();
    let full = reference(&spec);
    let dir = std::env::temp_dir()
        .join(format!("bf-imna-elastic-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // First run: a worker computes everything, and every record is saved.
    let worker = spawn_workers(1).remove(0);
    let eopts = ElasticOpts {
        timeout: Duration::from_secs(30),
        poll: Duration::from_millis(50),
        store: Some(ResultStore::open(&dir).expect("open store")),
        ..ElasticOpts::default()
    };
    let source = WorkerSource::Static(vec![worker.addr().to_string()]);
    let first = dispatch_elastic(&spec, &source, &eopts).expect("first stored dispatch");
    assert_eq!(first.doc.to_string(), full, "stored dispatch changed the assembled bytes");
    assert_eq!((first.computed_points, first.replayed_points), (8, 0));
    worker.shutdown();

    // Second run with NO workers at all: the store replays every point,
    // so the sweep never needs the network — and the bytes still match.
    let eopts = ElasticOpts {
        store: Some(ResultStore::open(&dir).expect("reopen store")),
        ..ElasticOpts::default()
    };
    let second = dispatch_elastic(&spec, &WorkerSource::Static(Vec::new()), &eopts)
        .expect("workerless replay");
    assert_eq!(second.doc.to_string(), full, "replayed document differs");
    assert_eq!((second.computed_points, second.replayed_points), (0, 8));
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- connection worker pool --------------------------------------------

#[test]
fn bounded_worker_pool_serves_concurrent_keepalive_connections() {
    // A 3-thread connection pool with 3 simultaneously open keep-alive
    // connections: every pooled handler is occupied, yet all three
    // connections are served (including keep-alive reuse) — and once they
    // close, the freed threads pick up fresh connections instead of the
    // accept loop spawning new ones.
    let worker = WorkerServer::spawn_with(
        "127.0.0.1:0",
        SweepEngine::with_threads(2),
        WorkerOpts { worker_threads: 3, ..WorkerOpts::default() },
    )
    .expect("bind worker");
    let addr = worker.addr().to_string();

    let mut conns: Vec<TcpStream> = (0..3)
        .map(|i| {
            let mut s = TcpStream::connect(&addr).expect("connect");
            s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            s.set_write_timeout(Some(Duration::from_secs(30))).unwrap();
            write_request_conn(&mut s, "GET", "/healthz", &addr, b"", false)
                .unwrap_or_else(|e| panic!("send on conn {i}: {e:?}"));
            s
        })
        .collect();
    // All three are open at once, each occupying one pooled handler.
    for (i, s) in conns.iter_mut().enumerate() {
        let (status, _) = read_response(s).unwrap_or_else(|e| panic!("reply on conn {i}: {e:?}"));
        assert_eq!(status, 200, "conn {i}");
    }
    // Keep-alive reuse still works through the pool.
    for (i, s) in conns.iter_mut().enumerate() {
        write_request_conn(s, "GET", "/stats", &addr, b"", false)
            .unwrap_or_else(|e| panic!("second send on conn {i}: {e:?}"));
        let (status, _) = read_response(s).unwrap_or_else(|e| panic!("reuse on conn {i}: {e:?}"));
        assert_eq!(status, 200, "conn {i} reuse");
    }
    drop(conns);

    // More fresh connections than the pool has threads (sequentially):
    // every one must be served by a recycled handler.
    for i in 0..6 {
        let (status, health) =
            http_request_json(&addr, "GET", "/healthz", b"", Duration::from_secs(10))
                .unwrap_or_else(|e| panic!("post-drain healthz {i}: {e:?}"));
        assert_eq!(status, 200);
        assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    }
    let (_, stats) =
        http_request_json(&addr, "GET", "/stats", b"", Duration::from_secs(10)).unwrap();
    assert_eq!(stats.get("accept_errors").and_then(Json::as_i64), Some(0), "{stats}");
    worker.shutdown();
}

#[test]
fn legacy_spawn_per_connection_worker_mode_still_serves() {
    // `worker_threads == 0` keeps the historical thread-per-connection
    // accept loop as the A/B churn baseline; it must stay fully
    // functional, health checks and shard compute alike.
    let worker = WorkerServer::spawn_with(
        "127.0.0.1:0",
        SweepEngine::with_threads(2),
        WorkerOpts { worker_threads: 0, ..WorkerOpts::default() },
    )
    .expect("bind worker");
    let addr = worker.addr().to_string();

    let reply = raw_roundtrip(&addr, &raw_get("/healthz"));
    assert!(reply.starts_with("HTTP/1.1 200"), "{reply}");
    let order = ShardRequest { spec: small_spec(), shards: 1, shard_id: 0 };
    let (status, doc) = http_request_json(
        &addr,
        "POST",
        "/shard",
        order.to_json().to_string().as_bytes(),
        Duration::from_secs(30),
    )
    .expect("legacy-mode shard");
    assert_eq!(status, 200);
    ShardResult::from_json(&doc).expect("legacy-mode shard document is valid");
    worker.shutdown();
}
