//! Cross-module integration: emulator vs analytic models, mapper vs
//! simulator, HAWQ configs through the full simulation pipeline.

use bf_imna::ap::{emulator, runtime_model as rt, ApKind};
use bf_imna::arch::HwConfig;
use bf_imna::model::zoo;
use bf_imna::precision::{hawq, PrecisionConfig};
use bf_imna::sim::{breakdown, simulate, SimParams};
use bf_imna::util::proptest::check;
use bf_imna::util::rng::Rng;

/// §IV "microbenchmark": the functional emulator's event counts must match
/// the analytic Table I models for the column-parallel operations.
#[test]
fn emulator_event_counts_match_analytic_models() {
    let mut rng = Rng::new(42);
    for m in [2usize, 4, 8] {
        let l = 64u64;
        let a = rng.vec_below(l as usize / 2, 1 << m);
        let b = rng.vec_below(l as usize / 2, 1 << m);
        let (_, counters) = emulator::emulate_add(&a, &b, m);
        let model = rt::add(m as u32, l, ApKind::TwoD);
        assert_eq!(
            counters.events().compares,
            model.events.compares,
            "add compares at M={m}"
        );
        let (_, counters) = emulator::emulate_multiply(&a, &b, m, m);
        let model = rt::multiply(m as u32, m as u32, l, ApKind::TwoD);
        // The emulator charges the model's 4*Ma*Mw passes plus Mw explicit
        // carry-flush passes (documented in `Cam::multiply`).
        assert_eq!(
            counters.events().compares,
            model.events.compares + m as u64,
            "multiply compares at M={m}"
        );
    }
}

/// Property: emulated arithmetic is exact for random operands.
#[test]
fn emulated_arithmetic_is_exact() {
    check("emulator add/multiply/relu/max", 40, |rng| {
        let m = rng.range(2, 8);
        let words = rng.range(1, 24);
        let a = rng.vec_below(words, 1 << m);
        let b = rng.vec_below(words, 1 << m);
        let (sum, _) = emulator::emulate_add(&a, &b, m);
        for ((&x, &y), &s) in a.iter().zip(&b).zip(&sum) {
            let expect = (x + y) & ((1 << (m + 1)) - 1);
            if s != expect {
                return Err(format!("add {x}+{y} gave {s}, want {expect}"));
            }
        }
        let (prod, _) = emulator::emulate_multiply(&a, &b, m, m);
        for ((&x, &y), &p) in a.iter().zip(&b).zip(&prod) {
            if p != x * y {
                return Err(format!("mul {x}*{y} gave {p}"));
            }
        }
        let (mx, _) = emulator::emulate_max(&a, &b, m);
        for ((&x, &y), &v) in a.iter().zip(&b).zip(&mx) {
            if v != x.max(y) {
                return Err(format!("max({x},{y}) gave {v}"));
            }
        }
        Ok(())
    });
}

/// Property: the simulator's energy is monotone in precision for any
/// uniform configuration on any workload.
#[test]
fn energy_monotone_in_precision() {
    let nets = [zoo::alexnet(), zoo::resnet18()];
    let params = SimParams::lr_sram();
    for net in &nets {
        let mut last = 0.0;
        for bits in 2..=8 {
            let cfg = PrecisionConfig::fixed(bits, net.weight_layers());
            let e = simulate(net, &cfg, &params).energy_j();
            assert!(e > last, "{}: energy fell at {bits} bits", net.name);
            last = e;
        }
    }
}

/// Property: random mixed configs never beat uniform-min or lose to
/// uniform-max energy (the bit-fluid envelope).
#[test]
fn mixed_energy_within_fixed_envelope() {
    let net = zoo::resnet18();
    let params = SimParams::lr_sram();
    let n = net.weight_layers();
    let e_min = simulate(&net, &PrecisionConfig::fixed(2, n), &params).energy_j();
    let e_max = simulate(&net, &PrecisionConfig::fixed(8, n), &params).energy_j();
    check("mixed config energy envelope", 12, |rng| {
        let bits: Vec<u32> = (0..n).map(|_| 2 + rng.below(7) as u32).collect();
        let cfg = PrecisionConfig::from_bits("rand", &bits);
        let e = simulate(&net, &cfg, &params).energy_j();
        if e < e_min * 0.999 || e > e_max * 1.001 {
            return Err(format!("energy {e} outside [{e_min}, {e_max}]"));
        }
        Ok(())
    });
}

/// Table VII pipeline: all five HAWQ rows simulate; EDP ordering matches
/// the paper's qualitative ranking (INT4 < low < medium < high < INT8).
#[test]
fn hawq_rows_simulate_with_paper_edp_ordering() {
    let net = zoo::resnet18();
    let params = SimParams::lr_sram();
    let mut edps = Vec::new();
    for row in hawq::table_vii_rows() {
        let cfg = hawq::config_for_resnet18(&net, &row);
        let r = simulate(&net, &cfg, &params);
        edps.push((row.budget, r.edp_js()));
    }
    // Table VII order: INT4, High, Medium, Low, INT8.
    let edp = |i: usize| edps[i].1;
    assert!(edp(0) < edp(3), "INT4 {} < Low {}", edp(0), edp(3));
    assert!(edp(3) < edp(2), "Low {} < Medium {}", edp(3), edp(2));
    assert!(edp(2) < edp(1), "Medium {} < High {}", edp(2), edp(1));
    assert!(edp(1) < edp(4), "High {} < INT8 {}", edp(1), edp(4));
}

/// The normalized-energy column mechanism: INT8/config energy ratios rank
/// like the paper's (INT4 highest, high-budget lowest).
#[test]
fn hawq_normalized_energy_ranks_like_paper() {
    let net = zoo::resnet18();
    let params = SimParams::lr_sram();
    let sim_e = |b: hawq::LatencyBudget| {
        let cfg = hawq::config_for_resnet18(&net, &hawq::row(b));
        simulate(&net, &cfg, &params).energy_j()
    };
    let e8 = sim_e(hawq::LatencyBudget::FixedInt8);
    let norm = |b| e8 / sim_e(b);
    let n4 = norm(hawq::LatencyBudget::FixedInt4);
    let nl = norm(hawq::LatencyBudget::Low);
    let nm = norm(hawq::LatencyBudget::Medium);
    let nh = norm(hawq::LatencyBudget::High);
    assert!(n4 > nl && nl > nm && nm > nh && nh > 1.0, "{n4} {nl} {nm} {nh}");
}

/// IR vs LR on every benchmark: IR is faster, LR is more area-efficient.
#[test]
fn ir_lr_tradeoff_holds_across_benchmarks() {
    for net in zoo::imagenet_benchmarks() {
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let lr = simulate(&net, &cfg, &SimParams::new(HwConfig::Lr, bf_imna::ap::tech::Tech::sram()));
        let ir = simulate(&net, &cfg, &SimParams::new(HwConfig::Ir, bf_imna::ap::tech::Tech::sram()));
        assert!(ir.latency_s() < lr.latency_s(), "{}: IR not faster", net.name);
        assert!(
            lr.gops_per_w_mm2() > ir.gops_per_w_mm2(),
            "{}: LR not more area-efficient",
            net.name
        );
    }
}

/// Breakdown invariant on all three benchmarks: reduce dominates GEMM
/// latency (Fig. 8b's headline).
#[test]
fn reduce_dominates_gemm_latency_across_benchmarks() {
    for net in zoo::imagenet_benchmarks() {
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let r = simulate(&net, &cfg, &SimParams::lr_sram());
        let shares = breakdown::gemm_latency_by_phase(&r);
        let red = breakdown::fraction_of(&shares, "Reduce");
        let mul = breakdown::fraction_of(&shares, "Multiply");
        assert!(red > mul, "{}: reduce {red:.3} <= multiply {mul:.3}", net.name);
    }
}

/// Property: the mapper's structural invariants hold for random layers and
/// precisions on both chips.
#[test]
fn mapper_structural_invariants() {
    use bf_imna::arch::ChipConfig;
    use bf_imna::mapper;
    let nets = [zoo::alexnet(), zoo::resnet18()];
    check("mapper invariants", 20, |rng| {
        let net = &nets[rng.range(0, 1)];
        let bits: Vec<u32> = (0..net.weight_layers()).map(|_| 2 + rng.below(7) as u32).collect();
        let cfg = PrecisionConfig::from_bits("r", &bits);
        for hw in [HwConfig::Lr, HwConfig::Ir] {
            let chip = ChipConfig::for_network(hw, net);
            let plan = mapper::map_network(net, &chip, &cfg);
            for l in &plan.layers {
                if l.caps_used > chip.total_caps() {
                    return Err(format!("{}: caps_used {} > chip {}", l.name, l.caps_used, chip.total_caps()));
                }
                if l.mesh_bits_critical > l.mesh_bits {
                    return Err(format!(
                        "{}: critical mesh {} > total {}",
                        l.name, l.mesh_bits_critical, l.mesh_bits
                    ));
                }
                if l.steps == 0 || l.caps_used == 0 {
                    return Err(format!("{}: zero steps/caps", l.name));
                }
                if hw == HwConfig::Ir && l.steps != 1 && l.kind == bf_imna::mapper::WorkKind::Gemm {
                    return Err(format!("{}: IR folded x{}", l.name, l.steps));
                }
            }
        }
        Ok(())
    });
}

/// Property: latency and energy are finite, positive, and EDP factors.
#[test]
fn simulator_outputs_are_well_formed() {
    let net = zoo::alexnet();
    check("simulator well-formedness", 16, |rng| {
        let bits: Vec<u32> = (0..net.weight_layers()).map(|_| 2 + rng.below(7) as u32).collect();
        let cfg = PrecisionConfig::from_bits("r", &bits);
        let r = simulate(&net, &cfg, &SimParams::lr_sram());
        let (e, l) = (r.energy_j(), r.latency_s());
        if !(e.is_finite() && e > 0.0 && l.is_finite() && l > 0.0) {
            return Err(format!("bad metrics e={e} l={l}"));
        }
        if (r.edp_js() - e * l).abs() > 1e-15 * e * l.max(1.0) {
            return Err("EDP != E*L".to_string());
        }
        if r.pipeline_interval_s() > l {
            return Err("pipeline interval exceeds latency".to_string());
        }
        Ok(())
    });
}

/// The 2D-AP emulator's vertical (row-pair) operations are exact too.
#[test]
fn emulator_vertical_ops_are_exact() {
    check("vertical reduce/matmat", 24, |rng| {
        let m = rng.range(2, 6);
        let n = 1 << rng.range(1, 4); // 2..16 values, power of two
        let vals = rng.vec_below(n, 1 << m);
        let (got, _) = emulator::emulate_reduce_2d(&vals, m);
        let want: u64 = vals.iter().sum();
        if got != want {
            return Err(format!("reduce {vals:?} gave {got}, want {want}"));
        }
        Ok(())
    });
}
