//! Byte-identity oracle for the `costs` refactor.
//!
//! The seed tree inlined every technology constant inside
//! `Tech::new` / `Tech::voltage_scaled`; this PR moved those numbers into
//! the declarative [`bf_imna::costs`] tables. The proof obligation is that
//! under the **default** table nothing observable changed — not "close",
//! but bit-for-bit. Rather than checked-in golden files (which a toolchain
//! change could silently regenerate), this suite carries the *seed code
//! itself* as a local oracle: `legacy_tech` / `legacy_voltage_scaled`
//! below are verbatim copies of the pre-refactor constructors, and every
//! field of every technology handle the library now derives from a cost
//! table is compared against them with `f64::to_bits`.
//!
//! The second half pins the serialization contract: default-table sweep
//! specs and documents must not mention costs at all, so every byte a
//! seed-era consumer ever saw — spec JSON, full-sweep documents, the
//! artifact catalog's tiny docs — is still produced verbatim.

use bf_imna::ap::tech::{
    CellTech, Tech, C_IN, COMPARE_PERIPHERAL_FACTOR, E_WRITE_FEFET, E_WRITE_PCM,
    E_WRITE_SRAM_SCALED, FEFET_AREA_SAVINGS, FJ, PCM_AREA_SAVINGS, PJ, P_ERR_SCALED,
    RERAM_AREA_SAVINGS, SRAM_CELL_AREA_M2, V_DD_NOMINAL, V_DD_SCALED,
};
use bf_imna::costs;
use bf_imna::sim::artifacts::catalog;
use bf_imna::sim::shard::{run_full, PrecisionGrid, SweepSpec};
use bf_imna::sim::SweepEngine;
use bf_imna::util::json::Json;

/// Verbatim copy of the seed tree's `Tech::new` (inlined constants), kept
/// as the oracle the cost tables must reproduce exactly.
fn legacy_tech(cell: CellTech) -> Tech {
    let e_compare_word = COMPARE_PERIPHERAL_FACTOR * C_IN * V_DD_NOMINAL * V_DD_NOMINAL;
    match cell {
        CellTech::Sram => Tech {
            cell,
            v_dd: V_DD_NOMINAL,
            e_write_cell: 0.24 * FJ,
            e_compare_word,
            e_read_word: e_compare_word,
            compare_cycles: 1.0,
            write_cycles: 2.0,
            read_cycles: 1.0,
            p_cell_error: 0.0,
            cell_area_m2: SRAM_CELL_AREA_M2,
        },
        CellTech::Reram => Tech {
            cell,
            v_dd: V_DD_NOMINAL,
            e_write_cell: 21.7 * PJ,
            e_compare_word,
            e_read_word: e_compare_word,
            compare_cycles: 1.0,
            write_cycles: 4.0,
            read_cycles: 1.0,
            p_cell_error: 0.0,
            cell_area_m2: SRAM_CELL_AREA_M2 / RERAM_AREA_SAVINGS,
        },
        CellTech::Pcm => Tech {
            cell,
            v_dd: V_DD_NOMINAL,
            e_write_cell: E_WRITE_PCM,
            e_compare_word,
            e_read_word: e_compare_word,
            compare_cycles: 1.0,
            write_cycles: 8.0,
            read_cycles: 1.0,
            p_cell_error: 0.0,
            cell_area_m2: SRAM_CELL_AREA_M2 / PCM_AREA_SAVINGS,
        },
        CellTech::Fefet => Tech {
            cell,
            v_dd: V_DD_NOMINAL,
            e_write_cell: E_WRITE_FEFET,
            e_compare_word,
            e_read_word: e_compare_word,
            compare_cycles: 1.0,
            write_cycles: 2.0,
            read_cycles: 1.0,
            p_cell_error: 0.0,
            cell_area_m2: SRAM_CELL_AREA_M2 / FEFET_AREA_SAVINGS,
        },
    }
}

/// Verbatim copy of the seed tree's `Tech::voltage_scaled`.
fn legacy_voltage_scaled(t: &Tech) -> Tech {
    let vr = V_DD_SCALED / V_DD_NOMINAL;
    let e_compare_word = t.e_compare_word * vr * vr;
    Tech {
        v_dd: V_DD_SCALED,
        e_write_cell: match t.cell {
            CellTech::Sram => E_WRITE_SRAM_SCALED,
            CellTech::Reram | CellTech::Pcm | CellTech::Fefet => t.e_write_cell * vr * vr,
        },
        e_compare_word,
        e_read_word: e_compare_word,
        p_cell_error: P_ERR_SCALED,
        ..*t
    }
}

/// Every f64 field compared by bit pattern, not tolerance.
fn assert_bits_eq(got: &Tech, want: &Tech, what: &str) {
    assert_eq!(got.cell, want.cell, "{what}: cell");
    for (g, w, field) in [
        (got.v_dd, want.v_dd, "v_dd"),
        (got.e_write_cell, want.e_write_cell, "e_write_cell"),
        (got.e_compare_word, want.e_compare_word, "e_compare_word"),
        (got.e_read_word, want.e_read_word, "e_read_word"),
        (got.compare_cycles, want.compare_cycles, "compare_cycles"),
        (got.write_cycles, want.write_cycles, "write_cycles"),
        (got.read_cycles, want.read_cycles, "read_cycles"),
        (got.p_cell_error, want.p_cell_error, "p_cell_error"),
        (got.cell_area_m2, want.cell_area_m2, "cell_area_m2"),
    ] {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: {field} drifted ({g:e} vs {w:e})");
    }
}

#[test]
fn default_table_reproduces_seed_constructors_bit_for_bit() {
    for cell in CellTech::EXTENDED {
        let oracle = legacy_tech(cell);
        assert_bits_eq(&Tech::new(cell), &oracle, "Tech::new");
        assert_bits_eq(
            &costs::default_table().tech_for(cell).unwrap(),
            &oracle,
            "default_table().tech_for",
        );
        // The library's own voltage_scaled is untouched code, but the
        // scaled-0v5 *preset* re-derives the same physics from table rows.
        let scaled_oracle = legacy_voltage_scaled(&oracle);
        assert_bits_eq(&Tech::new(cell).voltage_scaled(), &scaled_oracle, "voltage_scaled");
        assert_bits_eq(
            &costs::scaled_0v5_table().tech_for(cell).unwrap(),
            &scaled_oracle,
            "scaled_0v5_table().tech_for",
        );
    }
}

#[test]
fn default_spec_and_documents_keep_seed_bytes() {
    // A default-table spec serializes with no trace of the costs axis, so
    // its JSON is the exact seed-era text...
    let spec = SweepSpec::single(
        "serve_cnn",
        vec!["lr".to_string()],
        vec!["sram".to_string(), "reram".to_string()],
        PrecisionGrid::Fixed { bits: vec![2, 5, 8] },
    );
    let text = spec.to_json().to_string();
    assert!(!text.contains("costs"), "default spec leaked a costs key: {text}");
    // ...and a seed-era document (one with no costs key anywhere) parses
    // back to the identical spec and re-serializes to the identical bytes.
    let back = SweepSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, spec);
    assert_eq!(back.to_json().to_string(), text);

    let doc = run_full(&spec, &SweepEngine::serial()).unwrap().to_string();
    assert!(!doc.contains("\"costs\""), "default sweep document leaked a costs key");
}

#[test]
fn catalog_tiny_documents_render_and_stay_cost_silent() {
    // Every catalog artifact runs on the default table, so its tiny
    // full-sweep document must carry no costs key — the bytes a seed-era
    // reader would have produced — and must still render.
    let engine = SweepEngine::new();
    for artifact in catalog() {
        let doc = run_full(&artifact.tiny_spec(), &engine)
            .unwrap_or_else(|e| panic!("{}: tiny sweep failed: {e}", artifact.name));
        assert!(
            !doc.to_string().contains("\"costs\""),
            "{}: tiny document leaked a costs key",
            artifact.name
        );
        artifact
            .render_doc(&doc)
            .unwrap_or_else(|e| panic!("{}: render failed: {e}", artifact.name));
    }
}
