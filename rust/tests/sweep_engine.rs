//! Sweep-engine consistency: the plan cache and the parallel fan-out must
//! be *invisible* in the output. Every test here pins the same invariant
//! from a different angle: `SweepEngine` results are bit-identical —
//! `energy_j`, `latency_s`, and every per-phase table — to direct
//! `simulate()` calls, and parallel-order results match serial order.

use bf_imna::arch::{ChipConfig, HwConfig};
use bf_imna::model::{zoo, Network};
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::{
    dse, simulate, simulate_on, InferenceReport, SimParams, SweepEngine, SweepPoint,
};
use bf_imna::util::proptest::check;

/// Exact (bit-level) equality of two reports, including every per-layer
/// per-phase table.
fn assert_reports_identical(a: &InferenceReport, b: &InferenceReport) -> Result<(), String> {
    if a.net_name != b.net_name || a.cfg_name != b.cfg_name {
        return Err(format!("identity mismatch: {}/{} vs {}/{}", a.net_name, a.cfg_name, b.net_name, b.cfg_name));
    }
    if a.energy_j().to_bits() != b.energy_j().to_bits() {
        return Err(format!("energy {} != {}", a.energy_j(), b.energy_j()));
    }
    if a.latency_s().to_bits() != b.latency_s().to_bits() {
        return Err(format!("latency {} != {}", a.latency_s(), b.latency_s()));
    }
    if a.area_mm2.to_bits() != b.area_mm2.to_bits() {
        return Err("area diverged".to_string());
    }
    if a.layers.len() != b.layers.len() {
        return Err("layer count diverged".to_string());
    }
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        if la.name != lb.name || la.kind != lb.kind || la.steps != lb.steps {
            return Err(format!("layer identity diverged at {}", la.name));
        }
        if la.latency_phases != lb.latency_phases {
            return Err(format!("{}: latency phase table diverged", la.name));
        }
        if la.energy_phases != lb.energy_phases {
            return Err(format!("{}: energy phase table diverged", la.name));
        }
        if la.latency_s.to_bits() != lb.latency_s.to_bits()
            || la.ap_energy_j.to_bits() != lb.ap_energy_j.to_bits()
            || la.mesh_energy_j.to_bits() != lb.mesh_energy_j.to_bits()
            || la.map_energy_j.to_bits() != lb.map_energy_j.to_bits()
        {
            return Err(format!("{}: per-layer cost diverged", la.name));
        }
    }
    Ok(())
}

/// Property: for random networks, precisions, and hardware points, a
/// shared warm engine returns results bit-identical to direct simulate().
#[test]
fn engine_is_bit_identical_to_simulate_on_random_points() {
    let nets = [zoo::alexnet(), zoo::resnet18(), zoo::serve_cnn()];
    let engine = SweepEngine::new();
    check("engine == simulate", 24, |rng| {
        let net = &nets[rng.range(0, nets.len() - 1)];
        let hw = if rng.bool() { HwConfig::Lr } else { HwConfig::Ir };
        let tech = if rng.bool() {
            bf_imna::ap::tech::Tech::sram()
        } else {
            bf_imna::ap::tech::Tech::reram()
        };
        let params = SimParams::new(hw, tech);
        let bits: Vec<u32> =
            (0..net.weight_layers()).map(|_| 2 + rng.below(7) as u32).collect();
        let cfg = PrecisionConfig::from_bits("rand", &bits);
        let direct = simulate(net, &cfg, &params);
        let engined = engine.run(&[SweepPoint::new(net, &cfg, &params)]).remove(0);
        assert_reports_identical(&direct, &engined)
    });
    // The loop above re-visits layer/bits pairs constantly; the cache must
    // have been doing real work while staying invisible.
    assert!(engine.cache_stats().hits > 0, "{:?}", engine.cache_stats());
}

/// Parallel-order results match serial order, element by element.
#[test]
fn parallel_results_are_in_input_order() {
    let nets: Vec<Network> = vec![zoo::alexnet(), zoo::resnet18(), zoo::vgg16()];
    let params = SimParams::lr_sram();
    let mut cfgs = Vec::new();
    for (i, net) in nets.iter().enumerate() {
        for bits in 2..=8u32 {
            cfgs.push((i, PrecisionConfig::fixed(bits, net.weight_layers())));
        }
    }
    let points: Vec<SweepPoint> =
        cfgs.iter().map(|(i, c)| SweepPoint::new(&nets[*i], c, &params)).collect();
    let serial = SweepEngine::serial().run(&points);
    for threads in [2usize, 4, 8] {
        let parallel = SweepEngine::with_threads(threads).run(&points);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_reports_identical(s, p).unwrap_or_else(|e| {
                panic!("threads={threads}: {e}");
            });
        }
    }
}

/// Re-running the same sweep on a warm engine changes nothing.
#[test]
fn warm_cache_changes_nothing() {
    let net = zoo::resnet50();
    let params = SimParams::lr_sram();
    let cfgs: Vec<PrecisionConfig> =
        (2..=8).map(|b| PrecisionConfig::fixed(b, net.weight_layers())).collect();
    let engine = SweepEngine::new();
    let first = engine.run_configs(&net, &cfgs, &params);
    let second = engine.run_configs(&net, &cfgs, &params);
    for (a, b) in first.iter().zip(&second) {
        assert_reports_identical(a, b).unwrap();
    }
    let stats = engine.cache_stats();
    // A fixed-precision sweep stores at most one plan per (layer, bits).
    assert!(
        stats.entries <= 7 * net.layers.len(),
        "{} entries for {} layers",
        stats.entries,
        net.layers.len()
    );
}

/// The rewired DSE drivers return the same series on shared and fresh
/// engines (cache state cannot leak into figures).
#[test]
fn dse_series_agree_across_engines() {
    let net = zoo::alexnet();
    let shared = SweepEngine::new();
    // Warm the shared engine with unrelated work first.
    shared.run_configs(
        &net,
        &[PrecisionConfig::fixed(8, net.weight_layers())],
        &SimParams::lr_sram(),
    );
    let fresh = dse::fig7_series(&net, HwConfig::Lr, 7);
    let warm = dse::fig7_series_with(&shared, &net, HwConfig::Lr, 7);
    assert_eq!(fresh.len(), warm.len());
    for (f, w) in fresh.iter().zip(&warm) {
        assert_eq!(f.avg_bits, w.avg_bits);
        assert_eq!(f.samples, w.samples);
        assert_eq!(f.energy_j.to_bits(), w.energy_j.to_bits());
        assert_eq!(f.latency_s.to_bits(), w.latency_s.to_bits());
        assert_eq!(f.gops_per_w_mm2.to_bits(), w.gops_per_w_mm2.to_bits());
    }
    let fig6_fresh = dse::fig6_tech_ratios(&net);
    let fig6_warm = dse::fig6_tech_ratios_with(&shared, &net);
    for (f, w) in fig6_fresh.iter().zip(&fig6_warm) {
        assert_eq!(f.energy_ratio.to_bits(), w.energy_ratio.to_bits());
        assert_eq!(f.latency_ratio.to_bits(), w.latency_ratio.to_bits());
    }
}

/// Explicit-chip points bypass the (hw, net) chip memo but still cache and
/// still match the direct `simulate_on` path exactly.
#[test]
fn chip_override_matches_simulate_on() {
    let net = zoo::alexnet();
    let cfg = PrecisionConfig::fixed(6, net.weight_layers());
    let params = SimParams::lr_sram();
    let mut chip = ChipConfig::lr();
    chip.mesh.bits_per_transfer = 512;
    let direct = simulate_on(&net, &cfg, &params, &chip);
    let engine = SweepEngine::new();
    let engined =
        engine.run(&[SweepPoint::on_chip(&net, &cfg, &params, &chip)]).remove(0);
    assert_reports_identical(&direct, &engined).unwrap();
}
