//! End-to-end PJRT runtime tests: compile the real AOT artifacts and run
//! real numerics through them. Requires `make artifacts` **and** a build
//! with `--features pjrt` — the default (stub) runtime fails every load,
//! so without the feature gate these would panic whenever artifacts exist.
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use bf_imna::runtime::{argmax_rows, pad_batch, Runtime};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Deterministic pseudo-input: a low-frequency pattern, values in [-1, 1].
fn synth_input(batch: usize, elems: usize, seed: u64) -> Vec<f32> {
    let mut v = Vec::with_capacity(batch * elems);
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    for _ in 0..batch * elems {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        v.push(((state >> 11) as f64 / (1u64 << 53) as f64) as f32 * 2.0 - 1.0);
    }
    v
}

#[test]
fn loads_manifest_and_compiles_subset() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load_configs(&artifacts_dir(), &["int4"]).expect("load int4");
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let keys = rt.compiled_keys();
    assert!(!keys.is_empty());
    assert!(keys.iter().all(|(c, _)| c == "int4"));
}

#[test]
fn infer_produces_finite_logits() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load_configs(&artifacts_dir(), &["int4"]).expect("load");
    let m = rt.manifest();
    let elems = m.sample_elems();
    let logits = rt.infer("int4", 1, &synth_input(1, elems, 1)).expect("infer");
    assert_eq!(logits.len(), m.num_classes as usize);
    assert!(logits.iter().all(|x| x.is_finite()), "{logits:?}");
}

#[test]
fn batched_inference_is_consistent_with_single() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load_configs(&artifacts_dir(), &["int4"]).expect("load");
    let m = rt.manifest();
    let elems = m.sample_elems();
    let classes = m.num_classes as usize;
    let batch = *m.batch_sizes.iter().max().unwrap();
    let input = synth_input(batch as usize, elems, 7);
    let batched = rt.infer("int4", batch, &input).expect("batched infer");
    // Row 0 of the batched result must match the single-sample run.
    // (Quantization scales are per-GEMM over the whole batch, so rows can
    // differ slightly from a true single run — compare argmax, the serving
    // contract, plus a loose numeric check.)
    let single = rt.infer("int4", 1, &input[..elems]).expect("single infer");
    let am_b = argmax_rows(&batched[..classes], classes);
    let am_s = argmax_rows(&single, classes);
    assert_eq!(am_b, am_s, "batched {batched:?} single {single:?}");
}

#[test]
fn padded_partial_batch_round_trips() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load_configs(&artifacts_dir(), &["int8"]).expect("load");
    let m = rt.manifest();
    let elems = m.sample_elems();
    let classes = m.num_classes as usize;
    let batch = m.batch_for(3);
    assert!(batch >= 3);
    let three = synth_input(3, elems, 9);
    let padded = pad_batch(&three, 3, batch as usize, elems);
    let logits = rt.infer("int8", batch, &padded).expect("infer");
    assert_eq!(logits.len(), batch as usize * classes);
    // Padding repeats sample 3, so rows 3.. equal row 2.
    let row2 = &logits[2 * classes..3 * classes];
    for r in 3..batch as usize {
        let row = &logits[r * classes..(r + 1) * classes];
        for (a, b) in row.iter().zip(row2) {
            assert!((a - b).abs() < 1e-4, "pad row {r} diverged");
        }
    }
}

#[test]
fn all_configs_agree_on_easy_inputs() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // The float and int8 graphs must agree on argmax for well-separated
    // inputs; int4 may differ occasionally, so just check it runs.
    let rt = Runtime::load_configs(&artifacts_dir(), &["float", "int8", "int4"]).expect("load");
    let m = rt.manifest();
    let elems = m.sample_elems();
    let classes = m.num_classes as usize;
    let input = synth_input(1, elems, 42);
    let f = rt.infer("float", 1, &input).expect("float");
    let q8 = rt.infer("int8", 1, &input).expect("int8");
    let q4 = rt.infer("int4", 1, &input).expect("int4");
    assert_eq!(argmax_rows(&f, classes), argmax_rows(&q8, classes));
    assert_eq!(q4.len(), classes);
}

#[test]
fn float_logits_match_python_exactly() {
    // Cross-language numerics: PJRT execution of the exported float graph
    // must reproduce the Python-side logits (aot.py writes the expected
    // values for the first 8 eval samples).
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let read_f32 = |name: &str| -> Vec<f32> {
        std::fs::read(dir.join(name))
            .expect(name)
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect()
    };
    let rt = Runtime::load_configs(&dir, &["float"]).expect("load float");
    let elems = rt.manifest().sample_elems();
    let inputs = read_f32("eval_inputs.f32");
    let want = read_f32("eval_logits_float_b8.f32");
    let got = rt.infer("float", 8, &inputs[..8 * elems]).expect("infer");
    assert_eq!(got.len(), want.len());
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-2, "max |rust - python| = {max_err}");
}

#[test]
fn quantized_accuracy_on_real_eval_set() {
    // The serving contract end to end: int8 artifacts classify the held-out
    // eval set at (near) the accuracy the manifest records.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let rt = Runtime::load_configs(&dir, &["int8"]).expect("load int8");
    let m = rt.manifest();
    let elems = m.sample_elems();
    let classes = m.num_classes as usize;
    let inputs: Vec<f32> = std::fs::read(dir.join("eval_inputs.f32"))
        .unwrap()
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let labels = std::fs::read(dir.join("eval_labels.u8")).unwrap();
    let n = labels.len().min(64); // keep the test fast
    let mut correct = 0;
    for chunk in 0..n / 8 {
        let lo = chunk * 8 * elems;
        let logits = rt.infer("int8", 8, &inputs[lo..lo + 8 * elems]).expect("infer");
        let preds = argmax_rows(&logits, classes);
        for (i, p) in preds.iter().enumerate() {
            if *p == labels[chunk * 8 + i] as usize {
                correct += 1;
            }
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.9, "int8 accuracy on eval set = {acc}");
}

#[test]
fn failure_injection_bad_manifest_and_hlo() {
    // Corrupt inputs must surface as errors, not panics.
    let tmp = std::env::temp_dir().join("bf_imna_bad_artifacts");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();

    // 1. Missing manifest.
    assert!(Runtime::load(&tmp).is_err());

    // 2. Malformed manifest JSON.
    std::fs::write(tmp.join("manifest.json"), "{ not json").unwrap();
    assert!(Runtime::load(&tmp).is_err());

    // 3. Valid manifest pointing at a garbage HLO file.
    std::fs::write(
        tmp.join("manifest.json"),
        r#"{
          "model": "m", "input_shape": [2, 2, 1], "num_classes": 2,
          "param_count": 0, "batch_sizes": [1],
          "configs": {}, "accuracies": {},
          "artifacts": [
            {"config": "x", "batch": 1, "file": "bad.hlo.txt", "avg_bits": 8.0, "accuracy": 0.0}
          ]
        }"#,
    )
    .unwrap();
    std::fs::write(tmp.join("bad.hlo.txt"), "this is not HLO").unwrap();
    assert!(Runtime::load(&tmp).is_err());

    // 4. Manifest referencing a file that does not exist.
    std::fs::write(
        tmp.join("manifest.json"),
        r#"{
          "model": "m", "input_shape": [2, 2, 1], "num_classes": 2,
          "param_count": 0, "batch_sizes": [1],
          "configs": {}, "accuracies": {},
          "artifacts": [
            {"config": "x", "batch": 1, "file": "missing.hlo.txt", "avg_bits": 8.0, "accuracy": 0.0}
          ]
        }"#,
    )
    .unwrap();
    assert!(Runtime::load(&tmp).is_err());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn infer_rejects_unknown_config_and_bad_sizes() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load_configs(&artifacts_dir(), &["int4"]).expect("load");
    let elems = rt.manifest().sample_elems();
    // Unknown config.
    assert!(rt.infer("nope", 1, &vec![0.0; elems]).is_err());
    // Unknown batch.
    assert!(rt.infer("int4", 3, &vec![0.0; 3 * elems]).is_err());
    // Wrong input length.
    assert!(rt.infer("int4", 1, &vec![0.0; elems - 1]).is_err());
}
