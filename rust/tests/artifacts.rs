//! Golden tests for the paper-artifact catalog (`sim::artifacts`): every
//! artifact must render **byte-identically** from (a) an in-process
//! `run_full`, (b) a 4-shard `sweep` + `merge`, and (c) a two-worker
//! `dispatch` over the HTTP transport — the acceptance invariant of the
//! experiment-IR refactor. The documents themselves must also be
//! byte-identical, and a document whose records drifted from its spec
//! must be rejected before any renderer runs.

use std::time::Duration;

use bf_imna::sim::artifacts;
use bf_imna::sim::shard::{self, SweepSpec};
use bf_imna::sim::transport::{dispatch, DispatchOpts, WorkerServer};
use bf_imna::sim::SweepEngine;
use bf_imna::util::json::Json;

#[test]
fn every_artifact_renders_byte_identically_across_execution_modes() {
    // One worker pool serves every artifact's dispatch leg.
    let workers: Vec<WorkerServer> = (0..2)
        .map(|_| {
            WorkerServer::spawn("127.0.0.1:0", SweepEngine::with_threads(2)).expect("bind worker")
        })
        .collect();
    let pool: Vec<String> = workers.iter().map(|w| w.addr().to_string()).collect();
    let engine = SweepEngine::new();

    for artifact in artifacts::catalog() {
        let spec = artifact.tiny_spec();

        // (a) In-process reference document.
        let full = shard::run_full(&spec, &engine)
            .unwrap_or_else(|e| panic!("{}: run_full: {e}", artifact.name));
        let full_text = full.to_string();

        // (b) 4 independent shard "workers" (fresh engines, as separate
        // processes would be) + merge.
        let docs: Vec<Json> = (0..4)
            .map(|k| {
                shard::run_shard(&spec, 4, k, &SweepEngine::serial())
                    .unwrap_or_else(|e| panic!("{}: shard {k}: {e}", artifact.name))
                    .to_json()
            })
            .collect();
        let merged =
            shard::merge(&docs).unwrap_or_else(|e| panic!("{}: merge: {e}", artifact.name));
        assert_eq!(merged.to_string(), full_text, "{}: sharded merge diverged", artifact.name);

        // (c) Two-worker dispatch over the HTTP transport.
        let dopts = DispatchOpts {
            shards: 3,
            timeout: Duration::from_secs(60),
            ..DispatchOpts::default()
        };
        let report = dispatch(&spec, &pool, &dopts)
            .unwrap_or_else(|e| panic!("{}: dispatch: {e}", artifact.name));
        assert_eq!(report.doc.to_string(), full_text, "{}: dispatched doc diverged", artifact.name);

        // All three documents render to the same bytes.
        let r_full = artifact
            .render_doc(&full)
            .unwrap_or_else(|e| panic!("{}: render(full): {e}", artifact.name));
        assert!(!r_full.is_empty(), "{}: rendered empty", artifact.name);
        let r_merged = artifact.render_doc(&merged).unwrap();
        let r_dispatched = artifact.render_doc(&report.doc).unwrap();
        assert_eq!(r_merged, r_full, "{}: merged render diverged", artifact.name);
        assert_eq!(r_dispatched, r_full, "{}: dispatched render diverged", artifact.name);
    }

    for w in workers {
        w.shutdown();
    }
}

#[test]
fn run_and_render_matches_document_render() {
    // The in-process convenience path and the document path are the same
    // renderer over the same records — one way numbers become a figure.
    let engine = SweepEngine::new();
    for artifact in artifacts::catalog() {
        let doc = shard::run_full(&artifact.tiny_spec(), &engine).unwrap();
        assert_eq!(
            artifact.run_and_render(&engine, true).unwrap(),
            artifact.render_doc(&doc).unwrap(),
            "{}: run_and_render diverged from render_doc",
            artifact.name
        );
    }
}

#[test]
fn paper_scale_specs_serialize_and_resolve() {
    // Every catalog spec (paper-scale and tiny) must round-trip through
    // JSON and enumerate a positive number of points deterministically.
    for artifact in artifacts::catalog() {
        for (flavor, spec) in [("spec", artifact.spec()), ("tiny", artifact.tiny_spec())] {
            let text = spec.to_json().to_string();
            let back = SweepSpec::from_json(&Json::parse(&text).unwrap())
                .unwrap_or_else(|e| panic!("{} {flavor}: parse: {e}", artifact.name));
            assert_eq!(back, spec, "{} {flavor}: round trip changed the spec", artifact.name);
            let n = back
                .resolve()
                .unwrap_or_else(|e| panic!("{} {flavor}: resolve: {e}", artifact.name))
                .num_points();
            assert!(n >= 1, "{} {flavor}: no points", artifact.name);
            // Enumeration is deterministic: resolving twice gives the same
            // coordinates at every index.
            let (a, b) = (back.resolve().unwrap(), back.resolve().unwrap());
            for i in 0..n {
                assert_eq!(a.coords(i), b.coords(i), "{} {flavor}: point {i}", artifact.name);
            }
        }
    }
}

#[test]
fn drifted_documents_never_reach_a_renderer() {
    let engine = SweepEngine::serial();
    let artifact = artifacts::by_name("fig6").unwrap();
    let doc = shard::run_full(&artifact.tiny_spec(), &engine).unwrap();
    // Swap two records' echoed hw/tech coordinates: indices stay
    // contiguous, totals stay plausible — only the coordinate cross-check
    // can catch it.
    let mut bad = doc.clone();
    if let Json::Obj(m) = &mut bad {
        if let Some(Json::Arr(points)) = m.get_mut("points") {
            if let Json::Obj(p) = &mut points[0] {
                p.insert("tech".to_string(), Json::str("reram"));
            }
        }
    }
    let err = artifact.render_doc(&bad).unwrap_err();
    assert!(err.contains("drifted"), "{err}");
}
