//! Live coordinator tests: dynamic batching + bit-fluid precision control
//! over real PJRT execution. Requires `make artifacts` **and** a build
//! with `--features pjrt` (the default stub runtime cannot load
//! artifacts, so these tests only exist on the real backend).
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};
use std::time::Duration;

use bf_imna::coordinator::{Budget, BudgetTargets, Coordinator, CoordinatorConfig};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn sample(elems: usize, seed: u64) -> Vec<f32> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..elems)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64) as f32 * 2.0 - 1.0
        })
        .collect()
}

fn start(configs: &[&str]) -> Coordinator {
    Coordinator::start(
        &artifacts_dir(),
        CoordinatorConfig {
            configs: configs.iter().map(|s| s.to_string()).collect(),
            batch_window: Duration::from_millis(1),
            targets: BudgetTargets {
                low: Duration::from_millis(2),
                medium: Duration::from_millis(50),
                high: Duration::from_secs(5),
            },
            calibrate: true,
            pinned: Default::default(),
        },
    )
    .expect("coordinator start")
}

#[test]
fn serves_single_request() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let c = start(&["int8", "int4"]);
    let resp = c.infer(sample(c.sample_elems(), 1), Budget::High).expect("infer");
    assert_eq!(resp.logits.len(), c.num_classes());
    assert!(resp.logits.iter().all(|x| x.is_finite()));
    assert!(resp.latency_s > 0.0);
    let m = c.metrics();
    assert_eq!(m.completed, 1);
    assert_eq!(m.failed, 0);
}

#[test]
fn loose_budget_prefers_higher_bits_than_tight_budget() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let c = start(&["int8", "int4"]);
    let hi = c.infer(sample(c.sample_elems(), 2), Budget::High).expect("high");
    // With a 5 s budget the controller must keep the top-quality config.
    assert_eq!(hi.config, "int8", "high budget got {}", hi.config);
    // With a 2 ms budget on this CPU the controller degrades precision.
    let lo = c.infer(sample(c.sample_elems(), 3), Budget::Low).expect("low");
    assert_eq!(lo.config, "int4", "low budget got {}", lo.config);
}

#[test]
fn concurrent_requests_batch_together() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let c = start(&["int8"]);
    let elems = c.sample_elems();
    // Enqueue several requests back to back; the 1 ms window should batch
    // at least some of them.
    let pendings: Vec<_> = (0..8)
        .map(|i| c.submit(sample(elems, 100 + i), Budget::High).expect("submit"))
        .collect();
    for p in pendings {
        let r = p.wait().expect("response");
        assert_eq!(r.logits.len(), c.num_classes());
    }
    let m = c.metrics();
    assert_eq!(m.completed, 8);
    assert!(m.batches <= 8, "batches {}", m.batches);
    // Batch sizes recorded must be compiled sizes.
    for b in m.per_batch_size.keys() {
        assert!([1u64, 4, 8].contains(b), "unexpected batch size {b}");
    }
}

#[test]
fn mixed_budgets_all_get_answers() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let c = start(&["int8", "mixed_medium", "int4"]);
    let elems = c.sample_elems();
    let budgets = [Budget::Low, Budget::Medium, Budget::High];
    let pendings: Vec<_> = (0..6)
        .map(|i| c.submit(sample(elems, 200 + i as u64), budgets[i % 3]).expect("submit"))
        .collect();
    let mut configs_seen = std::collections::BTreeSet::new();
    for p in pendings {
        let r = p.wait().expect("response");
        configs_seen.insert(r.config);
    }
    assert!(!configs_seen.is_empty());
    let m = c.metrics();
    assert_eq!(m.completed, 6);
    assert!(m.latency_p(0.99) >= m.latency_p(0.5));
}

#[test]
fn rejects_wrong_input_size() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let c = start(&["int4"]);
    assert!(c.submit(vec![0.0; 7], Budget::High).is_err());
}

#[test]
fn quantized_configs_agree_with_each_other_on_argmax_mostly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let c = start(&["int8", "int4"]);
    let elems = c.sample_elems();
    let mut agree = 0;
    let n = 8;
    for i in 0..n {
        let x = sample(elems, 300 + i);
        let hi = c.infer(x.clone(), Budget::High).expect("int8");
        let lo = c.infer(x, Budget::Low).expect("int4");
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        if am(&hi.logits) == am(&lo.logits) {
            agree += 1;
        }
    }
    // Random noise inputs — quantization rarely flips the winner entirely.
    assert!(agree >= n / 2, "int8/int4 agreement {agree}/{n}");
}
