//! CAM cell technologies and the per-event energy / cycle cost model.
//!
//! Parameters come from the paper's Table VI (16 nm predictive technology
//! model, SPICE-calibrated by the authors):
//!
//! | parameter | definition                  | value    |
//! |-----------|-----------------------------|----------|
//! | `E_wS`    | SRAM write energy / cell    | 0.24 fJ  |
//! | `E_wR`    | ReRAM write energy / cell   | 21.7 pJ  |
//! | `R_LRS`   | ReRAM low-resistance state  | 5 kΩ     |
//! | `R_HRS`   | ReRAM high-resistance state | 2.5 MΩ   |
//! | `R_ON`    | ON transistor resistance    | 15 kΩ    |
//! | `R_OFF`   | OFF transistor resistance   | 24.25 MΩ |
//! | `C_in`    | sensing capacitance         | 50 fF    |
//! | `V_DD`    | supply voltage              | 1 V      |
//!
//! The compare (search) energy is dominated by charging the sense
//! capacitance of the matched row/column and is *technology independent* to
//! first order (the paper: "the comparison energy is similar in both
//! technologies"). The paper never states its absolute value; we use the
//! physical sense-capacitor charging energy `½·C_in·V_DD² = 25 fJ` per
//! word-sense. Cross-validation: this constant reproduces Table VIII's
//! absolute energy efficiency at 8-bit (BF-IMNA_8b: 641 GOPS/W published,
//! ≈625 modeled) and 16-bit (170 published, ≈156 modeled) with no further
//! tuning. This single derived constant plays the role the authors' SPICE
//! deck played; see ARCHITECTURE.md and EXPERIMENTS.md for where the
//! Fig. 6 ratio magnitudes land under it.
//!
//! The per-op numbers themselves are declared as data in
//! [`crate::costs::default_table`] (one row per technology, one
//! energy+cycles pair per AP op); [`Tech::new`] materializes that table's
//! row. This module keeps the physical constants (Table VI inputs) and the
//! [`Tech`] cost handle the mapper/sim stack consumes.

/// Joules per femtojoule.
pub const FJ: f64 = 1e-15;
/// Joules per picojoule.
pub const PJ: f64 = 1e-12;

/// Nominal supply voltage (Table VI).
pub const V_DD_NOMINAL: f64 = 1.0;
/// Scaled supply voltage explored in §V-A "Voltage Scaling".
pub const V_DD_SCALED: f64 = 0.5;
/// SRAM write energy per cell at 0.5 V (paper §V-A: 0.24 fJ -> 0.06 fJ).
pub const E_WRITE_SRAM_SCALED: f64 = 0.06 * FJ;
/// Average per-cell error probability at 0.5 V (paper §V-A).
pub const P_ERR_SCALED: f64 = 0.021;

/// Sense capacitance (Table VI), farads.
pub const C_IN: f64 = 50e-15;

/// Sense-energy coefficient (see module docs): the charging energy of the
/// sense capacitance, `E_compare_word = ½ · C_IN · V_DD²` = 25 fJ.
pub const COMPARE_PERIPHERAL_FACTOR: f64 = 0.5;

/// CAM cell technology. SRAM and ReRAM are the paper's Table VI pair;
/// PCM and FeFET are the §V-A extension technologies ("it is very easy to
/// extend our framework to perform a similar analysis for these
/// technologies" — constants from the cited Wong et al. [49] and Müller
/// et al. [29] lines of work).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellTech {
    /// 16 nm SRAM-based CAM cell.
    Sram,
    /// 16 nm ReRAM (RRAM) based CAM cell.
    Reram,
    /// Phase-change-memory cell (RESET-energy dominated writes, slow SET).
    Pcm,
    /// Ferroelectric-FET cell (field-driven, near-SRAM write energy,
    /// ReRAM-class density).
    Fefet,
}

impl CellTech {
    /// The paper's Table VI pair, SRAM first (the default after Fig. 6).
    pub const ALL: [CellTech; 2] = [CellTech::Sram, CellTech::Reram];

    /// All four technologies including the §V-A extensions.
    pub const EXTENDED: [CellTech; 4] =
        [CellTech::Sram, CellTech::Reram, CellTech::Pcm, CellTech::Fefet];

    /// Label used in report tables.
    pub fn label(&self) -> &'static str {
        match self {
            CellTech::Sram => "SRAM",
            CellTech::Reram => "ReRAM",
            CellTech::Pcm => "PCM",
            CellTech::Fefet => "FeFET",
        }
    }
}

/// SRAM write energy per cell (Table VI: `E_wS` = 0.24 fJ).
pub const E_WRITE_SRAM: f64 = 0.24 * FJ;
/// ReRAM write energy per cell (Table VI: `E_wR` = 21.7 pJ).
pub const E_WRITE_RERAM: f64 = 21.7 * PJ;
/// PCM write energy per cell (RESET pulse class figure, Wong et al.).
pub const E_WRITE_PCM: f64 = 13.5 * PJ;
/// FeFET write energy per cell (field-driven polarization switch).
pub const E_WRITE_FEFET: f64 = 1.0 * FJ;
/// PCM area savings vs SRAM (4F² class cell + amortized periphery).
pub const PCM_AREA_SAVINGS: f64 = 4.0;
/// FeFET area savings vs SRAM (1T cell, slightly larger than ReRAM 1T1R).
pub const FEFET_AREA_SAVINGS: f64 = 3.5;

/// Complete per-event cost model for one technology + supply point.
///
/// Cycle counts: a compare (search) phase and a read each take one cycle at
/// the AP clock; a write takes two cycles (paper §II-B: "a two-cycle
/// requirement per writing a row/column") for SRAM and twice that for ReRAM
/// (paper §V-A: SRAM cells "require half the cycles to write compared to
/// ReRAM cells").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Tech {
    /// Which CAM cell technology this models.
    pub cell: CellTech,
    /// Supply voltage, volts.
    pub v_dd: f64,
    /// Write energy per cell, joules.
    pub e_write_cell: f64,
    /// Compare (search) energy per word-sense, joules.
    pub e_compare_word: f64,
    /// Read energy per word-sense, joules (sensing path, same as compare).
    pub e_read_word: f64,
    /// Cycles per compare phase.
    pub compare_cycles: f64,
    /// Cycles per write phase.
    pub write_cycles: f64,
    /// Cycles per read phase.
    pub read_cycles: f64,
    /// Per-cell error probability (0 at nominal voltage; §V-A at 0.5 V).
    pub p_cell_error: f64,
    /// Effective area per CAM cell including amortized peripherals, m².
    pub cell_area_m2: f64,
}

/// Effective SRAM cell area (incl. amortized peripherals) chosen so that the
/// LR chip (4096 CAPs + 64 MAPs of 4800x16 cells) matches Table V's total
/// area of 137.45 mm². 137.45e-6 m² / (4160 * 4800 * 16) cells.
pub const SRAM_CELL_AREA_M2: f64 = 137.45e-6 / (4160.0 * 4800.0 * 16.0);

/// ReRAM area advantage at 8-bit support (paper §V-A: "4.4x area savings").
pub const RERAM_AREA_SAVINGS: f64 = 4.4;

impl Tech {
    /// Nominal-voltage model for a technology — the default
    /// [`CostTable`](crate::costs::CostTable) row materialized as a cost
    /// handle. The numbers themselves live in
    /// [`crate::costs::default_table`], declared via
    /// [`def_ap_cost!`](crate::def_ap_cost) with the exact constant
    /// expressions this function used to inline (bit-identical,
    /// golden-tested in `tests/goldens.rs`).
    pub fn new(cell: CellTech) -> Self {
        crate::costs::default_table()
            .tech_for(cell)
            .expect("default cost table declares every CellTech row")
    }

    /// PCM at nominal voltage (§V-A extension).
    pub fn pcm() -> Self {
        Self::new(CellTech::Pcm)
    }

    /// FeFET at nominal voltage (§V-A extension).
    pub fn fefet() -> Self {
        Self::new(CellTech::Fefet)
    }

    /// SRAM at nominal voltage — the paper's default technology.
    pub fn sram() -> Self {
        Self::new(CellTech::Sram)
    }

    /// ReRAM at nominal voltage.
    pub fn reram() -> Self {
        Self::new(CellTech::Reram)
    }

    /// Apply §V-A voltage scaling (supported for SRAM, where the paper
    /// reports the scaled write energy and error probability). Compare /
    /// read energies scale with V²; write energy uses the published scaled
    /// value; the published average cell-error probability is attached.
    pub fn voltage_scaled(&self) -> Self {
        let vr = V_DD_SCALED / V_DD_NOMINAL;
        let e_compare_word = self.e_compare_word * vr * vr;
        Tech {
            v_dd: V_DD_SCALED,
            e_write_cell: match self.cell {
                CellTech::Sram => E_WRITE_SRAM_SCALED,
                // NVM write energy is set-current dominated; scale ~V².
                CellTech::Reram | CellTech::Pcm | CellTech::Fefet => {
                    self.e_write_cell * vr * vr
                }
            },
            e_compare_word,
            e_read_word: e_compare_word,
            p_cell_error: P_ERR_SCALED,
            ..*self
        }
    }

    /// §V-A's *write-only* scaled operating point: the published 0.5 V
    /// write energy with the sensing path left at nominal — the paper's
    /// "how much does scaling writes alone buy" question. Previously
    /// re-implemented by hand (mutating `e_write_cell` inline) in both
    /// `sim::dse` and a `sim` test; one definition now.
    pub fn write_scaled_only(&self) -> Self {
        let scaled = self.voltage_scaled();
        Tech { e_write_cell: scaled.e_write_cell, ..*self }
    }

    /// Latency in cycles of an event bundle.
    pub fn cycles(&self, ev: &super::Events) -> f64 {
        ev.compares as f64 * self.compare_cycles
            + ev.writes as f64 * self.write_cycles
            + ev.reads as f64 * self.read_cycles
    }

    /// Energy in joules of a cell-activity bundle.
    pub fn energy(&self, c: &super::CellEvents) -> f64 {
        c.compare_senses * self.e_compare_word
            + (c.lut_write_cells + c.populate_write_cells) * self.e_write_cell
            + c.read_senses * self.e_read_word
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ap::{CellEvents, Events};

    #[test]
    fn table_vi_constants() {
        let s = Tech::sram();
        let r = Tech::reram();
        assert!((s.e_write_cell - 0.24e-15).abs() < 1e-20);
        assert!((r.e_write_cell - 21.7e-12).abs() < 1e-16);
        // Write-energy gap: "4 orders of magnitude less energy to write".
        let ratio = r.e_write_cell / s.e_write_cell;
        assert!(ratio > 1e4 && ratio < 1e5, "ratio {ratio}");
    }

    #[test]
    fn compare_energy_is_tech_independent() {
        assert_eq!(Tech::sram().e_compare_word, Tech::reram().e_compare_word);
    }

    #[test]
    fn sram_writes_in_half_the_cycles_of_reram() {
        assert_eq!(Tech::sram().write_cycles * 2.0, Tech::reram().write_cycles);
    }

    #[test]
    fn voltage_scaling_matches_paper() {
        let v = Tech::sram().voltage_scaled();
        assert_eq!(v.v_dd, 0.5);
        assert!((v.e_write_cell - 0.06e-15).abs() < 1e-20);
        assert_eq!(v.p_cell_error, 0.021);
        // Compare energy scales with V^2 -> quarter.
        assert!((v.e_compare_word - Tech::sram().e_compare_word / 4.0).abs() < 1e-18);
    }

    #[test]
    fn cycles_weighted_sum() {
        let s = Tech::sram();
        let ev = Events::new(4, 4, 1);
        assert_eq!(s.cycles(&ev), 4.0 + 8.0 + 1.0);
        let r = Tech::reram();
        assert_eq!(r.cycles(&ev), 4.0 + 16.0 + 1.0);
    }

    #[test]
    fn energy_weighted_sum() {
        let s = Tech::sram();
        let c = CellEvents {
            compare_senses: 2.0,
            lut_write_cells: 3.0,
            populate_write_cells: 1.0,
            read_senses: 1.0,
        };
        let e = s.energy(&c);
        let expect = 2.0 * s.e_compare_word + 4.0 * s.e_write_cell + s.e_read_word;
        assert!((e - expect).abs() < 1e-24);
    }

    #[test]
    fn extension_technologies_are_ordered_sanely() {
        // Write energy: FeFET ~ SRAM class << PCM < ReRAM.
        let (s, r, p, f) = (Tech::sram(), Tech::reram(), Tech::pcm(), Tech::fefet());
        assert!(f.e_write_cell < p.e_write_cell);
        assert!(p.e_write_cell < r.e_write_cell);
        assert!(s.e_write_cell < f.e_write_cell);
        // Density: all NVMs beat SRAM.
        for t in [&r, &p, &f] {
            assert!(t.cell_area_m2 < s.cell_area_m2);
        }
        // Write cycles: PCM is the slowest writer.
        assert!(p.write_cycles > r.write_cycles && r.write_cycles > s.write_cycles);
        assert_eq!(CellTech::EXTENDED.len(), 4);
        assert_eq!(CellTech::Pcm.label(), "PCM");
        assert_eq!(CellTech::Fefet.label(), "FeFET");
    }

    #[test]
    fn extension_voltage_scaling_is_quadratic() {
        let p = Tech::pcm().voltage_scaled();
        assert!((p.e_write_cell - E_WRITE_PCM / 4.0).abs() < 1e-18);
        let f = Tech::fefet().voltage_scaled();
        assert!((f.e_write_cell - E_WRITE_FEFET / 4.0).abs() < 1e-20);
    }

    #[test]
    fn write_scaled_only_touches_only_write_energy() {
        let s = Tech::sram();
        let w = s.write_scaled_only();
        assert_eq!(w.e_write_cell, E_WRITE_SRAM_SCALED);
        assert_eq!(w.e_compare_word.to_bits(), s.e_compare_word.to_bits());
        assert_eq!(w.e_read_word.to_bits(), s.e_read_word.to_bits());
        assert_eq!(w.v_dd, s.v_dd);
        assert_eq!(w.p_cell_error, 0.0);
        let r = Tech::reram();
        let rw = r.write_scaled_only();
        assert_eq!(rw.e_write_cell.to_bits(), (r.e_write_cell * 0.25).to_bits());
        assert_eq!(rw.e_compare_word.to_bits(), r.e_compare_word.to_bits());
    }

    #[test]
    fn reram_cell_is_smaller() {
        assert!(Tech::reram().cell_area_m2 < Tech::sram().cell_area_m2);
        let ratio = Tech::sram().cell_area_m2 / Tech::reram().cell_area_m2;
        assert!((ratio - RERAM_AREA_SAVINGS).abs() < 1e-9);
    }

    #[test]
    fn lr_chip_area_matches_table_v() {
        // 4096 CAPs + 64 MAPs, each 4800 rows x 16 bit-columns.
        let cells = 4160.0 * 4800.0 * 16.0;
        let area_mm2 = cells * SRAM_CELL_AREA_M2 * 1e6;
        assert!((area_mm2 - 137.45).abs() < 0.01, "area {area_mm2}");
    }
}
