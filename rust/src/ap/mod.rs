//! Associative-Processor (AP) substrate.
//!
//! This module implements the paper's three AP abstractions plus the shared
//! cost vocabulary every higher layer consumes:
//!
//! * [`tech`] — CAM cell technologies (Table VI 16 nm PTM parameters),
//!   per-event energies and cycle counts, voltage scaling;
//! * [`luts`] — the compare/write pass tables (LUTs) for in-place addition,
//!   out-of-place multiplication, ReLU (Table III) and max pooling
//!   (Table IV);
//! * [`emulator`] — a functional, bit-exact emulator of a (2D) CAM that
//!   executes the LUT pass sequences and counts every compare/write/read
//!   event — the paper's §IV "microbenchmark" used to validate the models;
//! * [`runtime_model`] — the closed-form runtime models of Table I /
//!   Eqs. (1)–(15) for 1D APs, 2D APs and 2D APs with vertical segmentation;
//! * [`complexity`] — Table II asymptotic classes (used as test oracles for
//!   the growth of the runtime models).
//!
//! ## Cost vocabulary
//!
//! Every AP operation decomposes into three primitive event kinds:
//! **compare** (one LUT search phase over the selected column/row pair),
//! **write** (one masked write phase, including data-population writes) and
//! **read** (one bit- or word-sequential read). Table I's runtime formulas
//! are exactly the *sum of event counts* with unit cost per event; latency
//! in cycles applies the per-technology cycle weights and energy applies the
//! per-technology cell energies (see [`tech::Tech`]).

pub mod complexity;
pub mod emulator;
pub mod luts;
pub mod runtime_model;
pub mod tech;

/// Which AP organization an operation runs on (Table I columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ApKind {
    /// 1D AP: column (horizontal) operations only; vertical combining is
    /// done by sequential word transfers.
    OneD,
    /// 2D AP without vertical segmentation: vertical (row-pair) operations
    /// exist but run one row pair at a time.
    TwoD,
    /// 2D AP with vertical segmentation: all row pairs of a segment operate
    /// in parallel (reduction-tree behaviour).
    TwoDSeg,
}

impl ApKind {
    /// All kinds, in Table I column order.
    pub const ALL: [ApKind; 3] = [ApKind::OneD, ApKind::TwoD, ApKind::TwoDSeg];

    /// Human-readable label used in tables.
    pub fn label(&self) -> &'static str {
        match self {
            ApKind::OneD => "1D AP",
            ApKind::TwoD => "2D AP",
            ApKind::TwoDSeg => "2D AP (seg)",
        }
    }
}

/// Primitive event counts of one AP operation (unit-cost == Table I runtime).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Events {
    /// LUT compare (search) phases.
    pub compares: u64,
    /// Write phases: LUT-result writes plus data-population writes.
    pub writes: u64,
    /// Bit-sequential / word-sequential read phases.
    pub reads: u64,
}

impl Events {
    /// New event bundle.
    pub fn new(compares: u64, writes: u64, reads: u64) -> Self {
        Self { compares, writes, reads }
    }

    /// Table I "runtime": unit cost per event.
    pub fn time_units(&self) -> u64 {
        self.compares + self.writes + self.reads
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Events) -> Events {
        Events {
            compares: self.compares + other.compares,
            writes: self.writes + other.writes,
            reads: self.reads + other.reads,
        }
    }

    /// Elementwise scale by an integer repeat count.
    pub fn scale(&self, k: u64) -> Events {
        Events { compares: self.compares * k, writes: self.writes * k, reads: self.reads * k }
    }
}

impl std::ops::Add for Events {
    type Output = Events;
    fn add(self, rhs: Events) -> Events {
        Events::add(&self, &rhs)
    }
}

impl std::iter::Sum for Events {
    fn sum<I: Iterator<Item = Events>>(iter: I) -> Events {
        iter.fold(Events::default(), |a, b| a + b)
    }
}

/// Cell-granularity activity of one AP operation, used by the energy model.
///
/// Units are "cell-events" (for writes/reads) and "row-sense events" (for
/// compares: one sense-amplifier evaluation of one word's tag). Stored as
/// `f64` because the paper's average write activity (1.5 effective writes
/// per 4-pass LUT group) makes these fractional, and end-to-end totals
/// exceed `u64` range for the large models.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CellEvents {
    /// Word-sense events: (compare phases) x (words sensed per phase).
    pub compare_senses: f64,
    /// Cells actually written by LUT write phases (average activity).
    pub lut_write_cells: f64,
    /// Cells written by data-population / transfer writes (full activity).
    pub populate_write_cells: f64,
    /// Word-sense events spent on reads.
    pub read_senses: f64,
}

impl CellEvents {
    /// Elementwise sum.
    pub fn add(&self, o: &CellEvents) -> CellEvents {
        CellEvents {
            compare_senses: self.compare_senses + o.compare_senses,
            lut_write_cells: self.lut_write_cells + o.lut_write_cells,
            populate_write_cells: self.populate_write_cells + o.populate_write_cells,
            read_senses: self.read_senses + o.read_senses,
        }
    }

    /// Elementwise scale.
    pub fn scale(&self, k: f64) -> CellEvents {
        CellEvents {
            compare_senses: self.compare_senses * k,
            lut_write_cells: self.lut_write_cells * k,
            populate_write_cells: self.populate_write_cells * k,
            read_senses: self.read_senses * k,
        }
    }
}

impl std::ops::Add for CellEvents {
    type Output = CellEvents;
    fn add(self, rhs: CellEvents) -> CellEvents {
        CellEvents::add(&self, &rhs)
    }
}

impl std::iter::Sum for CellEvents {
    fn sum<I: Iterator<Item = CellEvents>>(iter: I) -> CellEvents {
        iter.fold(CellEvents::default(), |a, b| a + b)
    }
}

/// Full cost of one AP operation: timing events + cell activity + the
/// bitwidth of the produced result (precision grows through multiply /
/// reduce, Table I comments).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct OpCost {
    /// Critical-path timing events.
    pub events: Events,
    /// Total cell activity (energy side).
    pub cells: CellEvents,
    /// Bitwidth of each result word after the operation.
    pub result_bits: u32,
}

impl OpCost {
    /// Combine two operation costs sequentially (result bits of the latter).
    pub fn then(&self, next: &OpCost) -> OpCost {
        OpCost {
            events: self.events + next.events,
            cells: self.cells + next.cells,
            result_bits: next.result_bits,
        }
    }
}

/// `ceil(log2(x))` with `clog2(0) = clog2(1) = 0`, used throughout the
/// runtime models (the paper's formulas implicitly assume powers of two).
pub fn clog2(x: u64) -> u32 {
    if x <= 1 {
        0
    } else {
        64 - (x - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clog2_basics() {
        assert_eq!(clog2(0), 0);
        assert_eq!(clog2(1), 0);
        assert_eq!(clog2(2), 1);
        assert_eq!(clog2(3), 2);
        assert_eq!(clog2(4), 2);
        assert_eq!(clog2(5), 3);
        assert_eq!(clog2(1024), 10);
    }

    #[test]
    fn events_time_units_sum() {
        let e = Events::new(4, 5, 1);
        assert_eq!(e.time_units(), 10);
    }

    #[test]
    fn events_add_scale() {
        let e = Events::new(1, 2, 3).scale(3) + Events::new(1, 1, 1);
        assert_eq!(e, Events::new(4, 7, 10));
    }

    #[test]
    fn cell_events_add_scale() {
        let c = CellEvents { compare_senses: 1.0, lut_write_cells: 2.0, populate_write_cells: 3.0, read_senses: 4.0 };
        let s = c.scale(2.0) + c;
        assert_eq!(s.compare_senses, 3.0);
        assert_eq!(s.read_senses, 12.0);
    }

    #[test]
    fn opcost_then_takes_final_bits() {
        let a = OpCost { events: Events::new(1, 0, 0), cells: CellEvents::default(), result_bits: 8 };
        let b = OpCost { events: Events::new(0, 1, 0), cells: CellEvents::default(), result_bits: 16 };
        let c = a.then(&b);
        assert_eq!(c.result_bits, 16);
        assert_eq!(c.events.time_units(), 2);
    }

    #[test]
    fn apkind_labels() {
        assert_eq!(ApKind::OneD.label(), "1D AP");
        assert_eq!(ApKind::ALL.len(), 3);
    }
}
