//! Closed-form AP runtime / activity models — paper Table I, Eqs. (1)–(15).
//!
//! Each function returns an [`OpCost`]: the primitive event counts (whose
//! unit-cost sum reproduces Table I's "runtime" exactly), the cell-level
//! activity used by the energy model, and the produced result bitwidth.
//!
//! Conventions (paper §III-B):
//! * `m` — operand bitwidth (Table I's `M`). Multiplication and GEMM accept
//!   separate weight/activation widths `(mw, ma)`; with `mw == ma == M` the
//!   formulas specialize to Table I verbatim.
//! * `l` — number of words stored in the AP (two per row except ReLU).
//! * `s`, `k` — pooling window size and number of pooling operations.
//! * Matrix-matrix multiplication multiplies an `i x j` by a `j x u` matrix.
//!
//! Energy-side activity: a compare senses every occupied word once per
//! pass (timing-wise, §V-A charges the fixed write *phases* — "4
//! comparisons and 1.5 writes on average" — but energy-wise a write phase
//! only flips cells in the words that *matched* the pass's key). A pass
//! with a k-bit key matches a uniformly-random word with probability
//! `2^-k`, so the expected written cells per pass are
//! `words · 2^-k · bits_per_write`. The per-LUT match probabilities are the
//! [`MATCH_PROB_2BIT`]/[`MATCH_PROB_3BIT`]/[`MATCH_PROB_4BIT`] constants
//! (ReLU keys are 2-bit; add/vertical-add keys 3-bit; gated-multiply and
//! max-pool keys 4-bit).

use super::{clog2, ApKind, CellEvents, Events, OpCost};

/// Match probability of a 2-bit LUT key (ReLU, Table III).
pub const MATCH_PROB_2BIT: f64 = 0.25;
/// Match probability of a 3-bit LUT key (full-adder passes).
pub const MATCH_PROB_3BIT: f64 = 0.125;
/// Match probability of a 4-bit LUT key (gated multiply, max pool
/// Table IV).
pub const MATCH_PROB_4BIT: f64 = 0.0625;

/// Cell activity of `n` LUT passes over `words` occupied words. Compares
/// sense every occupied word once per pass; write phases flip
/// `bits_per_write` cells in each matched word, with `match_prob` of the
/// words matching in expectation.
fn lut_cells_p(n_passes: u64, words: u64, bits_per_write: f64, match_prob: f64) -> CellEvents {
    CellEvents {
        compare_senses: n_passes as f64 * words as f64,
        lut_write_cells: n_passes as f64 * match_prob * words as f64 * bits_per_write,
        populate_write_cells: 0.0,
        read_senses: 0.0,
    }
}

/// Full-adder pass activity (3-bit keys, ~1.5 written cells per match).
fn lut_cells(n_passes: u64, words: u64, bits_per_write: f64) -> CellEvents {
    lut_cells_p(n_passes, words, bits_per_write, MATCH_PROB_3BIT)
}

/// Cell activity of populating `bits` bit-columns across `words` words.
fn populate_cells(bits: u64, words: u64) -> CellEvents {
    CellEvents {
        compare_senses: 0.0,
        lut_write_cells: 0.0,
        populate_write_cells: bits as f64 * words as f64,
        read_senses: 0.0,
    }
}

/// Cell activity of `bits` bit-sequential column reads over `words` words.
fn read_cells(bits: u64, words: u64) -> CellEvents {
    CellEvents {
        compare_senses: 0.0,
        lut_write_cells: 0.0,
        populate_write_cells: 0.0,
        read_senses: bits as f64 * words as f64,
    }
}

/// Cell activity of `n` word-sequential transfers of `bits`-bit words
/// (each transfer = one word-sense read + one word write).
fn transfer_cells(n: u64, bits: u64) -> CellEvents {
    CellEvents {
        compare_senses: 0.0,
        lut_write_cells: 0.0,
        populate_write_cells: n as f64 * bits as f64,
        read_senses: n as f64,
    }
}

/// Eq. (1) — in-place vector addition `B += A` over `l/2` word pairs of
/// width `m`. Identical on 1D and 2D APs (horizontal mode only).
///
/// Runtime: `(2M)_w + (4M)_c + (4M)_w + (M+1)_r  =  2M + 8M + M + 1`.
pub fn add(m: u32, l: u64, _kind: ApKind) -> OpCost {
    let m64 = m as u64;
    let pairs = l / 2;
    let events = Events::new(4 * m64, 2 * m64 + 4 * m64, m64 + 1);
    let cells = populate_cells(2 * m64, pairs)
        + lut_cells(4 * m64, pairs, 1.5)
        + read_cells(m64 + 1, pairs);
    OpCost { events, cells, result_bits: m + 1 }
}

/// Eq. (2) generalized to distinct operand widths — out-of-place
/// multiplication `C = A * B` over `l/2` word pairs, `A` of `ma` bits and
/// `B` of `mw` bits. With `ma == mw == M`: `2M + 8M² + 2M` (Table I).
///
/// Runtime: `(Ma+Mw)_w + (4·Ma·Mw)_c + (4·Ma·Mw)_w + (Ma+Mw)_r`.
pub fn multiply(ma: u32, mw: u32, l: u64, _kind: ApKind) -> OpCost {
    let (ma64, mw64) = (ma as u64, mw as u64);
    let pairs = l / 2;
    let passes = 4 * ma64 * mw64;
    let events = Events::new(passes, (ma64 + mw64) + passes, ma64 + mw64);
    let cells = populate_cells(ma64 + mw64, pairs)
        + lut_cells_p(passes, pairs, 1.5, MATCH_PROB_4BIT)
        + read_cells(ma64 + mw64, pairs);
    OpCost { events, cells, result_bits: ma + mw }
}

/// Eqs. (3)–(5) — reduction (sum of all `l` elements of width `m`).
///
/// * 1D (Eq. 3): `log2(L)` rounds of horizontal in-place addition with
///   growing width, plus `(L/2 - 1)` sequential word transfers.
/// * 2D (Eq. 4): one horizontal addition then `(L/2 - 1)` vertical row-pair
///   additions at 4 compares + 4 writes each.
/// * 2D seg (Eq. 5): vertical additions across all row pairs in parallel —
///   `log2(L/2)` rounds.
pub fn reduce(m: u32, l: u64, kind: ApKind) -> OpCost {
    let m64 = m as u64;
    let out_bits = m + clog2(l.max(1));
    let pairs = (l / 2).max(1);
    match kind {
        ApKind::OneD => {
            let mut events = Events::new(0, 2 * m64, 0);
            let mut cells = populate_cells(2 * m64, pairs);
            let rounds = clog2(l.max(1)) as u64;
            let mut active_pairs = pairs;
            for q in 1..=rounds {
                let width = m64 + q - 1;
                events = events + Events::new(4 * width, 4 * width, 0);
                cells = cells + lut_cells(4 * width, active_pairs.max(1), 1.5);
                active_pairs = (active_pairs / 2).max(1);
            }
            let transfers = pairs.saturating_sub(1);
            events = events + Events::new(0, transfers, transfers) + Events::new(0, 0, 1);
            cells = cells + transfer_cells(transfers, out_bits as u64) + read_cells(1, 1);
            OpCost { events, cells, result_bits: out_bits }
        }
        ApKind::TwoD => {
            let vertical_groups = pairs.saturating_sub(1);
            let events = Events::new(4 * m64, 2 * m64 + 4 * m64, 0)
                + Events::new(4 * vertical_groups, 4 * vertical_groups, 0)
                + Events::new(0, 0, 1);
            // A vertical pass senses the occupied bit-columns of the operand
            // row pair (result width) rather than all words.
            let cells = populate_cells(2 * m64, pairs)
                + lut_cells(4 * m64, pairs, 1.5)
                + lut_cells(4 * vertical_groups, out_bits as u64, 1.5)
                + read_cells(1, 1);
            OpCost { events, cells, result_bits: out_bits }
        }
        ApKind::TwoDSeg => {
            let rounds = clog2(pairs) as u64;
            let mut events = Events::new(4 * m64, 2 * m64 + 4 * m64, 0);
            let mut cells = populate_cells(2 * m64, pairs) + lut_cells(4 * m64, pairs, 1.5);
            // Parallel vertical rounds: same pass count per round, but the
            // cell activity spans all still-active row pairs.
            let mut active = pairs / 2;
            for _ in 0..rounds {
                events = events + Events::new(4, 4, 0);
                cells = cells + lut_cells(4, (active * out_bits as u64).max(1), 1.5);
                active = (active / 2).max(1);
            }
            events = events + Events::new(0, 0, 1);
            cells = cells + read_cells(1, 1);
            OpCost { events, cells, result_bits: out_bits }
        }
    }
}

/// Eqs. (6)–(8) generalized — matrix-matrix multiplication of an `i x j`
/// matrix (elements `ma` bits) by a `j x u` matrix (elements `mw` bits).
/// `i*j*u` product words are formed in parallel and then reduced in groups
/// of `j`. With `ma == mw == M` the totals match Table I verbatim.
pub fn matmat(ma: u32, mw: u32, i: u64, j: u64, u: u64, kind: ApKind) -> OpCost {
    let (ma64, mw64) = (ma as u64, mw as u64);
    let msum = ma64 + mw64;
    let words = i * j * u;
    let prod_bits = ma + mw;
    let out_bits = prod_bits + clog2(j.max(1));
    let mult_passes = 4 * ma64 * mw64;

    // Populate + multiply (all kinds identical, horizontal mode).
    let mut events = Events::new(mult_passes, msum + mult_passes, 0);
    let mut cells =
        populate_cells(msum, words) + lut_cells_p(mult_passes, words, 1.5, MATCH_PROB_4BIT);

    match kind {
        ApKind::OneD => {
            // log2(j) horizontal addition rounds of growing width plus
            // (i*u)(j-1) sequential word transfers (Eq. 6).
            let rounds = clog2(j.max(1)) as u64;
            let mut active = words / 2;
            for q in 1..=rounds {
                let width = msum + q - 1;
                events = events + Events::new(4 * width, 4 * width, 0);
                cells = cells + lut_cells(4 * width, active.max(1), 1.5);
                active = (active / 2).max(1);
            }
            let transfers = i * u * j.saturating_sub(1);
            events = events + Events::new(0, transfers, transfers);
            cells = cells + transfer_cells(transfers, out_bits as u64);
        }
        ApKind::TwoD => {
            // (i*u)(j-1) sequential vertical row-pair additions (Eq. 7).
            let groups = i * u * j.saturating_sub(1);
            events = events + Events::new(4 * groups, 4 * groups, 0);
            cells = cells + lut_cells(4 * groups, out_bits as u64, 1.5);
        }
        ApKind::TwoDSeg => {
            // log2(j) parallel vertical rounds (Eq. 8).
            let rounds = clog2(j.max(1)) as u64;
            let mut active = (i * u * j) / 2;
            for _ in 0..rounds {
                events = events + Events::new(4, 4, 0);
                cells = cells + lut_cells(4, (active * out_bits as u64).max(1), 1.5);
                active = (active / 2).max(1);
            }
        }
    }

    // Read out the i*u results bit-sequentially: (Ma+Mw+log2 j) column reads.
    let read_bits = out_bits as u64;
    events = events + Events::new(0, 0, read_bits);
    cells = cells + read_cells(read_bits, i * u);
    OpCost { events, cells, result_bits: out_bits }
}

/// Dot product — the `i == u == 1` special case of [`matmat`].
pub fn dot(ma: u32, mw: u32, j: u64, kind: ApKind) -> OpCost {
    matmat(ma, mw, 1, j, 1, kind)
}

/// Eq. (15) — ReLU over `l` words of width `m` (same on all AP kinds).
///
/// Runtime: `M_w + (2_w + 1_r) + (M-1)_c + (M-1)_w + M_r`.
pub fn relu(m: u32, l: u64, _kind: ApKind) -> OpCost {
    let m64 = m as u64;
    let events =
        Events::new(m64.saturating_sub(1), m64 + 2 + m64.saturating_sub(1), 1 + m64);
    let cells = populate_cells(m64, l)
        + read_cells(1, l) // read MSB column into flags
        + populate_cells(2, l) // write flag column + reset MSB
        + lut_cells_p(m64.saturating_sub(1), l, 1.0, MATCH_PROB_2BIT)
        + read_cells(m64, l);
    OpCost { events, cells, result_bits: m }
}

/// Eqs. (12)–(14) — max pooling with window size `s` over `k` windows,
/// elements of width `m` (`l = s*k` words stored as `s*k/2` pairs).
pub fn maxpool(m: u32, s: u64, k: u64, kind: ApKind) -> OpCost {
    let m64 = m as u64;
    let pairs = (s * k / 2).max(1);
    match kind {
        ApKind::OneD => {
            // Eq. 12: 2M_w + log2(S)((4M)_c + (4M)_w + 2_w) + (1r+1w)K(S/2-1) + M_r
            let rounds = clog2(s.max(1)) as u64;
            let mut events = Events::new(0, 2 * m64, 0);
            let mut cells = populate_cells(2 * m64, pairs);
            let mut active = pairs;
            for _ in 0..rounds {
                events = events + Events::new(4 * m64, 4 * m64 + 2, 0);
                cells = cells
                    + lut_cells_p(4 * m64, active.max(1), 1.5, MATCH_PROB_4BIT)
                    + populate_cells(2, active.max(1));
                active = (active / 2).max(1);
            }
            let transfers = k * (s / 2).saturating_sub(1);
            events = events + Events::new(0, transfers, transfers) + Events::new(0, 0, m64);
            cells = cells + transfer_cells(transfers, m64) + read_cells(m64, k);
            OpCost { events, cells, result_bits: m }
        }
        ApKind::TwoD => {
            // Eq. 13: 2M_w + (4M)_c + (4M)_w + K(S/2-1)(4c+4w+2w) + M_r + 2_w
            let groups = k * (s / 2).saturating_sub(1);
            let events = Events::new(4 * m64, 2 * m64 + 4 * m64, 0)
                + Events::new(4 * groups, 4 * groups + 2 * groups, 0)
                + Events::new(0, 2, m64);
            let cells = populate_cells(2 * m64, pairs)
                + lut_cells_p(4 * m64, pairs, 1.5, MATCH_PROB_4BIT)
                + lut_cells_p(4 * groups, m64, 1.5, MATCH_PROB_4BIT)
                + populate_cells(2, groups.max(1))
                + read_cells(m64, k)
                + populate_cells(2, pairs);
            OpCost { events, cells, result_bits: m }
        }
        ApKind::TwoDSeg => {
            // Eq. 14: 2M_w + (4M)_c + (4M)_w + log2(S/2)(4c + 4w + 2K_w) + M_r + 2_w
            let rounds = clog2((s / 2).max(1)) as u64;
            let mut events = Events::new(4 * m64, 2 * m64 + 4 * m64, 0);
            let mut cells =
                populate_cells(2 * m64, pairs) + lut_cells_p(4 * m64, pairs, 1.5, MATCH_PROB_4BIT);
            let mut active = pairs / 2;
            for _ in 0..rounds {
                events = events + Events::new(4, 4 + 2 * k, 0);
                cells = cells
                    + lut_cells_p(4, (active * m64 as u64).max(1), 1.5, MATCH_PROB_4BIT)
                    + populate_cells(2, (k * active.max(1)).max(1));
                active = (active / 2).max(1);
            }
            events = events + Events::new(0, 2, m64);
            cells = cells + populate_cells(2, pairs) + read_cells(m64, k);
            OpCost { events, cells, result_bits: m }
        }
    }
}

/// Eqs. (9)–(11) — average pooling with window `s` over `k` windows,
/// elements of width `m`. Division by the window size is a shifted
/// bit-sequential read (no extra passes).
pub fn avgpool(m: u32, s: u64, k: u64, kind: ApKind) -> OpCost {
    let m64 = m as u64;
    let pairs = (s * k / 2).max(1);
    match kind {
        ApKind::OneD => {
            // Eq. 9.
            let rounds = clog2(s.max(1)) as u64;
            let mut events = Events::new(0, 2 * m64, 0);
            let mut cells = populate_cells(2 * m64, pairs);
            let mut active = pairs;
            for q in 1..=rounds {
                let width = m64 + q - 1;
                events = events + Events::new(4 * width, 4 * width, 0);
                cells = cells + lut_cells(4 * width, active.max(1), 1.5);
                active = (active / 2).max(1);
            }
            let transfers = k * (s / 2).saturating_sub(1);
            events = events + Events::new(0, transfers, transfers) + Events::new(0, 0, m64);
            cells = cells + transfer_cells(transfers, m64 + rounds as u32 as u64)
                + read_cells(m64, k);
            OpCost { events, cells, result_bits: m }
        }
        ApKind::TwoD => {
            // Eq. 10.
            let groups = k * (s / 2).saturating_sub(1);
            let events = Events::new(4 * m64, 2 * m64 + 4 * m64, 0)
                + Events::new(4 * groups, 4 * groups, 0)
                + Events::new(0, 0, m64);
            let sum_bits = (m + clog2(s.max(1))) as u64;
            let cells = populate_cells(2 * m64, pairs)
                + lut_cells(4 * m64, pairs, 1.5)
                + lut_cells(4 * groups, sum_bits, 1.5)
                + read_cells(m64, k);
            OpCost { events, cells, result_bits: m }
        }
        ApKind::TwoDSeg => {
            // Eq. 11.
            let rounds = clog2((s / 2).max(1)) as u64;
            let mut events = Events::new(4 * m64, 2 * m64 + 4 * m64, 0);
            let mut cells = populate_cells(2 * m64, pairs) + lut_cells(4 * m64, pairs, 1.5);
            let sum_bits = (m + clog2(s.max(1))) as u64;
            let mut active = pairs / 2;
            for _ in 0..rounds {
                events = events + Events::new(4, 4, 0);
                cells = cells + lut_cells(4, (active * sum_bits).max(1), 1.5);
                active = (active / 2).max(1);
            }
            events = events + Events::new(0, 0, m64);
            cells = cells + read_cells(m64, k);
            OpCost { events, cells, result_bits: m }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I row "Addition": 2M + 8M + M + 1.
    #[test]
    fn add_matches_table_i() {
        for m in [2u32, 4, 8, 16] {
            for kind in ApKind::ALL {
                let rt = add(m, 128, kind).events.time_units();
                assert_eq!(rt, (2 * m + 8 * m + m + 1) as u64, "M={m} {kind:?}");
            }
        }
    }

    /// Table I row "Multiplication": 2M + 8M² + 2M.
    #[test]
    fn multiply_matches_table_i() {
        for m in [2u32, 4, 8, 16] {
            let rt = multiply(m, m, 128, ApKind::TwoD).events.time_units();
            assert_eq!(rt, (2 * m + 8 * m * m + 2 * m) as u64, "M={m}");
        }
    }

    /// Mixed-width multiply: 4·Ma·Mw passes, result Ma+Mw bits.
    #[test]
    fn multiply_mixed_width() {
        let c = multiply(4, 8, 128, ApKind::TwoD);
        assert_eq!(c.result_bits, 12);
        assert_eq!(c.events.compares, 4 * 4 * 8);
    }

    /// Table I row "Reduction", 1D: 2M + Σ_{q=1..log2 L} 8(M+q-1) + L - 1.
    /// (The closing `+ L - 1` in Table I is the (L/2-1) transfers at 1 read
    /// + 1 write each, plus the final word-sequential read.)
    #[test]
    fn reduce_1d_matches_table_i() {
        for (m, l) in [(4u32, 16u64), (8, 64), (8, 1024)] {
            let rt = reduce(m, l, ApKind::OneD).events.time_units();
            let sum: u64 = (1..=clog2(l) as u64).map(|q| 8 * (m as u64 + q - 1)).sum();
            let expect = 2 * m as u64 + sum + 2 * (l / 2 - 1) + 1;
            assert_eq!(rt, expect, "M={m} L={l}");
        }
    }

    /// Table I row "Reduction", 2D: 2M + 8M + 8(L/2-1) + 1.
    #[test]
    fn reduce_2d_matches_table_i() {
        for (m, l) in [(4u32, 16u64), (8, 64), (8, 1024)] {
            let rt = reduce(m, l, ApKind::TwoD).events.time_units();
            let expect = 2 * m as u64 + 8 * m as u64 + 8 * (l / 2 - 1) + 1;
            assert_eq!(rt, expect, "M={m} L={l}");
        }
    }

    /// Table I row "Reduction", 2D seg: 2M + 8M + 8·log2(L/2) + 1.
    #[test]
    fn reduce_2dseg_matches_table_i() {
        for (m, l) in [(4u32, 16u64), (8, 64), (8, 1024)] {
            let rt = reduce(m, l, ApKind::TwoDSeg).events.time_units();
            let expect = 2 * m as u64 + 8 * m as u64 + 8 * clog2(l / 2) as u64 + 1;
            assert_eq!(rt, expect, "M={m} L={l}");
        }
    }

    /// Result width of reduction grows by log2(L) bits.
    #[test]
    fn reduce_result_bits() {
        assert_eq!(reduce(8, 16, ApKind::TwoD).result_bits, 12);
    }

    /// Table I row "Matrix-Matrix Multiplication", all three kinds.
    #[test]
    fn matmat_matches_table_i() {
        for (m, i, j, u) in [(4u32, 4u64, 8u64, 4u64), (8, 2, 16, 2), (8, 16, 64, 16)] {
            let m64 = m as u64;
            // 1D (Eq. 6).
            let rt = matmat(m, m, i, j, u, ApKind::OneD).events.time_units();
            let sum: u64 = (1..=clog2(j) as u64).map(|q| 8 * (2 * m64 + q - 1)).sum();
            let expect =
                2 * m64 + 8 * m64 * m64 + sum + 2 * i * u * (j - 1) + 2 * m64 + clog2(j) as u64;
            assert_eq!(rt, expect, "1D M={m} {i}x{j}x{u}");
            // 2D (Eq. 7).
            let rt = matmat(m, m, i, j, u, ApKind::TwoD).events.time_units();
            let expect = 2 * m64 + 8 * m64 * m64 + 8 * i * u * (j - 1) + 2 * m64 + clog2(j) as u64;
            assert_eq!(rt, expect, "2D M={m} {i}x{j}x{u}");
            // 2D seg (Eq. 8).
            let rt = matmat(m, m, i, j, u, ApKind::TwoDSeg).events.time_units();
            let expect =
                2 * m64 + 8 * m64 * m64 + 8 * clog2(j) as u64 + 2 * m64 + clog2(j) as u64;
            assert_eq!(rt, expect, "2Dseg M={m} {i}x{j}x{u}");
        }
    }

    /// Dot product is matmat with i = u = 1.
    #[test]
    fn dot_is_special_case() {
        assert_eq!(
            dot(8, 8, 64, ApKind::TwoD).events,
            matmat(8, 8, 1, 64, 1, ApKind::TwoD).events
        );
    }

    /// Table I row "ReLU": 4M + 1 (identical across kinds).
    #[test]
    fn relu_matches_table_i() {
        for m in [2u32, 4, 8, 16] {
            for kind in ApKind::ALL {
                let rt = relu(m, 256, kind).events.time_units();
                assert_eq!(rt, (4 * m + 1) as u64, "M={m} {kind:?}");
            }
        }
    }

    /// Table I row "Max Pooling", all three kinds.
    #[test]
    fn maxpool_matches_table_i() {
        for (m, s, k) in [(4u32, 4u64, 4u64), (8, 4, 16), (8, 16, 8)] {
            let m64 = m as u64;
            let rt = maxpool(m, s, k, ApKind::OneD).events.time_units();
            let expect = 2 * m64 + (8 * m64 + 2) * clog2(s) as u64 + 2 * k * (s / 2 - 1) + m64;
            assert_eq!(rt, expect, "1D M={m} S={s} K={k}");
            let rt = maxpool(m, s, k, ApKind::TwoD).events.time_units();
            let expect = 2 * m64 + (8 * m64 + 2) + 10 * k * (s / 2 - 1) + m64;
            assert_eq!(rt, expect, "2D M={m} S={s} K={k}");
            let rt = maxpool(m, s, k, ApKind::TwoDSeg).events.time_units();
            let expect = 2 * m64 + (8 * m64 + 2) + (8 + 2 * k) * clog2(s / 2) as u64 + m64;
            assert_eq!(rt, expect, "2Dseg M={m} S={s} K={k}");
        }
    }

    /// Table I row "Average Pooling", all three kinds.
    #[test]
    fn avgpool_matches_table_i() {
        for (m, s, k) in [(4u32, 4u64, 4u64), (8, 4, 16), (8, 16, 8)] {
            let m64 = m as u64;
            let rt = avgpool(m, s, k, ApKind::OneD).events.time_units();
            let sum: u64 = (1..=clog2(s) as u64).map(|q| 8 * (m64 + q - 1)).sum();
            let expect = 2 * m64 + 2 * k * (s / 2 - 1) + sum + m64;
            assert_eq!(rt, expect, "1D M={m} S={s} K={k}");
            let rt = avgpool(m, s, k, ApKind::TwoD).events.time_units();
            let expect = 2 * m64 + 8 * m64 + 8 * k * (s / 2 - 1) + m64;
            assert_eq!(rt, expect, "2D M={m} S={s} K={k}");
            let rt = avgpool(m, s, k, ApKind::TwoDSeg).events.time_units();
            let expect = 2 * m64 + 8 * m64 + 8 * clog2(s / 2) as u64 + m64;
            assert_eq!(rt, expect, "2Dseg M={m} S={s} K={k}");
        }
    }

    /// Fig. 5 sanity: segmentation is always fastest; per Table I's own
    /// formulas the *unsegmented* 2D AP pays 8 units per row pair versus the
    /// 1D AP's 2-unit word transfers, so at large L the 1D AP's runtime is
    /// actually lower (the 2D AP's advantage is the segmented mode — and,
    /// architecturally, not needing inter-row transfer bandwidth).
    #[test]
    fn kind_ordering_for_reduction_heavy_ops() {
        let l = 4096;
        let r1 = reduce(8, l, ApKind::OneD).events.time_units();
        let r2 = reduce(8, l, ApKind::TwoD).events.time_units();
        let r3 = reduce(8, l, ApKind::TwoDSeg).events.time_units();
        assert!(r3 < r1 && r3 < r2, "seg {r3} must beat 1D {r1} and 2D {r2}");
        let m1 = matmat(8, 8, 8, 64, 8, ApKind::OneD).events.time_units();
        let m2 = matmat(8, 8, 8, 64, 8, ApKind::TwoD).events.time_units();
        let m3 = matmat(8, 8, 8, 64, 8, ApKind::TwoDSeg).events.time_units();
        assert!(m3 < m1 && m3 < m2, "seg {m3} must beat 1D {m1} and 2D {m2}");
        // Small-L regime: 2D beats 1D once the log-growth addition rounds
        // dominate the transfer count.
        let s1 = reduce(16, 8, ApKind::OneD).events.time_units();
        let s2 = reduce(16, 8, ApKind::TwoD).events.time_units();
        assert!(s2 < s1, "2D {s2} must beat 1D {s1} at small L");
    }

    /// Cell-activity totals are positive and populate scales with words.
    #[test]
    fn cell_activity_scales_with_words() {
        let small = matmat(8, 8, 2, 8, 2, ApKind::TwoD).cells;
        let large = matmat(8, 8, 4, 8, 4, ApKind::TwoD).cells;
        assert!(large.populate_write_cells > small.populate_write_cells);
        assert!(large.compare_senses > small.compare_senses);
    }

    /// Energy ordering: ReRAM must cost more than SRAM for any op.
    #[test]
    fn reram_energy_exceeds_sram() {
        use crate::ap::tech::Tech;
        let c = matmat(8, 8, 4, 16, 4, ApKind::TwoD).cells;
        assert!(Tech::reram().energy(&c) > Tech::sram().energy(&c));
    }
}
