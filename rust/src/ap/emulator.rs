//! Functional bit-serial CAM emulator.
//!
//! This is the reproduction of the paper's §IV microbenchmark: "We used
//! Python to emulate the AP functionally executing the micro/macro/CNN
//! functions. A microbenchmark, consisting of random vectors/matrices, was
//! used to validate the proposed mathematical models."
//!
//! The emulator holds an actual bit matrix and executes the LUT pass
//! sequences of [`super::luts`] compare/write phase by phase — horizontal
//! operations are **bit-exact** (every compare searches every occupied row,
//! every write updates exactly the matched rows) while counting each
//! compare / write / read event. Vertical (row-pair) operations compute the
//! row arithmetic directly and charge the event counts the paper's model
//! charges (4 compares + 4 writes per row-pair addition), because the
//! paper does not specify a pass-level vertical LUT (its cited 2D-AP design
//! handles inter-column carry movement in the write drivers).
//!
//! Exact event-count formulas of this emulator (validated in tests, and
//! printed next to Table I's models by `benches/table1_runtime_validation`):
//!
//! | op           | emulator compares   | Table I model | difference       |
//! |--------------|---------------------|---------------|------------------|
//! | add          | `4M`                | `4M`          | exact            |
//! | multiply     | `Mw(4Ma + 1)`       | `4·Ma·Mw`     | `+Mw` carry flush|
//! | ReLU         | `M - 1`             | `M - 1`       | exact            |
//! | max (1 step) | `4M`                | `4M`          | exact            |
//! | reduce 2D    | `4M + 4(L/2 - 1)`   | same          | exact            |

use super::luts::{self, Pass};
use super::Events;

/// Event counters accumulated by an emulator run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Counters {
    /// Compare (search) phases executed.
    pub compares: u64,
    /// Write phases executed.
    pub writes: u64,
    /// Read phases executed.
    pub reads: u64,
}

impl Counters {
    /// Convert to the shared [`Events`] type for model comparison.
    pub fn events(&self) -> Events {
        Events::new(self.compares, self.writes, self.reads)
    }
}

/// A content-addressable memory holding `rows x cols` bits plus per-run
/// event counters. Row 0..`rows` are the occupied words.
///
/// Storage is **column-major bitmaps** (one `u64` packs 64 rows of one bit
/// column), so a LUT pass — the emulator's hot loop — is a handful of
/// word-parallel AND/OR operations per column instead of a per-row boolean
/// scan. This mirrors the hardware (a compare drives every row's sense amp
/// simultaneously) and made the 8b x 8b multiply over 1024 words ~40x
/// faster (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct Cam {
    rows: usize,
    cols: usize,
    /// Words (u64 groups of rows) per column.
    words: usize,
    /// Bitmap mask of the occupied rows in the last word.
    tail_mask: u64,
    /// `cols x words` column bitmaps.
    data: Vec<u64>,
    /// Match tags of the last compare (bitmap over rows).
    tags: Vec<u64>,
    /// Event counters accumulated since creation.
    pub counters: Counters,
}

impl Cam {
    /// Create an all-zero CAM.
    pub fn new(rows: usize, cols: usize) -> Self {
        let words = rows.div_ceil(64).max(1);
        let rem = rows % 64;
        let tail_mask = if rem == 0 { u64::MAX } else { (1u64 << rem) - 1 };
        Self {
            rows,
            cols,
            words,
            tail_mask,
            data: vec![0; cols * words],
            tags: vec![0; words],
            counters: Counters::default(),
        }
    }

    /// Number of word rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of bit columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    fn cw(&self, c: usize, r: usize) -> (usize, u64) {
        debug_assert!(r < self.rows && c < self.cols, "({r},{c}) out of {}x{}", self.rows, self.cols);
        (c * self.words + r / 64, 1u64 << (r % 64))
    }

    /// Read one bit (no event charged — testing/debug accessor).
    pub fn get(&self, r: usize, c: usize) -> bool {
        let (i, m) = self.cw(c, r);
        self.data[i] & m != 0
    }

    /// Set one bit (no event charged — testing/debug accessor).
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        let (i, m) = self.cw(c, r);
        if v {
            self.data[i] |= m;
        } else {
            self.data[i] &= !m;
        }
    }

    /// Bit-sequential column write from packed bitmap words (bit `r % 64`
    /// of word `r / 64` is row `r`): one write event, drives the first
    /// `rows` rows and leaves the rest untouched. Operating on `u64` words
    /// keeps the driver loops allocation-free where the old `&[bool]` API
    /// materialized one `Vec<bool>` per column.
    pub fn write_column(&mut self, col: usize, bits: &[u64], rows: usize) {
        assert!(rows <= self.rows);
        assert!(bits.len() >= rows.div_ceil(64));
        let base = col * self.words;
        let full = rows / 64;
        self.data[base..base + full].copy_from_slice(&bits[..full]);
        let rem = rows % 64;
        if rem > 0 {
            let mask = (1u64 << rem) - 1;
            let word = &mut self.data[base + full];
            *word = (*word & !mask) | (bits[full] & mask);
        }
        self.counters.writes += 1;
    }

    /// Bit-sequential column read: one read event. Returns the column as
    /// packed bitmap words (see [`column_bit`] to test individual rows).
    pub fn read_column(&mut self, col: usize) -> Vec<u64> {
        self.counters.reads += 1;
        let base = col * self.words;
        let mut out = self.data[base..base + self.words].to_vec();
        if let Some(last) = out.last_mut() {
            *last &= self.tail_mask;
        }
        out
    }

    /// Copy one column into another word-by-word: one read + one write
    /// event (the hardware's column move through the sense amplifiers).
    pub fn copy_column(&mut self, src: usize, dst: usize) {
        let words = self.words;
        for w in 0..words {
            self.data[dst * words + w] = self.data[src * words + w];
        }
        self.counters.reads += 1;
        self.counters.writes += 1;
    }

    /// Zero a column: one write event (no row buffer materialized).
    pub fn clear_column(&mut self, col: usize) {
        let base = col * self.words;
        self.data[base..base + self.words].fill(0);
        self.counters.writes += 1;
    }

    /// Word-sequential read of `bits` columns of one row: one read event.
    pub fn read_word(&mut self, row: usize, offset: usize, bits: usize) -> u64 {
        self.counters.reads += 1;
        self.word_at(row, offset, bits)
    }

    /// Raw (uncharged) word extraction, LSB at `offset`.
    pub fn word_at(&self, row: usize, offset: usize, bits: usize) -> u64 {
        let mut v = 0u64;
        for b in 0..bits {
            if self.get(row, offset + b) {
                v |= 1 << b;
            }
        }
        v
    }

    /// Raw (uncharged) word store, LSB at `offset`.
    pub fn store_word(&mut self, row: usize, offset: usize, bits: usize, value: u64) {
        for b in 0..bits {
            self.set(row, offset + b, value >> b & 1 == 1);
        }
    }

    /// Word-sequential write of one row: one write event.
    pub fn write_word(&mut self, row: usize, offset: usize, bits: usize, value: u64) {
        self.store_word(row, offset, bits, value);
        self.counters.writes += 1;
    }

    /// One horizontal LUT pass: compare the key pattern (bound through
    /// `slot_cols`) across all rows, then write the pass's updates into the
    /// matched rows. Charges 1 compare + 1 write (the write phase is part of
    /// the fixed schedule whether or not any row matched — matching the
    /// paper's runtime accounting). Word-parallel: each key term is one
    /// AND (or AND-NOT) over the column bitmap; each write term one OR /
    /// AND-NOT under the tag mask.
    pub fn apply_pass(&mut self, pass: &Pass, slot_cols: &[usize]) {
        let words = self.words;
        // Compare phase: tags = AND over key columns (complemented for 0s).
        self.tags[..words].fill(u64::MAX);
        self.tags[words - 1] = self.tail_mask;
        for &(slot, bit) in pass.key {
            let base = slot_cols[slot] * words;
            if bit {
                for w in 0..words {
                    self.tags[w] &= self.data[base + w];
                }
            } else {
                for w in 0..words {
                    self.tags[w] &= !self.data[base + w];
                }
            }
        }
        self.counters.compares += 1;
        // Write phase: masked set/clear on the target columns.
        for &(slot, bit) in pass.write {
            let base = slot_cols[slot] * words;
            if bit {
                for w in 0..words {
                    self.data[base + w] |= self.tags[w];
                }
            } else {
                for w in 0..words {
                    self.data[base + w] &= !self.tags[w];
                }
            }
        }
        self.counters.writes += 1;
    }

    /// Apply a whole pass group with the same slot binding.
    pub fn apply_passes(&mut self, passes: &[Pass], slot_cols: &[usize]) {
        for p in passes {
            self.apply_pass(p, slot_cols);
        }
    }

    // ------------------------------------------------------------------
    // Population helpers
    // ------------------------------------------------------------------

    /// Populate a field of `bits` columns at `offset` from unsigned values,
    /// one per row, bit-sequentially (`bits` write events). One reusable
    /// word buffer serves every column — no per-column allocation.
    pub fn populate_field(&mut self, offset: usize, bits: usize, values: &[u64]) {
        assert!(values.len() <= self.rows);
        let n = values.len();
        let mut col = vec![0u64; n.div_ceil(64)];
        for b in 0..bits {
            col.fill(0);
            for (r, v) in values.iter().enumerate() {
                col[r / 64] |= (v >> b & 1) << (r % 64);
            }
            self.write_column(offset + b, &col, n);
        }
    }

    // ------------------------------------------------------------------
    // Horizontal (bit-exact) operations
    // ------------------------------------------------------------------

    /// In-place addition `B += A` over all rows. `A` occupies `m` columns at
    /// `a_off`; `B` occupies `m + 1` columns at `b_off` whose MSB column
    /// (`b_off + m`) doubles as the carry column and must start zeroed.
    /// Charges exactly `4m` compares + `4m` writes.
    pub fn add_inplace(&mut self, a_off: usize, b_off: usize, m: usize) {
        let carry = b_off + m;
        for i in 0..m {
            self.apply_passes(luts::ADD_LUT, &[carry, a_off + i, b_off + i]);
        }
    }

    /// Out-of-place multiplication `C = A * B` over all rows. `A`: `ma` bits
    /// at `a_off`; `B`: `mb` bits at `b_off`; `C`: `ma + mb` zeroed columns
    /// at `c_off`; `carry_col` is a zeroed scratch column. Charges exactly
    /// `mb * (4*ma + 1)` compares/writes (`4·Ma·Mw` model + `Mw` carry
    /// flushes).
    pub fn multiply(&mut self, a_off: usize, ma: usize, b_off: usize, mb: usize, c_off: usize, carry_col: usize) {
        for j in 0..mb {
            let gate = b_off + j;
            for i in 0..ma {
                self.apply_passes(luts::MUL_GATED_ADD_LUT, &[gate, carry_col, a_off + i, c_off + i + j]);
            }
            // Deposit the remaining carry into C[ma + j] (guaranteed 0).
            self.apply_passes(luts::MUL_CARRY_FLUSH, &[gate, carry_col, c_off + ma + j]);
        }
    }

    /// ReLU over all rows of a signed two's-complement field of `m` bits at
    /// `offset`, using `flag_col` as the sign-flag column. Implements the
    /// Eq. (15) schedule: read MSB column (1 read), write it to the flag
    /// column and reset the MSB (2 writes), then one Table III pass per
    /// remaining bit (`m - 1` compares + `m - 1` writes).
    pub fn relu(&mut self, offset: usize, m: usize, flag_col: usize) {
        // Move the sign column into the flag column (1 read + 1 write),
        // then reset it (1 write) — the same event counts as the old
        // read/write/write sequence, without the `vec![false; rows]`.
        self.copy_column(offset + m - 1, flag_col);
        self.clear_column(offset + m - 1);
        for i in (0..m - 1).rev() {
            self.apply_passes(luts::RELU_LUT, &[offset + i, flag_col]);
        }
    }

    /// One in-place max step `B = max(A, B)` (unsigned) over all rows,
    /// MSB -> LSB per Table IV. `f1_col`/`f2_col` are zeroed flag columns.
    /// Charges `4m` compares + `4m` writes, plus 2 writes to reset flags.
    pub fn max_inplace(&mut self, a_off: usize, b_off: usize, m: usize, f1_col: usize, f2_col: usize) {
        for i in (0..m).rev() {
            self.apply_passes(luts::MAX_LUT, &[a_off + i, b_off + i, f1_col, f2_col]);
        }
        self.clear_column(f1_col);
        self.clear_column(f2_col);
    }

    // ------------------------------------------------------------------
    // Vertical (event-faithful) operations
    // ------------------------------------------------------------------

    /// Vertical in-place addition between two rows: `row_b[field] +=
    /// row_a[field]` where the field is `bits` wide at `offset` (result must
    /// fit — callers allocate the grown width). Charges the model's 4
    /// compares + 4 writes.
    pub fn add_rows(&mut self, row_a: usize, row_b: usize, offset: usize, bits: usize) {
        let a = self.word_at(row_a, offset, bits);
        let b = self.word_at(row_b, offset, bits);
        self.store_word(row_b, offset, bits, a.wrapping_add(b));
        self.counters.compares += 4;
        self.counters.writes += 4;
    }

    /// Vertical in-place max between two rows (`row_b = max(row_a, row_b)`),
    /// charging Table IV's 4 compares + 4 writes + 2 flag-reset writes.
    pub fn max_rows(&mut self, row_a: usize, row_b: usize, offset: usize, bits: usize) {
        let a = self.word_at(row_a, offset, bits);
        let b = self.word_at(row_b, offset, bits);
        self.store_word(row_b, offset, bits, a.max(b));
        self.counters.compares += 4;
        self.counters.writes += 4 + 2;
    }
}

/// Test one row's bit in a packed column bitmap (as produced by
/// [`Cam::read_column`]).
#[inline]
pub fn column_bit(bits: &[u64], row: usize) -> bool {
    bits[row / 64] >> (row % 64) & 1 == 1
}

// ----------------------------------------------------------------------
// High-level drivers mirroring the Table I operations end to end.
// ----------------------------------------------------------------------

/// Scatter a read-out column into per-row output words at bit position
/// `bit` (the bit-sequential readout loop every driver shares).
fn scatter_column(col: &[u64], bit: usize, out: &mut [u64]) {
    for (r, o) in out.iter_mut().enumerate() {
        if column_bit(col, r) {
            *o |= 1 << bit;
        }
    }
}

/// Emulate Eq. (1): element-wise `b[k] += a[k]` over vectors of `m`-bit
/// unsigned values. Returns the sums and the exact event counters.
pub fn emulate_add(a: &[u64], b: &[u64], m: usize) -> (Vec<u64>, Counters) {
    assert_eq!(a.len(), b.len());
    // Layout: A [0, m), B [m, 2m + 1) with carry/MSB at column 2m.
    let mut cam = Cam::new(a.len(), 2 * m + 1);
    cam.populate_field(0, m, a);
    cam.populate_field(m, m, b);
    cam.add_inplace(0, m, m);
    let mut out = vec![0u64; a.len()];
    for bit in 0..=m {
        let col = cam.read_column(m + bit);
        scatter_column(&col, bit, &mut out);
    }
    (out, cam.counters)
}

/// Emulate Eq. (2): element-wise `c[k] = a[k] * b[k]` over `ma`/`mb`-bit
/// unsigned vectors. Returns products and counters.
pub fn emulate_multiply(a: &[u64], b: &[u64], ma: usize, mb: usize) -> (Vec<u64>, Counters) {
    assert_eq!(a.len(), b.len());
    // Layout: A [0, ma), B [ma, ma+mb), C [ma+mb, 2(ma+mb)), carry at end.
    let c_off = ma + mb;
    let mut cam = Cam::new(a.len(), 2 * (ma + mb) + 1);
    cam.populate_field(0, ma, a);
    cam.populate_field(ma, mb, b);
    cam.multiply(0, ma, ma, mb, c_off, 2 * (ma + mb));
    let mut out = vec![0u64; a.len()];
    for bit in 0..ma + mb {
        let col = cam.read_column(c_off + bit);
        scatter_column(&col, bit, &mut out);
    }
    (out, cam.counters)
}

/// Emulate Eq. (15): ReLU over a vector of signed `m`-bit values (two's
/// complement). Returns max(v, 0) per element and counters.
pub fn emulate_relu(v: &[i64], m: usize) -> (Vec<i64>, Counters) {
    let mask = (1u64 << m) - 1;
    let enc: Vec<u64> = v.iter().map(|&x| (x as u64) & mask).collect();
    let mut cam = Cam::new(v.len(), m + 1);
    cam.populate_field(0, m, &enc);
    cam.relu(0, m, m);
    let mut out = vec![0i64; v.len()];
    for bit in 0..m {
        let col = cam.read_column(bit);
        for (r, o) in out.iter_mut().enumerate() {
            if column_bit(&col, r) {
                *o |= 1 << bit;
            }
        }
    }
    (out, cam.counters)
}

/// Emulate the horizontal step of Eq. (13): `b[k] = max(a[k], b[k])` over
/// unsigned `m`-bit vectors. Returns maxima and counters.
pub fn emulate_max(a: &[u64], b: &[u64], m: usize) -> (Vec<u64>, Counters) {
    assert_eq!(a.len(), b.len());
    // Layout: A [0, m), B [m, 2m), F1 = 2m, F2 = 2m + 1.
    let mut cam = Cam::new(a.len(), 2 * m + 2);
    cam.populate_field(0, m, a);
    cam.populate_field(m, m, b);
    cam.max_inplace(0, m, m, 2 * m, 2 * m + 1);
    let mut out = vec![0u64; a.len()];
    for bit in 0..m {
        let col = cam.read_column(m + bit);
        scatter_column(&col, bit, &mut out);
    }
    (out, cam.counters)
}

/// Emulate Eq. (4): 2D-AP reduction of `l` unsigned `m`-bit values (`l`
/// even, two per row). Returns the total and counters.
pub fn emulate_reduce_2d(values: &[u64], m: usize) -> (u64, Counters) {
    assert!(values.len() >= 2 && values.len() % 2 == 0);
    let l = values.len();
    let pairs = l / 2;
    let out_bits = m + super::clog2(l as u64) as usize;
    // Layout: A [0, m), B [m, m + out_bits) — B's top columns take the
    // horizontal carry and the vertical growth.
    let mut cam = Cam::new(pairs, m + out_bits);
    let a: Vec<u64> = values.iter().step_by(2).copied().collect();
    let b: Vec<u64> = values.iter().skip(1).step_by(2).copied().collect();
    cam.populate_field(0, m, &a);
    cam.populate_field(m, m, &b);
    // Horizontal in-place add: B += A (4m compares + 4m writes).
    cam.add_inplace(0, m, m);
    // Vertical: fold rows 1..pairs into row 0 sequentially (pairs-1 adds).
    for r in 1..pairs {
        cam.add_rows(r, 0, m, out_bits);
    }
    let total = cam.read_word(0, m, out_bits);
    (total, cam.counters)
}

/// Emulate Eq. (7): 2D-AP matrix-matrix multiplication `A(i x j) * B(j x u)`
/// of unsigned `m`-bit elements. Returns the `i x u` output (row-major) and
/// counters. One CAM row per (ii, jj, uu) product triple, as in §III-B.
pub fn emulate_matmat_2d(
    a: &[Vec<u64>],
    b: &[Vec<u64>],
    m: usize,
) -> (Vec<Vec<u64>>, Counters) {
    let i = a.len();
    let j = b.len();
    let u = b[0].len();
    assert!(a.iter().all(|row| row.len() == j));
    let words = i * j * u;
    let prod_bits = 2 * m;
    let out_bits = prod_bits + super::clog2(j as u64) as usize;
    // Layout: A [0,m), B [m,2m), C [2m, 2m+out_bits), carry at end.
    let c_off = 2 * m;
    let mut cam = Cam::new(words, c_off + out_bits + 1);
    let mut av = vec![0u64; words];
    let mut bv = vec![0u64; words];
    for ii in 0..i {
        for uu in 0..u {
            for jj in 0..j {
                let r = (ii * u + uu) * j + jj;
                av[r] = a[ii][jj];
                bv[r] = b[jj][uu];
            }
        }
    }
    cam.populate_field(0, m, &av);
    cam.populate_field(m, m, &bv);
    cam.multiply(0, m, m, m, c_off, c_off + out_bits);
    // Vertical reduction within each group of j consecutive rows.
    for g in 0..i * u {
        let base = g * j;
        for jj in 1..j {
            cam.add_rows(base + jj, base, c_off, out_bits);
        }
    }
    // Bit-sequential result read-out: out_bits column reads.
    for bit in 0..out_bits {
        let _ = cam.read_column(c_off + bit);
    }
    let mut out = vec![vec![0u64; u]; i];
    for ii in 0..i {
        for uu in 0..u {
            out[ii][uu] = cam.word_at((ii * u + uu) * j, c_off, out_bits);
        }
    }
    (out, cam.counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ap::runtime_model as rt;
    use crate::ap::ApKind;
    use crate::util::proptest::check;

    #[test]
    fn add_is_bit_exact_and_matches_model() {
        check("emulated add == scalar add", 64, |rng| {
            let m = rng.range(2, 10);
            let n = rng.range(1, 40);
            let a = rng.vec_below(n, 1 << m);
            let b = rng.vec_below(n, 1 << m);
            let (out, counters) = emulate_add(&a, &b, m);
            for k in 0..n {
                if out[k] != a[k] + b[k] {
                    return Err(format!("{} + {} gave {}", a[k], b[k], out[k]));
                }
            }
            let model = rt::add(m as u32, 2 * n as u64, ApKind::TwoD).events;
            if counters.events() != model {
                return Err(format!("events {counters:?} != model {model:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn multiply_is_bit_exact() {
        check("emulated mul == scalar mul", 48, |rng| {
            let ma = rng.range(2, 8);
            let mb = rng.range(2, 8);
            let n = rng.range(1, 24);
            let a = rng.vec_below(n, 1 << ma);
            let b = rng.vec_below(n, 1 << mb);
            let (out, counters) = emulate_multiply(&a, &b, ma, mb);
            for k in 0..n {
                if out[k] != a[k] * b[k] {
                    return Err(format!("{} * {} gave {}", a[k], b[k], out[k]));
                }
            }
            // Emulator = model + Mw carry-flush passes (see module docs).
            let model = rt::multiply(ma as u32, mb as u32, 2 * n as u64, ApKind::TwoD).events;
            let (ec, mc) = (counters.compares, model.compares);
            if ec != mc + mb as u64 {
                return Err(format!("compares {ec} != model {mc} + {mb}"));
            }
            Ok(())
        });
    }

    #[test]
    fn relu_is_bit_exact_and_matches_model() {
        check("emulated relu == max(x,0)", 64, |rng| {
            let m = rng.range(3, 12);
            let n = rng.range(1, 40);
            let half = 1i64 << (m - 1);
            let v: Vec<i64> = (0..n).map(|_| rng.range_i64(-half, half - 1)).collect();
            let (out, counters) = emulate_relu(&v, m);
            for k in 0..n {
                if out[k] != v[k].max(0) {
                    return Err(format!("relu({}) gave {}", v[k], out[k]));
                }
            }
            let model = rt::relu(m as u32, n as u64, ApKind::TwoD).events;
            // Model charges M populate writes; emulator populated M columns.
            if counters.events() != model {
                return Err(format!("events {counters:?} != model {model:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn max_step_is_bit_exact() {
        check("emulated max == scalar max", 64, |rng| {
            let m = rng.range(2, 12);
            let n = rng.range(1, 40);
            let a = rng.vec_below(n, 1 << m);
            let b = rng.vec_below(n, 1 << m);
            let (out, counters) = emulate_max(&a, &b, m);
            for k in 0..n {
                if out[k] != a[k].max(b[k]) {
                    return Err(format!("max({}, {}) gave {}", a[k], b[k], out[k]));
                }
            }
            // 2m populate + 4m passes + 2 flag resets; m reads.
            let expect = Counters {
                compares: 4 * m as u64,
                writes: 2 * m as u64 + 4 * m as u64 + 2,
                reads: m as u64,
            };
            if counters != expect {
                return Err(format!("counters {counters:?} != {expect:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn reduce_2d_is_exact_and_matches_model() {
        check("emulated reduce == scalar sum", 48, |rng| {
            let m = rng.range(2, 10);
            let pairs = rng.range(1, 64);
            let values = rng.vec_below(2 * pairs, 1 << m);
            let (total, counters) = emulate_reduce_2d(&values, m);
            let expect: u64 = values.iter().sum();
            if total != expect {
                return Err(format!("sum gave {total}, want {expect}"));
            }
            let model = rt::reduce(m as u32, 2 * pairs as u64, ApKind::TwoD).events;
            if counters.events() != model {
                return Err(format!("events {counters:?} != model {model:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matmat_2d_is_exact() {
        check("emulated matmat == scalar matmul", 24, |rng| {
            let m = rng.range(2, 6);
            let (i, j, u) = (rng.range(1, 5), rng.range(2, 7), rng.range(1, 5));
            let a: Vec<Vec<u64>> = (0..i).map(|_| rng.vec_below(j, 1 << m)).collect();
            let b: Vec<Vec<u64>> = (0..j).map(|_| rng.vec_below(u, 1 << m)).collect();
            let (out, counters) = emulate_matmat_2d(&a, &b, m);
            for ii in 0..i {
                for uu in 0..u {
                    let expect: u64 = (0..j).map(|jj| a[ii][jj] * b[jj][uu]).sum();
                    if out[ii][uu] != expect {
                        return Err(format!("O[{ii}][{uu}] = {} want {expect}", out[ii][uu]));
                    }
                }
            }
            // Emulator compares = model + m carry flushes (multiply part).
            let model = rt::matmat(m as u32, m as u32, i as u64, j as u64, u as u64, ApKind::TwoD).events;
            if counters.compares != model.compares + m as u64 {
                return Err(format!("compares {} != model {} + {m}", counters.compares, model.compares));
            }
            Ok(())
        });
    }

    #[test]
    fn column_roundtrip() {
        let mut cam = Cam::new(4, 3);
        cam.write_column(1, &[0b0101], 4);
        let col = cam.read_column(1);
        assert_eq!(col, vec![0b0101]);
        assert!(column_bit(&col, 0) && !column_bit(&col, 1));
        assert!(column_bit(&col, 2) && !column_bit(&col, 3));
        assert_eq!(cam.counters.writes, 1);
        assert_eq!(cam.counters.reads, 1);
    }

    #[test]
    fn partial_column_write_preserves_tail_rows() {
        let mut cam = Cam::new(130, 2); // 3 words per column
        cam.set(100, 0, true);
        cam.set(129, 0, true);
        cam.write_column(0, &[u64::MAX, u64::MAX], 70);
        for r in 0..70 {
            assert!(cam.get(r, 0), "row {r} not written");
        }
        assert!(!cam.get(70, 0) && !cam.get(99, 0));
        assert!(cam.get(100, 0) && cam.get(129, 0), "tail rows clobbered");
    }

    #[test]
    fn copy_and_clear_columns_charge_events() {
        let mut cam = Cam::new(70, 2);
        cam.write_column(0, &[0xDEAD_BEEF, 0x2A], 70);
        cam.copy_column(0, 1);
        assert_eq!(cam.read_column(1), vec![0xDEAD_BEEF, 0x2A]);
        cam.clear_column(1);
        assert_eq!(cam.read_column(1), vec![0, 0]);
        // Writes: populate + copy + clear; reads: copy + 2 read_columns.
        assert_eq!(cam.counters.writes, 3);
        assert_eq!(cam.counters.reads, 3);
    }

    #[test]
    fn word_roundtrip() {
        let mut cam = Cam::new(2, 8);
        cam.write_word(1, 0, 8, 0xA5);
        assert_eq!(cam.read_word(1, 0, 8), 0xA5);
    }

    #[test]
    fn pass_only_touches_matched_rows() {
        let mut cam = Cam::new(3, 2);
        // rows: (1,0), (0,0), (1,1)
        cam.set(0, 0, true);
        cam.set(2, 0, true);
        cam.set(2, 1, true);
        // Match col0 == 1 && col1 == 0 -> set col1 = 1.
        let pass = Pass { name: "t", key: &[(0, true), (1, false)], write: &[(1, true)] };
        cam.apply_pass(&pass, &[0, 1]);
        assert!(cam.get(0, 1));
        assert!(!cam.get(1, 1));
        assert!(cam.get(2, 1)); // was already 1, untouched by key mismatch
        assert_eq!(cam.counters.compares, 1);
        assert_eq!(cam.counters.writes, 1);
    }
}
