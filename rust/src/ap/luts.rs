//! Compare/write pass tables (LUTs) for the AP operations.
//!
//! An AP executes an arithmetic/logical operation as an ordered sequence of
//! *passes*; each pass is one **compare** (search for a key pattern across
//! the selected columns/rows of all words) followed by one **write** (update
//! the selected bits of every matched word). The pass tables below are the
//! paper's LUTs: in-place addition and out-of-place multiplication follow
//! Yantir's AP formulation (paper refs. [50], [51]); ReLU is Table III and
//! max pooling is Table IV verbatim.
//!
//! Pass ordering matters: a pass must never produce a state that a *later*
//! pass in the same group would match again (that would double-apply the
//! LUT). The orderings below are hazard-free; `ap::emulator` tests verify
//! this bit-exactly against scalar arithmetic, and the unit tests here check
//! every LUT against its truth table.

/// One compare/write pass. `key` lists `(slot, bit)` requirements over the
/// operand slots bound by the caller; `write` lists the `(slot, bit)`
/// updates applied to every matched word.
#[derive(Debug, Clone, Copy)]
pub struct Pass {
    /// Pass label (for traces and tests).
    pub name: &'static str,
    /// `(slot, bit)` match requirements of the compare phase.
    pub key: &'static [(usize, bool)],
    /// `(slot, bit)` updates written to every matched word.
    pub write: &'static [(usize, bool)],
}

/// Slots for [`ADD_LUT`]: 0 = carry, 1 = A_i (augend bit, unchanged),
/// 2 = B_i (in-place sum bit).
pub const ADD_SLOT_CARRY: usize = 0;
/// Augend bit slot.
pub const ADD_SLOT_A: usize = 1;
/// In-place sum bit slot.
pub const ADD_SLOT_B: usize = 2;

/// In-place addition `B += A` full-adder LUT (4 passes per bit position).
///
/// Truth table of (carry, a, b) -> (carry', sum): only four input states
/// require a write; they are ordered so no pass re-matches a prior pass's
/// output (e.g. `(0,1,1)->(1,0)` must precede `(0,1,0)->(0,1)` because the
/// latter's output `(0,1,1)` is the former's key).
pub const ADD_LUT: &[Pass] = &[
    Pass { name: "add.p2", key: &[(0, false), (1, true), (2, true)], write: &[(0, true), (2, false)] },
    Pass { name: "add.p1", key: &[(0, false), (1, true), (2, false)], write: &[(2, true)] },
    Pass { name: "add.p3", key: &[(0, true), (1, false), (2, false)], write: &[(0, false), (2, true)] },
    Pass { name: "add.p4", key: &[(0, true), (1, false), (2, true)], write: &[(2, false)] },
];

/// Slots for [`MUL_GATED_ADD_LUT`]: 0 = gate (multiplier bit B_j, unchanged),
/// 1 = carry, 2 = A_i (multiplicand bit, unchanged), 3 = C_{i+j} (product
/// accumulator bit, in-place).
pub const MUL_SLOT_GATE: usize = 0;
/// Carry slot of the gated adder.
pub const MUL_SLOT_CARRY: usize = 1;
/// Multiplicand bit slot.
pub const MUL_SLOT_A: usize = 2;
/// Product accumulator bit slot.
pub const MUL_SLOT_C: usize = 3;

/// Gated in-place addition used by bit-serial multiplication: identical to
/// [`ADD_LUT`] but each key additionally requires the multiplier bit
/// (gate) to be 1, so only words whose current multiplier bit is set
/// accumulate the shifted multiplicand.
pub const MUL_GATED_ADD_LUT: &[Pass] = &[
    Pass {
        name: "mul.p2",
        key: &[(0, true), (1, false), (2, true), (3, true)],
        write: &[(1, true), (3, false)],
    },
    Pass { name: "mul.p1", key: &[(0, true), (1, false), (2, true), (3, false)], write: &[(3, true)] },
    Pass {
        name: "mul.p3",
        key: &[(0, true), (1, true), (2, false), (3, false)],
        write: &[(1, false), (3, true)],
    },
    Pass { name: "mul.p4", key: &[(0, true), (1, true), (2, false), (3, true)], write: &[(3, false)] },
];

/// Carry flush pass run once after the last multiplicand bit: deposits the
/// remaining carry into the next product column (which is guaranteed 0) and
/// clears the carry. Slots: 0 = gate, 1 = carry, 2 = target product bit.
pub const MUL_CARRY_FLUSH: &[Pass] =
    &[Pass { name: "mul.flush", key: &[(0, true), (1, true)], write: &[(1, false), (2, true)] }];

/// Slots for [`RELU_LUT`]: 0 = A_i (data bit, in-place), 1 = F (sign flag,
/// unchanged).
pub const RELU_SLOT_A: usize = 0;
/// Sign-flag slot.
pub const RELU_SLOT_F: usize = 1;

/// ReLU LUT (paper Table III): a single pass per bit position — words whose
/// sign flag is set (negative pre-activation) get the selected bit cleared.
/// Rows `10 -> NC(1)`, `01 -> NC(0)`, `00 -> NC(0)` of Table III need no
/// write; only `11 -> 0` does.
pub const RELU_LUT: &[Pass] =
    &[Pass { name: "relu.p1", key: &[(0, true), (1, true)], write: &[(0, false)] }];

/// Slots for [`MAX_LUT`]: 0 = A_i, 1 = B_i (in-place max), 2 = F1, 3 = F2.
/// Flag encoding (from Table IV): `(F1,F2) = (0,0)` undecided,
/// `(0,1)` A is larger, `(1,1)` B is larger; `(1,0)` unreachable ("NP").
pub const MAX_SLOT_A: usize = 0;
/// In-place max bit slot.
pub const MAX_SLOT_B: usize = 1;
/// First flag slot.
pub const MAX_SLOT_F1: usize = 2;
/// Second flag slot.
pub const MAX_SLOT_F2: usize = 3;

/// Max-pooling LUT (paper Table IV), processed MSB -> LSB. Four passes per
/// bit position; all other Table IV rows are no-change (NC) or unreachable
/// (NP):
///
/// * `1st` `(A,B,F1,F2) = (1,0,0,0)`: first differing bit, A larger — decide
///   for A (`F <- 01`) and copy A's 1 into B.
/// * `2nd` `(0,1,0,0)`: first differing bit, B larger — decide for B
///   (`F <- 11`), B keeps its bit.
/// * `3rd` `(1,0,0,1)`: already decided for A — copy A's 1 into B.
/// * `4th` `(0,1,0,1)`: already decided for A — copy A's 0 into B.
pub const MAX_LUT: &[Pass] = &[
    Pass {
        name: "max.1st",
        key: &[(0, true), (1, false), (2, false), (3, false)],
        write: &[(1, true), (3, true)],
    },
    Pass {
        name: "max.2nd",
        key: &[(0, false), (1, true), (2, false), (3, false)],
        write: &[(2, true), (3, true)],
    },
    Pass { name: "max.3rd", key: &[(0, true), (1, false), (2, false), (3, true)], write: &[(1, true)] },
    Pass { name: "max.4th", key: &[(0, false), (1, true), (2, false), (3, true)], write: &[(1, false)] },
];

/// Apply a pass sequence to a small state vector of slot bits (one word's
/// slice). Returns the new state and how many passes matched. This is the
/// scalar semantics used by the LUT truth-table tests; the emulator applies
/// the same passes word-parallel.
pub fn apply_passes(passes: &[Pass], state: &mut [bool]) -> usize {
    let mut matched = 0;
    for p in passes {
        if p.key.iter().all(|&(slot, bit)| state[slot] == bit) {
            for &(slot, bit) in p.write {
                state[slot] = bit;
            }
            matched += 1;
        }
    }
    matched
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exhaustive full-adder check of ADD_LUT over the 8 (carry, a, b)
    /// states: after the pass group, (carry, b) must hold (carry', sum).
    #[test]
    fn add_lut_is_a_full_adder() {
        for c in [false, true] {
            for a in [false, true] {
                for b in [false, true] {
                    let mut st = [c, a, b];
                    let matched = apply_passes(ADD_LUT, &mut st);
                    let total = c as u8 + a as u8 + b as u8;
                    assert_eq!(st[ADD_SLOT_B], total & 1 == 1, "sum for ({c},{a},{b})");
                    assert_eq!(st[ADD_SLOT_CARRY], total >= 2, "carry for ({c},{a},{b})");
                    assert_eq!(st[ADD_SLOT_A], a, "A must be unchanged");
                    assert!(matched <= 1, "at most one pass may fire per word");
                }
            }
        }
    }

    /// Gated adder: gate=0 must leave everything unchanged; gate=1 must be
    /// the full adder.
    #[test]
    fn mul_gated_add_lut_gates_correctly() {
        for g in [false, true] {
            for c in [false, true] {
                for a in [false, true] {
                    for b in [false, true] {
                        let mut st = [g, c, a, b];
                        apply_passes(MUL_GATED_ADD_LUT, &mut st);
                        if !g {
                            assert_eq!(st, [g, c, a, b], "gate=0 must be a no-op");
                        } else {
                            let total = c as u8 + a as u8 + b as u8;
                            assert_eq!(st[MUL_SLOT_C], total & 1 == 1);
                            assert_eq!(st[MUL_SLOT_CARRY], total >= 2);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mul_carry_flush_deposits_and_clears() {
        let mut st = [true, true, false];
        apply_passes(MUL_CARRY_FLUSH, &mut st);
        assert_eq!(st, [true, false, true]);
        let mut st = [true, false, false];
        apply_passes(MUL_CARRY_FLUSH, &mut st);
        assert_eq!(st, [true, false, false]);
        let mut st = [false, true, false]; // gate off: no flush
        apply_passes(MUL_CARRY_FLUSH, &mut st);
        assert_eq!(st, [false, true, false]);
    }

    /// Table III verbatim: A_i/F_i in {10, 01, 11, 00} -> resulting A_i in
    /// {1, 0, 0, 0}.
    #[test]
    fn relu_lut_matches_table_iii() {
        let cases = [
            ((true, false), true),
            ((false, true), false),
            ((true, true), false),
            ((false, false), false),
        ];
        for ((a, f), expect_a) in cases {
            let mut st = [a, f];
            apply_passes(RELU_LUT, &mut st);
            assert_eq!(st[RELU_SLOT_A], expect_a, "A for ({a},{f})");
            assert_eq!(st[RELU_SLOT_F], f, "flag unchanged");
        }
    }

    /// Table IV verbatim over all reachable states (F1F2 != 10).
    #[test]
    fn max_lut_matches_table_iv() {
        // (A, B, F1, F2) -> (B', F1', F2') from Table IV.
        let cases = [
            ((true, false, false, false), (true, false, true)),   // 1st
            ((false, true, false, false), (true, true, true)),    // 2nd
            ((true, true, false, false), (true, false, false)),   // NC
            ((false, false, false, false), (false, false, false)),// NC
            ((true, false, true, true), (false, true, true)),     // NC
            ((false, true, true, true), (true, true, true)),      // NC
            ((true, true, true, true), (true, true, true)),       // NC
            ((false, false, true, true), (false, true, true)),    // NC
            ((true, false, false, true), (true, false, true)),    // 3rd
            ((false, true, false, true), (false, false, true)),   // 4th
            ((true, true, false, true), (true, false, true)),     // NC
            ((false, false, false, true), (false, false, true)),  // NC
        ];
        for ((a, b, f1, f2), (eb, ef1, ef2)) in cases {
            let mut st = [a, b, f1, f2];
            apply_passes(MAX_LUT, &mut st);
            assert_eq!(st[MAX_SLOT_A], a, "A unchanged for ({a},{b},{f1},{f2})");
            assert_eq!(st[MAX_SLOT_B], eb, "B for ({a},{b},{f1},{f2})");
            assert_eq!(st[MAX_SLOT_F1], ef1, "F1 for ({a},{b},{f1},{f2})");
            assert_eq!(st[MAX_SLOT_F2], ef2, "F2 for ({a},{b},{f1},{f2})");
        }
    }

    /// MSB-first max over full words: walk the LUT across bit positions of
    /// random word pairs and check `B == max(A, B)`.
    #[test]
    fn max_lut_computes_max_of_words() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(1234);
        for _ in 0..200 {
            let m = rng.range(1, 10) as u32;
            let a = rng.below(1 << m);
            let b = rng.below(1 << m);
            let (mut bv, mut f1, mut f2) = (b, false, false);
            for i in (0..m).rev() {
                let abit = a >> i & 1 == 1;
                let bbit = bv >> i & 1 == 1;
                let mut st = [abit, bbit, f1, f2];
                apply_passes(MAX_LUT, &mut st);
                if st[MAX_SLOT_B] {
                    bv |= 1 << i;
                } else {
                    bv &= !(1 << i);
                }
                f1 = st[MAX_SLOT_F1];
                f2 = st[MAX_SLOT_F2];
            }
            assert_eq!(bv, a.max(b), "max of {a} and {b} (m={m})");
        }
    }

    /// Hazard-freedom: within each LUT, the post-write state of every pass
    /// must not match the key of any *later* pass.
    #[test]
    fn luts_are_hazard_free() {
        for (name, lut) in
            [("add", ADD_LUT), ("mul", MUL_GATED_ADD_LUT), ("relu", RELU_LUT), ("max", MAX_LUT)]
        {
            for (i, p) in lut.iter().enumerate() {
                // Build the post state of pass p from its key + writes.
                let nslots = 4;
                let mut state = vec![None; nslots];
                for &(s, b) in p.key {
                    state[s] = Some(b);
                }
                for &(s, b) in p.write {
                    state[s] = Some(b);
                }
                for later in &lut[i + 1..] {
                    let rematch = later
                        .key
                        .iter()
                        .all(|&(s, b)| state[s].map(|v| v == b).unwrap_or(true));
                    assert!(
                        !rematch,
                        "LUT {name}: output of pass {} re-matches later pass {}",
                        p.name, later.name
                    );
                }
            }
        }
    }
}
