//! Table II — asymptotic complexity classes of the AP functions.
//!
//! These are used as *oracles* in tests: the measured growth of the
//! closed-form runtime models (`runtime_model`) must match the dominant
//! term of the corresponding Table II entry.

use super::{clog2, ApKind};

/// The seven functions of Tables I & II.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Function {
    /// Element-wise addition.
    Addition,
    /// Element-wise multiplication.
    Multiplication,
    /// Vertical reduction (sum tree).
    Reduction,
    /// Matrix-matrix multiplication.
    MatMat,
    /// Rectified linear unit.
    Relu,
    /// Max pooling.
    MaxPooling,
    /// Average pooling.
    AveragePooling,
}

impl Function {
    /// All functions, Table I row order.
    pub const ALL: [Function; 7] = [
        Function::Addition,
        Function::Multiplication,
        Function::Reduction,
        Function::MatMat,
        Function::Relu,
        Function::MaxPooling,
        Function::AveragePooling,
    ];

    /// Row label used in regenerated tables.
    pub fn label(&self) -> &'static str {
        match self {
            Function::Addition => "Addition",
            Function::Multiplication => "Multiplication",
            Function::Reduction => "Reduction",
            Function::MatMat => "Matrix-Matrix Multiplication",
            Function::Relu => "ReLU",
            Function::MaxPooling => "Max Pooling",
            Function::AveragePooling => "Average Pooling",
        }
    }

    /// Table II complexity string for a given AP kind.
    pub fn complexity(&self, kind: ApKind) -> &'static str {
        use ApKind::*;
        use Function::*;
        match (self, kind) {
            (Addition, _) => "O(M)",
            (Multiplication, _) => "O(M) + O(M^2)",
            (Reduction, OneD) => "O(M) + O(M log2(L)) + O(L)",
            (Reduction, TwoD) => "O(M) + O(L)",
            (Reduction, TwoDSeg) => "O(M) + O(log2(L))",
            (MatMat, OneD) => "O(M) + O(M^2) + O(M log2(j)) + O(i*u*j)",
            (MatMat, TwoD) => "O(M) + O(M^2) + O(i*u*j)",
            (MatMat, TwoDSeg) => "O(M) + O(M^2) + O(log2(j))",
            (Relu, _) => "O(M)",
            (MaxPooling, OneD) => "O(M) + O(M log2(S)) + O(S*K)",
            (MaxPooling, TwoD) => "O(M) + O(S*K)",
            (MaxPooling, TwoDSeg) => "O(M) + O(log2(S)) + O(K log2(S))",
            (AveragePooling, OneD) => "O(M) + O(S*K) + O(M log2(S))",
            (AveragePooling, TwoD) => "O(M) + O(S*K)",
            (AveragePooling, TwoDSeg) => "O(M) + O(log2(S))",
        }
    }

    /// Dominant-term estimator for large inputs: the expected leading-order
    /// runtime as a function of (M, L-or-j, S, K, i, u). Used by growth
    /// tests to check the runtime models scale like Table II says.
    pub fn dominant_term(&self, kind: ApKind, m: u64, l: u64, s: u64, k: u64, i: u64, u: u64) -> f64 {
        use ApKind::*;
        use Function::*;
        let lg = |x: u64| clog2(x.max(1)) as f64;
        match (self, kind) {
            (Addition, _) | (Relu, _) => m as f64,
            (Multiplication, _) => (m * m) as f64,
            (Reduction, OneD) => m as f64 * lg(l) + l as f64,
            (Reduction, TwoD) => l as f64,
            (Reduction, TwoDSeg) => m as f64 + lg(l),
            (MatMat, OneD) => (m * m) as f64 + (i * u * l) as f64,
            (MatMat, TwoD) => (m * m) as f64 + (i * u * l) as f64,
            (MatMat, TwoDSeg) => (m * m) as f64 + lg(l),
            (MaxPooling, OneD) => m as f64 * lg(s) + (s * k) as f64,
            (MaxPooling, TwoD) => (s * k) as f64,
            (MaxPooling, TwoDSeg) => m as f64 + k as f64 * lg(s),
            (AveragePooling, OneD) => m as f64 * lg(s) + (s * k) as f64,
            (AveragePooling, TwoD) => (s * k) as f64,
            (AveragePooling, TwoDSeg) => m as f64 + lg(s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ap::runtime_model as rt;

    /// The measured runtime ratio when doubling the dominant parameter must
    /// approach the dominant-term prediction (within 30%).
    fn assert_growth(model: impl Fn(u64) -> u64, oracle: impl Fn(u64) -> f64, base: u64) {
        let (r1, r2) = (model(base), model(base * 4));
        let (o1, o2) = (oracle(base), oracle(base * 4));
        let measured = r2 as f64 / r1 as f64;
        let expected = o2 / o1;
        assert!(
            (measured / expected - 1.0).abs() < 0.3,
            "growth mismatch: measured {measured:.2} vs expected {expected:.2}"
        );
    }

    #[test]
    fn reduction_2d_grows_linearly_in_l() {
        assert_growth(
            |l| rt::reduce(8, l, ApKind::TwoD).events.time_units(),
            |l| Function::Reduction.dominant_term(ApKind::TwoD, 8, l, 0, 0, 0, 0),
            4096,
        );
    }

    #[test]
    fn reduction_2dseg_grows_logarithmically_in_l() {
        let r1 = rt::reduce(8, 1 << 10, ApKind::TwoDSeg).events.time_units();
        let r2 = rt::reduce(8, 1 << 20, ApKind::TwoDSeg).events.time_units();
        // Log growth: doubling the exponent adds ~8*10 units, far from 1024x.
        assert!(r2 < r1 * 3, "r1={r1} r2={r2}");
    }

    #[test]
    fn multiplication_grows_quadratically_in_m() {
        assert_growth(
            |m| rt::multiply(m as u32, m as u32, 64, ApKind::TwoD).events.time_units(),
            |m| (m * m) as f64,
            8,
        );
    }

    #[test]
    fn matmat_2d_grows_linearly_in_iuj() {
        assert_growth(
            |j| rt::matmat(8, 8, 8, j, 8, ApKind::TwoD).events.time_units(),
            |j| Function::MatMat.dominant_term(ApKind::TwoD, 8, j, 0, 0, 8, 8),
            512,
        );
    }

    #[test]
    fn complexity_strings_cover_all() {
        for f in Function::ALL {
            for k in ApKind::ALL {
                assert!(f.complexity(k).starts_with("O("));
            }
            assert!(!f.label().is_empty());
        }
    }
}
