//! Summary statistics used by the bench harness and the coordinator's
//! latency accounting.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation. Returns 0.0 for fewer than 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean of strictly-positive samples. Returns 0.0 if empty or if
/// any sample is non-positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Percentile by linear interpolation on a *sorted* slice; `q` in `[0, 100]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = (q / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice (copies + sorts internally).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Min/mean/p50/p99/max summary of a set of samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Smallest sample.
    pub min: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median.
    pub p50: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Build a summary; an empty input yields all-zero fields.
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self { n: 0, min: 0.0, mean: 0.0, p50: 0.0, p99: 0.0, max: 0.0 };
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n: v.len(),
            min: v[0],
            mean: mean(&v),
            p50: percentile_sorted(&v, 50.0),
            p99: percentile_sorted(&v, 99.0),
            max: v[v.len() - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[1.0, -1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.min <= s.p50 && s.p50 <= s.p99 && s.p99 <= s.max);
    }
}
