//! Small self-contained utilities shared across the crate.
//!
//! The offline build environment ships without `criterion`, `proptest`,
//! `clap` or `rand`, so this module provides minimal, deterministic
//! replacements:
//!
//! * [`error`] — an `anyhow`-shaped error type + `anyhow!` macro,
//! * [`json`] — a JSON parser + canonical (byte-deterministic) writer,
//! * [`rng`] — an xorshift64* PRNG (deterministic, seedable),
//! * [`stats`] — summary statistics (mean, percentiles, geomean),
//! * [`table`] — fixed-width ASCII table rendering for bench reports,
//! * [`benchkit`] — a tiny timing harness used by `cargo bench` targets,
//! * [`proptest`] — a tiny property-based-testing driver with shrinking-free
//!   counterexample reporting (seeded, reproducible).

pub mod benchkit;
pub mod error;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
