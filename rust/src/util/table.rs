//! Fixed-width ASCII table rendering used by every bench target so the
//! regenerated tables read like the paper's.

/// A simple left/right-aligned ASCII table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row. Rows shorter than the header are right-padded with "".
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let mut r: Vec<String> = cells.into_iter().map(Into::into).collect();
        while r.len() < self.header.len() {
            r.push(String::new());
        }
        self.rows.push(r);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string: header, separator, rows. First column is
    /// left-aligned, the rest right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, (c, w)) in cells.iter().zip(widths).enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                if i == 0 {
                    line.push_str(&format!("{c:<w$}"));
                } else {
                    line.push_str(&format!("{c:>w$}"));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as RFC-4180-style CSV: header line then one line per row,
    /// `\n` separated, cells quoted only when they need it. The plottable
    /// twin of [`Table::render`] — `bf-imna render --csv` emits these so
    /// CI can upload machine-readable artifacts next to the ASCII ones.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for (i, row) in std::iter::once(&self.header).chain(&self.rows).enumerate() {
            if i > 0 {
                out.push('\n');
            }
            for (j, cell) in row.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&csv_escape(cell));
            }
        }
        out.push('\n');
        out
    }
}

/// Quote a CSV cell when it contains a comma, quote, or newline; double
/// embedded quotes per RFC 4180.
pub fn csv_escape(cell: &str) -> String {
    if cell.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Format a float in engineering style with the given significant figures,
/// e.g. `fmt_eng(1.234e-5, 3)` -> "1.23e-5". Values in `[0.01, 10000)` are
/// printed plainly.
pub fn fmt_eng(v: f64, sig: usize) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if (0.01..10_000.0).contains(&a) {
        let decimals = if a >= 100.0 {
            sig.saturating_sub(3)
        } else if a >= 10.0 {
            sig.saturating_sub(2)
        } else if a >= 1.0 {
            sig.saturating_sub(1)
        } else {
            sig + 1
        };
        format!("{v:.decimals$}")
    } else {
        format!("{v:.prec$e}", prec = sig.saturating_sub(1))
    }
}

/// Format a ratio as "12.3x".
pub fn fmt_ratio(v: f64) -> String {
    format!("{}x", fmt_eng(v, 3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["alpha", "1"]);
        t.row(vec!["b", "22"]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.contains("alpha"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // Right alignment of the numeric column.
        assert!(lines[2].ends_with('1'));
        assert!(lines[3].ends_with("22"));
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["x"]);
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
        let _ = t.render(); // must not panic
    }

    #[test]
    fn fmt_eng_plain_and_exponent() {
        assert_eq!(fmt_eng(0.0, 3), "0");
        assert_eq!(fmt_eng(1.0, 3), "1.00");
        assert_eq!(fmt_eng(123.4, 3), "123");
        assert_eq!(fmt_eng(1.234e-5, 3), "1.23e-5");
    }

    #[test]
    fn fmt_ratio_suffix() {
        assert_eq!(fmt_ratio(2.0), "2.00x");
    }

    #[test]
    fn csv_quotes_only_when_needed() {
        let mut t = Table::new(vec!["name", "note"]);
        t.row(vec!["plain", "a,b"]);
        t.row(vec!["quo\"te", "fine"]);
        assert_eq!(t.to_csv(), "name,note\nplain,\"a,b\"\n\"quo\"\"te\",fine\n");
        assert_eq!(csv_escape("simple"), "simple");
        assert_eq!(csv_escape("line\nbreak"), "\"line\nbreak\"");
    }
}
