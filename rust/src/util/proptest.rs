//! Minimal property-based testing driver.
//!
//! The offline vendor set has no `proptest`, so this module provides the
//! subset we need: run a property over many random cases from a seeded
//! generator and, on failure, report the seed + case index so the exact
//! counterexample is reproducible (`Rng` is fully deterministic).
//!
//! Usage (compile-checked; `no_run` because doctest binaries don't carry
//! the workspace rpath to the PJRT runtime libs):
//! ```no_run
//! use bf_imna::util::proptest::check;
//! check("add commutes", 256, |rng| {
//!     let a = rng.range(0, 100);
//!     let b = rng.range(0, 100);
//!     if a + b != b + a { return Err(format!("{a} {b}")); }
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Default base seed; each case `i` runs with seed `BASE_SEED + i` so a
/// failing case can be re-run in isolation.
pub const BASE_SEED: u64 = 0xBF_1141A;

/// Run `cases` random cases of `property`. Each case receives a fresh,
/// deterministically-seeded [`Rng`]. Panics on the first failing case with
/// a reproducible report.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cases {
        let mut rng = Rng::new(BASE_SEED + i);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}): {msg}",
                seed = BASE_SEED + i
            );
        }
    }
}

/// Like [`check`] but with an explicit base seed (for targeted replay).
pub fn check_seeded<F>(name: &str, base_seed: u64, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cases {
        let mut rng = Rng::new(base_seed + i);
        if let Err(msg) = property(&mut rng) {
            panic!("property '{name}' failed at case {i} (seed {seed:#x}): {msg}", seed = base_seed + i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("tautology", 64, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        check("always fails", 4, |_| Err("boom".into()));
    }

    #[test]
    fn rng_is_distinct_across_cases() {
        let mut firsts = Vec::new();
        check("collect", 8, |rng| {
            firsts.push(rng.next_u64());
            Ok(())
        });
        firsts.sort_unstable();
        firsts.dedup();
        assert_eq!(firsts.len(), 8);
    }
}
