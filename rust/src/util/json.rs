//! Minimal JSON parser **and deterministic writer**.
//!
//! The offline vendor set has no `serde_json`, so this module provides a
//! small recursive-descent parser over a [`Json`] value enum. It supports
//! the full JSON grammar except `\u` escapes beyond the BMP (sufficient
//! for the ASCII manifest the AOT exporter writes).
//!
//! The writer (`Display`, i.e. `to_string()`) is **canonical**: objects
//! serialize with keys in sorted order (they are stored in a `BTreeMap`),
//! arrays in element order, no whitespace, and numbers in shortest
//! round-trip form — so two equal [`Json`] values always produce
//! byte-identical text. [`crate::sim::shard`] leans on this: a merged
//! sweep document is byte-identical to the single-process one because both
//! funnel through this writer.
//!
//! ```
//! use bf_imna::util::json::Json;
//! let doc = Json::parse(r#"{"b": [1, 2.5], "a": "x"}"#).unwrap();
//! // Canonical writer: sorted keys, no whitespace, shortest numbers.
//! assert_eq!(doc.to_string(), r#"{"a":"x","b":[1,2.5]}"#);
//! // Round trip is the identity on writer output.
//! assert_eq!(Json::parse(&doc.to_string()).unwrap(), doc);
//! ```

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// The `null` literal.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (JSON does not distinguish integers from floats).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object; `BTreeMap` keeps keys sorted, making the writer
    /// deterministic.
    Obj(BTreeMap<String, Json>),
}

impl fmt::Display for Json {
    /// Canonical compact serialization (see module docs).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write_num(f, *n),
            Json::Str(s) => write_str(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_str(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

/// Write a number in shortest round-trip form: integer-valued floats in
/// `i64` range print without a fractional part, everything else uses Rust's
/// shortest-round-trip `f64` formatting. Non-finite values (which JSON
/// cannot represent) serialize as `null`.
fn write_num(f: &mut fmt::Formatter<'_>, n: f64) -> fmt::Result {
    if !n.is_finite() {
        return f.write_str("null");
    }
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        write!(f, "{}", n as i64)
    } else {
        write!(f, "{n}")
    }
}

/// Write a string with the escapes the parser understands.
fn write_str(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            '\u{8}' => f.write_str("\\b")?,
            '\u{c}' => f.write_str("\\f")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Parse a JSON document from raw bytes (which must be valid UTF-8 —
    /// the encoding JSON mandates). This is the entry point wire code
    /// uses: HTTP bodies arrive as `Vec<u8>`, not `&str`.
    pub fn parse_bytes(bytes: &[u8]) -> Result<Json, JsonError> {
        let text = std::str::from_utf8(bytes).map_err(|e| JsonError {
            offset: e.valid_up_to(),
            message: "invalid utf-8".to_string(),
        })?;
        Json::parse(text)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Boolean content, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer content (numbers that round-trip through i64).
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    /// Array content, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object content, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Build an array from any iterator of values.
    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Build an object from key/value pairs (keys sort on write; duplicate
    /// keys keep the last value, as in the parser).
    pub fn obj<K: Into<String>>(entries: impl IntoIterator<Item = (K, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }
}

/// Streaming read helper for length-framed wire formats: read **exactly**
/// `len` bytes from `r` and parse them as one JSON document.
///
/// The buffer is sized up front from the declared length (callers enforce
/// their own caps *before* calling, so a hostile length never allocates),
/// short reads are retried until the frame is complete, and a peer that
/// closes the stream early yields a clean `truncated body` error instead
/// of a partial parse. [`crate::sim::transport`] uses this to consume
/// `Content-Length`-framed HTTP bodies straight off a socket.
pub fn read_json_exact(r: &mut impl std::io::Read, len: usize) -> Result<Json, String> {
    let mut buf = vec![0u8; len];
    let mut filled = 0;
    while filled < len {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(format!("truncated body: got {filled} of {len} bytes")),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(format!("read failed after {filled} of {len} bytes: {e}")),
        }
    }
    Json::parse_bytes(&buf).map_err(|e| e.to_string())
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
    /// Human-readable description of the failure.
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn accessors_and_integers() {
        let v = Json::parse(r#"{"n": 42, "f": 1.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_i64(), Some(42));
        assert_eq!(v.get("f").unwrap().as_i64(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn writer_is_canonical_and_round_trips() {
        let doc = Json::parse(r#"{ "b" : [1, 2.5, -3e2], "a": {"x": null, "y": true} }"#).unwrap();
        let text = doc.to_string();
        assert_eq!(text, r#"{"a":{"x":null,"y":true},"b":[1,2.5,-300]}"#);
        // parse(write(v)) == v, and write is idempotent on its own output.
        let reparsed = Json::parse(&text).unwrap();
        assert_eq!(reparsed, doc);
        assert_eq!(reparsed.to_string(), text);
    }

    #[test]
    fn writer_escapes_strings() {
        let v = Json::str("a\"b\\c\nd\te\u{1}");
        let text = v.to_string();
        assert_eq!(text, "\"a\\\"b\\\\c\\nd\\te\\u0001\"");
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn writer_float_round_trip_is_exact() {
        for x in [0.1, 1.0 / 3.0, 6.02e23, 137.45, 1e-15, -0.0, 5.0] {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            // -0.0 collapses to 0 in text, which compares equal; everything
            // else must round-trip to the same bits.
            if x != 0.0 {
                assert_eq!(back.to_bits(), x.to_bits(), "{x} -> {text} -> {back}");
            }
            // The writer is a function of the value: re-writing the parse
            // reproduces the text.
            assert_eq!(Json::Num(back).to_string(), text);
        }
    }

    #[test]
    fn builders_compose() {
        let v = Json::obj([
            ("n", Json::num(3.0)),
            ("s", Json::str("hi")),
            ("a", Json::arr([Json::Null, Json::Bool(false)])),
        ]);
        assert_eq!(v.to_string(), r#"{"a":[null,false],"n":3,"s":"hi"}"#);
    }

    #[test]
    fn parse_bytes_matches_parse_and_rejects_bad_utf8() {
        let text = r#"{"a":[1,2.5],"b":"x"}"#;
        assert_eq!(Json::parse_bytes(text.as_bytes()).unwrap(), Json::parse(text).unwrap());
        let err = Json::parse_bytes(&[b'"', 0xFF, b'"']).unwrap_err();
        assert!(err.message.contains("utf-8"), "{err}");
    }

    #[test]
    fn read_json_exact_consumes_only_the_frame() {
        use std::io::{Cursor, Read};
        let frame = r#"{"n":1}"#;
        let mut stream = Cursor::new(format!("{frame}TRAILING").into_bytes());
        let v = read_json_exact(&mut stream, frame.len()).unwrap();
        assert_eq!(v.get("n").unwrap().as_i64(), Some(1));
        // The trailing bytes are still on the stream.
        let mut rest = String::new();
        stream.read_to_string(&mut rest).unwrap();
        assert_eq!(rest, "TRAILING");
    }

    #[test]
    fn read_json_exact_fails_cleanly_on_truncation_and_garbage() {
        use std::io::Cursor;
        // Peer closed before the declared length arrived.
        let err = read_json_exact(&mut Cursor::new(b"{\"n\"".to_vec()), 32).unwrap_err();
        assert!(err.contains("truncated body: got 4 of 32 bytes"), "{err}");
        // Full frame, but not JSON.
        assert!(read_json_exact(&mut Cursor::new(b"notjson!".to_vec()), 8).is_err());
        // Zero-length frame is an empty document, which is invalid JSON.
        assert!(read_json_exact(&mut Cursor::new(Vec::new()), 0).is_err());
    }

    #[test]
    fn parses_manifest_shaped_document() {
        let doc = r#"{
            "model": "serve_cnn",
            "input_shape": [32, 32, 3],
            "batch_sizes": [1, 4, 8],
            "accuracies": {"int8": 1.0, "int4": 0.9921875},
            "artifacts": [
                {"config": "int8", "batch": 1, "file": "a.hlo.txt", "avg_bits": 8.0}
            ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("model").unwrap().as_str(), Some("serve_cnn"));
        let batches: Vec<i64> = v
            .get("batch_sizes")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.as_i64().unwrap())
            .collect();
        assert_eq!(batches, vec![1, 4, 8]);
        let art = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(art.get("config").unwrap().as_str(), Some("int8"));
    }
}
