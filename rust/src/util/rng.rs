//! Deterministic xorshift64* PRNG.
//!
//! `rand` is not available in the offline vendor set; this generator is more
//! than adequate for workload generation and property-based tests. It is
//! seedable and fully deterministic across platforms.

/// xorshift64* pseudo-random generator (Vigna, 2016).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped to a fixed
    /// non-zero constant (xorshift state must be non-zero).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift bounded sampling (Lemire); bias is negligible for
        // the bounds used in this crate.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random boolean with probability `p` of `true`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random boolean (p = 0.5).
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Signed integer uniform in `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Fill a vector with `n` unsigned values below `bound`.
    pub fn vec_below(&mut self, n: usize, bound: u64) -> Vec<u64> {
        (0..n).map(|_| self.below(bound)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(42);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(42);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
