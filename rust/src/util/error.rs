//! Minimal `anyhow`-style error plumbing.
//!
//! The offline vendor set has no `anyhow`, so this module provides the
//! small subset the crate uses: a message-carrying [`Error`] type, a
//! [`Result`] alias whose error type defaults to it, an [`anyhow!`] macro
//! building one from a format string (or any `Display` value), and a
//! [`Context`] extension trait adding `.context(..)` / `.with_context(..)`
//! to results.

use std::fmt;

/// A human-readable error: a message with any context prepended.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from a message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    /// Wrap with an outer context message (`"context: cause"`).
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e.to_string())
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to any displayable error.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Build an [`Error`] from a format string, or from any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::Error::msg(format!($msg $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg(format!("{}", $err))
    };
}

pub use crate::anyhow;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_formats_and_wraps() {
        let e = anyhow!("bad value {} at {}", 3, "site");
        assert_eq!(e.to_string(), "bad value 3 at site");
        let s = String::from("plain");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn context_chains_messages() {
        let r: Result<(), String> = Err("inner".to_string());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: Result<(), String> = Err("inner".to_string());
        let e = r.with_context(|| format!("lazy {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "lazy 1: inner");
    }

    #[test]
    fn boxes_as_std_error() {
        let b: Box<dyn std::error::Error> = anyhow!("boom").into();
        assert_eq!(b.to_string(), "boom");
    }
}
