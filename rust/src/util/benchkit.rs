//! Minimal timing harness for `cargo bench` targets.
//!
//! `criterion` is not available in the offline vendor set, so bench targets
//! are `harness = false` binaries that use this module: warmup, fixed-count
//! timed iterations, and a min/mean/p50/p99 report. Results are printed as
//! ASCII tables (see [`crate::util::table`]) so each bench regenerates the
//! corresponding paper table/figure in-place.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// One benchmark measurement: per-iteration wall-clock samples in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (printed in reports).
    pub name: String,
    /// Per-iteration wall-clock samples, seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Summary over the collected samples (seconds/iteration).
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    /// Mean iterations per second.
    pub fn throughput(&self) -> f64 {
        let s = self.summary();
        if s.mean > 0.0 {
            1.0 / s.mean
        } else {
            0.0
        }
    }

    /// One-line human-readable report.
    pub fn report_line(&self) -> String {
        let s = self.summary();
        format!(
            "{:<40} n={:<4} mean={:>12} p50={:>12} p99={:>12}",
            self.name,
            s.n,
            fmt_duration(s.mean),
            fmt_duration(s.p50),
            fmt_duration(s.p99),
        )
    }
}

/// Format seconds as a human-friendly duration string.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// A tiny bencher: `Bencher::new("name").run(|| work())`.
#[derive(Debug, Clone)]
pub struct Bencher {
    warmup_iters: u32,
    sample_count: u32,
    max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, sample_count: 30, max_total: Duration::from_secs(10) }
    }
}

impl Bencher {
    /// Default configuration (3 warmups, 30 samples, 10 s budget).
    pub fn new() -> Self {
        Self::default()
    }

    /// Override the number of timed samples.
    pub fn samples(mut self, n: u32) -> Self {
        self.sample_count = n.max(1);
        self
    }

    /// Override the warmup iteration count.
    pub fn warmup(mut self, n: u32) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Override the total time budget; sampling stops early when exceeded.
    pub fn budget(mut self, d: Duration) -> Self {
        self.max_total = d;
        self
    }

    /// Run a closure repeatedly and collect per-iteration timings. The
    /// closure's return value is passed through `std::hint::black_box` to
    /// prevent the optimizer from deleting the work.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let start_all = Instant::now();
        let mut samples = Vec::with_capacity(self.sample_count as usize);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            if start_all.elapsed() > self.max_total {
                break;
            }
        }
        BenchResult { name: name.to_string(), samples }
    }
}

/// Print a section banner used by bench binaries.
pub fn banner(title: &str) {
    let line = "=".repeat(title.len().max(20));
    println!("\n{line}\n{title}\n{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_requested_samples() {
        let r = Bencher::new().samples(5).warmup(1).run("noop", || 1 + 1);
        assert_eq!(r.name, "noop");
        assert_eq!(r.samples.len(), 5);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn respects_budget() {
        let r = Bencher::new()
            .samples(1000)
            .warmup(0)
            .budget(Duration::from_millis(20))
            .run("sleepy", || std::thread::sleep(Duration::from_millis(5)));
        assert!(r.samples.len() < 1000);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(2.0), "2.000s");
        assert_eq!(fmt_duration(2e-3), "2.000ms");
        assert_eq!(fmt_duration(2e-6), "2.000us");
        assert_eq!(fmt_duration(2e-9), "2.0ns");
    }

    #[test]
    fn report_line_contains_name() {
        let r = Bencher::new().samples(2).run("xyz", || 0);
        assert!(r.report_line().contains("xyz"));
    }
}
