//! The sim-backed inference backend — serving without PJRT.
//!
//! [`SimBackend`] implements [`InferenceBackend`](super::InferenceBackend)
//! on top of the BF-IMNA simulator instead of compiled XLA artifacts:
//!
//! * **Latency** comes from the `ap`/`mapper`/`sim` cost models — one
//!   [`simulate`] per manifest config at startup, with batches costed by
//!   the paper's inter-batch pipelining model (the first inference pays
//!   the full latency, each subsequent one the pipeline initiation
//!   interval).
//! * **Numerics** come from a deterministic functional stand-in: one fixed
//!   random projection (seeded, platform-independent generation) shared by
//!   every config, quantized to each config's average bitwidth — so
//!   different precision configs produce slightly different logits that
//!   mostly agree on the argmax, exactly the shape of a quantized model
//!   ladder.
//!
//! This is what lets the serving coordinator run end to end — and be
//! tested, benched, and driven over the network — in the default build,
//! where the PJRT runtime is only a stub. `modeled_latency_s` additionally
//! gives the precision controller a deterministic latency signal, so
//! config choices under a fixed request trace are reproducible.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use super::manifest::{ArtifactEntry, ConfigInfo, Manifest};
use crate::precision::{LayerPrec, PrecisionConfig};
use crate::sim::{simulate, SimParams};
use crate::util::error::{anyhow, Result};
use crate::util::rng::Rng;

/// Serving backend that executes batches through the BF-IMNA latency
/// models and a deterministic quantized projection (see module docs).
pub struct SimBackend {
    manifest: Manifest,
    /// Per-config projection weights, `(num_classes, sample_elems)`
    /// row-major — the underlying float model quantized to that config's
    /// average bitwidth.
    weights: BTreeMap<String, Vec<f32>>,
    /// Simulated per-batch execution latency by (config, batch), seconds.
    latencies: BTreeMap<(String, u64), f64>,
    /// Wall-clock pacing: each `infer` sleeps `modeled latency x scale`
    /// (0.0 disables pacing — the right setting for tests and benches).
    time_scale: f64,
}

impl SimBackend {
    /// Build a backend over an arbitrary manifest. The manifest's model
    /// must be a zoo network and every artifact's config must carry
    /// per-layer precision data (the simulator needs both).
    pub fn new(manifest: Manifest, time_scale: f64) -> Result<SimBackend> {
        let net = crate::sim::shard::net_by_name(&manifest.model).map_err(|e| anyhow!(e))?;
        let params = SimParams::lr_sram();
        let mut latencies = BTreeMap::new();
        let mut reports: BTreeMap<String, (f64, f64)> = BTreeMap::new();
        for entry in &manifest.artifacts {
            let (lat, interval) = match reports.get(&entry.config) {
                Some(&r) => r,
                None => {
                    let info = manifest.configs.get(&entry.config).ok_or_else(|| {
                        anyhow!("sim backend: config '{}' has no per-layer info", entry.config)
                    })?;
                    if info.per_layer.len() != net.weight_layers() {
                        return Err(anyhow!(
                            "sim backend: config '{}' quantizes {} layers but {} has {}",
                            entry.config,
                            info.per_layer.len(),
                            net.name,
                            net.weight_layers()
                        ));
                    }
                    let cfg = PrecisionConfig {
                        name: entry.config.clone(),
                        per_layer: info
                            .per_layer
                            .iter()
                            .map(|&(w, a)| LayerPrec { w: w.max(1), a: a.max(1) })
                            .collect(),
                    };
                    let r = simulate(&net, &cfg, &params);
                    let pair = (r.latency_s(), r.pipeline_interval_s());
                    reports.insert(entry.config.clone(), pair);
                    pair
                }
            };
            // Inter-batch pipelining (§V-B): the first inference pays the
            // full latency, each further one the initiation interval.
            let batch_lat = lat + interval * (entry.batch.saturating_sub(1)) as f64;
            latencies.insert((entry.config.clone(), entry.batch), batch_lat);
        }

        // One underlying float model for every config: a fixed random
        // projection, quantized per config. Seeded generation keeps the
        // stand-in deterministic across runs and processes.
        let elems = manifest.sample_elems();
        let classes = manifest.num_classes as usize;
        let mut rng = Rng::new(0xBF1A);
        let base: Vec<f32> =
            (0..classes * elems).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let mut weights = BTreeMap::new();
        for (name, info) in &manifest.configs {
            weights.insert(name.clone(), quantize(&base, info.avg_bits));
        }

        Ok(SimBackend { manifest, weights, latencies, time_scale })
    }

    /// The default backend: the built-in serve-CNN manifest (int8 / mixed
    /// / int4 ladder at batch sizes 1, 4, 8) — no files needed.
    pub fn serve_cnn(time_scale: f64) -> SimBackend {
        SimBackend::new(SimBackend::serve_manifest(), time_scale)
            .expect("built-in serve-CNN manifest is valid")
    }

    /// The built-in manifest [`SimBackend::serve_cnn`] serves: the zoo
    /// serve CNN with a three-config precision ladder, mirroring the shape
    /// `python/compile/aot.py` exports for the PJRT path.
    pub fn serve_manifest() -> Manifest {
        let layers = 6; // serve_cnn weight layers: conv1..conv5 + fc
        let ladder: [(&str, Vec<u32>, f64); 3] = [
            ("int8", vec![8; layers], 0.993),
            ("mixed", vec![8, 8, 6, 6, 4, 4], 0.981),
            ("int4", vec![4; layers], 0.952),
        ];
        let batch_sizes = vec![1u64, 4, 8];
        let mut configs = BTreeMap::new();
        let mut accuracies = BTreeMap::new();
        let mut artifacts = Vec::new();
        for (name, bits, acc) in &ladder {
            let per_layer: Vec<(u32, u32)> = bits.iter().map(|&b| (b, b)).collect();
            let avg_bits = bits.iter().sum::<u32>() as f64 / bits.len() as f64;
            configs.insert(name.to_string(), ConfigInfo { per_layer, avg_bits });
            accuracies.insert(name.to_string(), *acc);
            for &batch in &batch_sizes {
                artifacts.push(ArtifactEntry {
                    config: name.to_string(),
                    batch,
                    file: format!("sim://{name}/{batch}"),
                    avg_bits,
                    accuracy: *acc,
                });
            }
        }
        Manifest {
            model: "serve_cnn".to_string(),
            input_shape: (32, 32, 3),
            num_classes: 10,
            param_count: 0,
            batch_sizes,
            configs,
            accuracies,
            artifacts,
            dir: PathBuf::from("sim://"),
        }
    }

    /// Keep only the named configs (the `Runtime::load_configs` analogue).
    /// Unknown names are ignored; an empty survivor set is an error.
    pub fn retain_configs(&mut self, configs: &[String]) -> Result<()> {
        self.manifest.artifacts.retain(|a| configs.contains(&a.config));
        if self.manifest.artifacts.is_empty() {
            return Err(anyhow!(
                "sim backend: none of the requested configs [{}] exist in the manifest",
                configs.join(", ")
            ));
        }
        self.manifest.configs.retain(|name, _| configs.contains(name));
        self.latencies.retain(|(name, _), _| configs.contains(name));
        self.weights.retain(|name, _| configs.contains(name));
        Ok(())
    }

    /// The backend's manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Simulated per-batch execution latency for a compiled pair, seconds.
    pub fn modeled_latency_s(&self, config: &str, batch: u64) -> Option<f64> {
        self.latencies.get(&(config.to_string(), batch)).copied()
    }
}

/// Symmetric quantization of `[-1, 1]` weights to `avg_bits` levels;
/// 16-bit-plus configs (including the float reference) pass through.
fn quantize(base: &[f32], avg_bits: f64) -> Vec<f32> {
    let bits = avg_bits.round().clamp(1.0, 32.0) as u32;
    if bits >= 16 {
        return base.to_vec();
    }
    let step = 1.0f32 / (1u32 << (bits - 1)) as f32;
    base.iter().map(|&w| (w / step).round() * step).collect()
}

impl super::InferenceBackend for SimBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        "bf-imna simulator (sim backend)".to_string()
    }

    fn compiled_keys(&self) -> Vec<(String, u64)> {
        let mut keys: Vec<(String, u64)> =
            self.manifest.artifacts.iter().map(|a| (a.config.clone(), a.batch)).collect();
        keys.sort();
        keys
    }

    fn infer(&self, config: &str, batch: u64, input: &[f32]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .artifact(config, batch)
            .ok_or_else(|| anyhow!("no compiled artifact for ({config}, batch {batch})"))?;
        let elems = self.manifest.sample_elems();
        let classes = self.manifest.num_classes as usize;
        let want = batch as usize * elems;
        if input.len() != want {
            return Err(anyhow!("input has {} elements, executable expects {want}", input.len()));
        }
        let weights = self
            .weights
            .get(&entry.config)
            .ok_or_else(|| anyhow!("sim backend: no weights for '{config}'"))?;
        let mut logits = Vec::with_capacity(batch as usize * classes);
        for b in 0..batch as usize {
            let sample = &input[b * elems..(b + 1) * elems];
            for c in 0..classes {
                let row = &weights[c * elems..(c + 1) * elems];
                let mut acc = 0.0f32;
                for (w, x) in row.iter().zip(sample) {
                    acc += w * x;
                }
                // Normalize so logits stay O(1) regardless of input size.
                logits.push(acc / (elems as f32).sqrt());
            }
        }
        if self.time_scale > 0.0 {
            if let Some(lat) = self.modeled_latency_s(config, batch) {
                if lat.is_finite() && lat > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(lat * self.time_scale));
                }
            }
        }
        Ok(logits)
    }

    fn entry(&self, config: &str, batch: u64) -> Option<&ArtifactEntry> {
        self.manifest.artifact(config, batch)
    }

    fn modeled_latency_s(&self, config: &str, batch: u64) -> Option<f64> {
        SimBackend::modeled_latency_s(self, config, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::InferenceBackend;

    #[test]
    fn built_in_manifest_serves_the_ladder() {
        let b = SimBackend::serve_cnn(0.0);
        let m = InferenceBackend::manifest(&b);
        assert_eq!(m.model, "serve_cnn");
        assert_eq!(m.sample_elems(), 32 * 32 * 3);
        assert_eq!(m.quality_ladder(), vec!["int8".to_string(), "mixed".into(), "int4".into()]);
        assert_eq!(b.compiled_keys().len(), 9);
    }

    #[test]
    fn infer_is_deterministic_and_config_sensitive() {
        let b = SimBackend::serve_cnn(0.0);
        let elems = b.manifest().sample_elems();
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..elems).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let a1 = b.infer("int8", 1, &x).unwrap();
        let a2 = b.infer("int8", 1, &x).unwrap();
        assert_eq!(a1, a2, "same config must be bit-stable");
        assert_eq!(a1.len(), 10);
        assert!(a1.iter().all(|v| v.is_finite()));
        let lo = b.infer("int4", 1, &x).unwrap();
        assert_ne!(a1, lo, "different precision must perturb the logits");
    }

    #[test]
    fn batches_share_the_per_sample_result() {
        let b = SimBackend::serve_cnn(0.0);
        let elems = b.manifest().sample_elems();
        let x: Vec<f32> = (0..elems).map(|i| (i % 7) as f32 / 7.0 - 0.5).collect();
        let single = b.infer("mixed", 1, &x).unwrap();
        let mut batched = Vec::new();
        for _ in 0..4 {
            batched.extend_from_slice(&x);
        }
        let out = b.infer("mixed", 4, &batched).unwrap();
        for row in out.chunks_exact(10) {
            assert_eq!(row, &single[..], "batch rows must match the single-sample result");
        }
    }

    #[test]
    fn modeled_latencies_follow_the_precision_ladder() {
        let b = SimBackend::serve_cnn(0.0);
        let l8 = b.modeled_latency_s("int8", 1).unwrap();
        let l4 = b.modeled_latency_s("int4", 1).unwrap();
        assert!(l8 > 0.0 && l4 > 0.0);
        // Per-layer latency is max(compute, mesh), both nondecreasing in
        // precision — so the ladder can be flat (Fig. 7b) but never
        // inverted: fewer bits are never slower on the AP.
        assert!(l4 <= l8, "int4 {l4} must not exceed int8 {l8}");
        // Batches cost more than singles but less than linear (pipelining).
        let l8b8 = b.modeled_latency_s("int8", 8).unwrap();
        assert!(l8b8 > l8 && l8b8 < 8.0 * l8);
        assert!(b.modeled_latency_s("int8", 3).is_none(), "uncompiled batch");
    }

    #[test]
    fn rejects_bad_inputs_and_unknown_configs() {
        let b = SimBackend::serve_cnn(0.0);
        assert!(b.infer("int8", 1, &[0.0; 7]).is_err());
        assert!(b.infer("fp64", 1, &vec![0.0; 3072]).is_err());
        assert!(b.infer("int8", 3, &vec![0.0; 3 * 3072]).is_err());
    }

    #[test]
    fn retain_configs_narrows_the_ladder() {
        let mut b = SimBackend::serve_cnn(0.0);
        b.retain_configs(&["int8".to_string(), "int4".to_string()]).unwrap();
        assert_eq!(b.manifest().quality_ladder(), vec!["int8".to_string(), "int4".into()]);
        assert!(b.modeled_latency_s("mixed", 1).is_none());
        let mut b = SimBackend::serve_cnn(0.0);
        assert!(b.retain_configs(&["nope".to_string()]).is_err());
    }
}
