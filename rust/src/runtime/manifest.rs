//! Artifact manifest — what `python/compile/aot.py` exported.
//!
//! The manifest is the contract between the build-time Python side and the
//! serve-time rust side: which precision configurations exist, at which
//! batch sizes, with which held-out accuracies, and which HLO-text file
//! implements each (config, batch) pair.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, Context, Result};

use crate::util::json::Json;

/// One exported (config, batch) artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Precision configuration name (`int8`, `mixed_low`, ..., `float`).
    pub config: String,
    /// Compiled batch size.
    pub batch: u64,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Average configured bitwidth (32 for the float reference).
    pub avg_bits: f64,
    /// Held-out accuracy measured at export time.
    pub accuracy: f64,
}

/// One precision configuration's description.
#[derive(Debug, Clone)]
pub struct ConfigInfo {
    /// Per-weight-layer (w_bits, a_bits) pairs.
    pub per_layer: Vec<(u32, u32)>,
    /// Average configured bitwidth.
    pub avg_bits: f64,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model name the artifacts were exported from.
    pub model: String,
    /// Input feature-map shape (H, W, C).
    pub input_shape: (u64, u64, u64),
    /// Output class count.
    pub num_classes: u64,
    /// Total weight parameters.
    pub param_count: u64,
    /// Batch sizes each configuration was compiled at.
    pub batch_sizes: Vec<u64>,
    /// Precision configurations by name (excludes `float`).
    pub configs: BTreeMap<String, ConfigInfo>,
    /// Held-out accuracy by config name (includes `float`).
    pub accuracies: BTreeMap<String, f64>,
    /// Every exported (config, batch) artifact.
    pub artifacts: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let field = |k: &str| v.get(k).ok_or_else(|| anyhow!("manifest missing '{k}'"));

        let shape = field("input_shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("input_shape not an array"))?;
        if shape.len() != 3 {
            return Err(anyhow!("input_shape must have 3 dims"));
        }
        let dim = |i: usize| shape[i].as_i64().unwrap_or(0) as u64;

        let mut configs = BTreeMap::new();
        if let Some(obj) = field("configs")?.as_obj() {
            for (name, c) in obj {
                let per_layer = c
                    .get("per_layer")
                    .and_then(Json::as_arr)
                    .map(|rows| {
                        rows.iter()
                            .filter_map(|r| r.as_arr())
                            .filter(|r| r.len() == 2)
                            .map(|r| {
                                (r[0].as_i64().unwrap_or(0) as u32, r[1].as_i64().unwrap_or(0) as u32)
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                let avg_bits = c.get("avg_bits").and_then(Json::as_f64).unwrap_or(0.0);
                configs.insert(name.clone(), ConfigInfo { per_layer, avg_bits });
            }
        }

        let mut accuracies = BTreeMap::new();
        if let Some(obj) = field("accuracies")?.as_obj() {
            for (name, a) in obj {
                accuracies.insert(name.clone(), a.as_f64().unwrap_or(0.0));
            }
        }

        let mut artifacts = Vec::new();
        for a in field("artifacts")?.as_arr().unwrap_or(&[]) {
            artifacts.push(ArtifactEntry {
                config: a
                    .get("config")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing config"))?
                    .to_string(),
                batch: a
                    .get("batch")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow!("artifact missing batch"))? as u64,
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing file"))?
                    .to_string(),
                avg_bits: a.get("avg_bits").and_then(Json::as_f64).unwrap_or(0.0),
                accuracy: a.get("accuracy").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }

        Ok(Manifest {
            model: field("model")?.as_str().unwrap_or("").to_string(),
            input_shape: (dim(0), dim(1), dim(2)),
            num_classes: field("num_classes")?.as_i64().unwrap_or(0) as u64,
            param_count: v.get("param_count").and_then(Json::as_i64).unwrap_or(0) as u64,
            batch_sizes: field("batch_sizes")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_i64)
                .map(|b| b as u64)
                .collect(),
            configs,
            accuracies,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Elements per input sample (H*W*C).
    pub fn sample_elems(&self) -> usize {
        (self.input_shape.0 * self.input_shape.1 * self.input_shape.2) as usize
    }

    /// Find the artifact for a (config, batch) pair.
    pub fn artifact(&self, config: &str, batch: u64) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.config == config && a.batch == batch)
    }

    /// Smallest compiled batch size that fits `n` requests (falls back to
    /// the largest compiled batch when `n` exceeds them all).
    pub fn batch_for(&self, n: u64) -> u64 {
        let mut sizes = self.batch_sizes.clone();
        sizes.sort_unstable();
        sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .or_else(|| sizes.last().copied())
            .unwrap_or(1)
    }

    /// Config names in descending average-bits order (serving quality
    /// ladder: float first if present, then int8 ... int4).
    pub fn quality_ladder(&self) -> Vec<String> {
        let mut names: Vec<(String, f64)> = self
            .artifacts
            .iter()
            .map(|a| (a.config.clone(), a.avg_bits))
            .collect::<std::collections::BTreeMap<_, _>>()
            .into_iter()
            .collect();
        names.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        names.into_iter().map(|(n, _)| n).collect()
    }
}

#[cfg(test)]
pub(crate) const TEST_MANIFEST: &str = r#"{
  "model": "serve_cnn",
  "input_shape": [32, 32, 3],
  "num_classes": 10,
  "param_count": 35000,
  "batch_sizes": [1, 4, 8],
  "configs": {
    "int8": {"per_layer": [[8,8],[8,8],[8,8],[8,8],[8,8],[8,8]], "avg_bits": 8.0},
    "int4": {"per_layer": [[4,4],[4,4],[4,4],[4,4],[4,4],[4,4]], "avg_bits": 4.0}
  },
  "accuracies": {"float": 1.0, "int8": 1.0, "int4": 0.99},
  "artifacts": [
    {"config": "int8", "batch": 1, "file": "a.hlo.txt", "avg_bits": 8.0, "accuracy": 1.0},
    {"config": "int8", "batch": 4, "file": "b.hlo.txt", "avg_bits": 8.0, "accuracy": 1.0},
    {"config": "int4", "batch": 1, "file": "c.hlo.txt", "avg_bits": 4.0, "accuracy": 0.99}
  ]
}"#;

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::parse(TEST_MANIFEST, Path::new("/tmp/artifacts")).unwrap()
    }

    #[test]
    fn parses_core_fields() {
        let m = manifest();
        assert_eq!(m.model, "serve_cnn");
        assert_eq!(m.input_shape, (32, 32, 3));
        assert_eq!(m.num_classes, 10);
        assert_eq!(m.sample_elems(), 32 * 32 * 3);
        assert_eq!(m.batch_sizes, vec![1, 4, 8]);
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.configs["int8"].per_layer.len(), 6);
        assert_eq!(m.accuracies["int4"], 0.99);
    }

    #[test]
    fn artifact_lookup() {
        let m = manifest();
        assert!(m.artifact("int8", 4).is_some());
        assert!(m.artifact("int8", 8).is_none());
        assert!(m.artifact("nope", 1).is_none());
    }

    #[test]
    fn batch_selection_rounds_up() {
        let m = manifest();
        assert_eq!(m.batch_for(1), 1);
        assert_eq!(m.batch_for(2), 4);
        assert_eq!(m.batch_for(4), 4);
        assert_eq!(m.batch_for(5), 8);
        assert_eq!(m.batch_for(100), 8);
    }

    #[test]
    fn quality_ladder_descends() {
        let m = manifest();
        assert_eq!(m.quality_ladder(), vec!["int8".to_string(), "int4".to_string()]);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("not json", Path::new("/tmp")).is_err());
    }
}
