//! Stub runtime used when the crate is built without the `pjrt` feature
//! (the offline vendor set has no `xla` crate). The API matches the
//! feature-gated `pjrt::Runtime` exactly so the coordinator, benches, and
//! examples compile unchanged; loading artifacts fails with a clear error
//! at run time, which the artifact-gated tests and demos already treat as
//! "skip".

use std::path::Path;

use super::manifest::{ArtifactEntry, Manifest};
use crate::util::error::{anyhow, Result};

const UNAVAILABLE: &str = "built without the `pjrt` feature: vendor the `xla` crate and rebuild \
                           with `--features pjrt` to compile and execute AOT artifacts";

/// Feature-gated stand-in for the PJRT runtime.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Load `<dir>/manifest.json` and compile every artifact it lists.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(manifest)
    }

    /// Compile every artifact of an already-parsed manifest.
    pub fn from_manifest(manifest: Manifest) -> Result<Runtime> {
        let _ = manifest;
        Err(anyhow!("{}", UNAVAILABLE))
    }

    /// Load + compile only the artifacts for the given config names.
    pub fn load_configs(dir: &Path, configs: &[&str]) -> Result<Runtime> {
        let mut manifest = Manifest::load(dir)?;
        manifest.artifacts.retain(|a| configs.contains(&a.config.as_str()));
        Self::from_manifest(manifest)
    }

    /// The manifest this runtime serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (pjrt feature disabled)".to_string()
    }

    /// Compiled (config, batch) pairs — always empty in the stub.
    pub fn compiled_keys(&self) -> Vec<(String, u64)> {
        Vec::new()
    }

    /// Execute one inference — always an error in the stub.
    pub fn infer(&self, config: &str, batch: u64, _input: &[f32]) -> Result<Vec<f32>> {
        Err(anyhow!("cannot execute ({config}, batch {batch}): {}", UNAVAILABLE))
    }

    /// Accuracy recorded at export time for a config.
    pub fn accuracy(&self, config: &str) -> Option<f64> {
        self.manifest.accuracies.get(config).copied()
    }

    /// The artifact entry behind a compiled pair — always `None` here.
    pub fn entry(&self, _config: &str, _batch: u64) -> Option<&ArtifactEntry> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_manifest_reports_missing_backend() {
        let m = Manifest::parse(super::super::manifest::TEST_MANIFEST, Path::new("/tmp/a"))
            .expect("test manifest parses");
        let err = Runtime::from_manifest(m).expect_err("stub must not compile artifacts");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
