//! PJRT runtime — loads and executes the AOT artifacts from the request
//! path (Python never runs at serve time).
//!
//! `python/compile/aot.py` lowers every (precision config, batch) serving
//! graph to HLO **text** once at build time; [`Runtime::load`] compiles all
//! of them onto the PJRT CPU client, and [`Runtime::infer`] executes one.
//! Text (not serialized `HloModuleProto`) is the interchange format — the
//! `xla` crate's backend (xla_extension 0.5.1) rejects jax ≥ 0.5's
//! 64-bit-id protos, while the text parser reassigns ids.

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

pub use manifest::{ArtifactEntry, ConfigInfo, Manifest};

/// A compiled serving executable for one (config, batch) pair.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
}

/// The serve-time runtime: a PJRT CPU client plus every compiled artifact.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<(String, u64), Compiled>,
}

impl Runtime {
    /// Load `<dir>/manifest.json` and compile every artifact it lists.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(manifest)
    }

    /// Compile every artifact of an already-parsed manifest.
    pub fn from_manifest(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let mut compiled = HashMap::new();
        for entry in &manifest.artifacts {
            let path = manifest.dir.join(&entry.file);
            let exe = Self::compile_file(&client, &path)
                .with_context(|| format!("compiling {}", path.display()))?;
            compiled.insert((entry.config.clone(), entry.batch), Compiled {
                exe,
                entry: entry.clone(),
            });
        }
        Ok(Runtime { client, manifest, compiled })
    }

    /// Load + compile only the artifacts for the given config names (used
    /// by tests and latency-sensitive startups).
    pub fn load_configs(dir: &Path, configs: &[&str]) -> Result<Runtime> {
        let mut manifest = Manifest::load(dir)?;
        manifest.artifacts.retain(|a| configs.contains(&a.config.as_str()));
        Self::from_manifest(manifest)
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow!("pjrt compile: {e}"))
    }

    /// The manifest this runtime serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compiled (config, batch) pairs.
    pub fn compiled_keys(&self) -> Vec<(String, u64)> {
        let mut keys: Vec<_> = self.compiled.keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Execute one inference: `input` is a row-major `f32` batch of shape
    /// `(batch, H, W, C)`; returns the `(batch, num_classes)` logits.
    ///
    /// `input.len()` must equal `batch * H * W * C` for the *compiled*
    /// batch size — use [`Manifest::batch_for`] + [`pad_batch`] to fit a
    /// partial batch.
    pub fn infer(&self, config: &str, batch: u64, input: &[f32]) -> Result<Vec<f32>> {
        let compiled = self
            .compiled
            .get(&(config.to_string(), batch))
            .ok_or_else(|| anyhow!("no compiled artifact for ({config}, batch {batch})"))?;
        let want = batch as usize * self.manifest.sample_elems();
        if input.len() != want {
            return Err(anyhow!(
                "input has {} elements, executable expects {want}",
                input.len()
            ));
        }
        let (h, w, c) = self.manifest.input_shape;
        let lit = xla::Literal::vec1(input)
            .reshape(&[batch as i64, h as i64, w as i64, c as i64])
            .map_err(|e| anyhow!("reshape input: {e}"))?;
        let result = compiled
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("pjrt execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("unwrap tuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("read logits: {e}"))
    }

    /// Accuracy recorded at export time for a config.
    pub fn accuracy(&self, config: &str) -> Option<f64> {
        self.manifest.accuracies.get(config).copied()
    }

    /// The artifact entry behind a compiled pair.
    pub fn entry(&self, config: &str, batch: u64) -> Option<&ArtifactEntry> {
        self.compiled.get(&(config.to_string(), batch)).map(|c| &c.entry)
    }
}

/// Pad `n` samples up to `batch` by repeating the final sample (the padded
/// logits are discarded by the caller). Returns the padded buffer.
pub fn pad_batch(input: &[f32], n: usize, batch: usize, sample_elems: usize) -> Vec<f32> {
    assert_eq!(input.len(), n * sample_elems, "input length mismatch");
    assert!(n >= 1 && n <= batch, "cannot pad {n} samples to batch {batch}");
    let mut out = Vec::with_capacity(batch * sample_elems);
    out.extend_from_slice(input);
    let last = &input[(n - 1) * sample_elems..];
    for _ in n..batch {
        out.extend_from_slice(last);
    }
    out
}

/// Argmax over each row of a `(batch, classes)` logits buffer.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_repeats_last_sample() {
        let input = vec![1.0, 2.0, 3.0, 4.0]; // 2 samples x 2 elems
        let padded = pad_batch(&input, 2, 4, 2);
        assert_eq!(padded, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn pad_batch_noop_when_full() {
        let input = vec![1.0, 2.0];
        assert_eq!(pad_batch(&input, 1, 1, 2), input);
    }

    #[test]
    #[should_panic(expected = "cannot pad")]
    fn pad_batch_rejects_overfull() {
        pad_batch(&[1.0, 2.0, 3.0], 3, 2, 1);
    }

    #[test]
    fn argmax_rows_basic() {
        let logits = vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    // End-to-end PJRT tests (compile + execute real artifacts) live in
    // rust/tests/runtime_e2e.rs — they need `make artifacts` output.
}
