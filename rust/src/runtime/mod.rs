//! Serving runtime — the pluggable execution layer behind the bit-fluid
//! coordinator (Python never runs at serve time).
//!
//! The coordinator talks to an [`InferenceBackend`]: anything that owns a
//! [`Manifest`] and can execute one (precision config, batch) pair. Three
//! implementations exist:
//!
//! * [`SimBackend`] (the default) — executes batches through the BF-IMNA
//!   `ap`/`mapper`/`sim` latency models plus a deterministic functional
//!   stand-in (a quantized random projection), so the whole serving stack
//!   runs, and is testable, without any compiled artifacts or the `pjrt`
//!   feature.
//! * The PJRT [`Runtime`] (`--features pjrt`) — `python/compile/aot.py`
//!   lowers every (precision config, batch) serving graph to HLO **text**
//!   once at build time; [`Runtime::load`] compiles all of them onto the
//!   PJRT CPU client, and [`Runtime::infer`] executes one. Text (not
//!   serialized `HloModuleProto`) is the interchange format — the `xla`
//!   crate's backend (xla_extension 0.5.1) rejects jax ≥ 0.5's 64-bit-id
//!   protos, while the text parser reassigns ids.
//! * The stub [`Runtime`] (default build) — the identical API, erroring at
//!   artifact-load time, so PJRT-path code compiles and cleanly reports
//!   the missing backend. (The `xla` crate is not in the offline vendor
//!   set, hence the feature gate.)

pub mod manifest;
pub mod sim_backend;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

pub use manifest::{ArtifactEntry, ConfigInfo, Manifest};
pub use sim_backend::SimBackend;

use crate::util::error::Result;

/// What the serving coordinator needs from an execution backend: a
/// manifest describing the compiled (config, batch) artifacts, and the
/// ability to execute one. Extracted from the PJRT `Runtime` so the
/// coordinator is backend-agnostic — the default build serves through
/// [`SimBackend`]; `--features pjrt` serves real XLA artifacts.
pub trait InferenceBackend {
    /// The manifest this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Backend platform name (diagnostics).
    fn platform(&self) -> String;

    /// Executable (config, batch) pairs, sorted.
    fn compiled_keys(&self) -> Vec<(String, u64)>;

    /// Execute one inference: `input` is a row-major `f32` batch of shape
    /// `(batch, H, W, C)`; returns the `(batch, num_classes)` logits.
    fn infer(&self, config: &str, batch: u64, input: &[f32]) -> Result<Vec<f32>>;

    /// The artifact entry behind a compiled pair, if any.
    fn entry(&self, config: &str, batch: u64) -> Option<&ArtifactEntry>;

    /// The backend's own model of how long executing (config, batch)
    /// takes, seconds — `Some` only for model-driven backends like
    /// [`SimBackend`], where it feeds the precision controller a
    /// deterministic latency signal instead of the measured wall clock.
    fn modeled_latency_s(&self, config: &str, batch: u64) -> Option<f64> {
        let _ = (config, batch);
        None
    }

    /// Accuracy recorded at export time for a config.
    fn accuracy(&self, config: &str) -> Option<f64> {
        self.manifest().accuracies.get(config).copied()
    }
}

impl InferenceBackend for Runtime {
    fn manifest(&self) -> &Manifest {
        Runtime::manifest(self)
    }

    fn platform(&self) -> String {
        Runtime::platform(self)
    }

    fn compiled_keys(&self) -> Vec<(String, u64)> {
        Runtime::compiled_keys(self)
    }

    fn infer(&self, config: &str, batch: u64, input: &[f32]) -> Result<Vec<f32>> {
        Runtime::infer(self, config, batch, input)
    }

    fn entry(&self, config: &str, batch: u64) -> Option<&ArtifactEntry> {
        Runtime::entry(self, config, batch)
    }
}

/// Pad `n` samples up to `batch` by repeating the final sample (the padded
/// logits are discarded by the caller). Returns the padded buffer.
pub fn pad_batch(input: &[f32], n: usize, batch: usize, sample_elems: usize) -> Vec<f32> {
    assert_eq!(input.len(), n * sample_elems, "input length mismatch");
    assert!(n >= 1 && n <= batch, "cannot pad {n} samples to batch {batch}");
    let mut out = Vec::with_capacity(batch * sample_elems);
    out.extend_from_slice(input);
    let last = &input[(n - 1) * sample_elems..];
    for _ in n..batch {
        out.extend_from_slice(last);
    }
    out
}

/// Argmax over each row of a `(batch, classes)` logits buffer.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_repeats_last_sample() {
        let input = vec![1.0, 2.0, 3.0, 4.0]; // 2 samples x 2 elems
        let padded = pad_batch(&input, 2, 4, 2);
        assert_eq!(padded, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn pad_batch_noop_when_full() {
        let input = vec![1.0, 2.0];
        assert_eq!(pad_batch(&input, 1, 1, 2), input);
    }

    #[test]
    #[should_panic(expected = "cannot pad")]
    fn pad_batch_rejects_overfull() {
        pad_batch(&[1.0, 2.0, 3.0], 3, 2, 1);
    }

    #[test]
    fn argmax_rows_basic() {
        let logits = vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    // End-to-end PJRT tests (compile + execute real artifacts) live in
    // rust/tests/runtime_e2e.rs — they need `make artifacts` output and a
    // build with `--features pjrt`.
}
