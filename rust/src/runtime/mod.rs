//! Serving runtime — loads and executes the AOT artifacts from the request
//! path (Python never runs at serve time).
//!
//! `python/compile/aot.py` lowers every (precision config, batch) serving
//! graph to HLO **text** once at build time; [`Runtime::load`] compiles all
//! of them onto the PJRT CPU client, and [`Runtime::infer`] executes one.
//! Text (not serialized `HloModuleProto`) is the interchange format — the
//! `xla` crate's backend (xla_extension 0.5.1) rejects jax ≥ 0.5's
//! 64-bit-id protos, while the text parser reassigns ids.
//!
//! The PJRT backend requires the `xla` crate, which the offline vendor set
//! does not carry, so it is gated behind the `pjrt` cargo feature. The
//! default build substitutes the stub [`Runtime`] — the identical API, erroring
//! at artifact-load time — so the coordinator, benches, and examples
//! compile and cleanly report the missing backend.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

pub use manifest::{ArtifactEntry, ConfigInfo, Manifest};

/// Pad `n` samples up to `batch` by repeating the final sample (the padded
/// logits are discarded by the caller). Returns the padded buffer.
pub fn pad_batch(input: &[f32], n: usize, batch: usize, sample_elems: usize) -> Vec<f32> {
    assert_eq!(input.len(), n * sample_elems, "input length mismatch");
    assert!(n >= 1 && n <= batch, "cannot pad {n} samples to batch {batch}");
    let mut out = Vec::with_capacity(batch * sample_elems);
    out.extend_from_slice(input);
    let last = &input[(n - 1) * sample_elems..];
    for _ in n..batch {
        out.extend_from_slice(last);
    }
    out
}

/// Argmax over each row of a `(batch, classes)` logits buffer.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_repeats_last_sample() {
        let input = vec![1.0, 2.0, 3.0, 4.0]; // 2 samples x 2 elems
        let padded = pad_batch(&input, 2, 4, 2);
        assert_eq!(padded, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn pad_batch_noop_when_full() {
        let input = vec![1.0, 2.0];
        assert_eq!(pad_batch(&input, 1, 1, 2), input);
    }

    #[test]
    #[should_panic(expected = "cannot pad")]
    fn pad_batch_rejects_overfull() {
        pad_batch(&[1.0, 2.0, 3.0], 3, 2, 1);
    }

    #[test]
    fn argmax_rows_basic() {
        let logits = vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    // End-to-end PJRT tests (compile + execute real artifacts) live in
    // rust/tests/runtime_e2e.rs — they need `make artifacts` output and a
    // build with `--features pjrt`.
}
