//! The real PJRT-backed runtime (requires the `pjrt` cargo feature and a
//! vendored `xla` crate — see the module docs of [`super`]).

use std::collections::HashMap;
use std::path::Path;

use super::manifest::{ArtifactEntry, Manifest};
use crate::util::error::{anyhow, Context, Result};

/// A compiled serving executable for one (config, batch) pair.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
}

/// The serve-time runtime: a PJRT CPU client plus every compiled artifact.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<(String, u64), Compiled>,
}

impl Runtime {
    /// Load `<dir>/manifest.json` and compile every artifact it lists.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(manifest)
    }

    /// Compile every artifact of an already-parsed manifest.
    pub fn from_manifest(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let mut compiled = HashMap::new();
        for entry in &manifest.artifacts {
            let path = manifest.dir.join(&entry.file);
            let exe = Self::compile_file(&client, &path)
                .with_context(|| format!("compiling {}", path.display()))?;
            compiled.insert((entry.config.clone(), entry.batch), Compiled {
                exe,
                entry: entry.clone(),
            });
        }
        Ok(Runtime { client, manifest, compiled })
    }

    /// Load + compile only the artifacts for the given config names (used
    /// by tests and latency-sensitive startups).
    pub fn load_configs(dir: &Path, configs: &[&str]) -> Result<Runtime> {
        let mut manifest = Manifest::load(dir)?;
        manifest.artifacts.retain(|a| configs.contains(&a.config.as_str()));
        Self::from_manifest(manifest)
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow!("pjrt compile: {e}"))
    }

    /// The manifest this runtime serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compiled (config, batch) pairs.
    pub fn compiled_keys(&self) -> Vec<(String, u64)> {
        let mut keys: Vec<_> = self.compiled.keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Execute one inference: `input` is a row-major `f32` batch of shape
    /// `(batch, H, W, C)`; returns the `(batch, num_classes)` logits.
    ///
    /// `input.len()` must equal `batch * H * W * C` for the *compiled*
    /// batch size — use [`Manifest::batch_for`] + [`super::pad_batch`] to
    /// fit a partial batch.
    pub fn infer(&self, config: &str, batch: u64, input: &[f32]) -> Result<Vec<f32>> {
        let compiled = self
            .compiled
            .get(&(config.to_string(), batch))
            .ok_or_else(|| anyhow!("no compiled artifact for ({config}, batch {batch})"))?;
        let want = batch as usize * self.manifest.sample_elems();
        if input.len() != want {
            return Err(anyhow!(
                "input has {} elements, executable expects {want}",
                input.len()
            ));
        }
        let (h, w, c) = self.manifest.input_shape;
        let lit = xla::Literal::vec1(input)
            .reshape(&[batch as i64, h as i64, w as i64, c as i64])
            .map_err(|e| anyhow!("reshape input: {e}"))?;
        let result = compiled
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("pjrt execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("unwrap tuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("read logits: {e}"))
    }

    /// Accuracy recorded at export time for a config.
    pub fn accuracy(&self, config: &str) -> Option<f64> {
        self.manifest.accuracies.get(config).copied()
    }

    /// The artifact entry behind a compiled pair.
    pub fn entry(&self, config: &str, batch: u64) -> Option<&ArtifactEntry> {
        self.compiled.get(&(config.to_string(), batch)).map(|c| &c.entry)
    }
}
