//! `bf-imna` — command-line front end for the BF-IMNA simulator, the
//! sharded sweep service, and the bit-fluid serving coordinator.
//!
//! ```text
//! bf-imna simulate --net vgg16 --bits 8 [--hw lr|ir] [--tech sram|reram|pcm|fefet]
//!                  [--breakdown]                      # one point + Fig. 8 shares
//! bf-imna sweep    --net alexnet [--hw lr|ir]         # Fig. 7 series (table)
//! bf-imna sweep    --net alexnet --out full.json      # same sweep as JSON
//! bf-imna sweep    --shards 4 --shard-id 0 --out s0.json   # one sweep-service shard
//! bf-imna sweep    --artifact fig6 --shards 2 --shard-id 0 --out s0.json
//! bf-imna merge    s0.json s1.json s2.json s3.json --out full.json
//! bf-imna serve-worker --addr 127.0.0.1:8377          # HTTP sweep worker
//! bf-imna fleet    --addr 127.0.0.1:8376              # worker-fleet controller
//! bf-imna serve-worker --fleet 127.0.0.1:8376         # worker + heartbeats
//! bf-imna dispatch --workers a:8377,b:8377 --out full.json  # fan out + merge
//! bf-imna dispatch --fleet 127.0.0.1:8376 --out full.json   # elastic fan out
//! bf-imna sweep    --net alexnet --store results/ --out full.json  # replay cached points
//! bf-imna artifacts                                   # list the paper-artifact catalog
//! bf-imna render   --artifact fig7 --doc full.json    # document -> figure/table text
//! bf-imna render   --artifact fig7 --doc full.json --csv fig7.csv  # + plottable CSV
//! bf-imna hawq                                        # Table VII (table7 artifact)
//! bf-imna compare                                     # Table VIII (table8 artifact)
//! bf-imna validate                                    # Table I (table1 artifact)
//! bf-imna costs    --list                             # cost-table presets + versions
//! bf-imna costs    --show jia-65nm --out jia.json     # canonical table JSON
//! bf-imna sweep    --net alexnet --costs jia-65nm     # what-if sweep under a preset
//! bf-imna calibrate --out fitted.json                 # fit cycles to measured latency
//! bf-imna serve    --addr 127.0.0.1:8378              # HTTP serving front end
//! bf-imna serve    --requests 32                      # local serving demo
//! bf-imna infer    --addr 127.0.0.1:8378 --deadline-ms 5   # serving client
//! bf-imna loadgen  --addr 127.0.0.1:8378 --rps 200 --duration-s 10  # open-loop load + SLO report
//! ```
//!
//! The sharded form is the scale-out path: every shard is an independent
//! process (no coordination), and `merge` reassembles a byte-identical
//! copy of the single-process sweep document. Every paper artifact is a
//! named `SweepSpec` in the catalog (`sim::artifacts`), so any figure or
//! table can be produced locally, via `sweep`/`merge` shards, or via
//! `dispatch` on a worker fleet — and renders byte-identically from all
//! three. See `sim::shard` and `sim::artifacts`.
//!
//! (Hand-rolled argument parsing — the offline vendor set has no `clap`.)

use std::collections::BTreeMap;
use std::process::ExitCode;
use std::time::Duration;

use bf_imna::coordinator::loadgen;
use bf_imna::costs;
use bf_imna::coordinator::server::{self as serving, InferRequest};
use bf_imna::coordinator::{
    Budget, BudgetSpec, Coordinator, CoordinatorConfig, Priority, RequestSpec, ServingServer,
};
use bf_imna::mapper::CacheSnapshot;
use bf_imna::precision::PrecisionConfig;
use bf_imna::sim::fleet;
use bf_imna::sim::shard::{self, SweepSpec};
use bf_imna::sim::store::{self, ResultStore};
use bf_imna::sim::transport;
use bf_imna::sim::{artifacts, breakdown, dse, simulate, SimParams, SweepEngine};
use bf_imna::util::json::Json;
use bf_imna::util::table::{fmt_eng, Table};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let (opts, files) = parse_opts(&args[args.len().min(1)..]);
    let result = match cmd {
        "simulate" => cmd_simulate(&opts),
        "sweep" => cmd_sweep(&opts),
        "merge" => cmd_merge(&opts, &files),
        "serve-worker" => cmd_serve_worker(&opts),
        "fleet" => cmd_fleet(&opts),
        "dispatch" => cmd_dispatch(&opts),
        "artifacts" => cmd_artifacts(&opts),
        "render" => cmd_render(&opts),
        "hawq" => cmd_hawq(),
        "compare" => cmd_compare(),
        "validate" => cmd_validate(),
        "costs" => cmd_costs(&opts),
        "calibrate" => cmd_calibrate(&opts),
        "serve" => cmd_serve(&opts),
        "infer" => cmd_infer(&opts),
        "loadgen" => cmd_loadgen(&opts),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n\n{HELP}").into()),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const HELP: &str = "\
bf-imna — bit-fluid in-memory neural architecture (paper reproduction)

USAGE: bf-imna <command> [--key value ...] [FILE ...]

COMMANDS:
  simulate   end-to-end inference metrics for one network/config
             --net alexnet|vgg16|resnet18|resnet50|serve_cnn  (default vgg16)
             --bits N (fixed precision, default 8)   --hw lr|ir (default lr)
             --tech sram|reram|pcm|fefet (default sram)
             --breakdown (also print the Fig. 8 energy/latency shares)
  sweep      Fig. 7 mixed-precision DSE sweep / sweep-service shard runner
             --net ... (default alexnet)   --hw lr|ir (default lr)
             table mode (default): render the per-average-precision series
             through the catalog's fig7 renderer
             JSON / sweep-service mode (any of the flags below):
             --out FILE        write the sweep document (default: stdout)
             --spec FILE       run an explicit sweep-spec JSON
             --artifact NAME   run a catalog artifact's spec (see `artifacts`)
             --tiny            with --artifact: use the shrunk smoke grid
             --shards N        split the sweep into N contiguous shards
             --shard-id K      run shard K in 0..N (default 0)
             --tech sram|reram|pcm|fefet (default sram)
             --costs NAME|FILE run the sweep under a non-default cost
                               table: a preset (see `costs --list`) or a
                               table JSON file; the table name becomes a
                               point coordinate echoed in every record
             --combos N        mixed combos per avg-precision target (default 5)
             --seed N          combination-generator seed (default 7)
             --cache-in FILE   absorb a plan-cache snapshot before running
             --cache-out FILE  write this run's plan-cache snapshot
             --store DIR       persistent result store: replay every point
                               already in DIR, compute + save only the
                               novel ones (full sweeps only, not shards;
                               overlapping specs share stored points)
  merge      reassemble shard documents into the full sweep document
             bf-imna merge s0.json .. sN.json [--out FILE]
             output is byte-identical to the unsharded `sweep --out`
  serve-worker  run an HTTP sweep worker (the network side of the sweep
             service; see `dispatch` for the coordinator)
             --addr HOST:PORT  listen address (default 127.0.0.1:8377;
                               port 0 picks an ephemeral port)
             --cache-in FILE   absorb a plan-cache snapshot at startup
             --max-shards N    shard requests computing at once (default 2)
             --queue-depth N   admission queue before 503 worker-busy
                               replies (default 4; dispatch retries busy
                               workers elsewhere without retiring them)
             --idle-timeout-s N  close keep-alive connections idle for N
                               seconds (default 60)
             --conn-requests N  requests served per connection before a
                               clean connection: close (default 1024)
             --worker-threads N  pooled connection-handler threads reused
                               across keep-alive connections (default 64;
                               0 = spawn one thread per connection)
             --fleet HOST:PORT  register with a `fleet` controller and
                               heartbeat the worker's address + live
                               stats every --heartbeat-s seconds
             --advertise ADDR  address to register with the controller
                               (default: the bound listen address)
             --heartbeat-s F   heartbeat period in seconds (default 1)
             endpoints: POST /shard  run one fixed shard of a partition
                        POST /slice  run an arbitrary contiguous point
                               range (the elastic dispatcher's unit)
                        POST /cache  absorb a shipped plan-cache snapshot
                        GET /healthz, GET /stats  liveness + cache counters
             connections are keep-alive: many framed requests per socket
  fleet      worker-fleet controller: the registry `dispatch --fleet`
             polls for the live worker set
             --addr HOST:PORT  listen address (default 127.0.0.1:8376;
                               port 0 picks an ephemeral port)
             --expiry-s F      drop workers from the listing this many
                               seconds after their last heartbeat
                               (default 5; entries reappear when their
                               heartbeats resume)
             endpoints: POST /register  worker registration/heartbeat
                               (fingerprint-checked at the door)
                        GET /workers  live worker listing with ages and
                               per-worker stats documents
                        GET /healthz  liveness
  dispatch   fan a sweep out over serve-worker processes and merge
             --workers a:p1,b:p2  comma-separated worker addresses (required)
             --spec FILE       sweep-spec JSON; --artifact NAME [--tiny]
                               uses a catalog artifact's spec; when both
                               are absent the spec is built from
                               --net/--hw/--tech/--combos/--seed exactly
                               like `sweep`; --costs NAME|FILE swaps the
                               cost table exactly like `sweep`
             --shards N        shard count (default: one per worker)
             --timeout-s N     per-request timeout in seconds (default 120)
             --cache-in FILE   ship a plan-cache snapshot to every worker
             --pool N          idle pooled connections kept per worker
                               (default 2; shard requests reuse sockets)
             --out FILE        write the merged document (default: stdout)
             failed/slow workers are retried on healthy ones; refused
             prewarm connects are retried with short backoff (workers
             still binding at fleet start stay in the pool); the merged
             output is byte-identical to the unsharded `sweep --out`
             elastic mode (--fleet and/or --store): workers come from the
             fleet controller instead of a fixed list — late joiners are
             admitted mid-sweep, dead workers pause and resume with their
             heartbeats, and per-worker slice sizes adapt to observed
             latency; stored points replay without touching the network
             --fleet HOST:PORT  poll this `fleet` controller for the live
                               worker set (instead of --workers)
             --store DIR       persistent result store shared with
                               `sweep --store`: replay stored points,
                               save the newly computed ones
             --max-slice N     largest point range handed to the fastest
                               worker (default 8; slower workers get
                               proportionally smaller slices)
             --grace-s N       abort after N seconds with work left but
                               no live worker making progress (default 60)
  artifacts  list the paper-artifact catalog (one SweepSpec + renderer per
             figure/table of the paper)
             --names           print bare artifact names, one per line
             --spec NAME       print artifact NAME's sweep-spec JSON
             --tiny            with --spec: shrink to the CI smoke grid
             --out FILE        write instead of stdout
  render     render a paper artifact from a merged sweep document
             --artifact NAME   which artifact to render (required)
             --doc FILE        merged document from sweep/merge/dispatch;
                               when absent the spec runs in-process first
             --tiny            with no --doc: run the shrunk smoke grid
             --out FILE        write the rendered text (default: stdout)
             --csv FILE        also write the artifact's plottable CSV
                               (one row per sweep point, exact canonical
                               floats — what CI uploads next to the text)
             output is byte-identical across in-process, sharded, and
             dispatched documents of the same spec
  hawq       Table VII — HAWQ-V3 bit-fluid ResNet18 (the table7 artifact)
  compare    Table VIII — BF-IMNA peak rows vs SOTA (the table8 artifact)
  validate   Table I microbenchmark — emulator vs models (the table1 artifact)
  costs      the versioned AP cost-table presets (the `--costs` vocabulary)
             --list            table of presets: name, cost_version, cells
                               (the default mode)
             --show NAME|FILE  print a table's canonical JSON (a preset
                               name or a table JSON file to validate)
             --out FILE        with --show: write instead of stdout
  calibrate  least-squares fit of the SRAM cycle coefficients against the
             sim backend's measured serve-CNN latencies; prints the
             measured-vs-modeled residual report (also a catalog artifact:
             `render --artifact calibration`)
             --out FILE        write the fitted, versioned cost-table JSON
                               (loadable via `--costs FILE`)
  serve      bit-fluid serving coordinator: HTTP front end or local demo
             server mode (default): listen and serve inference requests
             --addr HOST:PORT  listen address (default 127.0.0.1:8378;
                               port 0 picks an ephemeral port)
             demo mode: --requests N  submit N mixed-budget requests
                               locally and print the serving table
             backend: the sim backend by default (ap/mapper/sim latency
             models + deterministic stand-in numerics — no artifacts
             needed); --artifacts DIR loads AOT artifacts instead
             (requires a --features pjrt build)
             --time-scale F    pace sim-backend executions at F x the
                               modeled latency (default 0 = no pacing)
             --fleet-priors HOST:PORT  seed the precision controller's
                               latency priors from a `fleet` controller's
                               GET /workers listing: live workers' per-
                               config execute-latency stats become the
                               prior scales (full-ladder coverage
                               required; falls back to the simulator
                               priors otherwise)
             --fleet HOST:PORT  register this serving front end with a
                               `fleet` controller and heartbeat its
                               address + live metrics document (including
                               the per-config execute stats that
                               --fleet-priors harvests)
             --advertise H:P   address to register with --fleet (default:
                               the bound listen address)
             --heartbeat-s F   heartbeat period in seconds (default 1)
             --max-requests N  concurrent-connection budget (default 256;
                               over-budget connections get 503 server-busy)
             --idle-timeout-s N  close keep-alive connections idle for N
                               seconds (default 60)
             --conn-requests N  requests served per connection before a
                               clean connection: close (default 1024)
             --serve-threads N  pooled connection-handler threads reused
                               across keep-alive connections (default 256;
                               0 = spawn one thread per connection)
             endpoints: POST /infer   one request (single-sample 'input'
                               or multi-sample 'inputs' with per-sample
                               verdicts under 'results')
                        GET /healthz  model contract (elems, classes, ladder)
                        GET /stats    serving metrics document (p50/p99/
                               p999 latency, met-deadline rate, ...)
                        GET /metrics  observability document: log-bucketed
                               latency histograms, per-class met-deadline
                               rates, queue depth, connection/admission
                               counters (what `loadgen` joins against)
             connections are keep-alive: many framed requests per socket
  infer      serving client for `serve`'s HTTP front end
             --addr HOST:PORT  server address (default 127.0.0.1:8378)
             --requests N      how many requests to send (default 1; one
                               fresh connection per request)
             --count N         send N requests over one pooled keep-alive
                               connection, printing per-request verdicts
                               and aggregate req/s
             --batch N         pack N samples into each framed request
                               (multi-sample POST /infer, per-sample
                               verdicts; combines with --count)
             --budget low|medium|high  class budget (default high)
             --deadline-ms F   explicit per-request deadline instead of a
                               class (mutually exclusive with --budget)
             --priority low|normal|high  scheduling priority
             --batch-hint N    largest compiled batch to ride in
             --seed N          deterministic input generator seed (default 1)
             --timeout-s N     per-request HTTP timeout (default 60)
             --stats           fetch and print GET /stats instead of
                               sending requests
  loadgen    open-loop load driver for `serve`'s HTTP front end
             plays a deterministic seeded workload at its scheduled
             arrival times (open loop: never paced by responses) and
             joins the client-side record with the server's
             GET /metrics deltas into an SLO report
             --addr HOST:PORT  server address (default 127.0.0.1:8378)
             --profile constant|diurnal|burst  built-in profile shape
                               (default constant; diurnal sweeps one
                               cosine cycle over the run, burst is
                               0.5 s on / 0.5 s off)
             --rps F           offered arrival rate (default 50)
             --duration-s F    run length in seconds (default 5)
             --seed N          workload seed — same spec + seed means a
                               byte-identical request plan (default 1)
             --spec FILE       explicit WorkloadSpec JSON (overrides
                               --profile/--rps/--duration-s/--seed)
             --workers N       sender threads bounding in-flight
                               requests (default: the machine's
                               available parallelism)
             --timeout-s N     per-request HTTP timeout (default 30)
             --out FILE        write the SLO report JSON (default:
                               print it to stdout)
";

type CliResult = Result<(), Box<dyn std::error::Error>>;

/// Split CLI arguments into `--key value` / `--flag` options and
/// positional arguments (e.g. `merge`'s shard files).
fn parse_opts(args: &[String]) -> (BTreeMap<String, String>, Vec<String>) {
    let mut map = BTreeMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).filter(|v| !v.starts_with("--"));
            match val {
                Some(v) => {
                    map.insert(key.to_string(), v.clone());
                    i += 2;
                }
                None => {
                    map.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (map, positional)
}

fn cmd_simulate(opts: &BTreeMap<String, String>) -> CliResult {
    let net = shard::net_by_name(opts.get("net").map(String::as_str).unwrap_or("vgg16"))?;
    let bits: u32 = opts.get("bits").map(String::as_str).unwrap_or("8").parse()?;
    let hw = shard::hw_by_name(opts.get("hw").map(String::as_str).unwrap_or("lr"))?;
    let tech = shard::tech_by_name(opts.get("tech").map(String::as_str).unwrap_or("sram"))?;
    let cfg = PrecisionConfig::fixed(bits, net.weight_layers());
    let r = simulate(&net, &cfg, &SimParams::new(hw, tech));
    println!(
        "{} | {} | {} | {} | batch 1",
        r.net_name,
        r.cfg_name,
        r.hw.label(),
        r.tech.cell.label()
    );
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["MACs".to_string(), format!("{:.2} G", r.macs as f64 / 1e9)]);
    t.row(vec!["latency / inference".to_string(), format!("{} s", fmt_eng(r.latency_s(), 3))]);
    t.row(vec!["energy / inference".to_string(), format!("{} J", fmt_eng(r.energy_j(), 3))]);
    t.row(vec!["EDP".to_string(), format!("{} J.s", fmt_eng(r.edp_js(), 3))]);
    t.row(vec!["die area".to_string(), format!("{:.2} mm2", r.area_mm2)]);
    t.row(vec!["throughput".to_string(), format!("{} GOPS", fmt_eng(r.gops(), 3))]);
    t.row(vec!["energy efficiency".to_string(), format!("{} GOPS/W", fmt_eng(r.gops_per_w(), 3))]);
    t.row(vec![
        "energy-area efficiency".to_string(),
        format!("{} GOPS/W/mm2", fmt_eng(r.gops_per_w_mm2(), 3)),
    ]);
    t.row(vec!["max time-folding".to_string(), format!("{}x", r.max_steps())]);
    print!("{}", t.render());

    if opts.contains_key("breakdown") {
        println!("\nenergy by kind (Fig. 8a):");
        let mut t = Table::new(vec!["category", "J", "share"]);
        for s in breakdown::energy_by_kind(&r) {
            t.row(vec![s.label, format!("{}", fmt_eng(s.value, 3)), format!("{:.1}%", 100.0 * s.fraction)]);
        }
        print!("{}", t.render());
        println!("\nGEMM latency by phase (Fig. 8b):");
        let mut t = Table::new(vec!["phase", "s", "share"]);
        for s in breakdown::gemm_latency_by_phase(&r) {
            t.row(vec![s.label, format!("{}", fmt_eng(s.value, 3)), format!("{:.1}%", 100.0 * s.fraction)]);
        }
        print!("{}", t.render());
    }
    Ok(())
}

fn cmd_sweep(opts: &BTreeMap<String, String>) -> CliResult {
    // Any sweep-service flag (as listed in HELP) switches to JSON mode;
    // plain `sweep --net X --hw Y` keeps the Fig. 7 table.
    let service_mode = [
        "out", "spec", "artifact", "tiny", "shards", "shard-id", "tech", "combos", "seed",
        "cache-in", "cache-out", "store", "costs",
    ]
    .iter()
    .any(|k| opts.contains_key(*k));
    if !service_mode {
        // Table mode: the same spec -> run -> render path as everything
        // else — the series table comes from the catalog's fig7 renderer,
        // not a second in-process derivation.
        let net_name = opts.get("net").map(String::as_str).unwrap_or("alexnet");
        let hw_name = opts.get("hw").map(String::as_str).unwrap_or("lr");
        let spec = SweepSpec::fig7(net_name, hw_name, dse::COMBOS_PER_TARGET, 7);
        let resolved = spec.resolve()?;
        let result = shard::run_shard(&spec, 1, 0, &SweepEngine::new())?;
        print!("{}", artifacts::render_fig7(&spec, &resolved, &result.points)?);
        return Ok(());
    }

    // Sweep-service mode: run the (possibly sharded) sweep, emit JSON.
    let shards: usize = match opts.get("shards") {
        Some(s) => s.parse()?,
        None => 1,
    };
    let shard_id: usize = match opts.get("shard-id") {
        Some(s) => s.parse()?,
        None => 0,
    };
    // Shard/spec validation happens inside `run_shard_prewarmed` below.
    let spec = spec_from_opts(opts)?;

    let engine = SweepEngine::new();
    if let Some(path) = opts.get("cache-in") {
        let snap = load_snapshot(path)?;
        let loaded = engine.cache().absorb(&snap);
        eprintln!("cache-in: absorbed {loaded} plans from {path}");
    }
    let sharded = opts.contains_key("shards") || opts.contains_key("shard-id");
    let (doc, n_points) = match opts.get("store") {
        Some(dir) => {
            if sharded {
                return Err("sweep: --store applies to full sweeps only — shard documents \
                            are partial; use `dispatch --store` to distribute a stored sweep"
                    .into());
            }
            let result_store = ResultStore::open(dir.as_str())?;
            let outcome = store::run_full_stored(&spec, &engine, &result_store)?;
            eprintln!(
                "sweep: {} computed, {} replayed (store {dir})",
                outcome.computed, outcome.replayed
            );
            let n = outcome.computed + outcome.replayed;
            (outcome.doc, n)
        }
        None => {
            // The prewarmed runner batch-prewarms this shard's slice so
            // the parallel run never maps cold (see `sim::shard`).
            let result = shard::run_shard_prewarmed(&spec, shards, shard_id, &engine)?;
            let n = result.points.len();
            let doc =
                if sharded { result.to_json() } else { shard::full_doc(&spec, &result.points) };
            (doc, n)
        }
    };
    if let Some(path) = opts.get("cache-out") {
        let snap = engine.cache().snapshot();
        std::fs::write(path, format!("{}\n", snap.to_json())).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("cache-out: wrote {} plans to {path}", snap.len());
    }
    match opts.get("out") {
        Some(path) => {
            std::fs::write(path, format!("{doc}\n")).map_err(|e| format!("{path}: {e}"))?;
            if sharded {
                eprintln!("wrote shard {shard_id}/{shards} ({n_points} points) to {path}");
            } else {
                eprintln!("wrote {n_points} points to {path}");
            }
        }
        None => println!("{doc}"),
    }
    Ok(())
}

/// Resolve the sweep spec `sweep`'s service mode and `dispatch` share:
/// a catalog artifact (`--artifact NAME [--tiny]`), an explicit spec file
/// (`--spec FILE`), or the Fig. 7 shape built from the common flags
/// (`--net/--hw/--tech/--combos/--seed`). One code path, so the commands'
/// documents stay byte-comparable by construction. `--costs NAME|FILE`
/// swaps the cost table on whichever spec was picked.
fn spec_from_opts(
    opts: &BTreeMap<String, String>,
) -> Result<SweepSpec, Box<dyn std::error::Error>> {
    let mut spec = if let Some(name) = opts.get("artifact") {
        let artifact = artifacts::by_name(name)?;
        if opts.contains_key("tiny") {
            artifact.tiny_spec()
        } else {
            artifact.spec()
        }
    } else if let Some(path) = opts.get("spec") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        SweepSpec::from_json(&Json::parse(&text).map_err(|e| format!("{path}: {e}"))?)?
    } else {
        let net = opts.get("net").map(String::as_str).unwrap_or("alexnet");
        let hw = opts.get("hw").map(String::as_str).unwrap_or("lr");
        let combos: usize = match opts.get("combos") {
            Some(s) => s.parse()?,
            None => dse::COMBOS_PER_TARGET,
        };
        let seed: u64 = match opts.get("seed") {
            Some(s) => s.parse()?,
            None => 7,
        };
        let mut spec = SweepSpec::fig7(net, hw, combos, seed);
        spec.tech = vec![opts.get("tech").cloned().unwrap_or_else(|| "sram".to_string())];
        spec
    };
    if let Some(arg) = opts.get("costs") {
        spec.costs = vec![costs::load(arg)?];
    }
    Ok(spec)
}

/// Read + parse a `CacheSnapshot` file (shared by `sweep --cache-in`,
/// `serve-worker --cache-in`, and `dispatch --cache-in`).
fn load_snapshot(path: &str) -> Result<CacheSnapshot, Box<dyn std::error::Error>> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    Ok(CacheSnapshot::from_json(&Json::parse(&text).map_err(|e| format!("{path}: {e}"))?)?)
}

fn cmd_serve_worker(opts: &BTreeMap<String, String>) -> CliResult {
    let addr = opts.get("addr").map(String::as_str).unwrap_or("127.0.0.1:8377");
    let engine = SweepEngine::new();
    if let Some(path) = opts.get("cache-in") {
        let snap = load_snapshot(path)?;
        let loaded = engine.cache().absorb(&snap);
        eprintln!("cache-in: absorbed {loaded} plans from {path}");
    }
    let mut wopts = transport::WorkerOpts::default();
    if let Some(s) = opts.get("max-shards") {
        wopts.max_concurrent_shards = s.parse::<usize>()?.max(1);
    }
    if let Some(s) = opts.get("queue-depth") {
        wopts.admission_queue = s.parse()?;
    }
    if let Some(s) = opts.get("idle-timeout-s") {
        wopts.idle_timeout = Duration::from_secs(s.parse()?);
    }
    if let Some(s) = opts.get("conn-requests") {
        wopts.max_requests_per_conn = s.parse::<usize>()?.max(1);
    }
    if let Some(s) = opts.get("worker-threads") {
        wopts.worker_threads = s.parse()?;
    }
    let server = transport::WorkerServer::spawn_with(addr, engine, wopts)
        .map_err(|e| format!("{addr}: {e}"))?;
    eprintln!(
        "serve-worker: listening on http://{} (POST /shard, POST /slice, POST /cache, \
         GET /healthz, GET /stats)",
        server.addr()
    );
    // With --fleet, a background thread re-registers the worker (address,
    // fingerprint, live stats) with the controller every period, which is
    // how `dispatch --fleet` finds it — and re-finds it after a pause.
    let _heartbeat = match opts.get("fleet") {
        Some(fleet_addr) => {
            let advertise =
                opts.get("advertise").cloned().unwrap_or_else(|| server.addr().to_string());
            let period = match opts.get("heartbeat-s") {
                Some(s) => {
                    let secs: f64 = s.parse()?;
                    if !(secs.is_finite() && secs > 0.0) {
                        return Err("serve-worker: --heartbeat-s must be > 0".into());
                    }
                    Duration::from_secs_f64(secs)
                }
                None => Duration::from_secs(1),
            };
            eprintln!(
                "serve-worker: heartbeating to http://{fleet_addr} as {advertise} every {} s",
                period.as_secs_f64()
            );
            Some(fleet::spawn_heartbeat(fleet_addr, &advertise, server.stats_handle(), period))
        }
        None => None,
    };
    // Serve until killed; `dispatch` is the other end.
    server.join();
    Ok(())
}

fn cmd_fleet(opts: &BTreeMap<String, String>) -> CliResult {
    let addr = opts.get("addr").map(String::as_str).unwrap_or("127.0.0.1:8376");
    let mut fopts = fleet::FleetOpts::default();
    if let Some(s) = opts.get("expiry-s") {
        let secs: f64 = s.parse()?;
        if !(secs.is_finite() && secs > 0.0) {
            return Err("fleet: --expiry-s must be > 0".into());
        }
        fopts.expiry = Duration::from_secs_f64(secs);
    }
    let server =
        fleet::FleetServer::spawn_with(addr, fopts).map_err(|e| format!("{addr}: {e}"))?;
    eprintln!(
        "fleet: listening on http://{} (POST /register, GET /workers, GET /healthz; \
         workers expire {} s after their last heartbeat)",
        server.addr(),
        fopts.expiry.as_secs_f64()
    );
    // Serve until killed; workers heartbeat in, `dispatch --fleet` polls.
    server.join();
    Ok(())
}

fn cmd_dispatch(opts: &BTreeMap<String, String>) -> CliResult {
    // --fleet and/or --store switch to the elastic dispatcher; a plain
    // --workers list keeps the fixed-partition legacy path (whose output
    // is byte-identical anyway).
    if opts.contains_key("fleet") || opts.contains_key("store") {
        return cmd_dispatch_elastic(opts);
    }
    let workers: Vec<String> = opts
        .get("workers")
        .ok_or("dispatch: --workers host:port[,host:port...] is required")?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if workers.is_empty() {
        return Err("dispatch: --workers list is empty".into());
    }
    let spec = spec_from_opts(opts)?;
    let mut dopts = transport::DispatchOpts::default();
    if let Some(s) = opts.get("shards") {
        dopts.shards = s.parse()?;
    }
    if let Some(s) = opts.get("timeout-s") {
        dopts.timeout = std::time::Duration::from_secs(s.parse()?);
    }
    if let Some(path) = opts.get("cache-in") {
        dopts.prewarm = Some(load_snapshot(path)?);
    }
    if let Some(s) = opts.get("pool") {
        dopts.pool_conns = s.parse::<usize>()?.max(1);
    }
    let report = transport::dispatch(&spec, &workers, &dopts)?;
    for (w, served) in &report.per_worker {
        eprintln!("dispatch: {w} served {served} shard(s)");
    }
    if report.retries > 0 {
        eprintln!("dispatch: {} failed shard request(s) were reassigned", report.retries);
    }
    if report.busy_retries > 0 {
        eprintln!(
            "dispatch: {} worker-busy bounce(s) were re-queued (backpressure, not failures)",
            report.busy_retries
        );
    }
    let n = report.doc.get("n_points").and_then(Json::as_i64).unwrap_or(0);
    match opts.get("out") {
        Some(path) => {
            std::fs::write(path, format!("{}\n", report.doc)).map_err(|e| format!("{path}: {e}"))?;
            eprintln!("dispatch: merged {n} points into {path}");
        }
        None => println!("{}", report.doc),
    }
    Ok(())
}

/// The elastic path of `dispatch`: workers come from a fleet controller
/// (`--fleet`) or a static list, slices are sized per worker from
/// observed latency, and a `--store` directory replays already-computed
/// points before any network traffic.
fn cmd_dispatch_elastic(opts: &BTreeMap<String, String>) -> CliResult {
    let source = match (opts.get("fleet"), opts.get("workers")) {
        (Some(_), Some(_)) => {
            return Err("dispatch: give either --fleet or --workers, not both".into())
        }
        (Some(addr), None) => fleet::WorkerSource::Fleet(addr.clone()),
        (None, Some(list)) => {
            let workers: Vec<String> = list
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if workers.is_empty() {
                return Err("dispatch: --workers list is empty".into());
            }
            fleet::WorkerSource::Static(workers)
        }
        (None, None) => {
            // --store alone still works: a fully stored spec replays
            // without any worker at all.
            fleet::WorkerSource::Static(Vec::new())
        }
    };
    let spec = spec_from_opts(opts)?;
    let mut eopts = fleet::ElasticOpts::default();
    if let Some(s) = opts.get("timeout-s") {
        eopts.timeout = Duration::from_secs(s.parse()?);
    }
    if let Some(s) = opts.get("grace-s") {
        eopts.grace = Duration::from_secs(s.parse()?);
    }
    if let Some(s) = opts.get("max-slice") {
        eopts.max_slice = s.parse::<usize>()?.max(1);
    }
    if let Some(path) = opts.get("cache-in") {
        eopts.prewarm = Some(load_snapshot(path)?);
    }
    if let Some(s) = opts.get("pool") {
        eopts.pool_conns = s.parse::<usize>()?.max(1);
    }
    if let Some(dir) = opts.get("store") {
        eopts.store = Some(ResultStore::open(dir.as_str())?);
    }
    // An empty static source is only useful when the store can replay
    // everything; dispatch_elastic errs out cleanly otherwise.
    if matches!(&source, fleet::WorkerSource::Static(ws) if ws.is_empty())
        && eopts.store.is_none()
    {
        return Err("dispatch: --fleet HOST:PORT or --workers host:port[,...] is required".into());
    }
    let report = fleet::dispatch_elastic(&spec, &source, &eopts)?;
    for (w, served) in &report.per_worker {
        eprintln!("dispatch: {w} served {served} point(s)");
    }
    if report.retries > 0 {
        eprintln!("dispatch: {} failed slice request(s) were reassigned", report.retries);
    }
    if report.busy_retries > 0 {
        eprintln!(
            "dispatch: {} worker-busy bounce(s) were re-queued (backpressure, not failures)",
            report.busy_retries
        );
    }
    eprintln!(
        "dispatch: {} computed, {} replayed",
        report.computed_points, report.replayed_points
    );
    let n = report.doc.get("n_points").and_then(Json::as_i64).unwrap_or(0);
    match opts.get("out") {
        Some(path) => {
            std::fs::write(path, format!("{}\n", report.doc)).map_err(|e| format!("{path}: {e}"))?;
            eprintln!("dispatch: merged {n} points into {path}");
        }
        None => println!("{}", report.doc),
    }
    Ok(())
}

fn cmd_merge(opts: &BTreeMap<String, String>, files: &[String]) -> CliResult {
    if files.is_empty() {
        return Err(
            "merge: no shard files given — pass the shard JSON documents as positional \
             arguments (e.g. `bf-imna merge s0.json s1.json --out full.json`)"
                .into(),
        );
    }
    let mut docs = Vec::with_capacity(files.len());
    for f in files {
        let text = std::fs::read_to_string(f).map_err(|e| format!("{f}: {e}"))?;
        docs.push(Json::parse(&text).map_err(|e| format!("{f}: {e}"))?);
    }
    let merged = shard::merge(&docs)?;
    let n = merged.get("n_points").and_then(Json::as_i64).unwrap_or(0);
    match opts.get("out") {
        Some(path) => {
            std::fs::write(path, format!("{merged}\n")).map_err(|e| format!("{path}: {e}"))?;
            eprintln!("merged {} shards ({n} points) into {path}", files.len());
        }
        None => println!("{merged}"),
    }
    Ok(())
}

fn cmd_artifacts(opts: &BTreeMap<String, String>) -> CliResult {
    if let Some(name) = opts.get("spec") {
        let artifact = artifacts::by_name(name)?;
        let spec =
            if opts.contains_key("tiny") { artifact.tiny_spec() } else { artifact.spec() };
        let text = format!("{}\n", spec.to_json());
        match opts.get("out") {
            Some(path) => {
                std::fs::write(path, &text).map_err(|e| format!("{path}: {e}"))?;
                eprintln!("artifacts: wrote {name} spec to {path}");
            }
            None => print!("{text}"),
        }
        return Ok(());
    }
    if opts.contains_key("names") {
        for artifact in artifacts::catalog() {
            println!("{}", artifact.name);
        }
        return Ok(());
    }
    println!("Paper-artifact catalog — each entry is a SweepSpec + renderer; see `render`.");
    let mut t = Table::new(vec!["artifact", "points", "description"]);
    for artifact in artifacts::catalog() {
        let points = artifact
            .spec()
            .resolve()
            .map(|r| r.num_points().to_string())
            .unwrap_or_else(|_| "?".to_string());
        t.row(vec![artifact.name.to_string(), points, artifact.title.to_string()]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_render(opts: &BTreeMap<String, String>) -> CliResult {
    let name = opts
        .get("artifact")
        .ok_or("render: --artifact NAME is required (list them with `bf-imna artifacts`)")?;
    let artifact = artifacts::by_name(name)?;
    // The CSV emitter needs the sweep *document*, not just the rendered
    // text, so with --csv both outputs derive from one document (one
    // in-process run at most).
    let (text, csv) = match opts.get("doc") {
        Some(path) => {
            let raw = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let doc = Json::parse(&raw).map_err(|e| format!("{path}: {e}"))?;
            let csv =
                if opts.contains_key("csv") { Some(artifact.csv_doc(&doc)?) } else { None };
            (artifact.render_doc(&doc)?, csv)
        }
        None if opts.contains_key("csv") => {
            let spec = if opts.contains_key("tiny") {
                artifact.tiny_spec()
            } else {
                artifact.spec()
            };
            let doc = shard::run_full(&spec, &SweepEngine::new())?;
            (artifact.render_doc(&doc)?, Some(artifact.csv_doc(&doc)?))
        }
        None => (artifact.run_and_render(&SweepEngine::new(), opts.contains_key("tiny"))?, None),
    };
    if let Some(csv) = csv {
        let path = opts.get("csv").map(String::as_str).filter(|p| *p != "true").ok_or(
            "render: --csv needs a file path (e.g. `render --artifact fig7 --csv fig7.csv`)",
        )?;
        std::fs::write(path, &csv).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("render: wrote {name} CSV to {path}");
    }
    match opts.get("out") {
        Some(path) => {
            std::fs::write(path, &text).map_err(|e| format!("{path}: {e}"))?;
            eprintln!("render: wrote {name} to {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_hawq() -> CliResult {
    print!("{}", artifacts::by_name("table7")?.run_and_render(&SweepEngine::new(), false)?);
    Ok(())
}

fn cmd_compare() -> CliResult {
    print!("{}", artifacts::by_name("table8")?.run_and_render(&SweepEngine::new(), false)?);
    Ok(())
}

fn cmd_validate() -> CliResult {
    print!("{}", artifacts::by_name("table1")?.run_and_render(&SweepEngine::new(), false)?);
    Ok(())
}

fn cmd_costs(opts: &BTreeMap<String, String>) -> CliResult {
    if let Some(arg) = opts.get("show") {
        // A preset name or a table JSON file — either way the output is
        // the canonical serialization (what `--costs FILE` reads back).
        let table = costs::load(arg)?;
        let text = format!("{}\n", table.to_json());
        match opts.get("out") {
            Some(path) => {
                std::fs::write(path, &text).map_err(|e| format!("{path}: {e}"))?;
                eprintln!(
                    "costs: wrote table '{}' (cost_version {}) to {path}",
                    table.name,
                    table.cost_version()
                );
            }
            None => print!("{text}"),
        }
        return Ok(());
    }
    // Default mode (--list): the preset catalog with versions.
    println!("Cost-table presets — swap with `--costs NAME|FILE`; export with `costs --show`.");
    let mut t = Table::new(vec!["preset", "cost_version", "cells", "note"]);
    for table in costs::presets() {
        let cells: Vec<&str> = table.rows.iter().map(|r| r.cell.label()).collect();
        let note = if table.is_default() { "the seed constants (implied everywhere)" } else { "" };
        t.row(vec![
            table.name.clone(),
            table.cost_version(),
            cells.join(","),
            note.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_calibrate(opts: &BTreeMap<String, String>) -> CliResult {
    let cal = costs::calibrate::calibrate_serve_cnn()?;
    if let Some(path) = opts.get("out") {
        std::fs::write(path, format!("{}\n", cal.table.to_json()))
            .map_err(|e| format!("{path}: {e}"))?;
        eprintln!(
            "calibrate: wrote fitted table '{}' (cost_version {}) to {path}",
            cal.table.name,
            cal.table.cost_version()
        );
    }
    print!("{}", cal.report());
    Ok(())
}

/// Start a coordinator from the shared `serve` backend flags: the sim
/// backend by default, the artifact-loading runtime when `--artifacts` is
/// given (which needs a `--features pjrt` build to actually execute).
fn start_coordinator(opts: &BTreeMap<String, String>) -> Result<Coordinator, Box<dyn std::error::Error>> {
    let mut cfg = CoordinatorConfig::default();
    // --fleet-priors: seed the precision controller from the fleet's live
    // per-config execute-latency stats (GET /workers). An empty harvest is
    // not an error — the coordinator falls back to its simulator priors.
    if let Some(addr) = opts.get("fleet-priors") {
        let doc = fleet::fetch_workers(addr, Duration::from_secs(10))?;
        cfg.fleet_prior_means = bf_imna::coordinator::fleet_prior_means(&doc);
        if cfg.fleet_prior_means.is_empty() {
            eprintln!(
                "serve: fleet {addr} carried no per-config execute stats; \
                 falling back to simulator priors"
            );
        } else {
            let configs: Vec<String> = cfg
                .fleet_prior_means
                .iter()
                .map(|(k, v)| format!("{k} {}s", fmt_eng(*v, 3)))
                .collect();
            eprintln!("serve: latency priors from fleet {addr}: {}", configs.join(", "));
        }
    }
    match opts.get("artifacts") {
        Some(dir) => Ok(Coordinator::start(std::path::Path::new(dir), cfg)?),
        None => {
            let time_scale: f64 = match opts.get("time-scale") {
                Some(s) => s.parse()?,
                None => 0.0,
            };
            Ok(Coordinator::start_sim(cfg, time_scale)?)
        }
    }
}

fn cmd_serve(opts: &BTreeMap<String, String>) -> CliResult {
    // Demo mode: submit N mixed-budget requests locally, print the table.
    if let Some(n) = opts.get("requests") {
        return serve_demo(opts, n.parse()?);
    }
    // Server mode: the coordinator on the wire.
    let addr = opts.get("addr").map(String::as_str).unwrap_or("127.0.0.1:8378");
    let coord = start_coordinator(opts)?;
    eprintln!(
        "serve: backend ready, configs [{}] (descending quality)",
        coord.configs().join(", ")
    );
    let mut sopts = serving::ServeOpts::default();
    if let Some(s) = opts.get("max-requests") {
        sopts.max_concurrent_requests = s.parse::<usize>()?.max(1);
    }
    if let Some(s) = opts.get("idle-timeout-s") {
        sopts.idle_timeout = Duration::from_secs(s.parse()?);
    }
    if let Some(s) = opts.get("conn-requests") {
        sopts.max_requests_per_conn = s.parse::<usize>()?.max(1);
    }
    if let Some(s) = opts.get("serve-threads") {
        sopts.serve_threads = s.parse()?;
    }
    // A cheap clone of the coordinator handle for the fleet heartbeat's
    // stats closure (the server consumes the original).
    let stats_coord = coord.clone();
    let server =
        ServingServer::spawn_with(addr, coord, sopts).map_err(|e| format!("{addr}: {e}"))?;
    eprintln!(
        "serve: listening on http://{} (POST /infer, GET /healthz, GET /stats, GET /metrics)",
        server.addr()
    );
    // With --fleet, register this serving front end with the controller
    // like a worker: beats carry the live metrics document (including
    // per_config_execute), which is exactly what a later
    // `serve --fleet-priors` against the same controller harvests.
    let _heartbeat = match opts.get("fleet") {
        Some(fleet_addr) => {
            let advertise =
                opts.get("advertise").cloned().unwrap_or_else(|| server.addr().to_string());
            let period = match opts.get("heartbeat-s") {
                Some(s) => {
                    let secs: f64 = s.parse()?;
                    if !(secs.is_finite() && secs > 0.0) {
                        return Err("serve: --heartbeat-s must be > 0".into());
                    }
                    Duration::from_secs_f64(secs)
                }
                None => Duration::from_secs(1),
            };
            eprintln!(
                "serve: heartbeating to http://{fleet_addr} as {advertise} every {} s",
                period.as_secs_f64()
            );
            Some(fleet::spawn_heartbeat_with(
                fleet_addr,
                &advertise,
                move || stats_coord.metrics().to_json(stats_coord.uptime_s()),
                period,
            ))
        }
        None => None,
    };
    // Serve until killed; `bf-imna infer` is the other end.
    server.join();
    Ok(())
}

fn serve_demo(opts: &BTreeMap<String, String>, n: usize) -> CliResult {
    let coord = start_coordinator(opts)?;
    println!(
        "serving {} ({} configs); sending {n} requests across class budgets and deadlines",
        coord.configs().join(", "),
        coord.configs().len()
    );
    let elems = coord.sample_elems();
    let budgets = [Budget::Low, Budget::Medium, Budget::High];
    let mut rng = bf_imna::util::rng::Rng::new(1);
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            let x: Vec<f32> = (0..elems).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
            if i % 4 == 3 {
                // Every fourth request carries an explicit deadline — the
                // open end of the budget API.
                coord
                    .request(x)
                    .deadline(Duration::from_millis(5 + 10 * (i % 3) as u64))
                    .submit()
                    .expect("submit")
            } else {
                coord.submit(x, budgets[i % 3]).expect("submit")
            }
        })
        .collect();
    let mut per_config: BTreeMap<String, u64> = BTreeMap::new();
    for p in pendings {
        let r = p.wait()?;
        *per_config.entry(r.config).or_default() += 1;
    }
    let m = coord.metrics();
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests".to_string(), m.completed.to_string()]);
    t.row(vec!["batches".to_string(), m.batches.to_string()]);
    t.row(vec!["batch occupancy".to_string(), format!("{:.0}%", 100.0 * m.batch_occupancy())]);
    t.row(vec!["deadlines met".to_string(), format!("{}/{}", m.deadline_met, m.completed)]);
    t.row(vec!["p50 latency".to_string(), format!("{} s", fmt_eng(m.latency_p(0.5), 3))]);
    t.row(vec!["p99 latency".to_string(), format!("{} s", fmt_eng(m.latency_p(0.99), 3))]);
    t.row(vec!["throughput".to_string(), format!("{:.1} req/s", m.throughput(coord.uptime_s()))]);
    for (cfg, count) in &per_config {
        t.row(vec![format!("served by {cfg}"), count.to_string()]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_infer(opts: &BTreeMap<String, String>) -> CliResult {
    let addr = opts.get("addr").map(String::as_str).unwrap_or("127.0.0.1:8378");
    let timeout = Duration::from_secs(match opts.get("timeout-s") {
        Some(s) => s.parse()?,
        None => 60,
    });
    if opts.contains_key("stats") {
        let stats = serving::fetch_stats(addr, timeout)?;
        println!("{stats}");
        return Ok(());
    }
    // The health document carries the model contract — no out-of-band
    // knowledge of the input shape needed.
    let health = serving::fetch_health(addr, timeout)?;
    let elems = health
        .get("sample_elems")
        .and_then(Json::as_i64)
        .ok_or("serve: /healthz carried no sample_elems")? as usize;

    let budget = match (opts.get("budget"), opts.get("deadline-ms")) {
        (Some(_), Some(_)) => {
            return Err("infer: give either --budget or --deadline-ms, not both".into())
        }
        (Some(b), None) => BudgetSpec::Class(Budget::parse(b)?),
        (None, Some(ms)) => {
            let ms: f64 = ms.parse()?;
            if !(ms.is_finite() && ms > 0.0 && ms <= serving::MAX_DEADLINE_MS) {
                return Err(format!(
                    "infer: --deadline-ms must be in (0, {}]",
                    serving::MAX_DEADLINE_MS
                )
                .into());
            }
            BudgetSpec::Deadline(Duration::from_secs_f64(ms / 1e3))
        }
        (None, None) => BudgetSpec::Class(Budget::High),
    };
    let priority = match opts.get("priority") {
        Some(p) => Priority::parse(p)?,
        None => Priority::Normal,
    };
    let batch_hint = match opts.get("batch-hint") {
        Some(h) => Some(h.parse::<u64>()?.max(1)),
        None => None,
    };
    let n: usize = match opts.get("requests") {
        Some(s) => s.parse()?,
        None => 1,
    };
    let seed: u64 = match opts.get("seed") {
        Some(s) => s.parse()?,
        None => 1,
    };
    let count: usize = match opts.get("count") {
        Some(s) => s.parse()?,
        None => 0,
    };
    let batch: usize = match opts.get("batch") {
        Some(s) => s.parse()?,
        None => 0,
    };
    if count > 0 || batch > 0 {
        let spec = RequestSpec { budget, priority, batch_hint };
        return infer_pooled(addr, timeout, elems, spec, count.max(1), batch.max(1), seed);
    }

    let mut rng = bf_imna::util::rng::Rng::new(seed);
    let mut latencies = Vec::with_capacity(n);
    let mut met = 0usize;
    let mut per_config: BTreeMap<String, u64> = BTreeMap::new();
    for i in 0..n {
        let input: Vec<f32> = (0..elems).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
        let req = InferRequest {
            input,
            spec: RequestSpec { budget, priority, batch_hint },
        };
        let r = serving::infer_remote(addr, &req, timeout)?;
        println!(
            "request {i}: config {} | batch {} | latency {} s | target {} s | {}",
            r.config,
            r.batch,
            fmt_eng(r.latency_s, 3),
            fmt_eng(r.target_s, 3),
            if r.met_deadline { "met" } else { "MISSED" }
        );
        latencies.push(r.latency_s);
        met += usize::from(r.met_deadline);
        *per_config.entry(r.config).or_default() += 1;
    }
    if n > 1 {
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = latencies[latencies.len() / 2];
        println!(
            "summary: {met}/{n} met | p50 {} s | served by {}",
            fmt_eng(p50, 3),
            per_config
                .iter()
                .map(|(k, v)| format!("{k}:{v}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }
    Ok(())
}

/// The pooled `infer --count/--batch` path: every exchange reuses one
/// keep-alive connection through a [`transport::ConnPool`], packing
/// `samples` inputs into each framed request when `samples > 1`.
fn infer_pooled(
    addr: &str,
    timeout: Duration,
    elems: usize,
    spec: RequestSpec,
    exchanges: usize,
    samples: usize,
    seed: u64,
) -> CliResult {
    let pool = transport::ConnPool::new(2);
    let mut rng = bf_imna::util::rng::Rng::new(seed);
    let mut met = 0usize;
    let mut total = 0usize;
    let mut per_config: BTreeMap<String, u64> = BTreeMap::new();
    let started = std::time::Instant::now();
    for i in 0..exchanges {
        let inputs: Vec<Vec<f32>> = (0..samples)
            .map(|_| (0..elems).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect())
            .collect();
        let responses = if samples > 1 {
            let req = serving::BatchInferRequest { inputs, spec: spec.clone() };
            serving::infer_remote_many(&pool, addr, &req, timeout)?
        } else {
            let input = inputs.into_iter().next().expect("one sample");
            let req = InferRequest { input, spec: spec.clone() };
            vec![serving::infer_remote_pooled(&pool, addr, &req, timeout)?]
        };
        for (j, r) in responses.iter().enumerate() {
            println!(
                "request {i}.{j}: config {} | batch {} | latency {} s | target {} s | {}",
                r.config,
                r.batch,
                fmt_eng(r.latency_s, 3),
                fmt_eng(r.target_s, 3),
                if r.met_deadline { "met" } else { "MISSED" }
            );
            met += usize::from(r.met_deadline);
            *per_config.entry(r.config.clone()).or_default() += 1;
            total += 1;
        }
    }
    let wall = started.elapsed().as_secs_f64();
    let ps = pool.stats();
    println!(
        "pooled: {total} requests in {} s | {:.1} req/s | {met}/{total} met | \
         connects {} reused {} | served by {}",
        fmt_eng(wall, 3),
        total as f64 / wall.max(1e-9),
        ps.fresh_connects,
        ps.reuses,
        per_config.iter().map(|(k, v)| format!("{k}:{v}")).collect::<Vec<_>>().join(" ")
    );
    Ok(())
}

fn cmd_loadgen(opts: &BTreeMap<String, String>) -> CliResult {
    let addr = opts.get("addr").map(String::as_str).unwrap_or("127.0.0.1:8378");
    let timeout = Duration::from_secs(match opts.get("timeout-s") {
        Some(s) => s.parse()?,
        None => 30,
    });
    let seed: u64 = match opts.get("seed") {
        Some(s) => s.parse()?,
        None => 1,
    };
    let rps: f64 = match opts.get("rps") {
        Some(s) => s.parse()?,
        None => 50.0,
    };
    let duration_s: f64 = match opts.get("duration-s") {
        Some(s) => s.parse()?,
        None => 5.0,
    };
    // An explicit spec file wins over the builder flags.
    let spec = match opts.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            loadgen::WorkloadSpec::from_json(&Json::parse(&text)?)?
        }
        None => {
            let profile = opts.get("profile").map(String::as_str).unwrap_or("constant");
            loadgen::WorkloadSpec::builtin(profile, rps, duration_s, seed)?
        }
    };
    let mut lopts = loadgen::LoadgenOpts { timeout, ..Default::default() };
    if let Some(w) = opts.get("workers") {
        lopts.workers = w.parse::<usize>()?.max(1);
    }
    eprintln!(
        "loadgen: workload '{}' | {:.0} rps x {} s | seed {} | {} senders -> {addr}",
        spec.name, spec.rps, spec.duration_s, spec.seed, lopts.workers
    );

    // Join window: /metrics before and after bracket the run, so the SLO
    // report's server-side numbers are deltas attributable to this load.
    let before = serving::fetch_metrics(addr, timeout)?;
    let report = loadgen::run_loadgen(addr, &spec, &lopts)?;
    let after = serving::fetch_metrics(addr, timeout)?;
    let slo = loadgen::slo_report(&report, &before, &after);

    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["offered".to_string(), format!("{:.1} req/s", report.offered_rps())]);
    t.row(vec!["achieved".to_string(), format!("{:.1} req/s", report.achieved_rps())]);
    t.row(vec![
        "sent / ok / busy / errors".to_string(),
        format!(
            "{} / {} / {} / {}",
            report.total.sent, report.total.ok, report.total.rejected_busy, report.total.errors
        ),
    ]);
    t.row(vec!["met deadline".to_string(), format!("{:.1}%", 100.0 * report.total.met_frac())]);
    t.row(vec![
        "client p50".to_string(),
        format!("{} s", fmt_eng(report.total.latency.percentile(0.5), 3)),
    ]);
    t.row(vec![
        "client p99".to_string(),
        format!("{} s", fmt_eng(report.total.latency.percentile(0.99), 3)),
    ]);
    t.row(vec![
        "client p999".to_string(),
        format!("{} s", fmt_eng(report.total.latency.percentile(0.999), 3)),
    ]);
    // Saturation diagnostics: how hard the connection pool and the sender
    // (worker) pool were driven — a saturated sender pool means measured
    // latency includes client-side queueing, so add --workers.
    let conn_total = report.pool.fresh_connects + report.pool.reuses;
    let conn_reuse = if conn_total > 0 {
        report.pool.reuses as f64 / conn_total as f64
    } else {
        0.0
    };
    t.row(vec![
        "conn pool".to_string(),
        format!(
            "{} connects / {} reuses ({:.0}% reuse)",
            report.pool.fresh_connects,
            report.pool.reuses,
            100.0 * conn_reuse
        ),
    ]);
    t.row(vec![
        "sender pool".to_string(),
        format!(
            "{} senders | {:.0}% utilized",
            report.senders,
            100.0 * report.sender_utilization()
        ),
    ]);
    for (name, c) in &report.per_class {
        t.row(vec![
            format!("class {name}"),
            format!(
                "{}/{} ok | {:.1}% met | p99 {} s",
                c.ok,
                c.sent,
                100.0 * c.met_frac(),
                fmt_eng(c.latency.percentile(0.99), 3)
            ),
        ]);
    }
    eprint!("{}", t.render());

    if let Some(path) = opts.get("out") {
        std::fs::write(path, format!("{slo}\n")).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("loadgen: SLO report written to {path}");
    } else {
        println!("{slo}");
    }
    Ok(())
}
