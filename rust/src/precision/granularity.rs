//! Fine-grained (per-channel) mixed precision on the AP.
//!
//! The paper's intro distinguishes coarse-grained (per-layer, what BF-IMNA
//! evaluates) from fine-grained (per-channel / per-parameter) granularity.
//! Bit-serial hardware supports the finer granularities *for energy*
//! automatically — a channel quantized to fewer bits simply skips its MSB
//! passes — but **latency** depends on scheduling: all words that share a
//! CAP step march through the same pass schedule, so a step is as slow as
//! its widest word.
//!
//! This module quantifies that: given per-output-channel weight widths, it
//! computes the multiply-pass cost under
//!
//! * [`lockstep_passes`] — naive packing, every step pays the layer-wide
//!   maximum width (fine-grained saves energy, zero latency),
//! * [`sorted_packed_passes`] — channels sorted by width before packing,
//!   so steps are width-homogeneous and latency tracks the width
//!   *distribution* (the scheduling optimization a bit-fluid compiler
//!   would apply),
//! * [`ideal_passes`] — the energy-side lower bound (schedule-free).

use crate::util::rng::Rng;

/// Per-output-channel precision of one layer: uniform activation bits,
/// one weight width per channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChannelConfig {
    /// Uniform activation bits.
    pub a_bits: u32,
    /// Weight bits per output channel (length = out channels).
    pub w_bits: Vec<u32>,
}

impl ChannelConfig {
    /// Uniform configuration (reduces to per-layer precision).
    pub fn uniform(a_bits: u32, w_bits: u32, channels: usize) -> Self {
        Self { a_bits, w_bits: vec![w_bits; channels] }
    }

    /// Random widths in `[lo, hi]` (fine-grained search output stand-in).
    pub fn random(a_bits: u32, lo: u32, hi: u32, channels: usize, rng: &mut Rng) -> Self {
        let w_bits = (0..channels).map(|_| lo + rng.below((hi - lo + 1) as u64) as u32).collect();
        Self { a_bits, w_bits }
    }

    /// Mean weight width.
    pub fn avg_w_bits(&self) -> f64 {
        if self.w_bits.is_empty() {
            return 0.0;
        }
        self.w_bits.iter().map(|&b| b as f64).sum::<f64>() / self.w_bits.len() as f64
    }

    /// Maximum weight width.
    pub fn max_w_bits(&self) -> u32 {
        self.w_bits.iter().copied().max().unwrap_or(0)
    }
}

/// Multiply passes per word at widths `(a, w)` (the `4·Ma·Mw` kernel of
/// Table I's multiplication).
fn passes(a: u32, w: u32) -> u64 {
    4 * a as u64 * w as u64
}

/// Naive packing: every channel rides the layer maximum — the latency a
/// per-layer (coarse) schedule pays regardless of per-channel widths.
pub fn lockstep_passes(cfg: &ChannelConfig, lanes: u64) -> u64 {
    let steps = (cfg.w_bits.len() as u64).div_ceil(lanes.max(1));
    steps * passes(cfg.a_bits, cfg.max_w_bits())
}

/// Width-sorted packing: channels sorted descending by width, packed
/// `lanes` per step; each step pays its own (homogeneous) maximum.
pub fn sorted_packed_passes(cfg: &ChannelConfig, lanes: u64) -> u64 {
    let lanes = lanes.max(1) as usize;
    let mut sorted = cfg.w_bits.clone();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    sorted
        .chunks(lanes)
        .map(|chunk| passes(cfg.a_bits, chunk[0])) // chunk max = first (sorted)
        .sum()
}

/// Schedule-free lower bound: each channel pays exactly its own passes
/// (this is also the *energy*-side pass count, which no schedule changes).
pub fn ideal_passes(cfg: &ChannelConfig, lanes: u64) -> f64 {
    let lanes = lanes.max(1) as f64;
    cfg.w_bits.iter().map(|&w| passes(cfg.a_bits, w) as f64).sum::<f64>() / lanes
}

/// Latency efficiency of a schedule: ideal / scheduled (1.0 = perfect).
pub fn schedule_efficiency(cfg: &ChannelConfig, lanes: u64, scheduled: u64) -> f64 {
    if scheduled == 0 {
        return 1.0;
    }
    ideal_passes(cfg, lanes) / scheduled as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_cfg() -> ChannelConfig {
        // Half the channels at 8 bits, half at 4.
        let mut w = vec![8u32; 32];
        w.extend(vec![4u32; 32]);
        ChannelConfig { a_bits: 8, w_bits: w }
    }

    #[test]
    fn uniform_schedules_coincide() {
        let cfg = ChannelConfig::uniform(8, 8, 64);
        let lanes = 16;
        assert_eq!(lockstep_passes(&cfg, lanes), sorted_packed_passes(&cfg, lanes));
        assert!(
            (ideal_passes(&cfg, lanes) - lockstep_passes(&cfg, lanes) as f64).abs() < 1e-9
        );
    }

    #[test]
    fn sorted_packing_beats_lockstep_on_mixed_widths() {
        let cfg = mixed_cfg();
        let lanes = 16;
        let lock = lockstep_passes(&cfg, lanes);
        let sorted = sorted_packed_passes(&cfg, lanes);
        assert!(sorted < lock, "sorted {sorted} vs lockstep {lock}");
        // Half 8b + half 4b with perfect packing: mean of 4*8*8 and 4*8*4.
        let ideal = ideal_passes(&cfg, lanes);
        assert!((sorted as f64 - ideal).abs() / ideal < 1e-9, "sorted == ideal here");
        // Lockstep pays the max everywhere: 4 steps x 256 passes.
        assert_eq!(lock, 4 * 4 * 8 * 8);
    }

    #[test]
    fn sorted_packing_is_never_worse_than_lockstep() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = 1 + rng.range(1, 200);
            let cfg = ChannelConfig::random(8, 2, 8, n, &mut rng);
            let lanes = 1 + rng.below(64);
            let lock = lockstep_passes(&cfg, lanes);
            let sorted = sorted_packed_passes(&cfg, lanes);
            assert!(sorted <= lock, "n={n} lanes={lanes}: {sorted} > {lock}");
            let eff = schedule_efficiency(&cfg, lanes, sorted);
            assert!(eff > 0.0 && eff <= 1.0 + 1e-9, "efficiency {eff}");
        }
    }

    #[test]
    fn avg_and_max_helpers() {
        let cfg = mixed_cfg();
        assert_eq!(cfg.max_w_bits(), 8);
        assert!((cfg.avg_w_bits() - 6.0).abs() < 1e-9);
        assert_eq!(ChannelConfig::uniform(8, 4, 0).avg_w_bits(), 0.0);
    }
}
