//! Mixed-precision configuration generators for the Fig. 7 design-space
//! exploration.
//!
//! §V-A: "we evaluate the performance of several mixed-precision per-layer
//! combinations, each of which yields a specific average precision value.
//! The mean performances across the combinations with similar average
//! precision are reported." This module generates those combinations:
//! random per-layer assignments from {2..8} whose mean hits a target
//! average precision.

use super::PrecisionConfig;
use crate::util::rng::Rng;

/// Minimum per-layer bitwidth explored by the paper's DSE.
pub const MIN_BITS: u32 = 2;
/// Maximum per-layer bitwidth (Table V: "Supported Bitwidth: up to 8").
pub const MAX_BITS: u32 = 8;

/// Generate one random per-layer configuration over `n_layers` whose mean
/// bitwidth equals `target` to within ±0.5/n_layers. Starts from the
/// uniform floor assignment and randomly promotes layers until the total
/// bit budget is met, then jitters pairs (one up, one down) to decorrelate
/// position from width.
pub fn random_with_avg(n_layers: usize, target: f64, rng: &mut Rng) -> PrecisionConfig {
    assert!(n_layers > 0);
    let target = target.clamp(MIN_BITS as f64, MAX_BITS as f64);
    let budget = (target * n_layers as f64).round() as u64;
    let mut bits = vec![MIN_BITS; n_layers];
    let mut total: u64 = (MIN_BITS as u64) * n_layers as u64;
    // Promote random layers one bit at a time until the budget is met.
    let mut guard = 0;
    while total < budget && guard < 100_000 {
        let k = rng.range(0, n_layers - 1);
        if bits[k] < MAX_BITS {
            bits[k] += 1;
            total += 1;
        }
        guard += 1;
    }
    // Jitter: swap a bit between random pairs, preserving the total.
    for _ in 0..n_layers {
        let up = rng.range(0, n_layers - 1);
        let down = rng.range(0, n_layers - 1);
        if bits[up] < MAX_BITS && bits[down] > MIN_BITS && up != down {
            bits[up] += 1;
            bits[down] -= 1;
        }
    }
    PrecisionConfig::from_bits(&format!("mixed-avg{target:.1}"), &bits)
}

/// Generate `count` random configurations per target average precision in
/// `targets`, as (target, configs) groups — the Fig. 7 sweep input.
pub fn sweep_groups(
    n_layers: usize,
    targets: &[f64],
    count: usize,
    seed: u64,
) -> Vec<(f64, Vec<PrecisionConfig>)> {
    let mut rng = Rng::new(seed);
    targets
        .iter()
        .map(|&t| {
            let cfgs = (0..count).map(|_| random_with_avg(n_layers, t, &mut rng)).collect();
            (t, cfgs)
        })
        .collect()
}

/// The integer average-precision grid of Fig. 7 (2..=8).
pub fn fig7_targets() -> Vec<f64> {
    (2..=8).map(|b| b as f64).collect()
}

/// Flattened sweep: every (target, config) pair in deterministic order —
/// exactly [`sweep_groups`]' configs, ungrouped. This is the shape
/// [`crate::sim::SweepEngine::run`] fans out directly: one independent
/// simulation point per element, groups recoverable as consecutive
/// `count`-sized chunks.
pub fn sweep_flat(
    n_layers: usize,
    targets: &[f64],
    count: usize,
    seed: u64,
) -> Vec<(f64, PrecisionConfig)> {
    sweep_groups(n_layers, targets, count, seed)
        .into_iter()
        .flat_map(|(t, cfgs)| cfgs.into_iter().map(move |c| (t, c)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn random_config_hits_target_average() {
        check("avg within tolerance", 128, |rng| {
            let n = rng.range(3, 60);
            let target = 2.0 + rng.f64() * 6.0;
            let cfg = random_with_avg(n, target, rng);
            let avg = cfg.avg_bits();
            let tol = 0.5 / n as f64 + 1e-9;
            if (avg - target).abs() > tol + 0.5 {
                return Err(format!("n={n} target={target:.2} avg={avg:.2}"));
            }
            for p in &cfg.per_layer {
                if p.w < MIN_BITS || p.w > MAX_BITS {
                    return Err(format!("bit {} out of range", p.w));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn extreme_targets_saturate() {
        let mut rng = Rng::new(3);
        let lo = random_with_avg(10, 2.0, &mut rng);
        assert!(lo.per_layer.iter().all(|p| p.w == 2));
        let hi = random_with_avg(10, 8.0, &mut rng);
        assert!(hi.per_layer.iter().all(|p| p.w == 8));
    }

    #[test]
    fn sweep_groups_shape() {
        let groups = sweep_groups(19, &fig7_targets(), 5, 42);
        assert_eq!(groups.len(), 7);
        for (t, cfgs) in &groups {
            assert_eq!(cfgs.len(), 5);
            for c in cfgs {
                assert!((c.avg_bits() - t).abs() < 0.6, "target {t} avg {}", c.avg_bits());
            }
        }
    }

    #[test]
    fn sweep_flat_matches_groups_order() {
        let groups = sweep_groups(12, &fig7_targets(), 3, 9);
        let flat = sweep_flat(12, &fig7_targets(), 3, 9);
        assert_eq!(flat.len(), groups.len() * 3);
        let mut i = 0;
        for (t, cfgs) in &groups {
            for c in cfgs {
                assert_eq!(flat[i].0, *t);
                assert_eq!(&flat[i].1, c);
                i += 1;
            }
        }
    }

    #[test]
    fn sweep_is_deterministic() {
        let a = sweep_groups(10, &[4.0], 3, 7);
        let b = sweep_groups(10, &[4.0], 3, 7);
        assert_eq!(a[0].1, b[0].1);
    }
}
