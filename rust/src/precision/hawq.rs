//! HAWQ-V3 per-layer precision configurations for ResNet18 (paper
//! Table VII).
//!
//! HAWQ-V3 [Yao et al., ICML'21] chooses INT4 or INT8 per layer under a
//! latency budget; the paper adopts its published configurations to
//! demonstrate bit fluidity. Each row below carries the per-layer bit
//! vector (19 entries, HAWQ-V3's layer accounting) plus the published
//! metrics we compare against: average bitwidth, normalized energy/latency
//! (expressed, as in Table VII, as the *improvement factor over INT8* —
//! `INT8_value / config_value`), absolute EDP in J·s, model size, and the
//! ImageNet top-1 accuracy HAWQ-V3 reports.
//!
//! The bit vectors reproduce Table VII's average bitwidths exactly
//! (4.00 / 7.16 / 6.53 / 5.05 / 8.00); the positions of the INT4 layers
//! follow the listed patterns (deeper layers drop to INT4 first as the
//! constraint tightens).

use super::PrecisionConfig;
use crate::model::Network;

/// Latency budget labels of Table VII.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LatencyBudget {
    /// Fixed INT4 baseline row.
    FixedInt4,
    /// "High" latency constraint (loosest): most layers INT8.
    High,
    /// "Medium" latency constraint.
    Medium,
    /// "Low" latency constraint (tightest): most layers INT4.
    Low,
    /// Fixed INT8 baseline row.
    FixedInt8,
}

impl LatencyBudget {
    /// All rows in Table VII order.
    pub const ALL: [LatencyBudget; 5] = [
        LatencyBudget::FixedInt4,
        LatencyBudget::High,
        LatencyBudget::Medium,
        LatencyBudget::Low,
        LatencyBudget::FixedInt8,
    ];

    /// Row label.
    pub fn label(&self) -> &'static str {
        match self {
            LatencyBudget::FixedInt4 => "INT4 (fixed)",
            LatencyBudget::High => "High",
            LatencyBudget::Medium => "Medium",
            LatencyBudget::Low => "Low",
            LatencyBudget::FixedInt8 => "INT8 (fixed)",
        }
    }
}

/// One Table VII row: configuration + published reference metrics.
#[derive(Debug, Clone)]
pub struct HawqRow {
    /// Which latency-budget row this is.
    pub budget: LatencyBudget,
    /// Per-layer bits, HAWQ-V3's 19-layer accounting.
    pub bits: [u32; 19],
    /// Published average bitwidth.
    pub paper_avg_bits: f64,
    /// Published normalized energy (INT8 / config — higher is better).
    pub paper_norm_energy: f64,
    /// Published normalized latency (INT8 / config).
    pub paper_norm_latency: f64,
    /// Published EDP, J·s.
    pub paper_edp_js: f64,
    /// Published model size, MB.
    pub paper_size_mb: f64,
    /// Published ImageNet top-1 accuracy, %.
    pub paper_top1_acc: f64,
}

/// Build a 19-entry bit vector with INT4 at the given (0-based) positions.
const fn bits_with_fours<const N: usize>(fours: [usize; N]) -> [u32; 19] {
    let mut b = [8u32; 19];
    let mut k = 0;
    while k < N {
        b[fours[k]] = 4;
        k += 1;
    }
    b
}

/// The five rows of Table VII.
pub fn table_vii_rows() -> Vec<HawqRow> {
    vec![
        HawqRow {
            budget: LatencyBudget::FixedInt4,
            bits: [4; 19],
            paper_avg_bits: 4.0,
            paper_norm_energy: 3.29,
            paper_norm_latency: 1.004,
            paper_edp_js: 0.58,
            paper_size_mb: 5.6,
            paper_top1_acc: 68.45,
        },
        HawqRow {
            budget: LatencyBudget::High,
            // 15 x INT8 + 4 x INT4 = avg 7.16.
            bits: bits_with_fours([8, 12, 14, 16]),
            paper_avg_bits: 7.16,
            paper_norm_energy: 1.13,
            paper_norm_latency: 1.001,
            paper_edp_js: 1.69,
            paper_size_mb: 8.7,
            paper_top1_acc: 70.4,
        },
        HawqRow {
            budget: LatencyBudget::Medium,
            // 12 x INT8 + 7 x INT4 = avg 6.53.
            bits: bits_with_fours([5, 8, 11, 12, 14, 16, 17]),
            paper_avg_bits: 6.53,
            paper_norm_energy: 1.22,
            paper_norm_latency: 1.002,
            paper_edp_js: 1.56,
            paper_size_mb: 7.2,
            paper_top1_acc: 70.34,
        },
        HawqRow {
            budget: LatencyBudget::Low,
            // 5 x INT8 + 14 x INT4 = avg 5.05 (early layers keep INT8).
            bits: {
                let mut b = [4u32; 19];
                b[0] = 8;
                b[1] = 8;
                b[2] = 8;
                b[4] = 8;
                b[6] = 8;
                b
            },
            paper_avg_bits: 5.05,
            paper_norm_energy: 1.90,
            paper_norm_latency: 1.004,
            paper_edp_js: 1.00,
            paper_size_mb: 6.1,
            paper_top1_acc: 68.56,
        },
        HawqRow {
            budget: LatencyBudget::FixedInt8,
            bits: [8; 19],
            paper_avg_bits: 8.0,
            paper_norm_energy: 1.0,
            paper_norm_latency: 1.0,
            paper_edp_js: 1.91,
            paper_size_mb: 11.2,
            paper_top1_acc: 71.56,
        },
    ]
}

/// Fetch one row by budget.
pub fn row(budget: LatencyBudget) -> HawqRow {
    table_vii_rows().into_iter().find(|r| r.budget == budget).expect("all budgets present")
}

/// Expand a 19-entry HAWQ bit vector onto a concrete ResNet18 [`Network`]
/// from the zoo (21 weight layers): non-downsample weight layers consume
/// config entries in order; each `.ds` projection inherits the entry of its
/// block's first conv (HAWQ-V3 folds the projection into the block). The
/// 19th entry covers the final fc layer.
pub fn config_for_resnet18(net: &Network, r: &HawqRow) -> PrecisionConfig {
    let indices = net.weight_layer_indices();
    let mut per_layer_bits = Vec::with_capacity(indices.len());
    let mut slot = 0usize;
    for &idx in &indices {
        let layer = &net.layers[idx];
        if layer.name.ends_with(".ds") {
            // Peek: same bits as the block's conv1 (the next config entry).
            let b = r.bits[slot.min(r.bits.len() - 1)];
            per_layer_bits.push(b);
        } else {
            let b = r.bits[slot.min(r.bits.len() - 1)];
            per_layer_bits.push(b);
            slot += 1;
        }
    }
    PrecisionConfig::from_bits(&format!("hawq-{}", r.budget.label()), &per_layer_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn avg_bits_match_table_vii() {
        for r in table_vii_rows() {
            let avg = r.bits.iter().sum::<u32>() as f64 / 19.0;
            assert!(
                (avg - r.paper_avg_bits).abs() < 0.01,
                "{:?}: avg {avg:.3} != {}",
                r.budget,
                r.paper_avg_bits
            );
        }
    }

    #[test]
    fn edp_consistency_of_published_numbers() {
        // Table VII's EDP column must equal EDP(INT8) / (normE x normL).
        let rows = table_vii_rows();
        let edp8 = row(LatencyBudget::FixedInt8).paper_edp_js;
        for r in &rows {
            let derived = edp8 / (r.paper_norm_energy * r.paper_norm_latency);
            assert!(
                (derived - r.paper_edp_js).abs() < 0.02,
                "{:?}: derived {derived:.3} != {}",
                r.budget,
                r.paper_edp_js
            );
        }
    }

    #[test]
    fn accuracy_ordering_matches_paper() {
        // INT8 > high > medium > low > INT4 (low beats INT4 slightly).
        let acc: Vec<f64> = LatencyBudget::ALL.iter().map(|&b| row(b).paper_top1_acc).collect();
        assert!(acc[4] > acc[1] && acc[1] > acc[2] && acc[2] > acc[3] && acc[3] > acc[0]);
    }

    #[test]
    fn config_expands_onto_zoo_resnet18() {
        let net = zoo::resnet18();
        for r in table_vii_rows() {
            let cfg = config_for_resnet18(&net, &r);
            assert_eq!(cfg.per_layer.len(), net.weight_layers());
            // Hardware average tracks the published average within half a
            // bit (the 2 extra ds layers shift it slightly).
            assert!(
                (cfg.avg_bits() - r.paper_avg_bits).abs() < 0.5,
                "{:?}: hw avg {:.2} vs paper {:.2}",
                r.budget,
                cfg.avg_bits(),
                r.paper_avg_bits
            );
        }
    }

    #[test]
    fn fixed_rows_are_fixed() {
        let net = zoo::resnet18();
        assert!(config_for_resnet18(&net, &row(LatencyBudget::FixedInt4)).is_fixed());
        assert!(config_for_resnet18(&net, &row(LatencyBudget::FixedInt8)).is_fixed());
        assert!(!config_for_resnet18(&net, &row(LatencyBudget::Medium)).is_fixed());
    }

    #[test]
    fn model_sizes_track_table_vii() {
        let net = zoo::resnet18();
        for r in table_vii_rows() {
            let cfg = config_for_resnet18(&net, &r);
            let mb = cfg.model_size_bytes(&net) as f64 / 1e6;
            // Within 20% of the published size (HAWQ-V3's accounting skips
            // the classifier in the 4-bit rows).
            assert!(
                (mb - r.paper_size_mb).abs() / r.paper_size_mb < 0.2,
                "{:?}: size {mb:.1} MB vs paper {}",
                r.budget,
                r.paper_size_mb
            );
        }
    }
}
