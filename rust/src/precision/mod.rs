//! Per-layer mixed-precision configurations ("bit fluidity").
//!
//! A [`PrecisionConfig`] assigns weight/activation bitwidths to every
//! weight-carrying layer of a network. Because the AP computes bit-serially,
//! *any* such configuration runs on BF-IMNA unchanged — lower precision
//! simply deactivates MSB columns (§III-A) — which is the paper's central
//! claim. [`hawq`] carries the HAWQ-V3 ResNet18 configurations of Table VII
//! and [`sweep`] generates the mixed-precision combinations behind Fig. 7.

pub mod granularity;
pub mod hawq;
pub mod sweep;

use crate::model::Network;

/// Weight / activation bitwidths of one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerPrec {
    /// Weight bits.
    pub w: u32,
    /// Activation bits.
    pub a: u32,
}

impl LayerPrec {
    /// Same width for weights and activations (the paper's per-layer
    /// "bitwidth (weight and activation)" convention).
    pub fn uniform(bits: u32) -> Self {
        Self { w: bits, a: bits }
    }
}

/// A named per-weight-layer precision assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct PrecisionConfig {
    /// Configuration name (e.g. `INT8`, `mixed-avg5.0`).
    pub name: String,
    /// One entry per weight-carrying layer, in execution order.
    pub per_layer: Vec<LayerPrec>,
}

impl PrecisionConfig {
    /// Fixed precision: all `n_layers` weight layers at `bits`.
    pub fn fixed(bits: u32, n_layers: usize) -> Self {
        Self {
            name: format!("INT{bits}"),
            per_layer: vec![LayerPrec::uniform(bits); n_layers],
        }
    }

    /// Build from a per-layer bit list (uniform weight/activation bits).
    pub fn from_bits(name: &str, bits: &[u32]) -> Self {
        Self { name: name.into(), per_layer: bits.iter().map(|&b| LayerPrec::uniform(b)).collect() }
    }

    /// Average bitwidth across layers (Table VII's "Average Bitwidth"
    /// column: the plain mean of the per-layer widths).
    pub fn avg_bits(&self) -> f64 {
        if self.per_layer.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.per_layer.iter().map(|p| (p.w + p.a) as f64 / 2.0).sum();
        sum / self.per_layer.len() as f64
    }

    /// Maximum bitwidth any layer uses (bounds the CAP column budget).
    pub fn max_bits(&self) -> u32 {
        self.per_layer.iter().map(|p| p.w.max(p.a)).max().unwrap_or(0)
    }

    /// Model size in bytes under this configuration: Σ params(layer) x
    /// w_bits / 8 (Table VII's "Size (MB)" methodology).
    pub fn model_size_bytes(&self, net: &Network) -> u64 {
        let mut size_bits = 0u64;
        for (slot, idx) in net.weight_layer_indices().iter().enumerate() {
            let prec = self.per_layer.get(slot).copied().unwrap_or_else(|| {
                *self.per_layer.last().expect("non-empty precision config")
            });
            size_bits += net.layers[*idx].params() * prec.w as u64;
        }
        size_bits / 8
    }

    /// Expand to a per-*network*-layer precision vector: weight layers take
    /// their configured entry (clamped to the last entry if the config is
    /// short); weight-less layers (pooling, residual add) inherit the
    /// activation precision flowing out of the previous layer.
    pub fn for_network(&self, net: &Network) -> Vec<LayerPrec> {
        assert!(!self.per_layer.is_empty(), "empty precision config");
        let mut out = Vec::with_capacity(net.layers.len());
        let mut slot = 0usize;
        let mut flowing = self.per_layer[0];
        for layer in &net.layers {
            if layer.has_weights() {
                let p = self.per_layer.get(slot).copied().unwrap_or(*self.per_layer.last().unwrap());
                slot += 1;
                flowing = p;
                out.push(p);
            } else {
                out.push(LayerPrec { w: 0, a: flowing.a });
            }
        }
        out
    }

    /// True when every layer runs at the same width.
    pub fn is_fixed(&self) -> bool {
        self.per_layer.windows(2).all(|w| w[0] == w[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn fixed_config_avg_and_flags() {
        let c = PrecisionConfig::fixed(8, 19);
        assert_eq!(c.avg_bits(), 8.0);
        assert!(c.is_fixed());
        assert_eq!(c.max_bits(), 8);
        assert_eq!(c.name, "INT8");
    }

    #[test]
    fn mixed_config_avg() {
        // 15 x 8 + 4 x 4 over 19 layers = 7.157 (Table VII "High").
        let mut bits = vec![8u32; 19];
        for i in [8usize, 12, 14, 16] {
            bits[i] = 4;
        }
        let c = PrecisionConfig::from_bits("high", &bits);
        assert!((c.avg_bits() - 7.16).abs() < 0.01, "avg {}", c.avg_bits());
        assert!(!c.is_fixed());
    }

    #[test]
    fn for_network_covers_every_layer() {
        let net = zoo::alexnet();
        let c = PrecisionConfig::fixed(4, net.weight_layers());
        let per_layer = c.for_network(&net);
        assert_eq!(per_layer.len(), net.layers.len());
        // Weight layers carry w bits; pools carry only activation bits.
        for (layer, p) in net.layers.iter().zip(&per_layer) {
            if layer.has_weights() {
                assert_eq!(p.w, 4);
            } else {
                assert_eq!(p.w, 0);
                assert_eq!(p.a, 4);
            }
        }
    }

    #[test]
    fn short_config_clamps_to_last_entry() {
        let net = zoo::vgg16();
        let c = PrecisionConfig::from_bits("short", &[8, 4]);
        let per_layer = c.for_network(&net);
        // All weight layers beyond the second get 4 bits.
        let w_bits: Vec<u32> =
            net.layers.iter().zip(&per_layer).filter(|(l, _)| l.has_weights()).map(|(_, p)| p.w).collect();
        assert_eq!(w_bits[0], 8);
        assert!(w_bits[2..].iter().all(|&b| b == 4));
    }

    #[test]
    fn model_size_tracks_bits() {
        let net = zoo::resnet18();
        let n = net.weight_layers();
        let s8 = PrecisionConfig::fixed(8, n).model_size_bytes(&net);
        let s4 = PrecisionConfig::fixed(4, n).model_size_bytes(&net);
        assert_eq!(s8, 2 * s4);
        // ResNet18 has ~11.7 M params -> INT8 ≈ 11.7 MB (Table VII: 11.2 MB
        // as HAWQ-V3 excludes some layers; within 10%).
        let mb = s8 as f64 / 1e6;
        assert!((mb - 11.2).abs() < 1.2, "INT8 size {mb:.1} MB");
    }
}
