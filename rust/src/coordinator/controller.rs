//! The bit-fluid precision controller — the serving-side embodiment of the
//! paper's central claim.
//!
//! Because the AP computes bit-serially, BF-IMNA switches per-layer
//! precision configurations at run time with **zero reconfiguration
//! overhead** (§V-B: "BF-IMNA allows switching between the three
//! mixed-precision configurations dynamically, as imposed by the changing
//! runtime resource requirements"). This controller performs exactly that
//! switch: each request carries a latency budget; the controller picks the
//! *highest-quality* (most bits, best accuracy) configuration whose
//! predicted latency fits the budget, learning per-(config, batch) latency
//! online with an exponential moving average seeded by the BF-IMNA
//! simulator's relative cost estimates.

use std::collections::BTreeMap;
use std::time::Duration;

/// A request's latency budget class (Table VII's constraint labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Budget {
    /// Tight deadline — favour INT4-heavy configs.
    Low,
    /// Intermediate deadline.
    Medium,
    /// Loose deadline — favour accuracy (INT8/float).
    High,
}

impl Budget {
    /// All classes, tightest first.
    pub const ALL: [Budget; 3] = [Budget::Low, Budget::Medium, Budget::High];

    /// Label used in logs and tables.
    pub fn label(&self) -> &'static str {
        match self {
            Budget::Low => "low",
            Budget::Medium => "medium",
            Budget::High => "high",
        }
    }
}

/// Per-budget latency targets.
#[derive(Debug, Clone)]
pub struct BudgetTargets {
    /// Target for [`Budget::Low`] (tightest).
    pub low: Duration,
    /// Target for [`Budget::Medium`].
    pub medium: Duration,
    /// Target for [`Budget::High`] (loosest).
    pub high: Duration,
}

impl BudgetTargets {
    /// Target for a class.
    pub fn target(&self, b: Budget) -> Duration {
        match b {
            Budget::Low => self.low,
            Budget::Medium => self.medium,
            Budget::High => self.high,
        }
    }
}

impl Default for BudgetTargets {
    /// Defaults sized for the CPU-PJRT serve CNN (ms scale); the serving
    /// example overrides them from its calibration pass.
    fn default() -> Self {
        Self {
            low: Duration::from_millis(30),
            medium: Duration::from_millis(120),
            high: Duration::from_millis(500),
        }
    }
}

/// EMA smoothing factor for observed latencies.
const EMA_ALPHA: f64 = 0.3;

/// Safety margin: predicted latency must fit in `target * MARGIN`.
const MARGIN: f64 = 0.9;

/// Online latency model + quality ladder.
#[derive(Debug, Clone)]
pub struct PrecisionController {
    /// Config names in descending quality (avg bits) order.
    ladder: Vec<String>,
    targets: BudgetTargets,
    /// EMA of observed per-batch latency, seconds, by (config, batch).
    ema: BTreeMap<(String, u64), f64>,
    /// Fallback relative cost (~avg_bits²-ish) used before observations.
    prior_scale: BTreeMap<String, f64>,
    /// Prior absolute latency for the cheapest config, seconds.
    prior_base_s: f64,
}

impl PrecisionController {
    /// Build from a quality ladder (descending avg bits) and per-config
    /// average bitwidths. `prior_base_s` seeds the absolute scale of the
    /// latency prior (e.g. the simulator's estimate or a calibration run).
    pub fn new(
        ladder: Vec<String>,
        avg_bits: &BTreeMap<String, f64>,
        targets: BudgetTargets,
        prior_base_s: f64,
    ) -> Self {
        // Bit-serial cost grows ~quadratically with precision (8M² multiply
        // passes dominate) — the same scaling Table I gives the AP.
        let min_bits = avg_bits.values().cloned().fold(f64::MAX, f64::min).max(1.0);
        let prior_scale = avg_bits
            .iter()
            .map(|(k, &b)| (k.clone(), (b / min_bits).powi(2)))
            .collect();
        Self::with_scales(ladder, prior_scale, targets, prior_base_s)
    }

    /// Build with explicit prior scales — e.g. the BF-IMNA simulator's
    /// relative per-config latencies, computed by the coordinator through
    /// [`crate::sim::SweepEngine`]. Configs missing from the map fall back
    /// to scale 1.0 in [`Self::predict`].
    pub fn with_scales(
        ladder: Vec<String>,
        prior_scale: BTreeMap<String, f64>,
        targets: BudgetTargets,
        prior_base_s: f64,
    ) -> Self {
        Self { ladder, targets, ema: BTreeMap::new(), prior_scale, prior_base_s }
    }

    /// Predicted per-batch latency, seconds.
    pub fn predict(&self, config: &str, batch: u64) -> f64 {
        if let Some(&s) = self.ema.get(&(config.to_string(), batch)) {
            return s;
        }
        let scale = self.prior_scale.get(config).copied().unwrap_or(1.0);
        // Batches amortize: assume linear growth with a fixed overhead.
        self.prior_base_s * scale * (0.5 + 0.5 * batch as f64)
    }

    /// Record an observed execution.
    pub fn observe(&mut self, config: &str, batch: u64, seconds: f64) {
        let key = (config.to_string(), batch);
        let e = self.ema.entry(key).or_insert(seconds);
        *e = (1.0 - EMA_ALPHA) * *e + EMA_ALPHA * seconds;
    }

    /// Pick the highest-quality config whose predicted latency fits the
    /// budget at this batch size; falls back to the cheapest config.
    pub fn pick(&self, budget: Budget, batch: u64) -> String {
        let target = self.targets.target(budget).as_secs_f64() * MARGIN;
        for config in &self.ladder {
            if self.predict(config, batch) <= target {
                return config.clone();
            }
        }
        self.ladder.last().cloned().unwrap_or_else(|| "int8".to_string())
    }

    /// The quality ladder (descending bits).
    pub fn ladder(&self) -> &[String] {
        &self.ladder
    }

    /// The configured targets.
    pub fn targets(&self) -> &BudgetTargets {
        &self.targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller() -> PrecisionController {
        let ladder = vec!["int8".to_string(), "mixed".to_string(), "int4".to_string()];
        let bits: BTreeMap<String, f64> = [
            ("int8".to_string(), 8.0),
            ("mixed".to_string(), 6.0),
            ("int4".to_string(), 4.0),
        ]
        .into();
        PrecisionController::new(
            ladder,
            &bits,
            BudgetTargets {
                low: Duration::from_millis(10),
                medium: Duration::from_millis(40),
                high: Duration::from_millis(1000),
            },
            0.004, // 4 ms base for the cheapest config at batch 1
        )
    }

    #[test]
    fn loose_budget_picks_highest_quality() {
        let c = controller();
        assert_eq!(c.pick(Budget::High, 1), "int8");
    }

    #[test]
    fn tight_budget_degrades_quality() {
        let c = controller();
        // Priors: int4 = 4ms, mixed = 4*(6/4)² = 9ms, int8 = 16ms at b=1.
        // Low target 10ms*0.9 = 9ms -> mixed just fits; int8 does not.
        assert_eq!(c.pick(Budget::Low, 1), "mixed");
        assert_eq!(c.pick(Budget::Medium, 1), "int8");
        // Tighten below the mixed prior -> int4.
        let mut c2 = c.clone();
        c2.observe("mixed", 1, 0.02);
        c2.observe("int4", 1, 0.004);
        assert_eq!(c2.pick(Budget::Low, 1), "int4");
    }

    #[test]
    fn observations_override_priors() {
        let mut c = controller();
        // int8 actually runs in 1 ms -> even the tight budget fits it.
        for _ in 0..20 {
            c.observe("int8", 1, 0.001);
        }
        assert_eq!(c.pick(Budget::Low, 1), "int8");
    }

    #[test]
    fn ema_converges_toward_observations() {
        let mut c = controller();
        c.observe("int4", 1, 0.008);
        for _ in 0..50 {
            c.observe("int4", 1, 0.002);
        }
        assert!((c.predict("int4", 1) - 0.002).abs() < 2e-4);
    }

    #[test]
    fn larger_batches_predict_longer() {
        let c = controller();
        assert!(c.predict("int8", 8) > c.predict("int8", 1));
    }

    #[test]
    fn falls_back_to_cheapest_when_nothing_fits() {
        let mut c = controller();
        for cfg in ["int8", "mixed", "int4"] {
            c.observe(cfg, 1, 10.0); // everything is slow
        }
        assert_eq!(c.pick(Budget::Low, 1), "int4");
    }

    #[test]
    fn explicit_scales_drive_predictions() {
        let ladder = vec!["int8".to_string(), "int4".to_string()];
        let scales: BTreeMap<String, f64> =
            [("int8".to_string(), 3.0), ("int4".to_string(), 1.0)].into();
        let c = PrecisionController::with_scales(
            ladder,
            scales,
            BudgetTargets::default(),
            0.002,
        );
        let p8 = c.predict("int8", 1);
        let p4 = c.predict("int4", 1);
        assert!((p8 / p4 - 3.0).abs() < 1e-9, "{p8} vs {p4}");
        // Unknown configs fall back to scale 1.0.
        assert_eq!(c.predict("mystery", 1), p4);
    }

    #[test]
    fn budget_labels() {
        assert_eq!(Budget::Low.label(), "low");
        assert_eq!(Budget::ALL.len(), 3);
    }
}
