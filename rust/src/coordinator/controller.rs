//! The bit-fluid precision controller — the serving-side embodiment of the
//! paper's central claim.
//!
//! Because the AP computes bit-serially, BF-IMNA switches per-layer
//! precision configurations at run time with **zero reconfiguration
//! overhead** (§V-B: "BF-IMNA allows switching between the three
//! mixed-precision configurations dynamically, as imposed by the changing
//! runtime resource requirements"). This controller performs exactly that
//! switch: each request carries a latency budget; the controller picks the
//! *highest-quality* (most bits, best accuracy) configuration whose
//! predicted latency fits the budget, learning per-(config, batch) latency
//! online with an exponential moving average seeded by the BF-IMNA
//! simulator's relative cost estimates.

use std::collections::BTreeMap;
use std::time::Duration;

/// A request's latency budget class (Table VII's constraint labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Budget {
    /// Tight deadline — favour INT4-heavy configs.
    Low,
    /// Intermediate deadline.
    Medium,
    /// Loose deadline — favour accuracy (INT8/float).
    High,
}

impl Budget {
    /// All classes, tightest first.
    pub const ALL: [Budget; 3] = [Budget::Low, Budget::Medium, Budget::High];

    /// Label used in logs and tables.
    pub fn label(&self) -> &'static str {
        match self {
            Budget::Low => "low",
            Budget::Medium => "medium",
            Budget::High => "high",
        }
    }

    /// Parse a class label (the inverse of [`Self::label`]).
    pub fn parse(s: &str) -> Result<Budget, String> {
        match s {
            "low" => Ok(Budget::Low),
            "medium" => Ok(Budget::Medium),
            "high" => Ok(Budget::High),
            other => Err(format!("unknown budget class '{other}' (low|medium|high)")),
        }
    }
}

/// How a request constrains latency at the API boundary: one of the three
/// Table VII classes, or an **explicit deadline** — the open end of the
/// budget API. Classes resolve to the coordinator's configured
/// [`BudgetTargets`]; a deadline is its own target, so the precision
/// controller picks against the caller's real latency requirement instead
/// of a fixed class bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetSpec {
    /// A Table VII latency-budget class.
    Class(Budget),
    /// An explicit end-to-end latency target for this request.
    Deadline(Duration),
}

impl BudgetSpec {
    /// Human-readable form (`low`, `deadline(12.5ms)`, ...).
    pub fn label(&self) -> String {
        match self {
            BudgetSpec::Class(b) => b.label().to_string(),
            BudgetSpec::Deadline(d) => format!("deadline({:.3}ms)", d.as_secs_f64() * 1e3),
        }
    }

    /// The metrics class label: the budget class's label, or `"deadline"`
    /// for every explicit-deadline request (they share one metrics class
    /// regardless of the specific deadline value — per-class metrics need
    /// a bounded label space).
    pub fn class_label(&self) -> &'static str {
        match self {
            BudgetSpec::Class(b) => b.label(),
            BudgetSpec::Deadline(_) => "deadline",
        }
    }
}

/// Per-budget latency targets.
#[derive(Debug, Clone)]
pub struct BudgetTargets {
    /// Target for [`Budget::Low`] (tightest).
    pub low: Duration,
    /// Target for [`Budget::Medium`].
    pub medium: Duration,
    /// Target for [`Budget::High`] (loosest).
    pub high: Duration,
}

impl BudgetTargets {
    /// Target for a class.
    pub fn target(&self, b: Budget) -> Duration {
        match b {
            Budget::Low => self.low,
            Budget::Medium => self.medium,
            Budget::High => self.high,
        }
    }
}

impl Default for BudgetTargets {
    /// Defaults sized for the CPU-PJRT serve CNN (ms scale); the serving
    /// example overrides them from its calibration pass.
    fn default() -> Self {
        Self {
            low: Duration::from_millis(30),
            medium: Duration::from_millis(120),
            high: Duration::from_millis(500),
        }
    }
}

/// EMA smoothing factor for observed latencies.
const EMA_ALPHA: f64 = 0.3;

/// An exponentially-weighted moving average over a scalar signal — the
/// smoothing primitive behind [`PrecisionController::observe`]'s latency
/// model, reused by the elastic dispatcher ([`crate::sim::fleet`]) to
/// track per-worker round-trip latency. The first observation seeds the
/// average; later ones blend in with weight `alpha`.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// A fresh (unseeded) average with smoothing factor `alpha`, clamped
    /// into `(0, 1]`.
    pub fn new(alpha: f64) -> Ewma {
        Ewma { alpha: alpha.clamp(f64::MIN_POSITIVE, 1.0), value: None }
    }

    /// Fold one sample in: the first sample seeds the average, later
    /// samples blend as `(1 - alpha) * value + alpha * sample`.
    pub fn observe(&mut self, sample: f64) {
        self.value = Some(match self.value {
            None => sample,
            Some(v) => (1.0 - self.alpha) * v + self.alpha * sample,
        });
    }

    /// The current average, or `None` before the first observation.
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Safety margin: predicted latency must fit in `target * MARGIN`.
const MARGIN: f64 = 0.9;

/// Online latency model + quality ladder.
#[derive(Debug, Clone)]
pub struct PrecisionController {
    /// Config names in descending quality (avg bits) order.
    ladder: Vec<String>,
    targets: BudgetTargets,
    /// EMA of observed per-batch latency, seconds, by (config, batch).
    ema: BTreeMap<(String, u64), Ewma>,
    /// Fallback relative cost (~avg_bits²-ish) used before observations.
    prior_scale: BTreeMap<String, f64>,
    /// Prior absolute latency for the cheapest config, seconds.
    prior_base_s: f64,
}

impl PrecisionController {
    /// Build from a quality ladder (descending avg bits) and per-config
    /// average bitwidths. `prior_base_s` seeds the absolute scale of the
    /// latency prior (e.g. the simulator's estimate or a calibration run).
    pub fn new(
        ladder: Vec<String>,
        avg_bits: &BTreeMap<String, f64>,
        targets: BudgetTargets,
        prior_base_s: f64,
    ) -> Self {
        // Bit-serial cost grows ~quadratically with precision (8M² multiply
        // passes dominate) — the same scaling Table I gives the AP.
        let min_bits = avg_bits.values().cloned().fold(f64::MAX, f64::min).max(1.0);
        let prior_scale = avg_bits
            .iter()
            .map(|(k, &b)| (k.clone(), (b / min_bits).powi(2)))
            .collect();
        Self::with_scales(ladder, prior_scale, targets, prior_base_s)
    }

    /// Build with explicit prior scales — e.g. the BF-IMNA simulator's
    /// relative per-config latencies, computed by the coordinator through
    /// [`crate::sim::SweepEngine`]. Configs missing from the map fall back
    /// to scale 1.0 in [`Self::predict`].
    pub fn with_scales(
        ladder: Vec<String>,
        prior_scale: BTreeMap<String, f64>,
        targets: BudgetTargets,
        prior_base_s: f64,
    ) -> Self {
        Self { ladder, targets, ema: BTreeMap::new(), prior_scale, prior_base_s }
    }

    /// Predicted per-batch latency, seconds.
    pub fn predict(&self, config: &str, batch: u64) -> f64 {
        if let Some(s) = self.ema.get(&(config.to_string(), batch)).and_then(Ewma::get) {
            return s;
        }
        let scale = self.prior_scale.get(config).copied().unwrap_or(1.0);
        // Batches amortize: assume linear growth with a fixed overhead.
        self.prior_base_s * scale * (0.5 + 0.5 * batch as f64)
    }

    /// Record an observed execution.
    pub fn observe(&mut self, config: &str, batch: u64, seconds: f64) {
        let key = (config.to_string(), batch);
        self.ema.entry(key).or_insert_with(|| Ewma::new(EMA_ALPHA)).observe(seconds);
    }

    /// The effective latency target of a budget spec: classes resolve to
    /// the configured [`BudgetTargets`]; deadlines are their own target.
    pub fn target_for(&self, spec: &BudgetSpec) -> Duration {
        match spec {
            BudgetSpec::Class(b) => self.targets.target(*b),
            BudgetSpec::Deadline(d) => *d,
        }
    }

    /// Pick the highest-quality config whose predicted latency fits an
    /// explicit latency target at this batch size (with the safety
    /// margin); falls back to the cheapest config. This is the single
    /// selection path — classes and deadlines both funnel through it.
    pub fn pick_target(&self, target: Duration, batch: u64) -> String {
        let target = target.as_secs_f64() * MARGIN;
        for config in &self.ladder {
            if self.predict(config, batch) <= target {
                return config.clone();
            }
        }
        self.ladder.last().cloned().unwrap_or_else(|| "int8".to_string())
    }

    /// Pick for a class budget ([`Self::pick_target`] at the class's
    /// configured target).
    pub fn pick(&self, budget: Budget, batch: u64) -> String {
        self.pick_target(self.targets.target(budget), batch)
    }

    /// Pick for any budget spec (class or explicit deadline).
    pub fn pick_spec(&self, spec: &BudgetSpec, batch: u64) -> String {
        self.pick_target(self.target_for(spec), batch)
    }

    /// The quality ladder (descending bits).
    pub fn ladder(&self) -> &[String] {
        &self.ladder
    }

    /// The configured targets.
    pub fn targets(&self) -> &BudgetTargets {
        &self.targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller() -> PrecisionController {
        let ladder = vec!["int8".to_string(), "mixed".to_string(), "int4".to_string()];
        let bits: BTreeMap<String, f64> = [
            ("int8".to_string(), 8.0),
            ("mixed".to_string(), 6.0),
            ("int4".to_string(), 4.0),
        ]
        .into();
        PrecisionController::new(
            ladder,
            &bits,
            BudgetTargets {
                low: Duration::from_millis(10),
                medium: Duration::from_millis(40),
                high: Duration::from_millis(1000),
            },
            0.004, // 4 ms base for the cheapest config at batch 1
        )
    }

    #[test]
    fn loose_budget_picks_highest_quality() {
        let c = controller();
        assert_eq!(c.pick(Budget::High, 1), "int8");
    }

    #[test]
    fn tight_budget_degrades_quality() {
        let c = controller();
        // Priors: int4 = 4ms, mixed = 4*(6/4)² = 9ms, int8 = 16ms at b=1.
        // Low target 10ms*0.9 = 9ms -> mixed just fits; int8 does not.
        assert_eq!(c.pick(Budget::Low, 1), "mixed");
        assert_eq!(c.pick(Budget::Medium, 1), "int8");
        // Tighten below the mixed prior -> int4.
        let mut c2 = c.clone();
        c2.observe("mixed", 1, 0.02);
        c2.observe("int4", 1, 0.004);
        assert_eq!(c2.pick(Budget::Low, 1), "int4");
    }

    #[test]
    fn observations_override_priors() {
        let mut c = controller();
        // int8 actually runs in 1 ms -> even the tight budget fits it.
        for _ in 0..20 {
            c.observe("int8", 1, 0.001);
        }
        assert_eq!(c.pick(Budget::Low, 1), "int8");
    }

    #[test]
    fn ema_converges_toward_observations() {
        let mut c = controller();
        c.observe("int4", 1, 0.008);
        for _ in 0..50 {
            c.observe("int4", 1, 0.002);
        }
        assert!((c.predict("int4", 1) - 0.002).abs() < 2e-4);
    }

    #[test]
    fn ewma_first_sample_seeds_then_blends() {
        let mut e = Ewma::new(0.3);
        assert_eq!(e.get(), None);
        e.observe(1.0);
        assert_eq!(e.get(), Some(1.0));
        e.observe(2.0);
        // (1 - 0.3) * 1.0 + 0.3 * 2.0
        assert!((e.get().unwrap() - 1.3).abs() < 1e-12);
    }

    #[test]
    fn ewma_clamps_alpha_into_unit_interval() {
        let mut e = Ewma::new(7.0); // clamped to 1.0 -> tracks the last sample
        e.observe(1.0);
        e.observe(5.0);
        assert_eq!(e.get(), Some(5.0));
    }

    #[test]
    fn larger_batches_predict_longer() {
        let c = controller();
        assert!(c.predict("int8", 8) > c.predict("int8", 1));
    }

    #[test]
    fn falls_back_to_cheapest_when_nothing_fits() {
        let mut c = controller();
        for cfg in ["int8", "mixed", "int4"] {
            c.observe(cfg, 1, 10.0); // everything is slow
        }
        assert_eq!(c.pick(Budget::Low, 1), "int4");
    }

    #[test]
    fn explicit_scales_drive_predictions() {
        let ladder = vec!["int8".to_string(), "int4".to_string()];
        let scales: BTreeMap<String, f64> =
            [("int8".to_string(), 3.0), ("int4".to_string(), 1.0)].into();
        let c = PrecisionController::with_scales(
            ladder,
            scales,
            BudgetTargets::default(),
            0.002,
        );
        let p8 = c.predict("int8", 1);
        let p4 = c.predict("int4", 1);
        assert!((p8 / p4 - 3.0).abs() < 1e-9, "{p8} vs {p4}");
        // Unknown configs fall back to scale 1.0.
        assert_eq!(c.predict("mystery", 1), p4);
    }

    #[test]
    fn budget_labels() {
        assert_eq!(Budget::Low.label(), "low");
        assert_eq!(Budget::ALL.len(), 3);
        for b in Budget::ALL {
            assert_eq!(Budget::parse(b.label()).unwrap(), b);
        }
        assert!(Budget::parse("tight").is_err());
    }

    #[test]
    fn deadline_targets_are_their_own_budget() {
        let c = controller();
        let spec = BudgetSpec::Deadline(Duration::from_millis(7));
        assert_eq!(c.target_for(&spec), Duration::from_millis(7));
        assert_eq!(
            c.target_for(&BudgetSpec::Class(Budget::Low)),
            Duration::from_millis(10),
            "class specs resolve to the configured class target"
        );
    }

    #[test]
    fn explicit_deadlines_walk_the_ladder() {
        let c = controller();
        // Priors at batch 1: int4 = 4ms, mixed = 9ms, int8 = 16ms.
        // A generous deadline keeps the top of the ladder...
        assert_eq!(c.pick_spec(&BudgetSpec::Deadline(Duration::from_millis(100)), 1), "int8");
        // ...a 12ms deadline (margin 0.9 -> 10.8ms effective) fits mixed
        // but not int8...
        assert_eq!(c.pick_spec(&BudgetSpec::Deadline(Duration::from_millis(12)), 1), "mixed");
        // ...a 5ms deadline (4.5ms effective) only fits int4...
        assert_eq!(c.pick_spec(&BudgetSpec::Deadline(Duration::from_millis(5)), 1), "int4");
        // ...and an impossible deadline degrades to the cheapest config
        // rather than erroring (flagged as missed on the response).
        assert_eq!(c.pick_spec(&BudgetSpec::Deadline(Duration::from_nanos(1)), 1), "int4");
    }

    #[test]
    fn deadline_picks_follow_observations_not_just_priors() {
        let mut c = controller();
        let d = BudgetSpec::Deadline(Duration::from_millis(12));
        assert_eq!(c.pick_spec(&d, 1), "mixed");
        // Measured int8 latency comes in far under its prior: the same
        // deadline now affords full quality.
        for _ in 0..20 {
            c.observe("int8", 1, 0.002);
        }
        assert_eq!(c.pick_spec(&d, 1), "int8");
        // And a measured regression on mixed pushes a mid deadline down
        // the ladder.
        let mut c2 = controller();
        for _ in 0..20 {
            c2.observe("mixed", 1, 0.050);
        }
        assert_eq!(c2.pick_spec(&d, 1), "int4");
    }

    #[test]
    fn class_and_deadline_picks_agree_at_equal_targets() {
        let c = controller();
        for (class, batch) in
            [(Budget::Low, 1u64), (Budget::Medium, 1), (Budget::High, 4), (Budget::Low, 8)]
        {
            let target = c.targets().target(class);
            assert_eq!(
                c.pick(class, batch),
                c.pick_spec(&BudgetSpec::Deadline(target), batch),
                "class {class:?} at batch {batch}"
            );
        }
    }

    #[test]
    fn budget_spec_labels() {
        assert_eq!(BudgetSpec::Class(Budget::Medium).label(), "medium");
        assert_eq!(
            BudgetSpec::Deadline(Duration::from_millis(12)).label(),
            "deadline(12.000ms)"
        );
    }
}
