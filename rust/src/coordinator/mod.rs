//! The bit-fluid serving coordinator (Layer 3's request path).
//!
//! A vLLM-router-shaped runtime around a pluggable [`InferenceBackend`]:
//!
//! ```text
//!  clients ──request(input).deadline(..).submit()──► queue ──► batcher
//!                                                     │          │
//!                                                     ▼          ▼
//!                                      worker thread (owns the backend:
//!                                      SimBackend by default, PJRT with
//!                                      --features pjrt) ◄── precision
//!                                                           controller
//! ```
//!
//! * **Dynamic batcher** — requests are pulled off the queue until the
//!   batch window closes or the largest compiled batch fills, then padded
//!   to the nearest compiled batch size. Higher-[`Priority`] requests are
//!   served first when more requests wait than a batch can carry, and a
//!   request's `batch_hint` caps how large a compiled batch it rides in.
//! * **Bit-fluid precision controller** — per batch, the tightest
//!   effective latency target (a [`Budget`] class's configured target or a
//!   request's explicit [`BudgetSpec::Deadline`]) picks the precision
//!   configuration ([`controller::PrecisionController`]); switching
//!   configs is just executing a different pre-compiled artifact — the
//!   serving analogue of the AP's zero-overhead precision switch.
//! * **Worker** — a single thread owns the backend (PJRT executables are
//!   not shared across threads) and executes batches back to back.
//!
//! The default build serves through [`SimBackend`] — batches execute
//! against the BF-IMNA latency models with a deterministic functional
//! stand-in — so the whole request path runs, and is testable, without
//! `--features pjrt`. `bf-imna serve` puts this coordinator on the wire
//! (see [`server`]); Python never runs here.

pub mod controller;
pub mod loadgen;
pub mod metrics;
pub mod server;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::error::{anyhow, Result};

pub use controller::{Budget, BudgetSpec, BudgetTargets, PrecisionController};
pub use loadgen::{LoadReport, LoadgenOpts, Profile, WorkloadClass, WorkloadSpec};
pub use metrics::{ExecStat, LatencyHistogram, Metrics, MetricsRecorder, ShardedMetrics};
pub use server::ServingServer;

use crate::model::zoo;
use crate::precision::{LayerPrec, PrecisionConfig};
use crate::runtime::{pad_batch, InferenceBackend, Manifest, Runtime, SimBackend};
use crate::sim::{SimParams, SweepEngine, SweepPoint};

/// Scheduling priority of a request: when more requests are waiting than a
/// batch can carry, higher priorities board first.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Board last.
    Low,
    /// The default.
    #[default]
    Normal,
    /// Board first.
    High,
}

impl Priority {
    /// Label used in logs and the wire protocol.
    pub fn label(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a priority label (inverse of [`Self::label`]).
    pub fn parse(s: &str) -> Result<Priority, String> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => Err(format!("unknown priority '{other}' (low|normal|high)")),
        }
    }
}

/// The declarative request descriptor the serving API accepts — built
/// fluently via [`Coordinator::request`], or directly for wire fronts.
#[derive(Debug, Clone)]
pub struct RequestSpec {
    /// Latency constraint: a class or an explicit deadline.
    pub budget: BudgetSpec,
    /// Scheduling priority.
    pub priority: Priority,
    /// Largest compiled batch this request is willing to ride in (a
    /// latency-sensitive caller hints `1` to avoid large-batch padding
    /// delays). `None` leaves batching to the coordinator.
    pub batch_hint: Option<u64>,
}

impl Default for RequestSpec {
    /// Loosest class, normal priority, no batch hint.
    fn default() -> Self {
        RequestSpec {
            budget: BudgetSpec::Class(Budget::High),
            priority: Priority::Normal,
            batch_hint: None,
        }
    }
}

/// Fluent request builder: `coordinator.request(input).deadline(d)
/// .priority(Priority::High).submit()`.
pub struct RequestBuilder<'a> {
    coordinator: &'a Coordinator,
    input: Vec<f32>,
    spec: RequestSpec,
}

impl RequestBuilder<'_> {
    /// Constrain by a Table VII budget class.
    pub fn class(mut self, b: Budget) -> Self {
        self.spec.budget = BudgetSpec::Class(b);
        self
    }

    /// Constrain by an explicit end-to-end deadline.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.spec.budget = BudgetSpec::Deadline(d);
        self
    }

    /// Set the scheduling priority.
    pub fn priority(mut self, p: Priority) -> Self {
        self.spec.priority = p;
        self
    }

    /// Cap the compiled batch size this request rides in (clamped ≥ 1).
    pub fn batch_hint(mut self, n: u64) -> Self {
        self.spec.batch_hint = Some(n.max(1));
        self
    }

    /// Submit the request; returns a [`Pending`] handle.
    pub fn submit(self) -> Result<Pending> {
        self.coordinator.submit_spec(self.input, self.spec)
    }
}

/// One inference request.
struct Request {
    input: Vec<f32>,
    spec: RequestSpec,
    submitted: Instant,
    /// How many times the batcher has carved this request out of a formed
    /// batch; at [`CARVE_PROMOTE_LIMIT`] it boards unconditionally, so a
    /// low-priority hinter cannot starve under sustained traffic.
    carved: u32,
    reply: mpsc::Sender<Result<Response, String>>,
}

/// After this many carves a request is promoted to the head of the
/// boarding order regardless of priority — the starvation bound for
/// low-priority batch-hint requests under sustained higher-priority load.
const CARVE_PROMOTE_LIMIT: u32 = 8;

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Logits for this request's sample.
    pub logits: Vec<f32>,
    /// Precision configuration that served it.
    pub config: String,
    /// Compiled batch size it rode in.
    pub batch: u64,
    /// End-to-end latency (submit -> reply), seconds.
    pub latency_s: f64,
    /// The effective latency target the request carried (its explicit
    /// deadline, or its class's configured target), seconds.
    pub target_s: f64,
    /// Whether the end-to-end latency met the target. Missed deadlines are
    /// flagged, never dropped — the response still carries full logits.
    pub met_deadline: bool,
}

/// A pending response handle.
pub struct Pending {
    rx: mpsc::Receiver<Result<Response, String>>,
}

impl Pending {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("coordinator shut down before replying"))?
            .map_err(|e| anyhow!(e))
    }

    /// Block with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<Response> {
        self.rx
            .recv_timeout(d)
            .map_err(|_| anyhow!("timed out waiting for response"))?
            .map_err(|e| anyhow!(e))
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Precision configs to load (must exist in the manifest); quality
    /// order is derived from their average bits. Empty = all.
    pub configs: Vec<String>,
    /// How long the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Per-budget-class latency targets for the precision controller
    /// (explicit [`BudgetSpec::Deadline`] requests bypass these).
    pub targets: BudgetTargets,
    /// Run one warmup execution per (config, batch) at startup so the
    /// controller starts from measured latencies instead of priors.
    pub calibrate: bool,
    /// Pin a precision config per budget class, bypassing the measured-
    /// latency controller. This is the Table VII mode: HAWQ-V3 names the
    /// configuration for each latency budget and BF-IMNA just switches.
    /// (Also the right mode on the CPU-PJRT testbed, where interpret-mode
    /// bit-plane kernels invert the hardware's latency ordering — on the
    /// real AP fewer bits are faster; on CPU they unroll more matmuls.)
    /// Deadline-carrying requests always go through the controller.
    pub pinned: BTreeMap<Budget, String>,
    /// Measured mean per-batch execute latency per config, seconds,
    /// harvested from a fleet controller's `GET /workers` listing (see
    /// [`fleet_prior_means`]). When every ladder config is covered these
    /// seed [`PrecisionController::with_scales`] — live fleet experience
    /// instead of simulator priors; otherwise they are ignored. Empty by
    /// default (`bf-imna serve --fleet-priors` fills it).
    pub fleet_prior_means: BTreeMap<String, f64>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            configs: Vec::new(),
            batch_window: Duration::from_millis(2),
            targets: BudgetTargets::default(),
            calibrate: true,
            pinned: BTreeMap::new(),
            fleet_prior_means: BTreeMap::new(),
        }
    }
}

/// Mine a fleet `GET /workers` listing for latency priors: every live
/// worker's stats document may carry a `per_config_execute` table (the
/// serving metrics' [`ExecStat`] export); batch counts and execute times
/// pool across workers, and each config maps to its fleet-wide mean
/// per-batch execute latency in seconds. Configs without a single
/// executed batch are omitted; an empty map means the listing carried
/// nothing usable (fall back to simulator priors).
pub fn fleet_prior_means(workers_doc: &crate::util::json::Json) -> BTreeMap<String, f64> {
    use crate::util::json::Json;
    let mut pooled: BTreeMap<String, (f64, f64)> = BTreeMap::new();
    let workers = match workers_doc.get("workers").and_then(Json::as_arr) {
        Some(ws) => ws,
        None => return BTreeMap::new(),
    };
    for w in workers {
        let table = match w
            .get("stats")
            .and_then(|s| s.get("per_config_execute"))
            .and_then(Json::as_obj)
        {
            Some(t) => t,
            None => continue,
        };
        for (config, e) in table {
            let batches = e.get("batches").and_then(Json::as_f64).unwrap_or(0.0);
            let total_s = e.get("total_s").and_then(Json::as_f64).unwrap_or(0.0);
            if batches > 0.0 && total_s.is_finite() && total_s >= 0.0 {
                let slot = pooled.entry(config.clone()).or_insert((0.0, 0.0));
                slot.0 += batches;
                slot.1 += total_s;
            }
        }
    }
    pooled
        .into_iter()
        .filter(|(_, (batches, _))| *batches > 0.0)
        .map(|(config, (batches, total_s))| (config, total_s / batches))
        .collect()
}

/// The serving coordinator handle (cheap to clone).
#[derive(Clone)]
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    metrics: Arc<ShardedMetrics>,
    /// Requests accepted by [`Self::submit_spec`] (queue depth is this
    /// minus the resolved count in [`Metrics`]).
    submitted: Arc<AtomicU64>,
    sample_elems: usize,
    num_classes: usize,
    configs: Vec<String>,
    started: Instant,
}

impl Coordinator {
    /// Start the coordinator over the artifact-loading [`Runtime`] (PJRT
    /// with `--features pjrt`, the erroring stub otherwise): loads +
    /// compiles artifacts on the worker thread, optionally calibrates,
    /// then serves until dropped.
    pub fn start(artifact_dir: &Path, cfg: CoordinatorConfig) -> Result<Coordinator> {
        let dir = artifact_dir.to_path_buf();
        let configs = cfg.configs.clone();
        Self::start_backend(cfg, move || {
            let runtime = if configs.is_empty() {
                Runtime::load(&dir)?
            } else {
                let names: Vec<&str> = configs.iter().map(String::as_str).collect();
                Runtime::load_configs(&dir, &names)?
            };
            Ok(Box::new(runtime) as Box<dyn InferenceBackend>)
        })
    }

    /// Start the coordinator over the default [`SimBackend`] — the
    /// no-artifacts, no-`pjrt` path. `time_scale` paces each execution at
    /// `modeled latency x scale` of wall-clock (0.0 = no pacing; right
    /// for tests and benches).
    pub fn start_sim(cfg: CoordinatorConfig, time_scale: f64) -> Result<Coordinator> {
        let configs = cfg.configs.clone();
        Self::start_backend(cfg, move || {
            let mut backend = SimBackend::serve_cnn(time_scale);
            if !configs.is_empty() {
                backend.retain_configs(&configs)?;
            }
            Ok(Box::new(backend) as Box<dyn InferenceBackend>)
        })
    }

    /// Start the coordinator over any backend. The factory runs **on the
    /// worker thread** (PJRT executables must not cross threads), so only
    /// the factory — not the backend — needs to be `Send`.
    pub fn start_backend<F>(cfg: CoordinatorConfig, make: F) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(ShardedMetrics::default());
        let recorder = metrics.recorder();

        // The worker owns the backend; report startup via a channel.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize, Vec<String>), String>>();
        std::thread::Builder::new()
            .name("bf-imna-worker".into())
            .spawn(move || {
                let backend = match make() {
                    Ok(b) => b,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let m = backend.manifest();
                let ladder = m.quality_ladder();
                let avg_bits: BTreeMap<String, f64> = backend
                    .compiled_keys()
                    .iter()
                    .filter_map(|(c, b)| backend.entry(c, *b).map(|e| (c.clone(), e.avg_bits)))
                    .collect();
                let _ = ready_tx.send(Ok((
                    m.sample_elems(),
                    m.num_classes as usize,
                    ladder.clone(),
                )));
                // Seed the latency priors from the BF-IMNA simulator: every
                // manifest config fans through the sweep engine on the serve
                // CNN, and the relative simulated latencies become the
                // prior scales (with the fastest config's simulated latency
                // as the absolute base, so `predict` starts out equal to
                // the simulator's estimate). Only trust them when every
                // ladder config got one — a partial map would leave the
                // missing configs at scale 1.0 (predicted as fast as the
                // fastest), so mixed manifests fall back to the avg-bits²
                // heuristic entirely.
                // Prior precedence: measured fleet experience (when every
                // ladder config is covered) > the simulator's relative
                // latencies > the avg-bits² heuristic. Partial coverage
                // always falls through — a config predicted at scale 1.0
                // (as fast as the fastest) would soak up traffic it
                // cannot serve in time.
                let fleet_covers = !cfg.fleet_prior_means.is_empty()
                    && ladder.iter().all(|c| cfg.fleet_prior_means.contains_key(c));
                let (sim_scales, sim_base_s) = if fleet_covers {
                    (BTreeMap::new(), 0.0)
                } else {
                    sim_prior_scales(m)
                };
                let covers_ladder = !sim_scales.is_empty()
                    && ladder.iter().all(|c| sim_scales.contains_key(c));
                let mut controller = if fleet_covers {
                    let base = cfg
                        .fleet_prior_means
                        .values()
                        .cloned()
                        .fold(f64::INFINITY, f64::min)
                        .max(1e-9);
                    let scales = cfg
                        .fleet_prior_means
                        .iter()
                        .map(|(k, &mean_s)| (k.clone(), mean_s / base))
                        .collect();
                    PrecisionController::with_scales(ladder, scales, cfg.targets.clone(), base)
                } else if covers_ladder {
                    PrecisionController::with_scales(
                        ladder,
                        sim_scales,
                        cfg.targets.clone(),
                        sim_base_s,
                    )
                } else {
                    PrecisionController::new(ladder, &avg_bits, cfg.targets.clone(), 0.005)
                };
                if cfg.calibrate {
                    calibrate(backend.as_ref(), &mut controller);
                }
                worker_loop(
                    backend,
                    controller,
                    cfg.pinned.clone(),
                    rx,
                    recorder,
                    cfg.batch_window,
                );
            })
            .map_err(|e| anyhow!("spawning worker: {e}"))?;

        let (sample_elems, num_classes, configs) = ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))?
            .map_err(|e| anyhow!(e))?;
        Ok(Coordinator {
            tx,
            metrics,
            submitted: Arc::new(AtomicU64::new(0)),
            sample_elems,
            num_classes,
            configs,
            started: Instant::now(),
        })
    }

    /// Begin a fluent request: `coord.request(x).deadline(d).submit()`.
    pub fn request(&self, input: Vec<f32>) -> RequestBuilder<'_> {
        RequestBuilder { coordinator: self, input, spec: RequestSpec::default() }
    }

    /// Submit one sample under a full request descriptor.
    pub fn submit_spec(&self, input: Vec<f32>, spec: RequestSpec) -> Result<Pending> {
        if input.len() != self.sample_elems {
            return Err(anyhow!(
                "input has {} elements, model expects {}",
                input.len(),
                self.sample_elems
            ));
        }
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { input, spec, submitted: Instant::now(), carved: 0, reply })
            .map_err(|_| anyhow!("coordinator is shut down"))?;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(Pending { rx })
    }

    /// Submit one sample under a class budget (convenience; equivalent to
    /// `request(input).class(budget).submit()`).
    pub fn submit(&self, input: Vec<f32>, budget: Budget) -> Result<Pending> {
        self.submit_spec(
            input,
            RequestSpec { budget: BudgetSpec::Class(budget), ..RequestSpec::default() },
        )
    }

    /// Blocking convenience: submit under a class budget and wait.
    pub fn infer(&self, input: Vec<f32>, budget: Budget) -> Result<Response> {
        self.submit(input, budget)?.wait()
    }

    /// Snapshot of the serving metrics: every shard of the lock-free
    /// [`ShardedMetrics`] folded into one plain [`Metrics`] — scraping
    /// never blocks the worker's recording.
    pub fn metrics(&self) -> Metrics {
        self.metrics.snapshot()
    }

    /// Requests accepted but not yet resolved (completed or failed) —
    /// they are queued, boarding, or executing. Both sides are relaxed
    /// atomic reads; the subtraction saturates, so a read racing a
    /// resolution can momentarily under-report depth but never wraps.
    pub fn queue_depth(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed).saturating_sub(self.metrics.resolved())
    }

    /// Seconds since the coordinator started (for throughput computation).
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Input sample element count (H*W*C).
    pub fn sample_elems(&self) -> usize {
        self.sample_elems
    }

    /// Output class count.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Loaded config names, descending quality.
    pub fn configs(&self) -> &[String] {
        &self.configs
    }
}

/// Relative simulated latency per manifest config plus the absolute
/// latency of the fastest one (the controller's prior base), computed by
/// fanning one BF-IMNA simulation point per config through a
/// [`SweepEngine`] on the serve CNN: the plan cache collapses the shared
/// layer/bits pairs and the points run in parallel, so this adds
/// negligible startup cost. Returns an empty map when no config carries
/// per-layer precision data.
fn sim_prior_scales(manifest: &Manifest) -> (BTreeMap<String, f64>, f64) {
    let net = zoo::serve_cnn();
    // The simulated priors are only meaningful for the network the
    // artifacts were exported from; other models fall back to the
    // avg-bits² heuristic in the caller.
    if manifest.model != net.name {
        return (BTreeMap::new(), 0.0);
    }
    let cfgs: Vec<PrecisionConfig> = manifest
        .configs
        .iter()
        .filter(|(_, info)| !info.per_layer.is_empty())
        .map(|(name, info)| PrecisionConfig {
            name: name.clone(),
            per_layer: info
                .per_layer
                .iter()
                .map(|&(w, a)| LayerPrec { w: w.max(1), a: a.max(1) })
                .collect(),
        })
        .collect();
    if cfgs.is_empty() {
        return (BTreeMap::new(), 0.0);
    }
    let params = SimParams::lr_sram();
    let engine = SweepEngine::new();
    let points: Vec<SweepPoint> =
        cfgs.iter().map(|c| SweepPoint::new(&net, c, &params)).collect();
    // Batch-level prewarm (the sweep-service discipline, see
    // `sim::shard`): the manifest configs share most (layer, bits) plans,
    // so populating the cache up front keeps the parallel fan-out below
    // from racing on cold keys during serving startup.
    engine.prewarm(&points);
    let reports = engine.run(&points);
    let floor = reports
        .iter()
        .map(|r| r.latency_s())
        .fold(f64::MAX, f64::min)
        .max(1e-12);
    (
        cfgs.iter()
            .zip(&reports)
            .map(|(c, r)| (c.name.clone(), r.latency_s() / floor))
            .collect(),
        floor,
    )
}

/// Warm up every compiled (config, batch) pair once and seed the
/// controller's latency model with the measurements.
fn calibrate(backend: &dyn InferenceBackend, controller: &mut PrecisionController) {
    let elems = backend.manifest().sample_elems();
    for (config, batch) in backend.compiled_keys() {
        let input = vec![0.1f32; batch as usize * elems];
        let t0 = Instant::now();
        if backend.infer(&config, batch, &input).is_ok() {
            // Feed several observations so the EMA settles on the sample —
            // the backend's own latency model when it has one (SimBackend),
            // the measured wall clock otherwise.
            let dt = backend
                .modeled_latency_s(&config, batch)
                .unwrap_or_else(|| t0.elapsed().as_secs_f64());
            for _ in 0..4 {
                controller.observe(&config, batch, dt);
            }
        }
    }
}

/// Order a formed batch for boarding: requests carved
/// [`CARVE_PROMOTE_LIMIT`] times board first (the starvation bound), then
/// highest priority; the sort is stable, so ties keep arrival order.
fn order_by_priority(batch: &mut [Request]) {
    batch.sort_by_key(|r| (r.carved < CARVE_PROMOTE_LIMIT, std::cmp::Reverse(r.spec.priority)));
}

/// The largest compiled batch size that does not exceed `hint` — a hint
/// is a *cap*, so it rounds **down** through the manifest's compiled
/// sizes (a hint below every compiled size clamps to the smallest one).
fn batch_cap_for(manifest: &Manifest, hint: u64) -> u64 {
    let mut sizes = manifest.batch_sizes.clone();
    sizes.sort_unstable();
    sizes
        .iter()
        .copied()
        .filter(|&b| b <= hint)
        .max()
        .or_else(|| sizes.first().copied())
        .unwrap_or(1)
}

/// The compiled batch size a formed batch should execute at: the smallest
/// compiled size that fits it, further capped by the smallest
/// `batch_hint` any member carries.
fn compiled_batch_for(manifest: &Manifest, batch: &[Request]) -> u64 {
    let mut compiled = manifest.batch_for(batch.len() as u64);
    if let Some(h) = batch.iter().filter_map(|r| r.spec.batch_hint).min() {
        let capped = batch_cap_for(manifest, h);
        if capped < compiled {
            compiled = capped;
        }
    }
    compiled
}

/// Carve a formed (boarding-sorted) batch down to its compiled size: pop
/// the lowest-ranked member to `carry`'s front while the batch overflows
/// its hint-capped compiled size, **recomputing the cap after every pop**
/// — a carved member's hint must not keep capping a batch it no longer
/// rides in. So a lowest-priority hint-1 request yields both its seat
/// *and its cap* to higher-priority traffic (which then executes at full
/// batch size) until its carve count promotes it to the head of the
/// boarding order, while an equal-or-higher-priority hinter keeps its
/// seat and the batch is carved down around it to the size it asked for.
/// Returns the compiled size of what remains.
fn carve_to_cap(manifest: &Manifest, batch: &mut Vec<Request>, carry: &mut Vec<Request>) -> u64 {
    loop {
        let compiled = compiled_batch_for(manifest, batch);
        if batch.len() <= compiled as usize {
            return compiled;
        }
        let mut popped = batch.pop().expect("batch is non-empty");
        popped.carved = popped.carved.saturating_add(1);
        carry.insert(0, popped);
    }
}

/// The batching + execution loop.
fn worker_loop(
    backend: Box<dyn InferenceBackend>,
    mut controller: PrecisionController,
    pinned: BTreeMap<Budget, String>,
    rx: mpsc::Receiver<Request>,
    metrics: MetricsRecorder,
    batch_window: Duration,
) {
    let manifest = backend.manifest().clone();
    let elems = manifest.sample_elems();
    let classes = manifest.num_classes as usize;
    let max_batch = manifest.batch_sizes.iter().copied().max().unwrap_or(1) as usize;

    // Requests a batch-hint cap pushed out of a formed batch; they board
    // the next one ahead of fresh arrivals.
    let mut carry: Vec<Request> = Vec::new();
    loop {
        // ---- Dynamic batching: fill until the window closes. ----
        let mut batch: Vec<Request> = Vec::new();
        while batch.len() < max_batch && !carry.is_empty() {
            batch.push(carry.remove(0));
        }
        if batch.is_empty() {
            match rx.recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        let deadline = Instant::now() + batch_window;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // ---- Boarding order + batch-hint cap: high priority first;
        // over-cap requests (the lowest priorities, at the sorted tail)
        // carry to the next batch, with the cap recomputed per carve so a
        // carved hinter cannot collapse everyone else's batch. ----
        order_by_priority(&mut batch);
        let compiled = carve_to_cap(&manifest, &mut batch, &mut carry);

        // ---- Bit-fluid precision pick: the tightest effective target in
        // the batch drives selection. A pinned class config still wins,
        // but only when the tightest constraint *is* that class —
        // deadline-carrying requests always go through the controller. ----
        let strictest_target = batch
            .iter()
            .map(|r| controller.target_for(&r.spec.budget))
            .min()
            .expect("batch is non-empty");
        let strictest_class = batch
            .iter()
            .filter_map(|r| match r.spec.budget {
                BudgetSpec::Class(b) => Some(b),
                BudgetSpec::Deadline(_) => None,
            })
            .min();
        let config = strictest_class
            .filter(|b| controller.target_for(&BudgetSpec::Class(*b)) <= strictest_target)
            .and_then(|b| pinned.get(&b))
            .filter(|c| manifest.artifact(c, compiled).is_some())
            .cloned()
            .unwrap_or_else(|| controller.pick_target(strictest_target, compiled));

        // ---- Execute. ----
        let n = batch.len();
        let mut input = Vec::with_capacity(n * elems);
        for r in &batch {
            input.extend_from_slice(&r.input);
        }
        let padded = pad_batch(&input, n, compiled as usize, elems);
        let t0 = Instant::now();
        let result = backend.infer(&config, compiled, &padded);
        let exec_s = t0.elapsed().as_secs_f64();
        // Model-driven backends report their own deterministic execution
        // latency (so config choices under a fixed trace are reproducible);
        // wall clock otherwise.
        let observed = backend.modeled_latency_s(&config, compiled).unwrap_or(exec_s);
        controller.observe(&config, compiled, observed);

        // ---- Reply + metrics. ----
        match result {
            Ok(logits) => {
                metrics.record_batch(&config, compiled, n as u64, observed);
                for (i, req) in batch.into_iter().enumerate() {
                    let latency_s = req.submitted.elapsed().as_secs_f64();
                    let target_s = controller.target_for(&req.spec.budget).as_secs_f64();
                    let met_deadline = latency_s <= target_s;
                    let row = logits[i * classes..(i + 1) * classes].to_vec();
                    // Record before replying: the reply delivery is the
                    // release/acquire edge that makes these relaxed
                    // stores visible to whoever scrapes after hearing
                    // back, so quiesced documents reconcile exactly.
                    metrics.record_request(
                        req.spec.budget.class_label(),
                        latency_s,
                        met_deadline,
                    );
                    let _ = req.reply.send(Ok(Response {
                        logits: row,
                        config: config.clone(),
                        batch: compiled,
                        latency_s,
                        target_s,
                        met_deadline,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                metrics.record_failed(batch.len() as u64);
                for req in batch {
                    let _ = req.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.configs.is_empty());
        assert!(c.batch_window < Duration::from_millis(100));
        assert!(c.calibrate);
        assert!(c.targets.target(Budget::Low) < c.targets.target(Budget::High));
    }

    #[test]
    fn fleet_prior_means_pools_batches_across_workers() {
        use crate::util::json::Json;
        // Two workers both served int8; only one served int4. Means pool
        // by total batches, not by averaging the workers' means.
        let doc = Json::parse(
            r#"{"workers":[
                {"addr":"a:1","stats":{"per_config_execute":{
                    "int8":{"batches":3,"total_s":0.3,"mean_s":0.1},
                    "int4":{"batches":2,"total_s":0.1,"mean_s":0.05}}}},
                {"addr":"b:2","stats":{"per_config_execute":{
                    "int8":{"batches":1,"total_s":0.5,"mean_s":0.5}}}},
                {"addr":"c:3","stats":{"requests":7}}
            ]}"#,
        )
        .unwrap();
        let means = fleet_prior_means(&doc);
        assert_eq!(means.len(), 2);
        assert!((means["int8"] - 0.2).abs() < 1e-12); // (0.3+0.5)/(3+1)
        assert!((means["int4"] - 0.05).abs() < 1e-12);
    }

    #[test]
    fn fleet_prior_means_ignores_unusable_listings() {
        use crate::util::json::Json;
        // No workers array at all.
        assert!(fleet_prior_means(&Json::parse(r#"{"expiry_s":30}"#).unwrap()).is_empty());
        // Workers without stats, and entries with zero batches or a
        // negative total, contribute nothing.
        let doc = Json::parse(
            r#"{"workers":[
                {"addr":"a:1"},
                {"addr":"b:2","stats":{"per_config_execute":{
                    "int8":{"batches":0,"total_s":0.0,"mean_s":0.0},
                    "int4":{"batches":2,"total_s":-1.0,"mean_s":-0.5}}}}
            ]}"#,
        )
        .unwrap();
        assert!(fleet_prior_means(&doc).is_empty());
    }

    #[test]
    fn budgets_order_strictest_first() {
        assert!(Budget::Low < Budget::Medium && Budget::Medium < Budget::High);
    }

    #[test]
    fn priorities_order_and_parse() {
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::parse(p.label()).unwrap(), p);
        }
        assert!(Priority::parse("urgent").is_err());
    }

    fn dummy_request(priority: Priority, batch_hint: Option<u64>, tag: f32) -> Request {
        let (reply, _rx) = mpsc::channel();
        Request {
            input: vec![tag],
            spec: RequestSpec { priority, batch_hint, ..RequestSpec::default() },
            submitted: Instant::now(),
            carved: 0,
            reply,
        }
    }

    #[test]
    fn priority_boarding_is_stable_highest_first() {
        let mut batch = vec![
            dummy_request(Priority::Normal, None, 0.0),
            dummy_request(Priority::High, None, 1.0),
            dummy_request(Priority::Low, None, 2.0),
            dummy_request(Priority::High, None, 3.0),
            dummy_request(Priority::Normal, None, 4.0),
        ];
        order_by_priority(&mut batch);
        let tags: Vec<f32> = batch.iter().map(|r| r.input[0]).collect();
        // High (arrival order 1, 3), then Normal (0, 4), then Low (2).
        assert_eq!(tags, vec![1.0, 3.0, 0.0, 4.0, 2.0]);
    }

    #[test]
    fn batch_hints_cap_the_compiled_batch() {
        let manifest = crate::runtime::SimBackend::serve_manifest(); // sizes 1, 4, 8
        let no_hints: Vec<Request> =
            (0..6).map(|i| dummy_request(Priority::Normal, None, i as f32)).collect();
        assert_eq!(compiled_batch_for(&manifest, &no_hints), 8);
        let hinted: Vec<Request> = (0..6)
            .map(|i| dummy_request(Priority::Normal, if i == 2 { Some(1) } else { None }, 0.0))
            .collect();
        // One member insists on batch 1: the whole batch is carved down.
        assert_eq!(compiled_batch_for(&manifest, &hinted), 1);
        let roomy: Vec<Request> =
            (0..3).map(|_| dummy_request(Priority::Normal, Some(100), 0.0)).collect();
        // Hints above every compiled size round down to the largest one —
        // but never *up* past what the member count needs.
        assert_eq!(compiled_batch_for(&manifest, &roomy), 4);
        // A hint *between* compiled sizes is a cap, so it rounds DOWN:
        // hint 2 with sizes [1,4,8] means batch 1, never batch 4.
        assert_eq!(batch_cap_for(&manifest, 2), 1);
        assert_eq!(batch_cap_for(&manifest, 4), 4);
        assert_eq!(batch_cap_for(&manifest, 0), 1, "sub-minimum hints clamp to the smallest size");
        let between: Vec<Request> =
            (0..6).map(|_| dummy_request(Priority::Normal, Some(2), 0.0)).collect();
        assert_eq!(compiled_batch_for(&manifest, &between), 1);
    }

    #[test]
    fn a_repeatedly_carved_request_is_promoted_and_served() {
        let manifest = crate::runtime::SimBackend::serve_manifest();
        // A low-priority hint-1 request that has hit the carve limit
        // boards ahead of everyone — the batch is carved down around it
        // and it finally executes at the size it asked for.
        let mut aged = dummy_request(Priority::Low, Some(1), 99.0);
        aged.carved = CARVE_PROMOTE_LIMIT;
        let mut batch: Vec<Request> =
            (0..5).map(|i| dummy_request(Priority::Normal, None, i as f32)).collect();
        batch.push(aged);
        order_by_priority(&mut batch);
        assert_eq!(batch[0].input[0], 99.0, "an aged request boards first");
        let mut carry = Vec::new();
        let compiled = carve_to_cap(&manifest, &mut batch, &mut carry);
        assert_eq!(compiled, 1);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].input[0], 99.0, "the aged hinter is the one served");
        assert_eq!(carry.len(), 5);
        // And carving counts toward promotion: a fresh low-priority hinter
        // accumulates carves on the way to the limit.
        let mut batch: Vec<Request> =
            (0..3).map(|i| dummy_request(Priority::Normal, None, i as f32)).collect();
        batch.push(dummy_request(Priority::Low, Some(1), 50.0));
        order_by_priority(&mut batch);
        let mut carry = Vec::new();
        carve_to_cap(&manifest, &mut batch, &mut carry);
        assert_eq!(carry[0].input[0], 50.0);
        assert_eq!(carry[0].carved, 1, "each carve is counted toward promotion");
    }

    #[test]
    fn a_carved_low_priority_hinter_releases_its_cap() {
        let manifest = crate::runtime::SimBackend::serve_manifest(); // sizes 1, 4, 8
        // Five normal requests plus one low-priority hint-1 request: the
        // hinter sorts last, is carved first, and — crucially — its cap
        // goes with it, so the surviving batch executes at full size
        // instead of collapsing to 1.
        let mut batch: Vec<Request> =
            (0..5).map(|i| dummy_request(Priority::Normal, None, i as f32)).collect();
        batch.push(dummy_request(Priority::Low, Some(1), 99.0));
        order_by_priority(&mut batch);
        let mut carry = Vec::new();
        let compiled = carve_to_cap(&manifest, &mut batch, &mut carry);
        assert_eq!(compiled, 8, "the carved hinter's cap must not survive it");
        assert_eq!(batch.len(), 5);
        assert!(batch.iter().all(|r| r.spec.batch_hint.is_none()));
        assert_eq!(carry.len(), 1);
        assert_eq!(carry[0].input[0], 99.0);

        // An equal-priority hinter keeps its seat instead: the batch is
        // carved down around it to the size it asked for.
        let mut batch: Vec<Request> =
            vec![dummy_request(Priority::Normal, Some(1), 0.0)];
        batch.extend((1..6).map(|i| dummy_request(Priority::Normal, None, i as f32)));
        order_by_priority(&mut batch);
        let mut carry = Vec::new();
        let compiled = carve_to_cap(&manifest, &mut batch, &mut carry);
        assert_eq!(compiled, 1);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].input[0], 0.0, "the hinter itself boards");
        assert_eq!(carry.len(), 5);
    }

    // Live coordinator tests on the sim backend (default build) are in
    // rust/tests/serving.rs; real-PJRT execution tests are in
    // rust/tests/coordinator_integration.rs.
}
