//! The bit-fluid serving coordinator (Layer 3's request path).
//!
//! A vLLM-router-shaped runtime around the PJRT executables:
//!
//! ```text
//!  clients ──submit(input, budget)──► queue ──► batcher ─► precision
//!                                                │         controller
//!                                                ▼             │
//!                                     worker thread (owns the PJRT
//!                                     Runtime; executes the chosen
//!                                     (config, batch) artifact) ──► replies
//! ```
//!
//! * **Dynamic batcher** — requests are pulled off the queue until the
//!   batch window closes or the largest compiled batch fills, then padded
//!   to the nearest compiled batch size.
//! * **Bit-fluid precision controller** — per batch, the strictest budget
//!   in the batch picks the precision configuration
//!   ([`controller::PrecisionController`]); switching configs is just
//!   executing a different pre-compiled artifact — the serving analogue of
//!   the AP's zero-overhead precision switch.
//! * **Worker** — a single thread owns the PJRT runtime (executables are
//!   not shared across threads) and executes batches back to back.
//!
//! Python never runs here: artifacts were lowered at build time.

pub mod controller;
pub mod metrics;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::{anyhow, Result};

pub use controller::{Budget, BudgetTargets, PrecisionController};
pub use metrics::Metrics;

use crate::model::zoo;
use crate::precision::{LayerPrec, PrecisionConfig};
use crate::runtime::{pad_batch, Manifest, Runtime};
use crate::sim::{SimParams, SweepEngine, SweepPoint};

/// One inference request.
struct Request {
    input: Vec<f32>,
    budget: Budget,
    submitted: Instant,
    reply: mpsc::Sender<Result<Response, String>>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Logits for this request's sample.
    pub logits: Vec<f32>,
    /// Precision configuration that served it.
    pub config: String,
    /// Compiled batch size it rode in.
    pub batch: u64,
    /// End-to-end latency (submit -> reply), seconds.
    pub latency_s: f64,
}

/// A pending response handle.
pub struct Pending {
    rx: mpsc::Receiver<Result<Response, String>>,
}

impl Pending {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("coordinator shut down before replying"))?
            .map_err(|e| anyhow!(e))
    }

    /// Block with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<Response> {
        self.rx
            .recv_timeout(d)
            .map_err(|_| anyhow!("timed out waiting for response"))?
            .map_err(|e| anyhow!(e))
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Precision configs to load (must exist in the manifest); quality
    /// order is derived from their average bits. Empty = all.
    pub configs: Vec<String>,
    /// How long the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Per-budget latency targets for the precision controller.
    pub targets: BudgetTargets,
    /// Run one warmup execution per (config, batch) at startup so the
    /// controller starts from measured latencies instead of priors.
    pub calibrate: bool,
    /// Pin a precision config per budget class, bypassing the measured-
    /// latency controller. This is the Table VII mode: HAWQ-V3 names the
    /// configuration for each latency budget and BF-IMNA just switches.
    /// (Also the right mode on this CPU testbed, where interpret-mode
    /// bit-plane kernels invert the hardware's latency ordering — on the
    /// real AP fewer bits are faster; on CPU they unroll more matmuls.)
    pub pinned: BTreeMap<Budget, String>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            configs: Vec::new(),
            batch_window: Duration::from_millis(2),
            targets: BudgetTargets::default(),
            calibrate: true,
            pinned: BTreeMap::new(),
        }
    }
}

/// The serving coordinator handle (cheap to clone).
#[derive(Clone)]
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    metrics: Arc<Mutex<Metrics>>,
    sample_elems: usize,
    num_classes: usize,
    configs: Vec<String>,
    started: Instant,
}

impl Coordinator {
    /// Start the coordinator: loads + compiles artifacts on the worker
    /// thread, optionally calibrates, then serves until dropped.
    pub fn start(artifact_dir: &Path, cfg: CoordinatorConfig) -> Result<Coordinator> {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let metrics_worker = Arc::clone(&metrics);
        let dir = artifact_dir.to_path_buf();

        // The worker owns the PJRT runtime; report startup via a channel.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize, Vec<String>), String>>();
        std::thread::Builder::new()
            .name("bf-imna-worker".into())
            .spawn(move || {
                let runtime = if cfg.configs.is_empty() {
                    Runtime::load(&dir)
                } else {
                    let names: Vec<&str> = cfg.configs.iter().map(String::as_str).collect();
                    Runtime::load_configs(&dir, &names)
                };
                let runtime = match runtime {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let m = runtime.manifest();
                let ladder = m.quality_ladder();
                let avg_bits: BTreeMap<String, f64> = runtime
                    .compiled_keys()
                    .iter()
                    .filter_map(|(c, b)| runtime.entry(c, *b).map(|e| (c.clone(), e.avg_bits)))
                    .collect();
                let _ = ready_tx.send(Ok((
                    m.sample_elems(),
                    m.num_classes as usize,
                    ladder.clone(),
                )));
                // Seed the latency priors from the BF-IMNA simulator: every
                // manifest config fans through the sweep engine on the serve
                // CNN, and the relative simulated latencies become the
                // prior scales. Only trust them when every ladder config got
                // one — a partial map would leave the missing configs at
                // scale 1.0 (predicted as fast as the fastest), so mixed
                // manifests fall back to the avg-bits² heuristic entirely.
                let sim_scales = sim_prior_scales(m);
                let covers_ladder = !sim_scales.is_empty()
                    && ladder.iter().all(|c| sim_scales.contains_key(c));
                let mut controller = if covers_ladder {
                    PrecisionController::with_scales(
                        ladder,
                        sim_scales,
                        cfg.targets.clone(),
                        0.005,
                    )
                } else {
                    PrecisionController::new(ladder, &avg_bits, cfg.targets.clone(), 0.005)
                };
                if cfg.calibrate {
                    calibrate(&runtime, &mut controller);
                }
                worker_loop(
                    runtime,
                    controller,
                    cfg.pinned.clone(),
                    rx,
                    metrics_worker,
                    cfg.batch_window,
                );
            })
            .map_err(|e| anyhow!("spawning worker: {e}"))?;

        let (sample_elems, num_classes, configs) = ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))?
            .map_err(|e| anyhow!(e))?;
        Ok(Coordinator { tx, metrics, sample_elems, num_classes, configs, started: Instant::now() })
    }

    /// Submit one sample under a latency budget; returns a handle.
    pub fn submit(&self, input: Vec<f32>, budget: Budget) -> Result<Pending> {
        if input.len() != self.sample_elems {
            return Err(anyhow!(
                "input has {} elements, model expects {}",
                input.len(),
                self.sample_elems
            ));
        }
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { input, budget, submitted: Instant::now(), reply })
            .map_err(|_| anyhow!("coordinator is shut down"))?;
        Ok(Pending { rx })
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, input: Vec<f32>, budget: Budget) -> Result<Response> {
        self.submit(input, budget)?.wait()
    }

    /// Snapshot of the serving metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Seconds since the coordinator started (for throughput computation).
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Input sample element count (H*W*C).
    pub fn sample_elems(&self) -> usize {
        self.sample_elems
    }

    /// Output class count.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Loaded config names, descending quality.
    pub fn configs(&self) -> &[String] {
        &self.configs
    }
}

/// Relative simulated latency per manifest config, computed by fanning one
/// BF-IMNA simulation point per config through a [`SweepEngine`] on the
/// serve CNN: the plan cache collapses the shared layer/bits pairs and the
/// points run in parallel, so this adds negligible startup cost. Returns
/// an empty map when no config carries per-layer precision data.
fn sim_prior_scales(manifest: &Manifest) -> BTreeMap<String, f64> {
    let net = zoo::serve_cnn();
    // The simulated priors are only meaningful for the network the
    // artifacts were exported from; other models fall back to the
    // avg-bits² heuristic in the caller.
    if manifest.model != net.name {
        return BTreeMap::new();
    }
    let cfgs: Vec<PrecisionConfig> = manifest
        .configs
        .iter()
        .filter(|(_, info)| !info.per_layer.is_empty())
        .map(|(name, info)| PrecisionConfig {
            name: name.clone(),
            per_layer: info
                .per_layer
                .iter()
                .map(|&(w, a)| LayerPrec { w: w.max(1), a: a.max(1) })
                .collect(),
        })
        .collect();
    if cfgs.is_empty() {
        return BTreeMap::new();
    }
    let params = SimParams::lr_sram();
    let engine = SweepEngine::new();
    let points: Vec<SweepPoint> =
        cfgs.iter().map(|c| SweepPoint::new(&net, c, &params)).collect();
    // Batch-level prewarm (the sweep-service discipline, see
    // `sim::shard`): the manifest configs share most (layer, bits) plans,
    // so populating the cache up front keeps the parallel fan-out below
    // from racing on cold keys during serving startup.
    engine.prewarm(&points);
    let reports = engine.run(&points);
    let floor = reports
        .iter()
        .map(|r| r.latency_s())
        .fold(f64::MAX, f64::min)
        .max(1e-12);
    cfgs.iter()
        .zip(&reports)
        .map(|(c, r)| (c.name.clone(), r.latency_s() / floor))
        .collect()
}

/// Warm up every compiled (config, batch) pair once and seed the
/// controller's latency model with the measurements.
fn calibrate(runtime: &Runtime, controller: &mut PrecisionController) {
    let elems = runtime.manifest().sample_elems();
    for (config, batch) in runtime.compiled_keys() {
        let input = vec![0.1f32; batch as usize * elems];
        let t0 = Instant::now();
        if runtime.infer(&config, batch, &input).is_ok() {
            // Feed several observations so the EMA settles on the sample.
            let dt = t0.elapsed().as_secs_f64();
            for _ in 0..4 {
                controller.observe(&config, batch, dt);
            }
        }
    }
}

/// The batching + execution loop.
fn worker_loop(
    runtime: Runtime,
    mut controller: PrecisionController,
    pinned: BTreeMap<Budget, String>,
    rx: mpsc::Receiver<Request>,
    metrics: Arc<Mutex<Metrics>>,
    batch_window: Duration,
) {
    let manifest = runtime.manifest().clone();
    let elems = manifest.sample_elems();
    let classes = manifest.num_classes as usize;
    let max_batch = manifest.batch_sizes.iter().copied().max().unwrap_or(1) as usize;

    while let Ok(first) = rx.recv() {
        // ---- Dynamic batching: fill until the window closes. ----
        let mut batch = vec![first];
        let deadline = Instant::now() + batch_window;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // ---- Bit-fluid precision pick: strictest budget wins. ----
        let strictest = batch.iter().map(|r| r.budget).min().unwrap_or(Budget::High);
        let n = batch.len();
        let compiled_batch = manifest.batch_for(n as u64);
        let config = pinned
            .get(&strictest)
            .filter(|c| manifest.artifact(c, compiled_batch).is_some())
            .cloned()
            .unwrap_or_else(|| controller.pick(strictest, compiled_batch));

        // ---- Execute. ----
        let mut input = Vec::with_capacity(n * elems);
        for r in &batch {
            input.extend_from_slice(&r.input);
        }
        let padded = pad_batch(&input, n, compiled_batch as usize, elems);
        let t0 = Instant::now();
        let result = runtime.infer(&config, compiled_batch, &padded);
        let exec_s = t0.elapsed().as_secs_f64();
        controller.observe(&config, compiled_batch, exec_s);

        // ---- Reply + metrics. ----
        match result {
            Ok(logits) => {
                {
                    let mut m = metrics.lock().unwrap();
                    m.record_batch(&config, compiled_batch, n as u64, exec_s);
                }
                for (i, req) in batch.into_iter().enumerate() {
                    let latency_s = req.submitted.elapsed().as_secs_f64();
                    let row = logits[i * classes..(i + 1) * classes].to_vec();
                    metrics.lock().unwrap().record_request(latency_s);
                    let _ = req.reply.send(Ok(Response {
                        logits: row,
                        config: config.clone(),
                        batch: compiled_batch,
                        latency_s,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let mut m = metrics.lock().unwrap();
                m.failed += batch.len() as u64;
                drop(m);
                for req in batch {
                    let _ = req.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.configs.is_empty());
        assert!(c.batch_window < Duration::from_millis(100));
        assert!(c.calibrate);
        assert!(c.targets.target(Budget::Low) < c.targets.target(Budget::High));
    }

    #[test]
    fn budgets_order_strictest_first() {
        assert!(Budget::Low < Budget::Medium && Budget::Medium < Budget::High);
    }

    // Live coordinator tests (real PJRT execution) are in
    // rust/tests/coordinator_integration.rs.
}
