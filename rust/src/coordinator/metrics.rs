//! Serving metrics: request counts, latency distribution, per-config and
//! per-batch-size usage.

use std::collections::BTreeMap;

use crate::util::stats;

/// Aggregated serving metrics (guarded by a mutex in the coordinator).
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Completed requests.
    pub completed: u64,
    /// Requests that failed (runtime error surfaced to the client).
    pub failed: u64,
    /// Executed batches.
    pub batches: u64,
    /// Total samples padded (wasted work in partial batches).
    pub padded_samples: u64,
    /// End-to-end per-request latency samples, seconds.
    pub request_latencies: Vec<f64>,
    /// Executor (PJRT execute only) per-batch latency samples, seconds.
    pub execute_latencies: Vec<f64>,
    /// Requests served per precision config.
    pub per_config: BTreeMap<String, u64>,
    /// Batches executed per compiled batch size.
    pub per_batch_size: BTreeMap<u64, u64>,
}

impl Metrics {
    /// Record one executed batch.
    pub fn record_batch(
        &mut self,
        config: &str,
        compiled_batch: u64,
        real_samples: u64,
        execute_s: f64,
    ) {
        self.batches += 1;
        self.padded_samples += compiled_batch - real_samples;
        self.execute_latencies.push(execute_s);
        *self.per_config.entry(config.to_string()).or_default() += real_samples;
        *self.per_batch_size.entry(compiled_batch).or_default() += 1;
    }

    /// Record one completed request with its end-to-end latency.
    pub fn record_request(&mut self, latency_s: f64) {
        self.completed += 1;
        self.request_latencies.push(latency_s);
    }

    /// Latency percentile over completed requests, seconds.
    pub fn latency_p(&self, q: f64) -> f64 {
        stats::percentile(&self.request_latencies, q)
    }

    /// Mean request latency, seconds.
    pub fn latency_mean(&self) -> f64 {
        stats::mean(&self.request_latencies)
    }

    /// Throughput given a wall-clock window, requests/second.
    pub fn throughput(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.completed as f64 / wall_s
        } else {
            0.0
        }
    }

    /// Mean executed batch occupancy (real samples / compiled batch).
    pub fn batch_occupancy(&self) -> f64 {
        let real: u64 = self.per_config.values().sum();
        let total = real + self.padded_samples;
        if total > 0 {
            real as f64 / total as f64
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = Metrics::default();
        m.record_batch("int8", 4, 3, 0.01);
        m.record_batch("int4", 8, 8, 0.02);
        m.record_request(0.05);
        m.record_request(0.15);
        assert_eq!(m.batches, 2);
        assert_eq!(m.padded_samples, 1);
        assert_eq!(m.per_config["int8"], 3);
        assert_eq!(m.per_config["int4"], 8);
        assert_eq!(m.per_batch_size[&8], 1);
        assert_eq!(m.completed, 2);
        assert!((m.latency_mean() - 0.10).abs() < 1e-12);
        assert!((m.batch_occupancy() - 11.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(0.99), 0.0);
        assert_eq!(m.throughput(1.0), 0.0);
        assert_eq!(m.batch_occupancy(), 0.0);
    }

    #[test]
    fn percentiles_order() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record_request(i as f64 / 100.0);
        }
        assert!(m.latency_p(0.5) < m.latency_p(0.99));
    }
}
