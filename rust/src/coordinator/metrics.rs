//! Serving metrics: request counts, latency distributions, deadline
//! outcomes, per-class and per-config usage.
//!
//! Two latency representations coexist, with different jobs:
//!
//! * **[`LatencyHistogram`]** — fixed log-bucketed histograms (constant
//!   memory, every sample ever recorded). Percentiles read from a
//!   histogram are exact to within one bucket (~15.5% relative width) no
//!   matter how long the server has been running; this is what
//!   `GET /metrics` and (since the loadgen PR) the `GET /stats`
//!   percentile fields report.
//! * **bounded sample rings** (`request_latencies` / `execute_latencies`)
//!   — the most recent [`LATENCY_WINDOW`] raw samples, kept for the
//!   legacy snapshot path ([`Metrics::latency_p_window`]) and for code
//!   that wants actual recent samples (mean-over-window, debugging). The
//!   ring silently forgets everything older than the window, which skews
//!   p999 on long runs — that is exactly why the percentile fields no
//!   longer read from it.
//!
//! Recording is **lock-free**: the live side of this module is
//! [`ShardedMetrics`] — N independent metric shards whose counters,
//! histogram buckets ([`AtomicHistogram`]), sample rings, and keyed
//! tables are all atomics recorded with `Ordering::Relaxed`. A
//! [`MetricsRecorder`] handle writes to exactly one shard; a scrape
//! ([`ShardedMetrics::snapshot`]) reads every shard and folds them into a
//! plain [`Metrics`] via [`Metrics::merge`] — so requests never take a
//! lock and scrapes never block requests. The plain [`Metrics`] struct
//! survives unchanged as the snapshot/merge/JSON type; every document it
//! renders is field-for-field identical to the mutex era.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::util::json::Json;
use crate::util::stats;

/// Retained latency samples per distribution (a sliding window): the
/// serving process is long-running, so sample storage must be bounded —
/// window percentiles are over the most recent samples, counters stay
/// exact, and a metrics snapshot stays cheap to build at scrape time.
pub const LATENCY_WINDOW: usize = 4096;

/// Smallest latency the histogram resolves, seconds (1 µs). Samples below
/// land in the underflow bucket and report as `HIST_MIN_S`.
pub const HIST_MIN_S: f64 = 1e-6;

/// Log-spaced buckets per decade. 16 per decade gives a bucket width
/// ratio of `10^(1/16) ≈ 1.155` — percentiles are exact to within ~15.5%.
pub const HIST_BUCKETS_PER_DECADE: usize = 16;

/// Decades covered: `[HIST_MIN_S, HIST_MIN_S * 10^HIST_DECADES)` =
/// 1 µs .. 100 s. Samples at or above the top land in the overflow
/// bucket and report as the largest sample seen.
pub const HIST_DECADES: usize = 8;

/// Total log-spaced buckets (underflow and overflow are carried
/// separately).
pub const HIST_BUCKETS: usize = HIST_BUCKETS_PER_DECADE * HIST_DECADES;

/// A fixed-geometry log-bucketed latency histogram.
///
/// The geometry is a compile-time constant (same buckets in every
/// process), so histograms from different snapshots — or different
/// machines — [`merge`](Self::merge) by plain element-wise addition, and
/// client/server documents are directly comparable. Memory is constant
/// (`HIST_BUCKETS + 2` counters) regardless of how many samples are
/// recorded; the exact sum and max ride along so means and maxima stay
/// exact.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    /// Bucket counts: `counts[0]` is underflow (`< HIST_MIN_S`),
    /// `counts[1..=HIST_BUCKETS]` are the log-spaced buckets,
    /// `counts[HIST_BUCKETS + 1]` is overflow.
    counts: Vec<u64>,
    /// Samples recorded.
    count: u64,
    /// Exact sum of all samples, seconds (for exact means).
    sum_s: f64,
    /// Largest sample seen, seconds (reported for overflow percentiles).
    max_s: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: vec![0; HIST_BUCKETS + 2],
            count: 0,
            sum_s: 0.0,
            max_s: 0.0,
        }
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// The bucket a sample falls in: `0` = underflow, `1..=HIST_BUCKETS`
    /// = log-spaced, `HIST_BUCKETS + 1` = overflow. Bucket `i` (log
    /// range) covers `[upper_edge(i-1), upper_edge(i))`.
    pub fn bucket_index(sample_s: f64) -> usize {
        if !(sample_s >= HIST_MIN_S) {
            // NaN and sub-minimum both land in underflow.
            return 0;
        }
        let pos = (sample_s / HIST_MIN_S).log10() * HIST_BUCKETS_PER_DECADE as f64;
        let idx = pos.floor() as usize + 1;
        idx.min(HIST_BUCKETS + 1)
    }

    /// The upper edge of a bucket, seconds: `upper_edge(0) = HIST_MIN_S`,
    /// `upper_edge(HIST_BUCKETS)` = the histogram's top (100 s). The
    /// overflow bucket has no finite edge; callers report the max sample.
    pub fn upper_edge(bucket: usize) -> f64 {
        let b = bucket.min(HIST_BUCKETS);
        HIST_MIN_S * 10f64.powf(b as f64 / HIST_BUCKETS_PER_DECADE as f64)
    }

    /// Record one sample (seconds).
    pub fn record(&mut self, sample_s: f64) {
        let idx = Self::bucket_index(sample_s);
        self.counts[idx] += 1;
        self.count += 1;
        if sample_s.is_finite() {
            self.sum_s += sample_s;
            if sample_s > self.max_s {
                self.max_s = sample_s;
            }
        }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of recorded samples, seconds.
    pub fn sum_s(&self) -> f64 {
        self.sum_s
    }

    /// Largest recorded sample, seconds (0.0 when empty).
    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Exact mean over *all* recorded samples (not a window), seconds.
    pub fn mean_s(&self) -> f64 {
        if self.count > 0 {
            self.sum_s / self.count as f64
        } else {
            0.0
        }
    }

    /// Absorb another histogram (element-wise; both share the fixed
    /// compile-time geometry).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
        if other.max_s > self.max_s {
            self.max_s = other.max_s;
        }
    }

    /// Latency percentile, seconds. `q` is a fraction in `[0, 1]`
    /// (`0.5` = median). Returns the **upper edge** of the bucket holding
    /// the rank-`ceil(q·count)` sample — the true sample lies within that
    /// bucket, so the error is bounded by one bucket width. Underflow
    /// ranks report `HIST_MIN_S`; overflow ranks report the exact largest
    /// sample. Empty histograms report 0.0.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                if idx == HIST_BUCKETS + 1 {
                    return self.max_s;
                }
                return Self::upper_edge(idx);
            }
        }
        self.max_s
    }

    /// The histogram document: exact count/sum/max, bucketed percentiles,
    /// and the non-empty buckets as `[upper_edge_s, count]` pairs
    /// (underflow reported under edge `HIST_MIN_S`; overflow under the
    /// max sample's value).
    pub fn to_json(&self) -> Json {
        let buckets = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(idx, &c)| {
                let edge = if idx == HIST_BUCKETS + 1 {
                    self.max_s
                } else {
                    Self::upper_edge(idx)
                };
                Json::arr([Json::num(edge), Json::num(c as f64)])
            });
        Json::obj([
            ("count", Json::num(self.count as f64)),
            ("sum_s", Json::num(self.sum_s)),
            ("max_s", Json::num(self.max_s)),
            ("p50_s", Json::num(self.percentile(0.5))),
            ("p99_s", Json::num(self.percentile(0.99))),
            ("p999_s", Json::num(self.percentile(0.999))),
            ("buckets", Json::arr(buckets)),
        ])
    }
}

/// Per-request-class serving outcomes (one per budget-class label, plus
/// `"deadline"` for requests carrying an explicit deadline).
#[derive(Debug, Default, Clone)]
pub struct ClassMetrics {
    /// Completed requests of this class.
    pub completed: u64,
    /// Completed requests of this class that met their target.
    pub deadline_met: u64,
    /// End-to-end latency histogram of this class.
    pub latency: LatencyHistogram,
}

impl ClassMetrics {
    /// Fraction of this class's completed requests that met their target
    /// (1.0 when nothing completed yet).
    pub fn met_frac(&self) -> f64 {
        if self.completed > 0 {
            self.deadline_met as f64 / self.completed as f64
        } else {
            1.0
        }
    }

    /// Absorb another class's outcomes (counter addition + histogram
    /// merge) — the per-class leg of [`Metrics::merge`].
    pub fn merge(&mut self, other: &ClassMetrics) {
        self.completed += other.completed;
        self.deadline_met += other.deadline_met;
        self.latency.merge(&other.latency);
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("completed", Json::num(self.completed as f64)),
            ("deadline_met", Json::num(self.deadline_met as f64)),
            (
                "deadline_missed",
                Json::num((self.completed - self.deadline_met) as f64),
            ),
            ("met_frac", Json::num(self.met_frac())),
            ("latency", self.latency.to_json()),
        ])
    }
}

/// Per-config execute-latency aggregate: executed batches and summed
/// backend execute time. Time is carried as integer nanoseconds so the
/// lock-free shards ([`ShardedMetrics`]) can accumulate it with a plain
/// atomic add and still fold to *exactly* what a `Mutex<Metrics>` would
/// have recorded. This is the stat `bf-imna serve --fleet-priors` mines
/// out of a fleet's `GET /workers` listing to seed a fresh coordinator's
/// [`PrecisionController`](super::PrecisionController) priors.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ExecStat {
    /// Batches executed at this config.
    pub batches: u64,
    /// Total backend execute time across those batches, nanoseconds.
    pub total_ns: u64,
}

impl ExecStat {
    /// Mean per-batch execute latency, seconds (0.0 before any batch).
    pub fn mean_s(&self) -> f64 {
        if self.batches > 0 {
            self.total_ns as f64 / 1e9 / self.batches as f64
        } else {
            0.0
        }
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("batches", Json::num(self.batches as f64)),
            ("total_s", Json::num(self.total_ns as f64 / 1e9)),
            ("mean_s", Json::num(self.mean_s())),
        ])
    }
}

/// Quantize an execute latency to the nanosecond grid [`ExecStat`] sums
/// on (clamped at zero — a backend cannot take negative time).
fn execute_ns(execute_s: f64) -> u64 {
    (execute_s.max(0.0) * 1e9).round() as u64
}

/// Aggregated serving metrics — the snapshot, merge, and JSON-rendering
/// type. The coordinator's live counters are a [`ShardedMetrics`]; a
/// scrape folds its shards into one of these via [`Self::merge`].
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Completed requests.
    pub completed: u64,
    /// Requests that failed (runtime error surfaced to the client).
    pub failed: u64,
    /// Completed requests whose end-to-end latency met their effective
    /// target (explicit deadline, or class target).
    pub deadline_met: u64,
    /// Completed requests flagged as having missed their target.
    pub deadline_missed: u64,
    /// Executed batches.
    pub batches: u64,
    /// Total samples padded (wasted work in partial batches).
    pub padded_samples: u64,
    /// End-to-end per-request latency samples, seconds — the most recent
    /// [`LATENCY_WINDOW`] of them (older samples are overwritten). Kept
    /// for the legacy snapshot path; percentiles route through
    /// [`Self::request_hist`].
    pub request_latencies: Vec<f64>,
    /// Executor (backend execute only) per-batch latency samples, seconds
    /// — the most recent [`LATENCY_WINDOW`] of them.
    pub execute_latencies: Vec<f64>,
    /// End-to-end request latency over the **whole** process lifetime
    /// (log-bucketed; what `/stats` and `/metrics` percentiles read).
    pub request_hist: LatencyHistogram,
    /// Executor per-batch latency over the whole process lifetime.
    pub execute_hist: LatencyHistogram,
    /// Outcomes per request class (`low`/`medium`/`high`/`deadline`).
    pub per_class: BTreeMap<String, ClassMetrics>,
    /// Requests served per precision config.
    pub per_config: BTreeMap<String, u64>,
    /// Execute-latency aggregate per precision config (what fleet-prior
    /// seeding consumes; see [`ExecStat`]).
    pub per_config_execute: BTreeMap<String, ExecStat>,
    /// Batches executed per compiled batch size.
    pub per_batch_size: BTreeMap<u64, u64>,
}

/// Push into a bounded ring: grow until `LATENCY_WINDOW`, then overwrite
/// round-robin (`count` is the 1-based total ever recorded).
fn push_windowed(window: &mut Vec<f64>, count: u64, sample: f64) {
    if window.len() < LATENCY_WINDOW {
        window.push(sample);
    } else {
        window[(count - 1) as usize % LATENCY_WINDOW] = sample;
    }
}

impl Metrics {
    /// Record one executed batch.
    pub fn record_batch(
        &mut self,
        config: &str,
        compiled_batch: u64,
        real_samples: u64,
        execute_s: f64,
    ) {
        self.batches += 1;
        self.padded_samples += compiled_batch - real_samples;
        push_windowed(&mut self.execute_latencies, self.batches, execute_s);
        self.execute_hist.record(execute_s);
        *self.per_config.entry(config.to_string()).or_default() += real_samples;
        let exec = self.per_config_execute.entry(config.to_string()).or_default();
        exec.batches += 1;
        exec.total_ns += execute_ns(execute_s);
        *self.per_batch_size.entry(compiled_batch).or_default() += 1;
    }

    /// Record one completed request: its class label (a budget-class
    /// label or `"deadline"` — see
    /// [`BudgetSpec::class_label`](super::BudgetSpec::class_label)), its
    /// end-to-end latency, and whether it met its effective target.
    pub fn record_request(&mut self, class: &str, latency_s: f64, met_deadline: bool) {
        self.completed += 1;
        if met_deadline {
            self.deadline_met += 1;
        } else {
            self.deadline_missed += 1;
        }
        push_windowed(&mut self.request_latencies, self.completed, latency_s);
        self.request_hist.record(latency_s);
        let c = self.per_class.entry(class.to_string()).or_default();
        c.completed += 1;
        c.deadline_met += u64::from(met_deadline);
        c.latency.record(latency_s);
    }

    /// Absorb another metrics document: counters add, histograms merge
    /// element-wise ([`LatencyHistogram::merge`]), keyed tables
    /// (per-class / per-config / per-batch-size) merge per key, and the
    /// bounded sample rings concatenate keeping the most recent
    /// [`LATENCY_WINDOW`] samples. This is the scrape-time fold
    /// [`ShardedMetrics::snapshot`] runs over its shards; merging shard
    /// snapshots is exactly equal to having recorded the union into one
    /// `Metrics` (the rings' sample *order* across sources is the only
    /// unspecified part, and nothing reads the rings order-sensitively).
    pub fn merge(&mut self, other: &Metrics) {
        self.completed += other.completed;
        self.failed += other.failed;
        self.deadline_met += other.deadline_met;
        self.deadline_missed += other.deadline_missed;
        self.batches += other.batches;
        self.padded_samples += other.padded_samples;
        self.request_latencies.extend_from_slice(&other.request_latencies);
        if self.request_latencies.len() > LATENCY_WINDOW {
            let excess = self.request_latencies.len() - LATENCY_WINDOW;
            self.request_latencies.drain(..excess);
        }
        self.execute_latencies.extend_from_slice(&other.execute_latencies);
        if self.execute_latencies.len() > LATENCY_WINDOW {
            let excess = self.execute_latencies.len() - LATENCY_WINDOW;
            self.execute_latencies.drain(..excess);
        }
        self.request_hist.merge(&other.request_hist);
        self.execute_hist.merge(&other.execute_hist);
        for (class, m) in &other.per_class {
            self.per_class.entry(class.clone()).or_default().merge(m);
        }
        for (config, &n) in &other.per_config {
            *self.per_config.entry(config.clone()).or_default() += n;
        }
        for (config, e) in &other.per_config_execute {
            let mine = self.per_config_execute.entry(config.clone()).or_default();
            mine.batches += e.batches;
            mine.total_ns += e.total_ns;
        }
        for (&size, &n) in &other.per_batch_size {
            *self.per_batch_size.entry(size).or_default() += n;
        }
    }

    /// Latency percentile over the **whole process lifetime**, seconds,
    /// read from the log-bucketed histogram (exact to within one bucket;
    /// immune to the window-forgetting skew). `q` is a fraction in
    /// `[0, 1]` (`0.5` = median, `0.999` = p999).
    pub fn latency_p(&self, q: f64) -> f64 {
        self.request_hist.percentile(q)
    }

    /// Latency percentile over the retained sample window (the most
    /// recent [`LATENCY_WINDOW`] raw samples) — the legacy snapshot path.
    /// `q` is a fraction in `[0, 1]`, converted here to the percent scale
    /// [`stats::percentile`] expects. On long runs this **forgets**
    /// everything older than the window, which skews tail percentiles;
    /// prefer [`Self::latency_p`].
    pub fn latency_p_window(&self, q: f64) -> f64 {
        stats::percentile(&self.request_latencies, q * 100.0)
    }

    /// Mean request latency over the whole process lifetime, seconds
    /// (exact: the histogram carries the exact sum).
    pub fn latency_mean(&self) -> f64 {
        self.request_hist.mean_s()
    }

    /// Throughput given a wall-clock window, requests/second.
    pub fn throughput(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.completed as f64 / wall_s
        } else {
            0.0
        }
    }

    /// Mean executed batch occupancy (real samples / compiled batch).
    pub fn batch_occupancy(&self) -> f64 {
        let real: u64 = self.per_config.values().sum();
        let total = real + self.padded_samples;
        if total > 0 {
            real as f64 / total as f64
        } else {
            0.0
        }
    }

    /// Fraction of completed requests that met their target (1.0 when
    /// nothing completed yet).
    pub fn deadline_met_frac(&self) -> f64 {
        if self.completed > 0 {
            self.deadline_met as f64 / self.completed as f64
        } else {
            1.0
        }
    }

    /// The `GET /stats` document of the serving front end (`uptime_s`
    /// feeds the throughput figure). The `latency_p*` fields read from
    /// the lifetime histogram ([`Self::latency_p`]), not the bounded
    /// sample ring.
    pub fn to_json(&self, uptime_s: f64) -> Json {
        Json::obj([
            ("completed", Json::num(self.completed as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("deadline_met", Json::num(self.deadline_met as f64)),
            ("deadline_missed", Json::num(self.deadline_missed as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("batch_occupancy", Json::num(self.batch_occupancy())),
            ("latency_p50_s", Json::num(self.latency_p(0.5))),
            ("latency_p99_s", Json::num(self.latency_p(0.99))),
            ("latency_p999_s", Json::num(self.latency_p(0.999))),
            ("deadline_met_frac", Json::num(self.deadline_met_frac())),
            ("uptime_s", Json::num(uptime_s)),
            ("throughput_rps", Json::num(self.throughput(uptime_s))),
            (
                "per_config",
                Json::obj(
                    self.per_config.iter().map(|(k, &v)| (k.clone(), Json::num(v as f64))),
                ),
            ),
            (
                "per_config_execute",
                Json::obj(
                    self.per_config_execute.iter().map(|(k, e)| (k.clone(), e.to_json())),
                ),
            ),
        ])
    }

    /// The coordinator half of the `GET /metrics` document: exact
    /// counters, full latency histograms (request + execute), per-class
    /// met-deadline rates and latency, the per-config mix, and the
    /// current queue depth (requests submitted but not yet resolved —
    /// supplied by the coordinator handle, which tracks submissions). The
    /// serving front end adds its connection counters before putting this
    /// on the wire.
    pub fn to_metrics_json(&self, uptime_s: f64, queue_depth: u64) -> Json {
        Json::obj([
            ("completed", Json::num(self.completed as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("deadline_met", Json::num(self.deadline_met as f64)),
            ("deadline_missed", Json::num(self.deadline_missed as f64)),
            ("deadline_met_frac", Json::num(self.deadline_met_frac())),
            ("batches", Json::num(self.batches as f64)),
            ("padded_samples", Json::num(self.padded_samples as f64)),
            ("batch_occupancy", Json::num(self.batch_occupancy())),
            ("queue_depth", Json::num(queue_depth as f64)),
            ("latency", self.request_hist.to_json()),
            ("execute", self.execute_hist.to_json()),
            (
                "per_class",
                Json::obj(self.per_class.iter().map(|(k, v)| (k.clone(), v.to_json()))),
            ),
            (
                "per_config",
                Json::obj(
                    self.per_config.iter().map(|(k, &v)| (k.clone(), Json::num(v as f64))),
                ),
            ),
            (
                "per_config_execute",
                Json::obj(
                    self.per_config_execute.iter().map(|(k, e)| (k.clone(), e.to_json())),
                ),
            ),
            ("uptime_s", Json::num(uptime_s)),
            ("throughput_rps", Json::num(self.throughput(uptime_s))),
        ])
    }
}

// ---------------------------------------------------------------------
// The lock-free recording side: atomic histograms and sharded metrics.
// ---------------------------------------------------------------------

/// Shards a [`ShardedMetrics::default`] carries. One shard per recording
/// thread avoids even cache-line contention; extra shards are harmless
/// (scrapes fold them all), so the default leaves headroom for future
/// multi-worker coordinators.
pub const DEFAULT_METRIC_SHARDS: usize = 4;

/// Distinct request-class labels one shard can attribute. The live set is
/// `low`/`medium`/`high`/`deadline`; a shard that somehow sees more drops
/// the *attribution* (the global counters still count the request).
const CLASS_SLOTS: usize = 16;

/// Distinct precision-config labels one shard can attribute.
const CONFIG_SLOTS: usize = 32;

/// Distinct compiled batch sizes one shard can attribute.
const BATCH_SLOTS: usize = 32;

/// Add to an `f64` carried as bits in an `AtomicU64` (relaxed CAS loop).
fn f64_add(cell: &AtomicU64, v: f64) {
    let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
        Some((f64::from_bits(bits) + v).to_bits())
    });
}

/// Raise an `f64`-as-bits `AtomicU64` to `v` if `v` is larger.
fn f64_max(cell: &AtomicU64, v: f64) {
    let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
        (v > f64::from_bits(bits)).then(|| v.to_bits())
    });
}

/// A [`LatencyHistogram`] recorded through `&self`: the fixed log-bucket
/// geometry becomes a fixed-size `AtomicU64` array, the exact sum and max
/// ride along as `f64` bits. All operations are `Ordering::Relaxed` —
/// recording threads never synchronize with each other or with readers;
/// a [`snapshot`](Self::snapshot) taken mid-record is still internally
/// consistent because its total is derived from the bucket counts it
/// actually read.
#[derive(Debug)]
pub struct AtomicHistogram {
    /// Bucket counts, same layout as [`LatencyHistogram`]: index 0 is
    /// underflow, `HIST_BUCKETS + 1` is overflow.
    counts: [AtomicU64; HIST_BUCKETS + 2],
    /// Exact sum of recorded samples, `f64` bits.
    sum_bits: AtomicU64,
    /// Largest recorded sample, `f64` bits (0.0 when empty).
    max_bits: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            max_bits: AtomicU64::new(0f64.to_bits()),
        }
    }
}

impl AtomicHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample (seconds) without taking a lock.
    pub fn record(&self, sample_s: f64) {
        let idx = LatencyHistogram::bucket_index(sample_s);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        if sample_s.is_finite() {
            f64_add(&self.sum_bits, sample_s);
            f64_max(&self.max_bits, sample_s);
        }
    }

    /// Snapshot into the plain [`LatencyHistogram`]. The snapshot's total
    /// `count` is the sum of the bucket counts it read — never the other
    /// way around — so percentile ranks computed from the snapshot can
    /// never exceed the bucket mass, even while writers race the read.
    pub fn snapshot(&self) -> LatencyHistogram {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let count = counts.iter().sum();
        LatencyHistogram {
            counts,
            count,
            sum_s: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
            max_s: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        }
    }
}

/// A bounded sample ring recorded through `&self`: [`LATENCY_WINDOW`]
/// `f64`-bit slots and a monotone write cursor. A reader may catch a slot
/// between the cursor bump and the sample store (it reads the slot's old
/// value) — the ring is a diagnostic sample set, not a counter, so that
/// is acceptable by design.
#[derive(Debug)]
struct AtomicWindow {
    slots: Vec<AtomicU64>,
    cursor: AtomicU64,
}

impl AtomicWindow {
    fn new() -> Self {
        AtomicWindow {
            slots: (0..LATENCY_WINDOW).map(|_| AtomicU64::new(0)).collect(),
            cursor: AtomicU64::new(0),
        }
    }

    fn record(&self, v: f64) {
        let at = self.cursor.fetch_add(1, Ordering::Relaxed) as usize % LATENCY_WINDOW;
        self.slots[at].store(v.to_bits(), Ordering::Relaxed);
    }

    fn snapshot(&self) -> Vec<f64> {
        let n = (self.cursor.load(Ordering::Relaxed) as usize).min(LATENCY_WINDOW);
        self.slots[..n].iter().map(|s| f64::from_bits(s.load(Ordering::Relaxed))).collect()
    }
}

/// One per-class attribution slot: a write-once label claimed by the
/// first recorder that sees the class, then atomic outcome counters.
#[derive(Debug, Default)]
struct ClassSlot {
    label: OnceLock<String>,
    completed: AtomicU64,
    deadline_met: AtomicU64,
    latency: AtomicHistogram,
}

/// One per-config attribution slot (real samples served, plus the
/// execute-latency aggregate in the same integer-nanosecond units as
/// [`ExecStat`], so shard folds reproduce plain recording exactly).
#[derive(Debug, Default)]
struct ConfigSlot {
    label: OnceLock<String>,
    samples: AtomicU64,
    batches: AtomicU64,
    execute_ns: AtomicU64,
}

/// One per-batch-size attribution slot. `size == 0` means unclaimed
/// (compiled batch sizes are always ≥ 1).
#[derive(Debug, Default)]
struct BatchSlot {
    size: AtomicU64,
    count: AtomicU64,
}

/// Find (or claim) the slot for `label` by linear probe. The tables are
/// small and their key sets are closed in practice, so a scan from the
/// front beats hashing; a full table returns `None` and the caller drops
/// the attribution (global counters are unaffected).
fn label_slot<'a, T>(
    slots: &'a [T],
    label: &str,
    cell: impl Fn(&T) -> &OnceLock<String>,
) -> Option<&'a T> {
    for slot in slots {
        match cell(slot).get() {
            Some(k) if k == label => return Some(slot),
            Some(_) => continue,
            None => {
                // Race to claim the empty slot; on loss, the winner's key
                // may still be ours (two recorders, same new label).
                if cell(slot).set(label.to_string()).is_ok()
                    || cell(slot).get().map(|k| k == label).unwrap_or(false)
                {
                    return Some(slot);
                }
            }
        }
    }
    None
}

/// One metric shard: every field of [`Metrics`], recorded atomically.
#[derive(Debug)]
struct MetricShard {
    completed: AtomicU64,
    failed: AtomicU64,
    deadline_met: AtomicU64,
    deadline_missed: AtomicU64,
    batches: AtomicU64,
    padded_samples: AtomicU64,
    request_window: AtomicWindow,
    execute_window: AtomicWindow,
    request_hist: AtomicHistogram,
    execute_hist: AtomicHistogram,
    per_class: Vec<ClassSlot>,
    per_config: Vec<ConfigSlot>,
    per_batch_size: Vec<BatchSlot>,
}

impl MetricShard {
    fn new() -> Self {
        MetricShard {
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            deadline_met: AtomicU64::new(0),
            deadline_missed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_samples: AtomicU64::new(0),
            request_window: AtomicWindow::new(),
            execute_window: AtomicWindow::new(),
            request_hist: AtomicHistogram::new(),
            execute_hist: AtomicHistogram::new(),
            per_class: (0..CLASS_SLOTS).map(|_| ClassSlot::default()).collect(),
            per_config: (0..CONFIG_SLOTS).map(|_| ConfigSlot::default()).collect(),
            per_batch_size: (0..BATCH_SLOTS).map(|_| BatchSlot::default()).collect(),
        }
    }

    fn record_batch(&self, config: &str, compiled_batch: u64, real_samples: u64, execute_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_samples.fetch_add(compiled_batch - real_samples, Ordering::Relaxed);
        self.execute_window.record(execute_s);
        self.execute_hist.record(execute_s);
        if let Some(slot) = label_slot(&self.per_config, config, |s| &s.label) {
            slot.samples.fetch_add(real_samples, Ordering::Relaxed);
            slot.batches.fetch_add(1, Ordering::Relaxed);
            slot.execute_ns.fetch_add(execute_ns(execute_s), Ordering::Relaxed);
        }
        for slot in &self.per_batch_size {
            let cur = slot.size.load(Ordering::Relaxed);
            if cur == compiled_batch {
                slot.count.fetch_add(1, Ordering::Relaxed);
                break;
            }
            if cur == 0 {
                match slot.size.compare_exchange(
                    0,
                    compiled_batch,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        slot.count.fetch_add(1, Ordering::Relaxed);
                        break;
                    }
                    Err(won) if won == compiled_batch => {
                        slot.count.fetch_add(1, Ordering::Relaxed);
                        break;
                    }
                    Err(_) => continue,
                }
            }
        }
    }

    fn record_request(&self, class: &str, latency_s: f64, met_deadline: bool) {
        // `completed` before `deadline_met`: per-class documents derive
        // `deadline_missed = completed - deadline_met`, so a racing
        // snapshot must never see met counters ahead of completions
        // (snapshots additionally clamp, belt and braces).
        self.completed.fetch_add(1, Ordering::Relaxed);
        if met_deadline {
            self.deadline_met.fetch_add(1, Ordering::Relaxed);
        } else {
            self.deadline_missed.fetch_add(1, Ordering::Relaxed);
        }
        self.request_window.record(latency_s);
        self.request_hist.record(latency_s);
        if let Some(slot) = label_slot(&self.per_class, class, |s| &s.label) {
            slot.completed.fetch_add(1, Ordering::Relaxed);
            slot.deadline_met.fetch_add(u64::from(met_deadline), Ordering::Relaxed);
            slot.latency.record(latency_s);
        }
    }

    fn snapshot(&self) -> Metrics {
        let mut m = Metrics {
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            deadline_met: self.deadline_met.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_samples: self.padded_samples.load(Ordering::Relaxed),
            request_latencies: self.request_window.snapshot(),
            execute_latencies: self.execute_window.snapshot(),
            request_hist: self.request_hist.snapshot(),
            execute_hist: self.execute_hist.snapshot(),
            per_class: BTreeMap::new(),
            per_config: BTreeMap::new(),
            per_config_execute: BTreeMap::new(),
            per_batch_size: BTreeMap::new(),
        };
        for slot in &self.per_class {
            if let Some(label) = slot.label.get() {
                let completed = slot.completed.load(Ordering::Relaxed);
                // Clamp: a racing reader must never produce a class whose
                // met count exceeds its completions (the document
                // subtracts them).
                let met = slot.deadline_met.load(Ordering::Relaxed).min(completed);
                m.per_class.insert(
                    label.clone(),
                    ClassMetrics {
                        completed,
                        deadline_met: met,
                        latency: slot.latency.snapshot(),
                    },
                );
            }
        }
        for slot in &self.per_config {
            if let Some(label) = slot.label.get() {
                m.per_config.insert(label.clone(), slot.samples.load(Ordering::Relaxed));
                let batches = slot.batches.load(Ordering::Relaxed);
                if batches > 0 {
                    m.per_config_execute.insert(
                        label.clone(),
                        ExecStat { batches, total_ns: slot.execute_ns.load(Ordering::Relaxed) },
                    );
                }
            }
        }
        for slot in &self.per_batch_size {
            let size = slot.size.load(Ordering::Relaxed);
            if size != 0 {
                m.per_batch_size.insert(size, slot.count.load(Ordering::Relaxed));
            }
        }
        m
    }
}

/// N independent metric shards plus a round-robin recorder dispenser —
/// the live, lock-free replacement for `Mutex<Metrics>`. Recording
/// threads each hold a [`MetricsRecorder`] (one shard each, relaxed
/// atomics all the way down); scrapes fold every shard into a plain
/// [`Metrics`] with [`Metrics::merge`].
///
/// Memory-ordering contract: all stores are `Relaxed`. A scraper that
/// synchronizes with a recording thread through *any* release/acquire
/// edge — an mpsc reply delivery, a thread join, or in practice a
/// socket round trip — observes everything that thread recorded before
/// the edge, which is why quiesced-server documents reconcile exactly.
/// A scrape racing live recorders sees some prefix of each shard's
/// writes: counters are monotone across scrapes and every snapshot is
/// internally consistent, but cross-counter invariants (e.g.
/// `met + missed == completed`) only reconcile at quiescence.
#[derive(Debug)]
pub struct ShardedMetrics {
    shards: Vec<MetricShard>,
    next_recorder: AtomicUsize,
}

impl Default for ShardedMetrics {
    fn default() -> Self {
        Self::new(DEFAULT_METRIC_SHARDS)
    }
}

impl ShardedMetrics {
    /// `shards` independent shards (clamped to ≥ 1).
    pub fn new(shards: usize) -> ShardedMetrics {
        ShardedMetrics {
            shards: (0..shards.max(1)).map(|_| MetricShard::new()).collect(),
            next_recorder: AtomicUsize::new(0),
        }
    }

    /// A recording handle bound to one shard, assigned round-robin.
    /// Handles are cheap; give each recording thread its own so threads
    /// land on distinct shards (sharing one is correct, just contended).
    pub fn recorder(self: &Arc<Self>) -> MetricsRecorder {
        let shard =
            self.next_recorder.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        MetricsRecorder { shards: Arc::clone(self), shard }
    }

    /// Fold every shard into one plain [`Metrics`] — the scrape path.
    pub fn snapshot(&self) -> Metrics {
        let mut folded = Metrics::default();
        for shard in &self.shards {
            folded.merge(&shard.snapshot());
        }
        folded
    }

    /// Requests resolved (completed + failed) across all shards — the
    /// cheap read `queue_depth` needs, without snapshotting histograms.
    pub fn resolved(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.completed.load(Ordering::Relaxed) + s.failed.load(Ordering::Relaxed)
            })
            .sum()
    }
}

/// A lock-free recording handle onto one shard of a [`ShardedMetrics`].
/// The mirror of the old `metrics.lock().unwrap().record_*` calls, minus
/// the lock.
#[derive(Debug, Clone)]
pub struct MetricsRecorder {
    shards: Arc<ShardedMetrics>,
    shard: usize,
}

impl MetricsRecorder {
    fn shard(&self) -> &MetricShard {
        &self.shards.shards[self.shard]
    }

    /// Record one executed batch (see [`Metrics::record_batch`]).
    pub fn record_batch(
        &self,
        config: &str,
        compiled_batch: u64,
        real_samples: u64,
        execute_s: f64,
    ) {
        self.shard().record_batch(config, compiled_batch, real_samples, execute_s);
    }

    /// Record one completed request (see [`Metrics::record_request`]).
    pub fn record_request(&self, class: &str, latency_s: f64, met_deadline: bool) {
        self.shard().record_request(class, latency_s, met_deadline);
    }

    /// Record `n` failed requests.
    pub fn record_failed(&self, n: u64) {
        self.shard().failed.fetch_add(n, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The rank-based exact percentile the histogram approximates:
    /// `sorted[ceil(q·n) - 1]`.
    fn exact_percentile(samples: &[f64], q: f64) -> f64 {
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    /// Bucket width ratio: consecutive upper edges differ by this factor.
    fn width_ratio() -> f64 {
        10f64.powf(1.0 / HIST_BUCKETS_PER_DECADE as f64)
    }

    /// The within-one-bucket guarantee, for in-range positive samples:
    /// `exact <= hist_p <= exact * ratio`.
    fn assert_within_one_bucket(samples: &[f64], q: f64) {
        let mut h = LatencyHistogram::new();
        for &s in samples {
            h.record(s);
        }
        let exact = exact_percentile(samples, q);
        let approx = h.percentile(q);
        assert!(
            approx >= exact * (1.0 - 1e-12),
            "q={q}: histogram {approx} below exact {exact}"
        );
        assert!(
            approx <= exact * width_ratio() * (1.0 + 1e-12),
            "q={q}: histogram {approx} more than one bucket above exact {exact}"
        );
    }

    #[test]
    fn bucket_boundaries_are_log_spaced_and_monotone() {
        // The upper edges grow by exactly one width ratio per bucket.
        for b in 1..=HIST_BUCKETS {
            let lo = LatencyHistogram::upper_edge(b - 1);
            let hi = LatencyHistogram::upper_edge(b);
            assert!(
                (hi / lo - width_ratio()).abs() < 1e-9,
                "bucket {b}: ratio {}",
                hi / lo
            );
        }
        // Decade alignment: 16 buckets per decade means edge 16 is 10x
        // the minimum, edge 32 is 100x, ...
        assert!((LatencyHistogram::upper_edge(HIST_BUCKETS_PER_DECADE) / (HIST_MIN_S * 10.0) - 1.0).abs() < 1e-9);
        assert!((LatencyHistogram::upper_edge(HIST_BUCKETS) / 1e2 - 1.0).abs() < 1e-9);
        // Index assignment: a sample strictly inside bucket b's range maps
        // to b, and the index function is monotone in the sample.
        for b in 1..=HIST_BUCKETS {
            let mid = (LatencyHistogram::upper_edge(b - 1) * LatencyHistogram::upper_edge(b)).sqrt();
            assert_eq!(LatencyHistogram::bucket_index(mid), b, "midpoint of bucket {b}");
        }
        let mut last = 0;
        for i in 0..400 {
            let x = 1e-7 * 1.1f64.powi(i);
            let idx = LatencyHistogram::bucket_index(x);
            assert!(idx >= last, "bucket index not monotone at {x}");
            last = idx;
        }
        // Out-of-range samples land in underflow/overflow, never panic.
        assert_eq!(LatencyHistogram::bucket_index(0.0), 0);
        assert_eq!(LatencyHistogram::bucket_index(-1.0), 0);
        assert_eq!(LatencyHistogram::bucket_index(f64::NAN), 0);
        assert_eq!(LatencyHistogram::bucket_index(1e9), HIST_BUCKETS + 1);
    }

    #[test]
    fn percentiles_match_exact_on_adversarial_distributions() {
        // All samples in one bucket (a constant distribution).
        let constant: Vec<f64> = vec![0.0123; 500];
        for q in [0.0, 0.5, 0.99, 0.999, 1.0] {
            assert_within_one_bucket(&constant, q);
        }
        // Bimodal: half very fast, half very slow — percentiles must jump
        // between the modes, never interpolate across the gap.
        let bimodal: Vec<f64> =
            (0..500).map(|_| 1e-4).chain((0..500).map(|_| 2.0)).collect();
        for q in [0.25, 0.5, 0.501, 0.9, 0.999] {
            assert_within_one_bucket(&bimodal, q);
        }
        let mut h = LatencyHistogram::new();
        for &s in &bimodal {
            h.record(s);
        }
        assert!(h.percentile(0.25) < 1e-3, "fast mode");
        assert!(h.percentile(0.9) > 1.0, "slow mode — no cross-gap interpolation");
        // A single sample: every percentile is that sample's bucket.
        let single = vec![0.037];
        for q in [0.0, 0.5, 1.0] {
            assert_within_one_bucket(&single, q);
        }
        // A geometric spread across many decades.
        let spread: Vec<f64> = (0..200).map(|i| 1e-5 * 1.08f64.powi(i)).collect();
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_within_one_bucket(&spread, q);
        }
    }

    #[test]
    fn histogram_handles_out_of_range_and_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.mean_s(), 0.0);
        let mut h = LatencyHistogram::new();
        h.record(1e-9); // underflow
        assert_eq!(h.percentile(0.5), HIST_MIN_S, "underflow reports the floor");
        let mut h = LatencyHistogram::new();
        h.record(7e3); // overflow (above the 100 s top)
        assert_eq!(h.percentile(0.999), 7e3, "overflow reports the exact max sample");
        assert_eq!(h.max_s(), 7e3);
    }

    #[test]
    fn merge_equals_recording_the_union() {
        let xs: Vec<f64> = (0..300).map(|i| 1e-4 * (i + 1) as f64).collect();
        let ys: Vec<f64> = (0..200).map(|i| 0.5 + 0.01 * i as f64).collect();
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut both = LatencyHistogram::new();
        for &x in &xs {
            a.record(x);
            both.record(x);
        }
        for &y in &ys {
            b.record(y);
            both.record(y);
        }
        a.merge(&b);
        // Bucket counts, totals, and the max are exact; the running sum is
        // compared with a tolerance (merge adds partial sums, so the f64
        // rounding can differ from sequential recording in the last ulp).
        assert_eq!(a.counts, both.counts, "merge must equal recording the union");
        assert_eq!(a.count(), 500);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.max_s(), both.max_s());
        assert!((a.sum_s() - both.sum_s()).abs() < 1e-9);
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(a.percentile(q), both.percentile(q), "q={q}");
        }
    }

    #[test]
    fn records_accumulate() {
        let mut m = Metrics::default();
        m.record_batch("int8", 4, 3, 0.01);
        m.record_batch("int4", 8, 8, 0.02);
        m.record_request("low", 0.05, true);
        m.record_request("deadline", 0.15, false);
        assert_eq!(m.batches, 2);
        assert_eq!(m.padded_samples, 1);
        assert_eq!(m.per_config["int8"], 3);
        assert_eq!(m.per_config["int4"], 8);
        assert_eq!(m.per_batch_size[&8], 1);
        assert_eq!(m.completed, 2);
        assert_eq!(m.deadline_met, 1);
        assert_eq!(m.deadline_missed, 1);
        assert!((m.deadline_met_frac() - 0.5).abs() < 1e-12);
        assert!((m.latency_mean() - 0.10).abs() < 1e-12);
        assert!((m.batch_occupancy() - 11.0 / 12.0).abs() < 1e-12);
        // Per-class outcomes split by label.
        assert_eq!(m.per_class["low"].completed, 1);
        assert_eq!(m.per_class["low"].deadline_met, 1);
        assert_eq!(m.per_class["deadline"].completed, 1);
        assert_eq!(m.per_class["deadline"].deadline_met, 0);
        assert_eq!(m.per_class["deadline"].met_frac(), 0.0);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(0.99), 0.0);
        assert_eq!(m.latency_p_window(0.99), 0.0);
        assert_eq!(m.throughput(1.0), 0.0);
        assert_eq!(m.batch_occupancy(), 0.0);
        assert_eq!(m.deadline_met_frac(), 1.0);
    }

    #[test]
    fn percentiles_order() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record_request("high", i as f64 / 100.0, true);
        }
        assert!(m.latency_p(0.5) < m.latency_p(0.99));
        assert!(m.latency_p(0.99) <= m.latency_p(0.999));
    }

    #[test]
    fn latency_p_takes_a_fraction_not_a_percent() {
        // 100 uniform samples in (0, 1]: the median must land near 0.5,
        // not near the bottom of the distribution (which is what passing
        // the fraction straight through to the percent-scaled percentile
        // helper used to produce).
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record_request("high", i as f64 / 100.0, true);
        }
        let p50 = m.latency_p(0.5);
        assert!((0.4..=0.6).contains(&p50), "median {p50} is not near 0.5");
        let p999 = m.latency_p(0.999);
        assert!(p999 >= 0.99, "p999 {p999} should sit at the top of the distribution");
        // The window path takes the same fraction scale.
        let w50 = m.latency_p_window(0.5);
        assert!((0.4..=0.6).contains(&w50), "window median {w50} is not near 0.5");
    }

    #[test]
    fn stats_document_reports_tail_latency_and_met_rate() {
        let mut m = Metrics::default();
        for i in 0..10 {
            m.record_request("medium", 0.01 * (i + 1) as f64, i < 9);
        }
        let doc = m.to_json(1.0);
        let p50 = doc.get("latency_p50_s").and_then(Json::as_f64).unwrap();
        let p99 = doc.get("latency_p99_s").and_then(Json::as_f64).unwrap();
        let p999 = doc.get("latency_p999_s").and_then(Json::as_f64).unwrap();
        assert!(p50 <= p99 && p99 <= p999, "percentiles must be ordered: {p50} {p99} {p999}");
        assert!((0.04..=0.07).contains(&p50), "median of 0.01..=0.10 near 0.055, got {p50}");
        let met = doc.get("deadline_met_frac").and_then(Json::as_f64).unwrap();
        assert!((met - 0.9).abs() < 1e-12, "9 of 10 met: {met}");
    }

    #[test]
    fn latency_windows_stay_bounded_while_counters_stay_exact() {
        let mut m = Metrics::default();
        for i in 0..(LATENCY_WINDOW as u64 + 500) {
            m.record_request("high", i as f64, true);
            m.record_batch("int8", 1, 1, i as f64);
        }
        assert_eq!(m.request_latencies.len(), LATENCY_WINDOW);
        assert_eq!(m.execute_latencies.len(), LATENCY_WINDOW);
        assert_eq!(m.completed, LATENCY_WINDOW as u64 + 500);
        assert_eq!(m.batches, LATENCY_WINDOW as u64 + 500);
        // The ring holds the most recent samples: the 500 oldest were
        // overwritten, the 501st survives, and the newest is present.
        assert!(!m.request_latencies.contains(&0.0));
        assert!(!m.request_latencies.contains(&499.0));
        assert!(m.request_latencies.contains(&500.0));
        assert!(m.request_latencies.contains(&((LATENCY_WINDOW as u64 + 499) as f64)));
        // The histogram never forgets: every sample ever recorded counts.
        assert_eq!(m.request_hist.count(), LATENCY_WINDOW as u64 + 500);
    }

    #[test]
    fn histogram_percentiles_survive_a_long_run_where_the_window_forgets() {
        // The regression this layer fixes: a slow early phase (500 × 10 s)
        // followed by a long fast phase (4600 × 1 ms). The ring holds only
        // the most recent LATENCY_WINDOW samples — all fast — so the
        // window p999 reports ~1 ms and silently forgets the slow tail.
        // The histogram keeps every sample: 500 of 5100 are slow, so the
        // true p999 (rank 5095) is a slow sample, and /stats (which now
        // reads the histogram) must report it.
        let mut m = Metrics::default();
        for _ in 0..500 {
            m.record_request("high", 10.0, false);
        }
        for _ in 0..4600 {
            m.record_request("high", 0.001, true);
        }
        let window_p999 = m.latency_p_window(0.999);
        let hist_p999 = m.latency_p(0.999);
        assert!(window_p999 < 0.01, "the bounded ring forgot the slow phase: {window_p999}");
        assert!(hist_p999 > 1.0, "the histogram must remember it: {hist_p999}");
        let doc = m.to_json(1.0);
        let stats_p999 = doc.get("latency_p999_s").and_then(Json::as_f64).unwrap();
        assert_eq!(stats_p999, hist_p999, "/stats percentiles must route through the histogram");
    }

    #[test]
    fn stats_and_metrics_documents_agree_and_reconcile() {
        // The agreement pin: /stats and /metrics are rendered from the
        // same counters and the same histograms, so their shared fields
        // must be equal — and the deadline counters must reconcile
        // (met + missed == completed) in both documents.
        let mut m = Metrics::default();
        let latencies = [0.002, 0.005, 0.011, 0.03, 0.3, 1.7];
        for (i, &l) in latencies.iter().enumerate() {
            let class = ["low", "medium", "deadline"][i % 3];
            m.record_request(class, l, i % 4 != 0);
        }
        m.record_batch("int8", 4, 3, 0.01);
        let stats = m.to_json(2.0);
        let metrics = m.to_metrics_json(2.0, 1);
        for key in ["completed", "failed", "deadline_met", "deadline_missed", "deadline_met_frac"]
        {
            assert_eq!(stats.get(key).and_then(Json::as_f64), metrics.get(key).and_then(Json::as_f64), "{key}");
        }
        for (stats_key, hist_key) in
            [("latency_p50_s", "p50_s"), ("latency_p99_s", "p99_s"), ("latency_p999_s", "p999_s")]
        {
            assert_eq!(
                stats.get(stats_key).and_then(Json::as_f64),
                metrics.get("latency").and_then(|l| l.get(hist_key)).and_then(Json::as_f64),
                "{stats_key} must equal the histogram's {hist_key}"
            );
        }
        let met = metrics.get("deadline_met").and_then(Json::as_i64).unwrap();
        let missed = metrics.get("deadline_missed").and_then(Json::as_i64).unwrap();
        let completed = metrics.get("completed").and_then(Json::as_i64).unwrap();
        assert_eq!(met + missed, completed, "deadline counters must reconcile");
        assert_eq!(metrics.get("queue_depth").and_then(Json::as_i64), Some(1));
        // Per-class counters reconcile too, and sum to the total.
        let per_class = metrics.get("per_class").and_then(Json::as_obj).unwrap();
        let class_total: i64 = per_class
            .values()
            .map(|c| c.get("completed").and_then(Json::as_i64).unwrap())
            .sum();
        assert_eq!(class_total, completed);
        for (name, c) in per_class {
            let met = c.get("deadline_met").and_then(Json::as_i64).unwrap();
            let missed = c.get("deadline_missed").and_then(Json::as_i64).unwrap();
            let done = c.get("completed").and_then(Json::as_i64).unwrap();
            assert_eq!(met + missed, done, "class {name}");
        }
    }

    #[test]
    fn exec_stat_accumulates_on_the_nanosecond_grid() {
        let mut m = Metrics::default();
        m.record_batch("int8", 4, 3, 0.010);
        m.record_batch("int8", 4, 4, 0.030);
        m.record_batch("int4", 8, 8, -1.0); // clamped to zero, still counted
        let e = m.per_config_execute["int8"];
        assert_eq!(e, ExecStat { batches: 2, total_ns: 40_000_000 });
        assert!((e.mean_s() - 0.020).abs() < 1e-12);
        assert_eq!(m.per_config_execute["int4"], ExecStat { batches: 1, total_ns: 0 });
        assert_eq!(ExecStat::default().mean_s(), 0.0);
        // The stat round-trips through the JSON docs in the exact shape
        // fleet_prior_means mines: {batches, total_s, mean_s}.
        for doc in [m.to_json(1.0), m.to_metrics_json(1.0, 0)] {
            let table = doc.get("per_config_execute").expect("stat exported");
            let int8 = table.get("int8").unwrap();
            assert_eq!(int8.get("batches").and_then(Json::as_f64), Some(2.0));
            assert_eq!(int8.get("total_s").and_then(Json::as_f64), Some(0.04));
            assert_eq!(int8.get("mean_s").and_then(Json::as_f64), Some(0.02));
        }
    }

    #[test]
    fn metrics_merge_equals_recording_the_union() {
        // The Metrics-level analogue of the histogram merge pin: two
        // documents merged must equal one document that recorded both
        // streams (modulo the last-ulp float sums the histogram pin
        // already tolerates).
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        let mut both = Metrics::default();
        for i in 0..40 {
            let l = 0.001 * (i + 1) as f64;
            a.record_request(["low", "deadline"][i % 2], l, i % 3 != 0);
            both.record_request(["low", "deadline"][i % 2], l, i % 3 != 0);
            a.record_batch("int8", 4, 3, l);
            both.record_batch("int8", 4, 3, l);
        }
        for i in 0..25 {
            let l = 0.5 + 0.01 * i as f64;
            b.record_request(["low", "high"][i % 2], l, false);
            both.record_request(["low", "high"][i % 2], l, false);
            b.record_batch("int4", 8, 8, l);
            both.record_batch("int4", 8, 8, l);
        }
        a.merge(&b);
        assert_eq!(a.completed, both.completed);
        assert_eq!(a.deadline_met, both.deadline_met);
        assert_eq!(a.deadline_missed, both.deadline_missed);
        assert_eq!(a.batches, both.batches);
        assert_eq!(a.padded_samples, both.padded_samples);
        assert_eq!(a.per_config, both.per_config);
        assert_eq!(a.per_config_execute, both.per_config_execute);
        assert_eq!(a.per_batch_size, both.per_batch_size);
        assert_eq!(a.request_hist.counts, both.request_hist.counts);
        assert_eq!(a.execute_hist.counts, both.execute_hist.counts);
        for (class, m) in &both.per_class {
            let merged = &a.per_class[class];
            assert_eq!(merged.completed, m.completed, "{class}");
            assert_eq!(merged.deadline_met, m.deadline_met, "{class}");
            assert_eq!(merged.latency.counts, m.latency.counts, "{class}");
        }
        for q in [0.1, 0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.latency_p(q), both.latency_p(q), "q={q}");
        }
        // Ring bound survives merging.
        let mut big = Metrics::default();
        for i in 0..LATENCY_WINDOW {
            big.record_request("high", i as f64, true);
        }
        big.merge(&both);
        assert_eq!(big.request_latencies.len(), LATENCY_WINDOW);
        // The most recent samples (the merged-in tail) survive the cut.
        assert!(big.request_latencies.contains(&0.74));
    }

    #[test]
    fn sharded_snapshot_matches_plain_recording() {
        // Recording the same stream through sharded recorders (split
        // across shards) and through a plain Metrics must render the
        // same documents: same counters, same bucket counts, same
        // percentiles, same keyed tables.
        let sharded = Arc::new(ShardedMetrics::new(3));
        let recorders: Vec<MetricsRecorder> = (0..3).map(|_| sharded.recorder()).collect();
        let mut plain = Metrics::default();
        for i in 0..600 {
            let r = &recorders[i % 3];
            let l = 1e-4 * (i + 1) as f64;
            let class = ["low", "medium", "high", "deadline"][i % 4];
            let met = i % 5 != 0;
            r.record_request(class, l, met);
            plain.record_request(class, l, met);
            if i % 2 == 0 {
                let config = ["int8", "int4"][i % 4 / 2];
                r.record_batch(config, 8, 5, l);
                plain.record_batch(config, 8, 5, l);
            }
        }
        recorders[1].record_failed(7);
        plain.failed += 7;
        let snap = sharded.snapshot();
        assert_eq!(snap.completed, plain.completed);
        assert_eq!(snap.failed, plain.failed);
        assert_eq!(snap.deadline_met, plain.deadline_met);
        assert_eq!(snap.deadline_missed, plain.deadline_missed);
        assert_eq!(snap.batches, plain.batches);
        assert_eq!(snap.padded_samples, plain.padded_samples);
        assert_eq!(snap.per_config, plain.per_config);
        assert_eq!(snap.per_config_execute, plain.per_config_execute);
        assert_eq!(snap.per_batch_size, plain.per_batch_size);
        assert_eq!(snap.request_hist.counts, plain.request_hist.counts);
        assert_eq!(snap.execute_hist.counts, plain.execute_hist.counts);
        assert_eq!(snap.request_hist.max_s(), plain.request_hist.max_s());
        assert!((snap.request_hist.sum_s() - plain.request_hist.sum_s()).abs() < 1e-9);
        for q in [0.5, 0.99, 0.999] {
            assert_eq!(snap.latency_p(q), plain.latency_p(q), "q={q}");
        }
        for (class, m) in &plain.per_class {
            let s = &snap.per_class[class];
            assert_eq!(s.completed, m.completed, "{class}");
            assert_eq!(s.deadline_met, m.deadline_met, "{class}");
            assert_eq!(s.latency.counts, m.latency.counts, "{class}");
        }
        assert_eq!(sharded.resolved(), plain.completed + plain.failed);
        // Windows: same multiset of retained samples (all 600 fit).
        let mut got = snap.request_latencies.clone();
        let mut want = plain.request_latencies.clone();
        got.sort_by(f64::total_cmp);
        want.sort_by(f64::total_cmp);
        assert_eq!(got, want);
    }

    #[test]
    fn concurrent_scrapes_are_monotone_and_internally_consistent() {
        // The scrape-consistency pin: writer threads hammer recorders
        // while a reader scrapes — every snapshot must show monotone
        // non-decreasing counters, ordered percentiles, and a histogram
        // whose count equals its bucket mass (no torn percentile reads);
        // after the writers join (a release/acquire edge), the fold must
        // equal the union exactly.
        const WRITERS: usize = 4;
        const PER_WRITER: u64 = 3000;
        let sharded = Arc::new(ShardedMetrics::new(WRITERS));
        let mut handles = Vec::new();
        for w in 0..WRITERS {
            let rec = sharded.recorder();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    let l = 1e-4 + (i as f64 % 97.0) * 1e-4;
                    rec.record_request(["low", "high"][w % 2], l, i % 7 != 0);
                    if i % 8 == 0 {
                        rec.record_batch("int8", 4, 3, l);
                    }
                }
            }));
        }
        let mut last_completed = 0u64;
        let mut last_batches = 0u64;
        loop {
            let snap = sharded.snapshot();
            assert!(
                snap.completed >= last_completed,
                "completed went backwards: {} -> {}",
                last_completed,
                snap.completed
            );
            assert!(snap.batches >= last_batches, "batches went backwards");
            last_completed = snap.completed;
            last_batches = snap.batches;
            let (p50, p99, p999) =
                (snap.latency_p(0.5), snap.latency_p(0.99), snap.latency_p(0.999));
            assert!(p50 <= p99 && p99 <= p999, "torn percentiles: {p50} {p99} {p999}");
            // Internal consistency: the snapshot's count is its bucket
            // mass by construction; met never exceeds completed per class.
            assert_eq!(
                snap.request_hist.count(),
                snap.request_hist.counts.iter().sum::<u64>()
            );
            for (class, c) in &snap.per_class {
                assert!(c.deadline_met <= c.completed, "{class}");
            }
            if snap.completed >= WRITERS as u64 * PER_WRITER {
                break;
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        let total = WRITERS as u64 * PER_WRITER;
        let snap = sharded.snapshot();
        assert_eq!(snap.completed, total);
        assert_eq!(snap.deadline_met + snap.deadline_missed, total);
        assert_eq!(snap.request_hist.count(), total);
        let class_total: u64 = snap.per_class.values().map(|c| c.completed).sum();
        assert_eq!(class_total, total, "shard-merge totals equal the union");
        assert_eq!(snap.per_config["int8"], {
            let batches_per_writer = PER_WRITER.div_ceil(8);
            WRITERS as u64 * batches_per_writer * 3
        });
    }

    #[test]
    fn stats_document_carries_the_serving_story() {
        let mut m = Metrics::default();
        m.record_batch("int8", 4, 4, 0.01);
        for _ in 0..4 {
            m.record_request("high", 0.02, true);
        }
        let doc = m.to_json(2.0);
        assert_eq!(doc.get("completed").and_then(Json::as_i64), Some(4));
        assert_eq!(doc.get("deadline_met").and_then(Json::as_i64), Some(4));
        assert_eq!(doc.get("throughput_rps").and_then(Json::as_f64), Some(2.0));
        assert_eq!(
            doc.get("per_config").and_then(|c| c.get("int8")).and_then(Json::as_i64),
            Some(4)
        );
    }
}
