//! Serving metrics: request counts, latency distribution, deadline
//! outcomes, per-config and per-batch-size usage.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::stats;

/// Retained latency samples per distribution (a sliding window): the
/// serving process is long-running, so sample storage must be bounded —
/// percentiles are over the most recent window, counters stay exact, and
/// a metrics snapshot stays cheap to clone under the worker's mutex.
pub const LATENCY_WINDOW: usize = 4096;

/// Aggregated serving metrics (guarded by a mutex in the coordinator).
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Completed requests.
    pub completed: u64,
    /// Requests that failed (runtime error surfaced to the client).
    pub failed: u64,
    /// Completed requests whose end-to-end latency met their effective
    /// target (explicit deadline, or class target).
    pub deadline_met: u64,
    /// Completed requests flagged as having missed their target.
    pub deadline_missed: u64,
    /// Executed batches.
    pub batches: u64,
    /// Total samples padded (wasted work in partial batches).
    pub padded_samples: u64,
    /// End-to-end per-request latency samples, seconds — the most recent
    /// [`LATENCY_WINDOW`] of them (older samples are overwritten).
    pub request_latencies: Vec<f64>,
    /// Executor (backend execute only) per-batch latency samples, seconds
    /// — the most recent [`LATENCY_WINDOW`] of them.
    pub execute_latencies: Vec<f64>,
    /// Requests served per precision config.
    pub per_config: BTreeMap<String, u64>,
    /// Batches executed per compiled batch size.
    pub per_batch_size: BTreeMap<u64, u64>,
}

/// Push into a bounded ring: grow until `LATENCY_WINDOW`, then overwrite
/// round-robin (`count` is the 1-based total ever recorded).
fn push_windowed(window: &mut Vec<f64>, count: u64, sample: f64) {
    if window.len() < LATENCY_WINDOW {
        window.push(sample);
    } else {
        window[(count - 1) as usize % LATENCY_WINDOW] = sample;
    }
}

impl Metrics {
    /// Record one executed batch.
    pub fn record_batch(
        &mut self,
        config: &str,
        compiled_batch: u64,
        real_samples: u64,
        execute_s: f64,
    ) {
        self.batches += 1;
        self.padded_samples += compiled_batch - real_samples;
        push_windowed(&mut self.execute_latencies, self.batches, execute_s);
        *self.per_config.entry(config.to_string()).or_default() += real_samples;
        *self.per_batch_size.entry(compiled_batch).or_default() += 1;
    }

    /// Record one completed request with its end-to-end latency and
    /// whether it met its effective latency target.
    pub fn record_request(&mut self, latency_s: f64, met_deadline: bool) {
        self.completed += 1;
        if met_deadline {
            self.deadline_met += 1;
        } else {
            self.deadline_missed += 1;
        }
        push_windowed(&mut self.request_latencies, self.completed, latency_s);
    }

    /// Latency percentile over the retained request window, seconds. `q`
    /// is a fraction in `[0, 1]` (`0.5` = median, `0.999` = p999) —
    /// converted here to the percent scale [`stats::percentile`] expects,
    /// so callers quoting "p50" actually get the median rather than the
    /// 0.5th percentile.
    pub fn latency_p(&self, q: f64) -> f64 {
        stats::percentile(&self.request_latencies, q * 100.0)
    }

    /// Mean request latency, seconds.
    pub fn latency_mean(&self) -> f64 {
        stats::mean(&self.request_latencies)
    }

    /// Throughput given a wall-clock window, requests/second.
    pub fn throughput(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.completed as f64 / wall_s
        } else {
            0.0
        }
    }

    /// Mean executed batch occupancy (real samples / compiled batch).
    pub fn batch_occupancy(&self) -> f64 {
        let real: u64 = self.per_config.values().sum();
        let total = real + self.padded_samples;
        if total > 0 {
            real as f64 / total as f64
        } else {
            0.0
        }
    }

    /// Fraction of completed requests that met their target (1.0 when
    /// nothing completed yet).
    pub fn deadline_met_frac(&self) -> f64 {
        if self.completed > 0 {
            self.deadline_met as f64 / self.completed as f64
        } else {
            1.0
        }
    }

    /// The `GET /stats` document of the serving front end (`uptime_s`
    /// feeds the throughput figure).
    pub fn to_json(&self, uptime_s: f64) -> Json {
        Json::obj([
            ("completed", Json::num(self.completed as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("deadline_met", Json::num(self.deadline_met as f64)),
            ("deadline_missed", Json::num(self.deadline_missed as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("batch_occupancy", Json::num(self.batch_occupancy())),
            ("latency_p50_s", Json::num(self.latency_p(0.5))),
            ("latency_p99_s", Json::num(self.latency_p(0.99))),
            ("latency_p999_s", Json::num(self.latency_p(0.999))),
            ("deadline_met_frac", Json::num(self.deadline_met_frac())),
            ("uptime_s", Json::num(uptime_s)),
            ("throughput_rps", Json::num(self.throughput(uptime_s))),
            (
                "per_config",
                Json::obj(
                    self.per_config.iter().map(|(k, &v)| (k.clone(), Json::num(v as f64))),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = Metrics::default();
        m.record_batch("int8", 4, 3, 0.01);
        m.record_batch("int4", 8, 8, 0.02);
        m.record_request(0.05, true);
        m.record_request(0.15, false);
        assert_eq!(m.batches, 2);
        assert_eq!(m.padded_samples, 1);
        assert_eq!(m.per_config["int8"], 3);
        assert_eq!(m.per_config["int4"], 8);
        assert_eq!(m.per_batch_size[&8], 1);
        assert_eq!(m.completed, 2);
        assert_eq!(m.deadline_met, 1);
        assert_eq!(m.deadline_missed, 1);
        assert!((m.deadline_met_frac() - 0.5).abs() < 1e-12);
        assert!((m.latency_mean() - 0.10).abs() < 1e-12);
        assert!((m.batch_occupancy() - 11.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_p(0.99), 0.0);
        assert_eq!(m.throughput(1.0), 0.0);
        assert_eq!(m.batch_occupancy(), 0.0);
        assert_eq!(m.deadline_met_frac(), 1.0);
    }

    #[test]
    fn percentiles_order() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record_request(i as f64 / 100.0, true);
        }
        assert!(m.latency_p(0.5) < m.latency_p(0.99));
        assert!(m.latency_p(0.99) <= m.latency_p(0.999));
    }

    #[test]
    fn latency_p_takes_a_fraction_not_a_percent() {
        // 100 uniform samples in (0, 1]: the median must land near 0.5,
        // not near the bottom of the distribution (which is what passing
        // the fraction straight through to the percent-scaled percentile
        // helper used to produce).
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record_request(i as f64 / 100.0, true);
        }
        let p50 = m.latency_p(0.5);
        assert!((0.4..=0.6).contains(&p50), "median {p50} is not near 0.5");
        let p999 = m.latency_p(0.999);
        assert!(p999 >= 0.99, "p999 {p999} should sit at the top of the window");
    }

    #[test]
    fn stats_document_reports_tail_latency_and_met_rate() {
        let mut m = Metrics::default();
        for i in 0..10 {
            m.record_request(0.01 * (i + 1) as f64, i < 9);
        }
        let doc = m.to_json(1.0);
        let p50 = doc.get("latency_p50_s").and_then(Json::as_f64).unwrap();
        let p99 = doc.get("latency_p99_s").and_then(Json::as_f64).unwrap();
        let p999 = doc.get("latency_p999_s").and_then(Json::as_f64).unwrap();
        assert!(p50 <= p99 && p99 <= p999, "percentiles must be ordered: {p50} {p99} {p999}");
        assert!((0.04..=0.07).contains(&p50), "median of 0.01..=0.10 near 0.055, got {p50}");
        let met = doc.get("deadline_met_frac").and_then(Json::as_f64).unwrap();
        assert!((met - 0.9).abs() < 1e-12, "9 of 10 met: {met}");
    }

    #[test]
    fn latency_windows_stay_bounded_while_counters_stay_exact() {
        let mut m = Metrics::default();
        for i in 0..(LATENCY_WINDOW as u64 + 500) {
            m.record_request(i as f64, true);
            m.record_batch("int8", 1, 1, i as f64);
        }
        assert_eq!(m.request_latencies.len(), LATENCY_WINDOW);
        assert_eq!(m.execute_latencies.len(), LATENCY_WINDOW);
        assert_eq!(m.completed, LATENCY_WINDOW as u64 + 500);
        assert_eq!(m.batches, LATENCY_WINDOW as u64 + 500);
        // The ring holds the most recent samples: the 500 oldest were
        // overwritten, the 501st survives, and the newest is present.
        assert!(!m.request_latencies.contains(&0.0));
        assert!(!m.request_latencies.contains(&499.0));
        assert!(m.request_latencies.contains(&500.0));
        assert!(m.request_latencies.contains(&((LATENCY_WINDOW as u64 + 499) as f64)));
    }

    #[test]
    fn stats_document_carries_the_serving_story() {
        let mut m = Metrics::default();
        m.record_batch("int8", 4, 4, 0.01);
        for _ in 0..4 {
            m.record_request(0.02, true);
        }
        let doc = m.to_json(2.0);
        assert_eq!(doc.get("completed").and_then(Json::as_i64), Some(4));
        assert_eq!(doc.get("deadline_met").and_then(Json::as_i64), Some(4));
        assert_eq!(doc.get("throughput_rps").and_then(Json::as_f64), Some(2.0));
        assert_eq!(
            doc.get("per_config").and_then(|c| c.get("int8")).and_then(Json::as_i64),
            Some(4)
        );
    }
}
