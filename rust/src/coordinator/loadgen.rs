//! `bf-imna loadgen` — an open-loop traffic generator for the serving
//! front end, plus the SLO-report join.
//!
//! **Open loop** means arrivals are scheduled by the workload, never by
//! the server's responses: a [`WorkloadSpec`] plus its seed fully
//! determines the request sequence (arrival times, class draws, input
//! seeds) before the first byte goes on the wire. Replaying the same spec
//! therefore produces a **byte-identical plan** — the client-side record
//! of what was offered — no matter how the server behaved, which is what
//! makes a loadgen run an artifact instead of an anecdote.
//!
//! The pieces:
//!
//! * [`WorkloadSpec`] — serializable workload description (same canonical
//!   JSON discipline as `SweepSpec`): a seeded arrival [`Profile`]
//!   (constant rate, diurnal curve, on/off bursts) over a weighted mix of
//!   [`WorkloadClass`]es, each carrying a full [`RequestSpec`]
//!   (budget class or explicit deadline, priority, batch hint).
//! * [`WorkloadSpec::schedule`] — the deterministic expansion into
//!   [`Arrival`]s: exponential inter-arrival gaps at the profile's
//!   instantaneous rate, weighted class draws, per-request input seeds.
//! * [`run_loadgen`] — the driver: a pacer thread dispatches each arrival
//!   at its scheduled wall-clock time onto a pool of sender threads
//!   sharing one [`ConnPool`]; latency is measured **from the scheduled
//!   arrival time**, so client-side queueing under overload counts
//!   against the server (no coordinated omission).
//! * [`LoadReport`] — the client-side record: the deterministic plan
//!   (with a digest) plus the observed outcomes (per-class counts,
//!   a [`LatencyHistogram`] per class, pool counters).
//! * [`slo_report`] — joins the client record with the server's
//!   `GET /metrics` documents scraped before and after the run: offered
//!   vs achieved rps, client vs server percentiles, met-deadline
//!   fractions, admission rejections.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

use super::metrics::LatencyHistogram;
use super::server::{
    infer_remote_pooled, push_spec_fields, spec_from_json, InferRequest,
};
use super::RequestSpec;
use crate::sim::transport::{ConnPool, PoolStats};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Hard cap on expanded arrivals per run — a guard against specs whose
/// `rps * duration_s` product would materialize an absurd plan, enforced
/// by [`WorkloadSpec::validate`].
pub const MAX_ARRIVALS: u64 = 2_000_000;

/// One weighted member of a workload's request population.
#[derive(Debug, Clone)]
pub struct WorkloadClass {
    /// Class name (the report key; must be unique within a spec).
    pub name: String,
    /// Relative draw weight (> 0; weights need not sum to 1).
    pub weight: f64,
    /// The request descriptor every request of this class carries.
    pub spec: RequestSpec,
}

impl WorkloadClass {
    /// Canonical JSON: `name`, `weight`, plus the wire descriptor fields
    /// (`budget` / `deadline_ms`, `priority`, `batch_hint`) in exactly
    /// the `POST /infer` shape.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("name", Json::str(self.name.clone())),
            ("weight", Json::num(self.weight)),
        ];
        push_spec_fields(&mut pairs, &self.spec);
        Json::obj(pairs)
    }

    /// Parse a value produced by [`Self::to_json`].
    pub fn from_json(v: &Json) -> Result<WorkloadClass, String> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or("workload class: missing 'name'")?
            .to_string();
        let weight = v
            .get("weight")
            .and_then(Json::as_f64)
            .ok_or("workload class: missing 'weight'")?;
        Ok(WorkloadClass { name, weight, spec: spec_from_json(v)? })
    }
}

/// The arrival-rate shape of a workload over its duration.
#[derive(Debug, Clone, PartialEq)]
pub enum Profile {
    /// A flat rate: `rps` for the whole run.
    Constant,
    /// A cosine day-curve: the rate starts at `trough × rps`, peaks at
    /// `rps` half a period in, and returns to the trough each
    /// `period_s` — `rate(t) = rps · (trough + (1−trough) ·
    /// (1 − cos 2πt/period)/2)`.
    Diurnal {
        /// Seconds per full trough→peak→trough cycle.
        period_s: f64,
        /// Rate floor as a fraction of `rps`, in `(0, 1]`.
        trough: f64,
    },
    /// On/off square wave: full `rps` for `on_s` seconds, silence for
    /// `off_s`, repeating.
    Burst {
        /// Seconds at full rate per cycle.
        on_s: f64,
        /// Seconds of silence per cycle.
        off_s: f64,
    },
}

impl Profile {
    /// The profile's mode label (`constant` | `diurnal` | `burst`).
    pub fn mode(&self) -> &'static str {
        match self {
            Profile::Constant => "constant",
            Profile::Diurnal { .. } => "diurnal",
            Profile::Burst { .. } => "burst",
        }
    }

    /// Canonical JSON (`{"mode": ..., ...params}`).
    pub fn to_json(&self) -> Json {
        match self {
            Profile::Constant => Json::obj([("mode", Json::str("constant"))]),
            Profile::Diurnal { period_s, trough } => Json::obj([
                ("mode", Json::str("diurnal")),
                ("period_s", Json::num(*period_s)),
                ("trough", Json::num(*trough)),
            ]),
            Profile::Burst { on_s, off_s } => Json::obj([
                ("mode", Json::str("burst")),
                ("off_s", Json::num(*off_s)),
                ("on_s", Json::num(*on_s)),
            ]),
        }
    }

    /// Parse a value produced by [`Self::to_json`].
    pub fn from_json(v: &Json) -> Result<Profile, String> {
        let mode = v
            .get("mode")
            .and_then(Json::as_str)
            .ok_or("workload profile: missing 'mode'")?;
        let num = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("workload profile: {mode} needs numeric '{key}'"))
        };
        match mode {
            "constant" => Ok(Profile::Constant),
            "diurnal" => Ok(Profile::Diurnal { period_s: num("period_s")?, trough: num("trough")? }),
            "burst" => Ok(Profile::Burst { on_s: num("on_s")?, off_s: num("off_s")? }),
            other => Err(format!(
                "workload profile: unknown mode '{other}' (constant|diurnal|burst)"
            )),
        }
    }
}

/// A serializable open-loop workload: an arrival profile over a weighted
/// class mix, fully determined by its seed.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Workload name (labels reports and artifacts).
    pub name: String,
    /// Seed driving arrivals, class draws, and per-request inputs.
    pub seed: u64,
    /// Peak/mean offered rate, requests per second (the profile modulates
    /// it; for `Constant` it is the rate).
    pub rps: f64,
    /// Run length, seconds.
    pub duration_s: f64,
    /// Arrival-rate shape.
    pub profile: Profile,
    /// The request population (weighted; at least one class).
    pub classes: Vec<WorkloadClass>,
}

/// One planned request: where it sits in time, which class it drew, and
/// the seed its input sample is generated from. Pure data — the whole
/// plan exists before any request is sent.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    /// Scheduled offset from the run's start, seconds.
    pub at_s: f64,
    /// Index into [`WorkloadSpec::classes`].
    pub class: usize,
    /// Seed for this request's input sample.
    pub input_seed: u64,
}

impl WorkloadSpec {
    /// The standard mixed population used by the builtin profiles: a
    /// deadline-carrying interactive class (high priority, batch hint 1),
    /// a medium-budget bulk class, a throughput-oriented low-priority
    /// class, and a strict short-deadline class.
    pub fn default_classes() -> Vec<WorkloadClass> {
        use super::controller::{Budget, BudgetSpec};
        use super::Priority;
        vec![
            WorkloadClass {
                name: "interactive".to_string(),
                weight: 4.0,
                spec: RequestSpec {
                    budget: BudgetSpec::Deadline(Duration::from_millis(50)),
                    priority: Priority::High,
                    batch_hint: Some(1),
                },
            },
            WorkloadClass {
                name: "standard".to_string(),
                weight: 8.0,
                spec: RequestSpec {
                    budget: BudgetSpec::Class(Budget::Medium),
                    ..RequestSpec::default()
                },
            },
            WorkloadClass {
                name: "batch".to_string(),
                weight: 2.0,
                spec: RequestSpec {
                    budget: BudgetSpec::Class(Budget::High),
                    priority: Priority::Low,
                    batch_hint: Some(8),
                },
            },
            WorkloadClass {
                name: "strict".to_string(),
                weight: 1.0,
                spec: RequestSpec {
                    budget: BudgetSpec::Deadline(Duration::from_millis(5)),
                    priority: Priority::High,
                    batch_hint: None,
                },
            },
        ]
    }

    /// A ready-made spec for a builtin profile name (`constant` |
    /// `diurnal` | `burst`) over [`Self::default_classes`]. The diurnal
    /// period is the run length (one full cycle per run); bursts are
    /// 0.5 s on / 0.5 s off.
    pub fn builtin(
        profile: &str,
        rps: f64,
        duration_s: f64,
        seed: u64,
    ) -> Result<WorkloadSpec, String> {
        let profile = match profile {
            "constant" => Profile::Constant,
            "diurnal" => Profile::Diurnal { period_s: duration_s, trough: 0.2 },
            "burst" => Profile::Burst { on_s: 0.5, off_s: 0.5 },
            other => {
                return Err(format!(
                    "unknown builtin profile '{other}' (constant|diurnal|burst)"
                ))
            }
        };
        let spec = WorkloadSpec {
            name: format!("builtin-{}", profile.mode()),
            seed,
            rps,
            duration_s,
            profile,
            classes: Self::default_classes(),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Reject non-viable specs: non-positive or non-finite rates and
    /// durations, plans past [`MAX_ARRIVALS`], empty or ill-weighted
    /// class mixes, duplicate class names, and out-of-range profile
    /// parameters.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.rps.is_finite() && self.rps > 0.0) {
            return Err("workload spec: 'rps' must be a positive finite number".to_string());
        }
        if !(self.duration_s.is_finite() && self.duration_s > 0.0) {
            return Err("workload spec: 'duration_s' must be a positive finite number".to_string());
        }
        if self.rps * self.duration_s > MAX_ARRIVALS as f64 {
            return Err(format!(
                "workload spec: rps x duration_s = {} exceeds the {MAX_ARRIVALS}-arrival cap",
                self.rps * self.duration_s
            ));
        }
        match self.profile {
            Profile::Constant => {}
            Profile::Diurnal { period_s, trough } => {
                if !(period_s.is_finite() && period_s > 0.0) {
                    return Err("workload spec: diurnal 'period_s' must be > 0".to_string());
                }
                if !(trough.is_finite() && trough > 0.0 && trough <= 1.0) {
                    return Err("workload spec: diurnal 'trough' must be in (0, 1]".to_string());
                }
            }
            Profile::Burst { on_s, off_s } => {
                if !(on_s.is_finite() && on_s > 0.0) {
                    return Err("workload spec: burst 'on_s' must be > 0".to_string());
                }
                if !(off_s.is_finite() && off_s >= 0.0) {
                    return Err("workload spec: burst 'off_s' must be >= 0".to_string());
                }
            }
        }
        if self.classes.is_empty() {
            return Err("workload spec: 'classes' must carry at least one class".to_string());
        }
        let mut seen = std::collections::BTreeSet::new();
        for c in &self.classes {
            if !(c.weight.is_finite() && c.weight > 0.0) {
                return Err(format!(
                    "workload spec: class '{}' weight must be a positive finite number",
                    c.name
                ));
            }
            if !seen.insert(c.name.as_str()) {
                return Err(format!("workload spec: duplicate class name '{}'", c.name));
            }
        }
        Ok(())
    }

    /// Canonical JSON (sorted keys, shortest round-trip floats — the
    /// `SweepSpec` discipline), so a spec is a byte-stable artifact.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("classes", Json::arr(self.classes.iter().map(WorkloadClass::to_json))),
            ("duration_s", Json::num(self.duration_s)),
            ("name", Json::str(self.name.clone())),
            ("profile", self.profile.to_json()),
            ("rps", Json::num(self.rps)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    /// Parse and validate a value produced by [`Self::to_json`].
    pub fn from_json(v: &Json) -> Result<WorkloadSpec, String> {
        let spec = WorkloadSpec {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or("workload spec: missing 'name'")?
                .to_string(),
            seed: v
                .get("seed")
                .and_then(Json::as_i64)
                .filter(|&s| s >= 0)
                .ok_or("workload spec: missing or negative 'seed'")? as u64,
            rps: v.get("rps").and_then(Json::as_f64).ok_or("workload spec: missing 'rps'")?,
            duration_s: v
                .get("duration_s")
                .and_then(Json::as_f64)
                .ok_or("workload spec: missing 'duration_s'")?,
            profile: Profile::from_json(
                v.get("profile").ok_or("workload spec: missing 'profile'")?,
            )?,
            classes: v
                .get("classes")
                .and_then(Json::as_arr)
                .ok_or("workload spec: missing 'classes' array")?
                .iter()
                .map(WorkloadClass::from_json)
                .collect::<Result<Vec<_>, String>>()?,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// The profile's instantaneous rate at offset `t` seconds, rps.
    pub fn rate_at(&self, t: f64) -> f64 {
        match self.profile {
            Profile::Constant => self.rps,
            Profile::Diurnal { period_s, trough } => {
                let phase = (1.0 - (2.0 * std::f64::consts::PI * t / period_s).cos()) * 0.5;
                self.rps * (trough + (1.0 - trough) * phase)
            }
            Profile::Burst { on_s, off_s } => {
                if t % (on_s + off_s) < on_s {
                    self.rps
                } else {
                    0.0
                }
            }
        }
    }

    /// Expand the spec into its deterministic arrival plan: a
    /// non-homogeneous Poisson process approximated by exponential gaps
    /// at the rate sampled at each arrival (exact for `Constant`; for
    /// `Burst`, off-windows are skipped to the next on-edge). A pure
    /// function of the spec — two calls return identical plans.
    pub fn schedule(&self) -> Vec<Arrival> {
        let mut rng = Rng::new(self.seed);
        let total_weight: f64 = self.classes.iter().map(|c| c.weight).sum();
        let mut arrivals = Vec::new();
        let mut t = 0.0f64;
        while t < self.duration_s && (arrivals.len() as u64) < MAX_ARRIVALS {
            let rate = self.rate_at(t);
            if rate <= 0.0 {
                // Inside a burst's off-window: jump to the next on-edge
                // (the only profile that can rest at zero — validation
                // keeps the diurnal trough strictly positive).
                let (on_s, off_s) = match &self.profile {
                    Profile::Burst { on_s, off_s } => (*on_s, *off_s),
                    _ => break,
                };
                let cycle = on_s + off_s;
                t = (t / cycle).floor() * cycle + cycle;
                continue;
            }
            // Exponential inter-arrival gap at the current rate; 1 - u is
            // in (0, 1], so the log is finite.
            let gap = -(1.0 - rng.f64()).ln() / rate;
            t += gap;
            if t >= self.duration_s {
                break;
            }
            if self.rate_at(t) <= 0.0 {
                // The gap overshot into a burst off-window; the arrival is
                // thinned and the loop jumps to the next on-edge.
                continue;
            }
            // Weighted class draw.
            let mut pick = rng.f64() * total_weight;
            let mut class = self.classes.len() - 1;
            for (i, c) in self.classes.iter().enumerate() {
                if pick < c.weight {
                    class = i;
                    break;
                }
                pick -= c.weight;
            }
            let input_seed = rng.next_u64();
            arrivals.push(Arrival { at_s: t, class, input_seed });
        }
        arrivals
    }

    /// The deterministic client-side plan document: the spec itself, the
    /// expanded request sequence (time, class, budget/deadline), and a
    /// digest over the sequence. Identical spec ⇒ byte-identical plan,
    /// regardless of what any server does.
    pub fn plan_json(&self) -> Json {
        let arrivals = self.schedule();
        let requests: Vec<Json> = arrivals
            .iter()
            .map(|a| {
                let class = &self.classes[a.class];
                Json::obj([
                    ("at_s", Json::num(a.at_s)),
                    ("budget", Json::str(class.spec.budget.label())),
                    ("class", Json::str(class.name.clone())),
                ])
            })
            .collect();
        let mut per_class: BTreeMap<String, u64> = BTreeMap::new();
        for a in &arrivals {
            *per_class.entry(self.classes[a.class].name.clone()).or_default() += 1;
        }
        let mut doc = Json::obj([
            ("arrivals", Json::num(arrivals.len() as f64)),
            (
                "per_class",
                Json::obj(per_class.into_iter().map(|(k, v)| (k, Json::num(v as f64)))),
            ),
            ("requests", Json::arr(requests)),
            ("spec", self.to_json()),
        ]);
        let digest = fnv1a(doc.to_string().as_bytes());
        if let Json::Obj(map) = &mut doc {
            map.insert("digest".to_string(), Json::str(format!("{digest:016x}")));
        }
        doc
    }
}

/// FNV-1a over bytes — the plan digest (stable, dependency-free).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Driver knobs for [`run_loadgen`].
#[derive(Debug, Clone)]
pub struct LoadgenOpts {
    /// Sender threads (each rides the shared pool; this bounds in-flight
    /// requests, clamped to ≥ 1). The arrival schedule never slows down —
    /// when all senders are busy, dispatched arrivals queue and their
    /// queueing delay counts against measured latency. Defaults to the
    /// machine's available parallelism, so a multi-core loadgen box
    /// offers multi-core load out of the box. Note the plan (and its
    /// digest) is a pure function of the spec — sender count never
    /// changes what is offered, only how fast it drains.
    pub workers: usize,
    /// Per-exchange timeout.
    pub timeout: Duration,
}

impl Default for LoadgenOpts {
    /// `available_parallelism` senders (8 when it cannot be determined),
    /// 30 s per exchange.
    fn default() -> Self {
        let workers = thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
        LoadgenOpts { workers, timeout: Duration::from_secs(30) }
    }
}

/// Observed outcomes for one class (or the whole run).
#[derive(Debug, Default, Clone)]
pub struct ClassOutcome {
    /// Requests dispatched.
    pub sent: u64,
    /// Requests answered 200 with a verdict.
    pub ok: u64,
    /// Requests bounced by admission control (`503` server-busy).
    pub rejected_busy: u64,
    /// Other failures (timeouts, transport errors, non-503 statuses).
    pub errors: u64,
    /// Of `ok`, how many met their deadline/target (server verdict).
    pub met: u64,
    /// Client-measured latency (scheduled arrival → verdict) of `ok`
    /// requests.
    pub latency: LatencyHistogram,
}

impl ClassOutcome {
    fn absorb(&mut self, other: &ClassOutcome) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.rejected_busy += other.rejected_busy;
        self.errors += other.errors;
        self.met += other.met;
        self.latency.merge(&other.latency);
    }

    /// Met-deadline fraction over answered requests (1.0 when none).
    pub fn met_frac(&self) -> f64 {
        if self.ok > 0 {
            self.met as f64 / self.ok as f64
        } else {
            1.0
        }
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("errors", Json::num(self.errors as f64)),
            ("latency_p50_s", Json::num(self.latency.percentile(0.5))),
            ("latency_p99_s", Json::num(self.latency.percentile(0.99))),
            ("latency_p999_s", Json::num(self.latency.percentile(0.999))),
            ("met", Json::num(self.met as f64)),
            ("met_frac", Json::num(self.met_frac())),
            ("ok", Json::num(self.ok as f64)),
            ("rejected_busy", Json::num(self.rejected_busy as f64)),
            ("sent", Json::num(self.sent as f64)),
        ])
    }
}

/// The client-side record of one loadgen run: the deterministic plan plus
/// everything observed on the wire.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// The deterministic plan ([`WorkloadSpec::plan_json`]) — the
    /// byte-identical-under-replay half.
    pub plan: Json,
    /// Wall-clock from first scheduled arrival to last verdict, seconds.
    pub wall_s: f64,
    /// Aggregate outcomes across all classes.
    pub total: ClassOutcome,
    /// Outcomes per class name.
    pub per_class: BTreeMap<String, ClassOutcome>,
    /// The shared connection pool's counters.
    pub pool: PoolStats,
    /// Sender threads the run used.
    pub senders: usize,
    /// Seconds sender threads spent busy on exchanges, summed across all
    /// senders — [`Self::sender_utilization`] is this over
    /// `senders × wall_s`.
    pub send_busy_s: f64,
}

impl LoadReport {
    /// Offered rate: planned arrivals over the spec duration, rps.
    pub fn offered_rps(&self) -> f64 {
        let arrivals =
            self.plan.get("arrivals").and_then(Json::as_f64).unwrap_or(0.0);
        let duration = self
            .plan
            .get("spec")
            .and_then(|s| s.get("duration_s"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if duration > 0.0 {
            arrivals / duration
        } else {
            0.0
        }
    }

    /// Achieved rate: answered requests over the run's wall clock, rps.
    pub fn achieved_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total.ok as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Fraction of sender-thread capacity the run consumed: busy seconds
    /// over `senders × wall_s`. Near 1.0 means the client side was
    /// saturated (add `--workers`); low values mean the offered load left
    /// sender capacity idle and measured latency is the server's.
    pub fn sender_utilization(&self) -> f64 {
        if self.wall_s > 0.0 && self.senders > 0 {
            (self.send_busy_s / (self.senders as f64 * self.wall_s)).min(1.0)
        } else {
            0.0
        }
    }

    /// The full report document (plan + observed).
    pub fn to_json(&self) -> Json {
        Json::obj([
            (
                "observed",
                Json::obj([
                    ("achieved_rps", Json::num(self.achieved_rps())),
                    (
                        "per_class",
                        Json::obj(
                            self.per_class.iter().map(|(k, v)| (k.clone(), v.to_json())),
                        ),
                    ),
                    (
                        "pool",
                        Json::obj([
                            ("discards", Json::num(self.pool.discards as f64)),
                            ("fresh_connects", Json::num(self.pool.fresh_connects as f64)),
                            ("reuses", Json::num(self.pool.reuses as f64)),
                            ("stale_retries", Json::num(self.pool.stale_retries as f64)),
                        ]),
                    ),
                    ("send_busy_s", Json::num(self.send_busy_s)),
                    ("sender_utilization", Json::num(self.sender_utilization())),
                    ("senders", Json::num(self.senders as f64)),
                    ("total", self.total.to_json()),
                    ("wall_s", Json::num(self.wall_s)),
                ]),
            ),
            ("plan", self.plan.clone()),
        ])
    }
}

/// Play a workload against a live serving front end at `addr`
/// (host:port). Scrapes `/healthz` first for the model contract, expands
/// the plan, then paces it out open-loop. Fails only on setup errors
/// (unreachable server, invalid spec) — per-request failures are
/// outcomes, recorded in the report.
pub fn run_loadgen(
    addr: &str,
    spec: &WorkloadSpec,
    opts: &LoadgenOpts,
) -> Result<LoadReport, String> {
    spec.validate()?;
    let health = super::server::fetch_health(addr, opts.timeout)?;
    let sample_elems = health
        .get("sample_elems")
        .and_then(Json::as_i64)
        .filter(|&n| n > 0)
        .ok_or_else(|| format!("{addr}: /healthz did not report sample_elems"))?
        as usize;

    let arrivals = spec.schedule();
    let plan = spec.plan_json();
    let workers = opts.workers.max(1);
    let pool = ConnPool::new(workers);

    // Pacer → senders over a channel: the pacer owns the clock and never
    // waits on responses (open loop); senders pull dispatched arrivals
    // and carry them over the shared pool. Each in-flight item carries
    // its scheduled Instant so latency includes any dispatch backlog.
    let (work_tx, work_rx) = mpsc::channel::<(Arrival, Instant)>();
    let work_rx = Mutex::new(work_rx);
    let started = Instant::now();
    let mut outcomes: Vec<(Vec<ClassOutcome>, LatencyHistogram, f64)> = Vec::new();
    thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let work_rx = &work_rx;
            let pool = &pool;
            let spec = &spec;
            handles.push(scope.spawn(move || {
                let mut per_class: Vec<ClassOutcome> =
                    vec![ClassOutcome::default(); spec.classes.len()];
                let mut all = LatencyHistogram::new();
                let mut busy = Duration::ZERO;
                loop {
                    let item = {
                        let rx = work_rx.lock().unwrap();
                        rx.recv()
                    };
                    let Ok((arrival, scheduled)) = item else { break };
                    // Busy time starts at pickup, not at the scheduled
                    // instant: dispatch backlog is the *server's* debt
                    // (it counts against latency), sender utilization
                    // measures only what this thread actually spent.
                    let picked_up = Instant::now();
                    let class = &spec.classes[arrival.class];
                    let mut input_rng = Rng::new(arrival.input_seed);
                    let input: Vec<f32> =
                        (0..sample_elems).map(|_| input_rng.f64() as f32).collect();
                    let req = InferRequest { input, spec: class.spec.clone() };
                    let out = &mut per_class[arrival.class];
                    out.sent += 1;
                    match infer_remote_pooled(pool, addr, &req, opts.timeout) {
                        Ok(resp) => {
                            out.ok += 1;
                            out.met += u64::from(resp.met_deadline);
                            let latency = scheduled.elapsed().as_secs_f64();
                            out.latency.record(latency);
                            all.record(latency);
                        }
                        Err(e) if e.contains("HTTP 503") => out.rejected_busy += 1,
                        Err(_) => out.errors += 1,
                    }
                    busy += picked_up.elapsed();
                }
                (per_class, all, busy.as_secs_f64())
            }));
        }

        // The pacer: dispatch each arrival at its scheduled offset.
        for arrival in &arrivals {
            let scheduled = started + Duration::from_secs_f64(arrival.at_s);
            let now = Instant::now();
            if scheduled > now {
                thread::sleep(scheduled - now);
            }
            if work_tx.send((arrival.clone(), scheduled)).is_err() {
                break;
            }
        }
        drop(work_tx); // senders drain the backlog, then exit
        for h in handles {
            if let Ok(tally) = h.join() {
                outcomes.push(tally);
            }
        }
    });
    let wall_s = started.elapsed().as_secs_f64();

    let mut per_class_merged: Vec<ClassOutcome> =
        vec![ClassOutcome::default(); spec.classes.len()];
    let mut total = ClassOutcome::default();
    let mut send_busy_s = 0.0;
    for (per_class, all, busy_s) in &outcomes {
        for (merged, part) in per_class_merged.iter_mut().zip(per_class) {
            merged.absorb(part);
        }
        total.latency.merge(all);
        send_busy_s += busy_s;
    }
    for c in &per_class_merged {
        total.sent += c.sent;
        total.ok += c.ok;
        total.rejected_busy += c.rejected_busy;
        total.errors += c.errors;
        total.met += c.met;
    }
    let per_class = spec
        .classes
        .iter()
        .zip(per_class_merged)
        .map(|(c, o)| (c.name.clone(), o))
        .collect();
    Ok(LoadReport { plan, wall_s, total, per_class, pool: pool.stats(), senders: workers, send_busy_s })
}

/// Read a numeric field (possibly nested one level, `"a.b"`) out of a
/// `/metrics` document; 0.0 when absent.
fn metric_num(doc: &Json, path: &str) -> f64 {
    let mut cur = doc;
    for part in path.split('.') {
        match cur.get(part) {
            Some(next) => cur = next,
            None => return 0.0,
        }
    }
    cur.as_f64().unwrap_or(0.0)
}

/// Join the client-side [`LoadReport`] with the server's `GET /metrics`
/// documents scraped before and after the run into the SLO-report
/// artifact: offered vs achieved rps, client-side per-class percentiles
/// and met fractions, and the server-side deltas (completions, deadline
/// verdicts, admission rejections, connection churn) plus the server's
/// cumulative latency percentiles.
///
/// The server's counters are cumulative since *its* start, not the
/// run's: if the server restarted between the two scrapes, `after` can
/// be smaller than `before` and the raw differences go negative. A
/// negative delta is impossible for a monotonic counter, so each one
/// clamps to zero and the report carries `server.server_restarted:
/// true` — the window's server-side numbers are unusable, and the flag
/// says so instead of smuggling negatives into downstream gates.
pub fn slo_report(report: &LoadReport, before: &Json, after: &Json) -> Json {
    let mut restarted = false;
    let mut delta = |path: &str| {
        let d = metric_num(after, path) - metric_num(before, path);
        if d < 0.0 {
            restarted = true;
            return 0.0;
        }
        d
    };
    let spec = report.plan.get("spec").cloned().unwrap_or(Json::Null);
    let server_completed = delta("completed");
    let server_met = delta("deadline_met");
    let admission_rejections = delta("connections.rejected_busy");
    let connections_accepted = delta("connections.accepted");
    let connections_dropped = delta("connections.dropped");
    let deadline_missed = delta("deadline_missed");
    let failed = delta("failed");
    let server_met_frac =
        if server_completed > 0.0 { server_met / server_completed } else { 1.0 };
    Json::obj([
        (
            "client",
            Json::obj([
                ("achieved_rps", Json::num(report.achieved_rps())),
                ("errors", Json::num(report.total.errors as f64)),
                ("latency_p50_s", Json::num(report.total.latency.percentile(0.5))),
                ("latency_p99_s", Json::num(report.total.latency.percentile(0.99))),
                ("latency_p999_s", Json::num(report.total.latency.percentile(0.999))),
                ("met_frac", Json::num(report.total.met_frac())),
                ("ok", Json::num(report.total.ok as f64)),
                (
                    "per_class",
                    Json::obj(
                        report.per_class.iter().map(|(k, v)| (k.clone(), v.to_json())),
                    ),
                ),
                ("rejected_busy", Json::num(report.total.rejected_busy as f64)),
                ("sent", Json::num(report.total.sent as f64)),
                ("wall_s", Json::num(report.wall_s)),
            ]),
        ),
        ("kind", Json::str("slo-report")),
        (
            "offered",
            Json::obj([
                (
                    "arrivals",
                    Json::num(report.plan.get("arrivals").and_then(Json::as_f64).unwrap_or(0.0)),
                ),
                (
                    "digest",
                    report.plan.get("digest").cloned().unwrap_or(Json::Null),
                ),
                ("rps", Json::num(report.offered_rps())),
            ]),
        ),
        (
            "server",
            Json::obj([
                ("admission_rejections_delta", Json::num(admission_rejections)),
                ("completed_delta", Json::num(server_completed)),
                ("connections_accepted_delta", Json::num(connections_accepted)),
                ("connections_dropped_delta", Json::num(connections_dropped)),
                ("deadline_met_delta", Json::num(server_met)),
                ("deadline_missed_delta", Json::num(deadline_missed)),
                ("failed_delta", Json::num(failed)),
                ("latency_p50_s", Json::num(metric_num(after, "latency.p50_s"))),
                ("latency_p99_s", Json::num(metric_num(after, "latency.p99_s"))),
                ("latency_p999_s", Json::num(metric_num(after, "latency.p999_s"))),
                ("met_frac_delta_window", Json::num(server_met_frac)),
                ("queue_depth_after", Json::num(metric_num(after, "queue_depth"))),
                ("server_restarted", Json::Bool(restarted)),
            ]),
        ),
        ("workload", spec),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec(profile: Profile) -> WorkloadSpec {
        WorkloadSpec {
            name: "t".to_string(),
            seed: 11,
            rps: 200.0,
            duration_s: 2.0,
            profile,
            classes: WorkloadSpec::default_classes(),
        }
    }

    #[test]
    fn spec_json_round_trips_byte_identically() {
        for profile in [
            Profile::Constant,
            Profile::Diurnal { period_s: 2.0, trough: 0.25 },
            Profile::Burst { on_s: 0.5, off_s: 0.25 },
        ] {
            let spec = small_spec(profile);
            let text = spec.to_json().to_string();
            let back = WorkloadSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_json().to_string(), text, "canonical round trip");
        }
    }

    #[test]
    fn from_json_rejects_bad_specs() {
        let good = small_spec(Profile::Constant).to_json().to_string();
        for (field, bad) in [
            ("rps", r#""rps":0"#),
            ("rps", r#""rps":-5"#),
            ("duration_s", r#""duration_s":0"#),
            ("seed", r#""seed":-1"#),
        ] {
            let text = {
                // Patch one field in the canonical text.
                let needle_start = good.find(&format!("\"{field}\":")).unwrap();
                let needle_end = good[needle_start..]
                    .find(|c| c == ',' || c == '}')
                    .unwrap()
                    + needle_start;
                format!("{}{}{}", &good[..needle_start], bad, &good[needle_end..])
            };
            assert!(
                WorkloadSpec::from_json(&Json::parse(&text).unwrap()).is_err(),
                "{field} => {bad}"
            );
        }
        // Structural rejections.
        let mut spec = small_spec(Profile::Constant);
        spec.classes.clear();
        assert!(spec.validate().is_err(), "empty classes");
        let mut spec = small_spec(Profile::Constant);
        spec.classes[1].name = spec.classes[0].name.clone();
        assert!(spec.validate().is_err(), "duplicate class names");
        let mut spec = small_spec(Profile::Constant);
        spec.classes[0].weight = 0.0;
        assert!(spec.validate().is_err(), "zero weight");
        let spec = small_spec(Profile::Diurnal { period_s: 1.0, trough: 0.0 });
        assert!(spec.validate().is_err(), "zero trough would stall the schedule");
        let spec = small_spec(Profile::Burst { on_s: 0.0, off_s: 1.0 });
        assert!(spec.validate().is_err(), "zero on-window");
        let mut spec = small_spec(Profile::Constant);
        spec.rps = 1e9;
        spec.duration_s = 1e5;
        assert!(spec.validate().is_err(), "arrival cap");
        // Unknown profile mode.
        assert!(Profile::from_json(
            &Json::parse(r#"{"mode":"sawtooth"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn schedule_is_deterministic_and_seed_sensitive() {
        let spec = small_spec(Profile::Constant);
        let a = spec.schedule();
        let b = spec.schedule();
        assert!(!a.is_empty());
        assert_eq!(a, b, "same spec, same plan");
        let mut reseeded = small_spec(Profile::Constant);
        reseeded.seed = 12;
        assert_ne!(a, reseeded.schedule(), "a different seed must change the plan");
        // And the plan document (the client-side report's deterministic
        // half) is byte-identical across expansions.
        assert_eq!(spec.plan_json().to_string(), spec.plan_json().to_string());
    }

    #[test]
    fn constant_profile_offers_the_requested_rate() {
        let spec = small_spec(Profile::Constant);
        let n = spec.schedule().len() as f64;
        let expected = spec.rps * spec.duration_s;
        assert!(
            (n - expected).abs() < expected * 0.3,
            "{n} arrivals for an expectation of {expected}"
        );
    }

    #[test]
    fn burst_profile_is_silent_in_off_windows() {
        let spec = small_spec(Profile::Burst { on_s: 0.5, off_s: 0.5 });
        let arrivals = spec.schedule();
        assert!(!arrivals.is_empty());
        for a in &arrivals {
            let phase = a.at_s % 1.0;
            assert!(phase < 0.5 + 1e-9, "arrival at {} is inside an off-window", a.at_s);
        }
    }

    #[test]
    fn diurnal_profile_peaks_mid_period() {
        // One full cycle across the run: the rate troughs at the edges and
        // peaks in the middle, so the middle third must out-arrive the
        // first third by roughly the rate ratio.
        let mut spec = small_spec(Profile::Diurnal { period_s: 2.0, trough: 0.1 });
        spec.rps = 500.0;
        let arrivals = spec.schedule();
        let third = spec.duration_s / 3.0;
        let first = arrivals.iter().filter(|a| a.at_s < third).count();
        let middle =
            arrivals.iter().filter(|a| a.at_s >= third && a.at_s < 2.0 * third).count();
        assert!(
            middle as f64 > first as f64 * 1.5,
            "middle third ({middle}) should out-arrive the first ({first})"
        );
    }

    #[test]
    fn builtin_profiles_build_and_validate() {
        for name in ["constant", "diurnal", "burst"] {
            let spec = WorkloadSpec::builtin(name, 100.0, 1.0, 5).unwrap();
            assert_eq!(spec.profile.mode(), name);
            assert!(!spec.classes.is_empty());
            assert!(spec.validate().is_ok());
        }
        assert!(WorkloadSpec::builtin("sawtooth", 100.0, 1.0, 5).is_err());
    }

    #[test]
    fn class_mix_respects_weights() {
        let spec = small_spec(Profile::Constant);
        let arrivals = spec.schedule();
        let mut counts = vec![0usize; spec.classes.len()];
        for a in &arrivals {
            counts[a.class] += 1;
        }
        // "standard" (weight 8) must dominate "strict" (weight 1).
        let standard = counts[1];
        let strict = counts[3];
        assert!(
            standard > strict * 3,
            "weight-8 class ({standard}) should dominate weight-1 ({strict})"
        );
        assert!(counts.iter().all(|&c| c > 0), "every class should appear: {counts:?}");
    }

    #[test]
    fn slo_report_joins_client_and_server_deltas() {
        let spec = small_spec(Profile::Constant);
        let mut total = ClassOutcome::default();
        total.sent = 10;
        total.ok = 8;
        total.met = 6;
        total.rejected_busy = 2;
        for i in 0..8 {
            total.latency.record(0.01 * (i + 1) as f64);
        }
        let report = LoadReport {
            plan: spec.plan_json(),
            wall_s: 2.0,
            total,
            per_class: BTreeMap::new(),
            pool: PoolStats { fresh_connects: 2, reuses: 8, stale_retries: 0, discards: 0 },
            senders: 4,
            send_busy_s: 1.6,
        };
        let before = Json::parse(
            r#"{"completed":100,"deadline_met":90,"deadline_missed":10,"failed":0,
                "connections":{"accepted":5,"rejected_busy":1,"dropped":0},"queue_depth":0,
                "latency":{"p50_s":0.01,"p99_s":0.05,"p999_s":0.09}}"#,
        )
        .unwrap();
        let after = Json::parse(
            r#"{"completed":108,"deadline_met":96,"deadline_missed":12,"failed":0,
                "connections":{"accepted":9,"rejected_busy":3,"dropped":0},"queue_depth":0,
                "latency":{"p50_s":0.012,"p99_s":0.06,"p999_s":0.10}}"#,
        )
        .unwrap();
        let slo = slo_report(&report, &before, &after);
        assert_eq!(slo.get("kind").and_then(Json::as_str), Some("slo-report"));
        let server = slo.get("server").unwrap();
        assert_eq!(server.get("completed_delta").and_then(Json::as_f64), Some(8.0));
        assert_eq!(server.get("deadline_met_delta").and_then(Json::as_f64), Some(6.0));
        assert_eq!(
            server.get("admission_rejections_delta").and_then(Json::as_f64),
            Some(2.0)
        );
        assert_eq!(
            server.get("connections_accepted_delta").and_then(Json::as_f64),
            Some(4.0)
        );
        let client = slo.get("client").unwrap();
        assert_eq!(client.get("met_frac").and_then(Json::as_f64), Some(0.75));
        assert_eq!(client.get("rejected_busy").and_then(Json::as_f64), Some(2.0));
        assert_eq!(server.get("server_restarted"), Some(&Json::Bool(false)));
        assert!(slo.get("offered").and_then(|o| o.get("rps")).is_some());
        assert!(slo.get("workload").and_then(|w| w.get("seed")).is_some());
        // The artifact is canonical: serializing twice is byte-identical.
        assert_eq!(slo.to_string(), slo_report(&report, &before, &after).to_string());
    }

    #[test]
    fn slo_report_clamps_deltas_across_a_server_restart() {
        let spec = small_spec(Profile::Constant);
        let report = LoadReport {
            plan: spec.plan_json(),
            wall_s: 2.0,
            total: ClassOutcome::default(),
            per_class: BTreeMap::new(),
            pool: PoolStats { fresh_connects: 1, reuses: 0, stale_retries: 0, discards: 0 },
            senders: 1,
            send_busy_s: 0.0,
        };
        let before = Json::parse(
            r#"{"completed":100,"deadline_met":90,"deadline_missed":10,"failed":0,
                "connections":{"accepted":5,"rejected_busy":1,"dropped":0},"queue_depth":0,
                "latency":{"p50_s":0.01,"p99_s":0.05,"p999_s":0.09}}"#,
        )
        .unwrap();
        // The server restarted mid-run: its cumulative counters reset, so
        // the `after` scrape is *smaller* than `before`.
        let after = Json::parse(
            r#"{"completed":3,"deadline_met":2,"deadline_missed":1,"failed":0,
                "connections":{"accepted":1,"rejected_busy":0,"dropped":0},"queue_depth":0,
                "latency":{"p50_s":0.012,"p99_s":0.06,"p999_s":0.10}}"#,
        )
        .unwrap();
        let slo = slo_report(&report, &before, &after);
        let server = slo.get("server").unwrap();
        assert_eq!(server.get("server_restarted"), Some(&Json::Bool(true)));
        // Monotonic counters cannot go backwards: every delta clamps to
        // zero instead of going negative.
        for key in [
            "completed_delta",
            "deadline_met_delta",
            "deadline_missed_delta",
            "admission_rejections_delta",
            "connections_accepted_delta",
            "failed_delta",
        ] {
            let v = server.get(key).and_then(Json::as_f64).unwrap();
            assert!(v >= 0.0, "{key} should clamp to >= 0, got {v}");
        }
        assert_eq!(server.get("completed_delta").and_then(Json::as_f64), Some(0.0));
        // With zero completions in the window the met fraction degrades
        // to its vacuous 1.0, not NaN.
        assert_eq!(server.get("met_frac_delta_window").and_then(Json::as_f64), Some(1.0));
    }

    // Live loadgen runs against a spawned server are in
    // rust/tests/serving.rs (replay byte-identity, overload rejections).
}
