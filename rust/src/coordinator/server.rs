//! The networked serving front end — the coordinator on the wire.
//!
//! Serving joins `sweep`/`dispatch` as a networked mode: [`ServingServer`]
//! wraps a [`Coordinator`] in the same dependency-free HTTP/1.1 layer the
//! sweep transport uses (`Content-Length` framing, hard head/body caps,
//! whole-exchange deadline streams — see [`crate::sim::transport`]), with
//! three endpoints:
//!
//! * `POST /infer` — one inference exchange. Either a single sample
//!   ([`InferRequest`] JSON: the input plus the full request descriptor —
//!   budget class or explicit `deadline_ms`, priority, batch hint) or a
//!   **multi-sample** body ([`BatchInferRequest`]: an `inputs` array of
//!   samples sharing one descriptor), whose reply carries one verdict per
//!   sample under `results`. Every verdict carries the logits, the
//!   precision config that served it, and the met-or-flagged-deadline
//!   flag.
//! * `GET /healthz` — liveness plus the model contract (sample element
//!   count, class count, loaded config ladder), so clients can size their
//!   inputs without out-of-band knowledge.
//! * `GET /stats` — the serving [`Metrics`](super::Metrics) document
//!   (completed/failed, deadline met/missed, p50/p99/p999 latency,
//!   met-deadline rate, throughput, per-config mix).
//! * `GET /metrics` — the full observability document: everything above
//!   plus the log-bucketed latency **histograms** (request + execute),
//!   per-class met-deadline rates and latency, queue depth, and the front
//!   end's connection counters (accepted / open / rejected-busy /
//!   dropped). This is what `bf-imna loadgen` scrapes before and after a
//!   run to join server-side deltas into its SLO report.
//!
//! Connections are keep-alive: the server loops framed exchanges on one
//! socket (idle timeout, per-connection request cap, `connection: close`
//! honored — the lifecycle in [`crate::sim::transport`]'s module docs),
//! and the pooled clients ([`infer_remote_pooled`], [`infer_remote_many`],
//! [`fetch_stats_pooled`]) reuse sockets through a
//! [`ConnPool`](crate::sim::transport::ConnPool). An admitted connection
//! holds its admission slot for its whole life, which both knobs bound.
//!
//! CLI front ends: `bf-imna serve --addr HOST:PORT` (server) and
//! `bf-imna infer --addr HOST:PORT` (client; also `--stats`, `--count`,
//! `--batch`). The client half of this module ([`infer_remote`],
//! [`fetch_stats`], [`fetch_health`], and the pooled variants) is what
//! `bf-imna infer` calls.

use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::thread;
use std::time::Duration;

use super::controller::{Budget, BudgetSpec};
use super::{Coordinator, Priority, RequestSpec, Response};
use crate::sim::transport::{
    err_doc, http_request_json, read_request, serve_exchanges, write_response, AdmissionGate,
    ConnPolicy, ConnPool, ConnWorkerPool, DeadlineStream, ReplyBody, Request,
    ACCEPT_BACKOFF_MAX, ACCEPT_BACKOFF_MIN,
};
use crate::util::json::Json;

/// Whole-exchange deadline for reading one `/infer` request and (with a
/// fresh budget) writing one response — generous next to any sane request
/// deadline, tight enough that a slowloris cannot hold a handler thread.
const SERVE_EXCHANGE_DEADLINE: Duration = Duration::from_secs(120);

/// How long a handler waits for the coordinator's reply before giving up
/// with a 500 (the worker thread died or is wedged).
const REPLY_DEADLINE: Duration = Duration::from_secs(300);

/// Largest accepted `deadline_ms` (24 h). Anything above is a client
/// error — and must be rejected *before* `Duration::from_secs_f64`, which
/// panics on durations that overflow.
pub const MAX_DEADLINE_MS: f64 = 86_400_000.0;

/// Wire constant: the `code` the front end attaches to a `503` when its
/// connection budget is exhausted — machine-readable backpressure, like
/// the sweep worker's `worker-busy`.
pub const CODE_SERVER_BUSY: &str = "server-busy";

/// Admission control and connection policy for the serving front end: a
/// hard cap on concurrent connections (each holds one pooled handler
/// thread and, for `/infer`, one pending coordinator reply). Connections beyond
/// the cap are answered `503` + [`CODE_SERVER_BUSY`] by a short-deadline
/// rejection handler that does no coordinator work — the same
/// backpressure discipline the sweep worker applies to `POST /shard`.
///
/// A keep-alive connection holds its admission slot for its whole life,
/// so `idle_timeout` and `max_requests_per_conn` are what bound a quiet
/// or hogging client's hold on the budget.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Concurrent connections allowed (clamped to ≥ 1).
    pub max_concurrent_requests: usize,
    /// How long a keep-alive connection may sit idle between requests
    /// before the server closes it (and frees its admission slot).
    pub idle_timeout: Duration,
    /// Requests served on one connection before the server answers the
    /// last with `connection: close` and hangs up (clamped to ≥ 1).
    pub max_requests_per_conn: usize,
    /// Size of the bounded connection-worker pool: handler threads are
    /// spawned lazily up to this cap and then reused across keep-alive
    /// connections (idle workers park, they are not destroyed). `0`
    /// falls back to spawning one short-lived thread per connection —
    /// the legacy behaviour, kept as the A/B baseline for the `hotpath`
    /// bench. CLI: `bf-imna serve --serve-threads N`.
    pub serve_threads: usize,
}

impl Default for ServeOpts {
    /// 256 concurrent connections — far above the worker thread's
    /// throughput needs, low enough that a connection flood cannot grow
    /// threads and queued requests without bound. Keep-alive connections
    /// idle out after 60 s and are recycled after 1024 requests. The
    /// worker pool matches the connection budget, so an admitted
    /// connection never waits for a handler thread.
    fn default() -> Self {
        ServeOpts {
            max_concurrent_requests: 256,
            idle_timeout: Duration::from_secs(60),
            max_requests_per_conn: 1024,
            serve_threads: 256,
        }
    }
}

/// One wire-level inference request: the input sample plus the request
/// descriptor. The JSON shape is
/// `{"input": [...], "budget": "low"|"medium"|"high" | "deadline_ms": N,
///   "priority": "low"|"normal"|"high", "batch_hint": N}` —
/// exactly one of `budget` / `deadline_ms`; `priority` and `batch_hint`
/// are optional.
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// The input sample, row-major `(H, W, C)`.
    pub input: Vec<f32>,
    /// The request descriptor (budget, priority, batch hint).
    pub spec: RequestSpec,
}

/// Append the descriptor fields (`budget` / `deadline_ms`, `priority`,
/// `batch_hint`) a request body shares regardless of sample count. Also
/// the canonical serialization of a [`RequestSpec`] inside a loadgen
/// `WorkloadClass` — one wire idiom for both.
pub(crate) fn push_spec_fields(pairs: &mut Vec<(&str, Json)>, spec: &RequestSpec) {
    match spec.budget {
        BudgetSpec::Class(b) => pairs.push(("budget", Json::str(b.label()))),
        BudgetSpec::Deadline(d) => pairs.push(("deadline_ms", Json::num(d.as_secs_f64() * 1e3))),
    }
    if spec.priority != Priority::Normal {
        pairs.push(("priority", Json::str(spec.priority.label())));
    }
    if let Some(h) = spec.batch_hint {
        pairs.push(("batch_hint", Json::num(h as f64)));
    }
}

/// Parse the descriptor fields shared by [`InferRequest`] and
/// [`BatchInferRequest`] bodies (and loadgen `WorkloadClass` entries).
/// Rejects requests carrying both a class and a deadline, and non-finite
/// or out-of-range deadlines.
pub(crate) fn spec_from_json(v: &Json) -> Result<RequestSpec, String> {
    let budget = match (v.get("budget"), v.get("deadline_ms")) {
        (Some(_), Some(_)) => {
            return Err(
                "infer request: give either 'budget' or 'deadline_ms', not both".to_string()
            )
        }
        (Some(b), None) => BudgetSpec::Class(Budget::parse(
            b.as_str().ok_or("infer request: 'budget' must be a string")?,
        )?),
        (None, Some(d)) => {
            let ms = d.as_f64().ok_or("infer request: 'deadline_ms' must be a number")?;
            if !(ms.is_finite() && ms > 0.0 && ms <= MAX_DEADLINE_MS) {
                return Err(format!(
                    "infer request: 'deadline_ms' must be in (0, {MAX_DEADLINE_MS}]"
                ));
            }
            BudgetSpec::Deadline(Duration::from_secs_f64(ms / 1e3))
        }
        (None, None) => BudgetSpec::Class(Budget::High),
    };
    let priority = match v.get("priority") {
        None => Priority::Normal,
        Some(p) => {
            Priority::parse(p.as_str().ok_or("infer request: 'priority' must be a string")?)?
        }
    };
    let batch_hint = match v.get("batch_hint") {
        None => None,
        Some(h) => Some(
            h.as_i64()
                .filter(|&n| n >= 1)
                .ok_or("infer request: 'batch_hint' must be an integer >= 1")?
                as u64,
        ),
    };
    Ok(RequestSpec { budget, priority, batch_hint })
}

/// Parse one sample array (a JSON array of numbers) into `f32`s.
fn sample_from_json(v: &Json, what: &str) -> Result<Vec<f32>, String> {
    v.as_arr()
        .ok_or_else(|| format!("infer request: {what} must be an array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| format!("infer request: {what} entries must be numbers"))
        })
        .collect()
}

impl InferRequest {
    /// Serialize to the canonical wire body.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> =
            vec![("input", Json::arr(self.input.iter().map(|&x| Json::num(x as f64))))];
        push_spec_fields(&mut pairs, &self.spec);
        Json::obj(pairs)
    }

    /// Parse a value produced by [`Self::to_json`] (or hand-written by any
    /// HTTP client). Rejects requests carrying both a class and a
    /// deadline, non-finite deadlines, and non-numeric inputs.
    pub fn from_json(v: &Json) -> Result<InferRequest, String> {
        let input = sample_from_json(
            v.get("input").ok_or("infer request: missing 'input' array")?,
            "'input'",
        )?;
        Ok(InferRequest { input, spec: spec_from_json(v)? })
    }
}

/// A multi-sample wire request: many input samples riding one framed
/// `POST /infer` exchange under one shared descriptor. The JSON shape is
/// `{"inputs": [[...], ...], ...}` with the same descriptor fields as
/// [`InferRequest`]; the reply is `{"results": [...]}` with one
/// [`Response`] document per sample, in input order. Amortizes framing
/// as well as connects, and lands all samples in the coordinator's batch
/// window together.
#[derive(Debug, Clone)]
pub struct BatchInferRequest {
    /// The input samples, each row-major `(H, W, C)`.
    pub inputs: Vec<Vec<f32>>,
    /// The request descriptor every sample shares.
    pub spec: RequestSpec,
}

impl BatchInferRequest {
    /// Serialize to the canonical wire body.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![(
            "inputs",
            Json::arr(
                self.inputs
                    .iter()
                    .map(|s| Json::arr(s.iter().map(|&x| Json::num(x as f64)))),
            ),
        )];
        push_spec_fields(&mut pairs, &self.spec);
        Json::obj(pairs)
    }

    /// Parse a value produced by [`Self::to_json`]. Rejects empty sample
    /// lists (an exchange must carry work) and everything
    /// [`InferRequest::from_json`] rejects.
    pub fn from_json(v: &Json) -> Result<BatchInferRequest, String> {
        let inputs = v
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or("infer request: missing 'inputs' array")?
            .iter()
            .enumerate()
            .map(|(i, s)| sample_from_json(s, &format!("'inputs[{i}]'")))
            .collect::<Result<Vec<Vec<f32>>, String>>()?;
        if inputs.is_empty() {
            return Err("infer request: 'inputs' must carry at least one sample".to_string());
        }
        Ok(BatchInferRequest { inputs, spec: spec_from_json(v)? })
    }
}

/// Serialize a coordinator [`Response`] to the `/infer` reply body.
pub fn response_to_json(r: &Response) -> Json {
    Json::obj([
        ("logits", Json::arr(r.logits.iter().map(|&x| Json::num(x as f64)))),
        ("config", Json::str(r.config.clone())),
        ("batch", Json::num(r.batch as f64)),
        ("latency_s", Json::num(r.latency_s)),
        ("target_s", Json::num(r.target_s)),
        ("met_deadline", Json::Bool(r.met_deadline)),
    ])
}

/// Parse an `/infer` reply body back into a [`Response`] (client side).
pub fn response_from_json(v: &Json) -> Result<Response, String> {
    Ok(Response {
        logits: v
            .get("logits")
            .and_then(Json::as_arr)
            .ok_or("infer reply: missing 'logits' array")?
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| "infer reply: 'logits' entries must be numbers".to_string())
            })
            .collect::<Result<Vec<f32>, String>>()?,
        config: v
            .get("config")
            .and_then(Json::as_str)
            .ok_or("infer reply: missing 'config'")?
            .to_string(),
        batch: v
            .get("batch")
            .and_then(Json::as_i64)
            .filter(|&b| b >= 1)
            .ok_or("infer reply: missing 'batch'")? as u64,
        latency_s: v
            .get("latency_s")
            .and_then(Json::as_f64)
            .ok_or("infer reply: missing 'latency_s'")?,
        target_s: v
            .get("target_s")
            .and_then(Json::as_f64)
            .ok_or("infer reply: missing 'target_s'")?,
        met_deadline: v
            .get("met_deadline")
            .and_then(Json::as_bool)
            .ok_or("infer reply: missing 'met_deadline'")?,
    })
}

/// Connection-level counters of the serving front end, reported under
/// `connections` in the `GET /metrics` document. All monotone except the
/// derived "open" gauge (the admission gate's live count).
#[derive(Debug, Default)]
pub struct FrontendStats {
    /// Connections admitted through the main budget (each got a full
    /// keep-alive handler).
    pub accepted: AtomicU64,
    /// Connections answered `503` + [`CODE_SERVER_BUSY`] by a rejection
    /// handler (admission rejections under overload).
    pub rejected_busy: AtomicU64,
    /// Connections dropped without a reply (both the main budget and the
    /// rejection pool were exhausted).
    pub dropped: AtomicU64,
    /// `accept()` errors observed by the accept loop (e.g. fd exhaustion
    /// under a connection flood). The loop backs off exponentially while
    /// these persist; the counter makes the stall visible on `/metrics`
    /// instead of silent.
    pub accept_errors: AtomicU64,
}

impl FrontendStats {
    /// The `connections` sub-document of `GET /metrics`. `open` is the
    /// number of connections currently holding admission slots.
    pub fn to_json(&self, open: usize) -> Json {
        Json::obj([
            ("accepted", Json::num(self.accepted.load(Ordering::Relaxed) as f64)),
            ("open", Json::num(open as f64)),
            ("rejected_busy", Json::num(self.rejected_busy.load(Ordering::Relaxed) as f64)),
            ("dropped", Json::num(self.dropped.load(Ordering::Relaxed) as f64)),
            ("accept_errors", Json::num(self.accept_errors.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// Everything a handler thread needs to answer any endpoint: the
/// coordinator handle plus the front end's own observability state (the
/// connection counters and the admission gate whose live count is the
/// "open connections" gauge).
struct ServeState {
    coordinator: Coordinator,
    stats: Arc<FrontendStats>,
    gate: Arc<AdmissionGate>,
    /// The `/healthz` body, serialized once at spawn: the model contract
    /// it carries is immutable for the server's life, so the hot
    /// liveness probe never re-renders JSON.
    healthz: Arc<str>,
}

/// A running serving front end: a TCP listener routing `/infer`,
/// `/healthz`, `/stats`, and `/metrics` onto a [`Coordinator`], with
/// connections handled on a bounded pool of reusable worker threads
/// ([`ServeOpts::serve_threads`]; the coordinator handle is cheap to
/// clone, and its worker thread serializes execution).
///
/// ```no_run
/// use bf_imna::coordinator::{Coordinator, CoordinatorConfig, ServingServer};
///
/// let coord = Coordinator::start_sim(CoordinatorConfig::default(), 0.0).unwrap();
/// let server = ServingServer::spawn("127.0.0.1:0", coord).unwrap();
/// println!("serving on {}", server.addr());
/// server.shutdown();
/// ```
#[derive(Debug)]
pub struct ServingServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl ServingServer {
    /// Bind `addr` (port `0` picks an ephemeral port) and serve until
    /// dropped or [`Self::shutdown`], with the default connection budget
    /// ([`ServeOpts::default`]).
    pub fn spawn(addr: &str, coordinator: Coordinator) -> io::Result<ServingServer> {
        Self::spawn_with(addr, coordinator, ServeOpts::default())
    }

    /// [`Self::spawn`] with an explicit connection budget and policy.
    pub fn spawn_with(
        addr: &str,
        coordinator: Coordinator,
        opts: ServeOpts,
    ) -> io::Result<ServingServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(AdmissionGate::new(opts.max_concurrent_requests, 0));
        let reject_gate = Arc::new(AdmissionGate::new(REJECT_POOL, 0));
        let policy = ConnPolicy {
            exchange_deadline: SERVE_EXCHANGE_DEADLINE,
            idle_timeout: opts.idle_timeout,
            max_requests: opts.max_requests_per_conn,
        };
        let healthz: Arc<str> = Arc::from(health_doc(&coordinator).to_string().as_str());
        let state = Arc::new(ServeState {
            coordinator,
            stats: Arc::new(FrontendStats::default()),
            gate,
            healthz,
        });
        let conn_pool = ConnWorkerPool::new("bf-imna-serve", opts.serve_threads);
        // Rejections ride a small dedicated pool so an overload reply
        // never waits behind busy keep-alive handlers (in legacy
        // spawn-per-connection mode they spawn too).
        let reject_pool = ConnWorkerPool::new(
            "bf-imna-reject",
            if opts.serve_threads == 0 { 0 } else { REJECT_POOL },
        );
        let handle = {
            let stop = Arc::clone(&stop);
            thread::spawn(move || {
                accept_loop(listener, state, stop, reject_gate, policy, conn_pool, reject_pool)
            })
        };
        Ok(ServingServer { addr, stop, handle: Some(handle) })
    }

    /// The bound socket address (with the real port for `:0` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections, drop the listener, and join the accept
    /// loop; in-flight requests still complete.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// Block until the accept loop exits — i.e. forever, for a CLI server.
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    fn stop_and_join(&mut self) {
        if self.handle.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so a blocking accept() observes the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServingServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    state: Arc<ServeState>,
    stop: Arc<AtomicBool>,
    reject_gate: Arc<AdmissionGate>,
    policy: ConnPolicy,
    conn_pool: ConnWorkerPool,
    reject_pool: ConnWorkerPool,
) {
    let mut backoff = ACCEPT_BACKOFF_MIN;
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => {
                backoff = ACCEPT_BACKOFF_MIN;
                stream
            }
            Err(_) => {
                // A stop request surfaces as an accept error (the
                // shutdown path pokes the listener); everything else is
                // transient (e.g. fd exhaustion under a flood) — count
                // it and back off exponentially instead of spinning at a
                // fixed cadence.
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                state.stats.accept_errors.fetch_add(1, Ordering::Relaxed);
                thread::sleep(backoff);
                backoff = (backoff * 2).min(ACCEPT_BACKOFF_MAX);
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // Connection budget: over the cap, hand the connection to a
        // short-deadline rejection handler instead of a full one — no
        // coordinator work, no long-lived exchange deadline. The
        // rejection handlers ride their own small pool so an overload
        // reply never queues behind busy keep-alive handlers; past
        // REJECT_POOL of them, the connection is simply dropped — under
        // a genuine flood, a TCP-level refusal is the only honest (and
        // bounded) signal left, and total thread count stays capped
        // either way. Every outcome is counted, so `/metrics` shows the
        // overload.
        let Some(permit) = AdmissionGate::admit(&state.gate) else {
            if let Some(reject_permit) = AdmissionGate::admit(&reject_gate) {
                state.stats.rejected_busy.fetch_add(1, Ordering::Relaxed);
                reject_pool.execute(Box::new(move || {
                    let _permit = reject_permit;
                    reject_busy(stream);
                }));
            } else {
                state.stats.dropped.fetch_add(1, Ordering::Relaxed);
            }
            continue;
        };
        state.stats.accepted.fetch_add(1, Ordering::Relaxed);
        let state = Arc::clone(&state);
        conn_pool.execute(Box::new(move || {
            // The permit rides the handler job for the connection's
            // whole keep-alive life; dropping it (normal return or
            // panic) frees the slot.
            let _permit = permit;
            handle_connection(stream, policy, &state);
        }));
    }
    // Unpark idle pool workers so they exit; in-flight connections finish.
    conn_pool.shutdown();
    reject_pool.shutdown();
}

/// Tight deadline for over-budget connections: long enough for a
/// well-behaved client's request/response exchange, short enough that a
/// flood's rejection handlers cannot accumulate.
const REJECT_DEADLINE: Duration = Duration::from_secs(5);

/// Concurrent rejection handlers allowed; connections arriving past both
/// the main budget and this pool are dropped without a reply.
const REJECT_POOL: usize = 32;

/// Answer one over-budget connection: read the (size-capped) request
/// under the short deadline — closing with unread bytes in flight could
/// RST the reply off the wire — then answer `503` + [`CODE_SERVER_BUSY`].
fn reject_busy(stream: TcpStream) {
    let reader = match stream.try_clone() {
        Ok(s) => DeadlineStream::new(s, REJECT_DEADLINE),
        Err(_) => return,
    };
    let _ = read_request(&mut BufReader::new(reader));
    let mut writer = DeadlineStream::new(stream, REJECT_DEADLINE);
    // The 503 body is static — serialize it once per process, not once
    // per rejected connection (a flood sends many).
    static BODY: OnceLock<String> = OnceLock::new();
    let body = BODY.get_or_init(|| {
        Json::obj([
            ("code", Json::str(CODE_SERVER_BUSY)),
            ("error", Json::str("serving front end at connection capacity")),
        ])
        .to_string()
    });
    let _ = write_response(&mut writer, 503, body.as_bytes());
}

/// The shared keep-alive loop with the serving protocol routed in — the
/// same per-exchange discipline (and slowloris protection) as the sweep
/// worker.
fn handle_connection(stream: TcpStream, policy: ConnPolicy, state: &ServeState) {
    serve_exchanges(stream, &policy, |parsed| match parsed {
        Ok(req) => route(req, state),
        Err(e) => (e.status, err_doc(e.message.clone()).into()),
    });
}

fn route(req: &Request, state: &ServeState) -> (u16, ReplyBody) {
    let coordinator = &state.coordinator;
    let (status, doc) = match (req.method.as_str(), req.path.as_str()) {
        // The health body was serialized at spawn; the probe path does
        // no JSON work at all.
        ("GET", "/healthz") => return (200, ReplyBody::Preserialized(Arc::clone(&state.healthz))),
        ("GET", "/stats") => (200, coordinator.metrics().to_json(coordinator.uptime_s())),
        ("GET", "/metrics") => (200, metrics_doc(state)),
        ("POST", "/infer") => handle_infer(&req.body, coordinator),
        ("GET", _) | ("POST", _) => (404, err_doc(format!("no such endpoint {:?}", req.path))),
        _ => (405, err_doc(format!("method {:?} not allowed", req.method))),
    };
    (status, doc.into())
}

/// Build the `GET /metrics` document: the coordinator's histogram-backed
/// metrics (queue depth included) with the front end's connection
/// counters folded in.
fn metrics_doc(state: &ServeState) -> Json {
    let coordinator = &state.coordinator;
    let queue_depth = coordinator.queue_depth();
    let mut doc = coordinator.metrics().to_metrics_json(coordinator.uptime_s(), queue_depth);
    if let Json::Obj(map) = &mut doc {
        map.insert(
            "connections".to_string(),
            state.stats.to_json(state.gate.running()),
        );
    }
    doc
}

fn health_doc(coordinator: &Coordinator) -> Json {
    Json::obj([
        ("ok", Json::Bool(true)),
        ("sample_elems", Json::num(coordinator.sample_elems() as f64)),
        ("num_classes", Json::num(coordinator.num_classes() as f64)),
        (
            "configs",
            Json::arr(coordinator.configs().iter().map(|c| Json::str(c.clone()))),
        ),
    ])
}

fn handle_infer(body: &[u8], coordinator: &Coordinator) -> (u16, Json) {
    let v = match Json::parse_bytes(body) {
        Ok(v) => v,
        Err(e) => return (400, err_doc(format!("bad infer request: {e}"))),
    };
    // The multi-sample shape is keyed by `inputs`; its presence selects
    // the branch so a body carrying neither gets the single-sample
    // parser's "missing 'input'" message.
    if v.get("inputs").is_some() {
        return handle_infer_batch(&v, coordinator);
    }
    let req = match InferRequest::from_json(&v) {
        Ok(req) => req,
        Err(e) => return (400, err_doc(e)),
    };
    let pending = match coordinator.submit_spec(req.input, req.spec) {
        Ok(p) => p,
        // Submission rejections (wrong input size, shut-down coordinator)
        // are the client's fault or a dead server, respectively — but the
        // input-size case dominates, so reply 400 with the exact message.
        Err(e) => return (400, err_doc(e.to_string())),
    };
    match pending.wait_timeout(REPLY_DEADLINE) {
        Ok(r) => (200, response_to_json(&r)),
        Err(e) => (500, err_doc(e.to_string())),
    }
}

/// The multi-sample `/infer` branch: submit every sample before awaiting
/// any, so they all land inside one coordinator batch window, then reply
/// with per-sample verdicts in input order.
fn handle_infer_batch(v: &Json, coordinator: &Coordinator) -> (u16, Json) {
    let req = match BatchInferRequest::from_json(v) {
        Ok(req) => req,
        Err(e) => return (400, err_doc(e)),
    };
    // Validate every sample up front: rejecting mid-batch would leave the
    // already-submitted samples running with their replies dropped.
    for (i, input) in req.inputs.iter().enumerate() {
        if input.len() != coordinator.sample_elems() {
            return (
                400,
                err_doc(format!(
                    "infer request: 'inputs[{i}]' has {} elements, the model expects {}",
                    input.len(),
                    coordinator.sample_elems()
                )),
            );
        }
    }
    let mut pendings = Vec::with_capacity(req.inputs.len());
    for input in req.inputs {
        match coordinator.submit_spec(input, req.spec.clone()) {
            Ok(p) => pendings.push(p),
            // Sizes were validated above, so only a shut-down coordinator
            // lands here — a server-side failure.
            Err(e) => return (500, err_doc(e.to_string())),
        }
    }
    let mut results = Vec::with_capacity(pendings.len());
    for pending in pendings {
        match pending.wait_timeout(REPLY_DEADLINE) {
            Ok(r) => results.push(response_to_json(&r)),
            Err(e) => return (500, err_doc(e.to_string())),
        }
    }
    (200, Json::obj([("results", Json::arr(results))]))
}

// ---------------------------------------------------------------------
// Client half — what `bf-imna infer` drives.
// ---------------------------------------------------------------------

/// Turn one `/infer` reply `(status, doc)` into a [`Response`].
fn parse_infer_reply(addr: &str, status: u16, doc: &Json) -> Result<Response, String> {
    if status != 200 {
        let detail = doc.get("error").and_then(Json::as_str).unwrap_or("unknown error");
        return Err(format!("{addr}: HTTP {status}: {detail}"));
    }
    response_from_json(doc).map_err(|e| format!("{addr}: invalid infer reply: {e}"))
}

/// Send one inference request to a serving front end and parse the reply.
/// Opens (and closes) a fresh connection per call; latency-sensitive
/// callers should prefer [`infer_remote_pooled`].
pub fn infer_remote(
    addr: &str,
    req: &InferRequest,
    timeout: Duration,
) -> Result<Response, String> {
    let (status, doc) =
        http_request_json(addr, "POST", "/infer", req.to_json().to_string().as_bytes(), timeout)?;
    parse_infer_reply(addr, status, &doc)
}

/// [`infer_remote`] over a pooled keep-alive connection: every call after
/// the first rides an already-open socket (with the pool's health check
/// and stale-retry semantics).
pub fn infer_remote_pooled(
    pool: &ConnPool,
    addr: &str,
    req: &InferRequest,
    timeout: Duration,
) -> Result<Response, String> {
    let (status, doc) = pool
        .request_json(addr, "POST", "/infer", req.to_json().to_string().as_bytes(), timeout)
        .map_err(|e| e.message)?;
    parse_infer_reply(addr, status, &doc)
}

/// Send a multi-sample request ([`BatchInferRequest`]) over a pooled
/// connection and parse the per-sample verdicts, returned in input
/// order. The server guarantees `results` matches the sample count on
/// success; a reply that does not is reported as invalid.
pub fn infer_remote_many(
    pool: &ConnPool,
    addr: &str,
    req: &BatchInferRequest,
    timeout: Duration,
) -> Result<Vec<Response>, String> {
    let (status, doc) = pool
        .request_json(addr, "POST", "/infer", req.to_json().to_string().as_bytes(), timeout)
        .map_err(|e| e.message)?;
    if status != 200 {
        let detail = doc.get("error").and_then(Json::as_str).unwrap_or("unknown error");
        return Err(format!("{addr}: HTTP {status}: {detail}"));
    }
    let results = doc
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{addr}: invalid infer reply: missing 'results' array"))?;
    if results.len() != req.inputs.len() {
        return Err(format!(
            "{addr}: invalid infer reply: {} results for {} samples",
            results.len(),
            req.inputs.len()
        ));
    }
    results
        .iter()
        .map(|r| response_from_json(r).map_err(|e| format!("{addr}: invalid infer reply: {e}")))
        .collect()
}

/// Fetch a serving front end's `/stats` document.
pub fn fetch_stats(addr: &str, timeout: Duration) -> Result<Json, String> {
    let (status, doc) = http_request_json(addr, "GET", "/stats", b"", timeout)?;
    if status != 200 {
        return Err(format!("{addr}: GET /stats returned HTTP {status}"));
    }
    Ok(doc)
}

/// [`fetch_stats`] over a pooled keep-alive connection.
pub fn fetch_stats_pooled(pool: &ConnPool, addr: &str, timeout: Duration) -> Result<Json, String> {
    let (status, doc) =
        pool.request_json(addr, "GET", "/stats", b"", timeout).map_err(|e| e.message)?;
    if status != 200 {
        return Err(format!("{addr}: GET /stats returned HTTP {status}"));
    }
    Ok(doc)
}

/// Fetch a serving front end's `/metrics` document (histograms, per-class
/// rates, queue depth, connection counters).
pub fn fetch_metrics(addr: &str, timeout: Duration) -> Result<Json, String> {
    let (status, doc) = http_request_json(addr, "GET", "/metrics", b"", timeout)?;
    if status != 200 {
        return Err(format!("{addr}: GET /metrics returned HTTP {status}"));
    }
    Ok(doc)
}

/// [`fetch_metrics`] over a pooled keep-alive connection.
pub fn fetch_metrics_pooled(
    pool: &ConnPool,
    addr: &str,
    timeout: Duration,
) -> Result<Json, String> {
    let (status, doc) =
        pool.request_json(addr, "GET", "/metrics", b"", timeout).map_err(|e| e.message)?;
    if status != 200 {
        return Err(format!("{addr}: GET /metrics returned HTTP {status}"));
    }
    Ok(doc)
}

/// Fetch a serving front end's `/healthz` document (the model contract:
/// `sample_elems`, `num_classes`, `configs`).
pub fn fetch_health(addr: &str, timeout: Duration) -> Result<Json, String> {
    let (status, doc) = http_request_json(addr, "GET", "/healthz", b"", timeout)?;
    if status != 200 {
        return Err(format!("{addr}: GET /healthz returned HTTP {status}"));
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_request_round_trips_every_budget_shape() {
        let shapes = [
            RequestSpec::default(),
            RequestSpec { budget: BudgetSpec::Class(Budget::Low), ..RequestSpec::default() },
            RequestSpec {
                budget: BudgetSpec::Deadline(Duration::from_millis(12)),
                priority: Priority::High,
                batch_hint: Some(4),
            },
        ];
        for spec in shapes {
            let req = InferRequest { input: vec![0.25, -1.0, 0.5], spec: spec.clone() };
            let text = req.to_json().to_string();
            let back = InferRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.input, req.input);
            assert_eq!(back.spec.budget, spec.budget);
            assert_eq!(back.spec.priority, spec.priority);
            assert_eq!(back.spec.batch_hint, spec.batch_hint);
        }
    }

    #[test]
    fn infer_request_rejects_contradictions_and_garbage() {
        let both = r#"{"input":[1.0],"budget":"low","deadline_ms":5}"#;
        assert!(InferRequest::from_json(&Json::parse(both).unwrap())
            .unwrap_err()
            .contains("not both"));
        for bad in [
            r#"{"budget":"low"}"#,
            r#"{"input":[1.0],"budget":"urgent"}"#,
            r#"{"input":[1.0],"deadline_ms":-3}"#,
            // A deadline past the 24h cap would overflow Duration (panic)
            // if it were not rejected here.
            r#"{"input":[1.0],"deadline_ms":1e300}"#,
            r#"{"input":[1.0],"priority":"asap"}"#,
            r#"{"input":[1.0],"batch_hint":0}"#,
            r#"{"input":["x"]}"#,
        ] {
            assert!(InferRequest::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
        // No budget at all defaults to the loosest class.
        let plain = InferRequest::from_json(&Json::parse(r#"{"input":[1.0]}"#).unwrap()).unwrap();
        assert_eq!(plain.spec.budget, BudgetSpec::Class(Budget::High));
    }

    #[test]
    fn batch_infer_request_round_trips_and_rejects_empty() {
        let req = BatchInferRequest {
            inputs: vec![vec![0.5, -1.0], vec![2.0, 3.5]],
            spec: RequestSpec {
                budget: BudgetSpec::Class(Budget::Medium),
                priority: Priority::High,
                batch_hint: Some(2),
            },
        };
        let back =
            BatchInferRequest::from_json(&Json::parse(&req.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.inputs, req.inputs);
        assert_eq!(back.spec.budget, req.spec.budget);
        assert_eq!(back.spec.priority, req.spec.priority);
        assert_eq!(back.spec.batch_hint, req.spec.batch_hint);

        for bad in [
            r#"{"inputs":[]}"#,
            r#"{"inputs":"x"}"#,
            r#"{"inputs":[["x"]]}"#,
            r#"{"inputs":[[1.0]],"budget":"low","deadline_ms":5}"#,
        ] {
            assert!(BatchInferRequest::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn response_round_trips() {
        let r = Response {
            logits: vec![0.1, -2.5, 3.0],
            config: "mixed".to_string(),
            batch: 4,
            latency_s: 0.012,
            target_s: 0.03,
            met_deadline: true,
        };
        let back = response_from_json(&response_to_json(&r)).unwrap();
        assert_eq!(back.logits, r.logits);
        assert_eq!(back.config, r.config);
        assert_eq!(back.batch, r.batch);
        assert!(back.met_deadline);
        assert!((back.latency_s - r.latency_s).abs() < 1e-12);
        assert!((back.target_s - r.target_s).abs() < 1e-12);
    }

    // Live server round trips (spawn + POST /infer over real sockets) are
    // in rust/tests/serving.rs — they need the sim-backed coordinator.
}
