//! The networked serving front end — the coordinator on the wire.
//!
//! Serving joins `sweep`/`dispatch` as a networked mode: [`ServingServer`]
//! wraps a [`Coordinator`] in the same dependency-free HTTP/1.1 layer the
//! sweep transport uses (`Content-Length` framing, hard head/body caps,
//! whole-exchange deadline streams — see [`crate::sim::transport`]), with
//! three endpoints:
//!
//! * `POST /infer` — one inference request ([`InferRequest`] JSON: the
//!   input sample plus the full request descriptor — budget class or
//!   explicit `deadline_ms`, priority, batch hint). The reply carries the
//!   logits, the precision config that served it, and the
//!   met-or-flagged-deadline verdict.
//! * `GET /healthz` — liveness plus the model contract (sample element
//!   count, class count, loaded config ladder), so clients can size their
//!   inputs without out-of-band knowledge.
//! * `GET /stats` — the serving [`Metrics`](super::Metrics) document
//!   (completed/failed, deadline met/missed, latency percentiles,
//!   throughput, per-config mix).
//!
//! CLI front ends: `bf-imna serve --addr HOST:PORT` (server) and
//! `bf-imna infer --addr HOST:PORT` (client; also `--stats`). The client
//! half of this module ([`infer_remote`], [`fetch_stats`],
//! [`fetch_health`]) is what `bf-imna infer` calls.

use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::controller::{Budget, BudgetSpec};
use super::{Coordinator, Priority, RequestSpec, Response};
use crate::sim::transport::{
    err_doc, http_request_json, read_request, write_response, AdmissionGate, DeadlineStream,
    Request,
};
use crate::util::json::Json;

/// Whole-exchange deadline for reading one `/infer` request and (with a
/// fresh budget) writing one response — generous next to any sane request
/// deadline, tight enough that a slowloris cannot hold a handler thread.
const SERVE_EXCHANGE_DEADLINE: Duration = Duration::from_secs(120);

/// How long a handler waits for the coordinator's reply before giving up
/// with a 500 (the worker thread died or is wedged).
const REPLY_DEADLINE: Duration = Duration::from_secs(300);

/// Largest accepted `deadline_ms` (24 h). Anything above is a client
/// error — and must be rejected *before* `Duration::from_secs_f64`, which
/// panics on durations that overflow.
pub const MAX_DEADLINE_MS: f64 = 86_400_000.0;

/// Wire constant: the `code` the front end attaches to a `503` when its
/// connection budget is exhausted — machine-readable backpressure, like
/// the sweep worker's `worker-busy`.
pub const CODE_SERVER_BUSY: &str = "server-busy";

/// Admission control for the serving front end: a hard cap on concurrent
/// connections (each holds one handler thread and, for `/infer`, one
/// pending coordinator reply). Connections beyond the cap are answered
/// `503` + [`CODE_SERVER_BUSY`] by a short-deadline rejection handler
/// that does no coordinator work — the same backpressure discipline the
/// sweep worker applies to `POST /shard`.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Concurrent connections allowed (clamped to ≥ 1).
    pub max_concurrent_requests: usize,
}

impl Default for ServeOpts {
    /// 256 concurrent connections — far above the worker thread's
    /// throughput needs, low enough that a connection flood cannot grow
    /// threads and queued requests without bound.
    fn default() -> Self {
        ServeOpts { max_concurrent_requests: 256 }
    }
}

/// One wire-level inference request: the input sample plus the request
/// descriptor. The JSON shape is
/// `{"input": [...], "budget": "low"|"medium"|"high" | "deadline_ms": N,
///   "priority": "low"|"normal"|"high", "batch_hint": N}` —
/// exactly one of `budget` / `deadline_ms`; `priority` and `batch_hint`
/// are optional.
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// The input sample, row-major `(H, W, C)`.
    pub input: Vec<f32>,
    /// The request descriptor (budget, priority, batch hint).
    pub spec: RequestSpec,
}

impl InferRequest {
    /// Serialize to the canonical wire body.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> =
            vec![("input", Json::arr(self.input.iter().map(|&x| Json::num(x as f64))))];
        match self.spec.budget {
            BudgetSpec::Class(b) => pairs.push(("budget", Json::str(b.label()))),
            BudgetSpec::Deadline(d) => {
                pairs.push(("deadline_ms", Json::num(d.as_secs_f64() * 1e3)))
            }
        }
        if self.spec.priority != Priority::Normal {
            pairs.push(("priority", Json::str(self.spec.priority.label())));
        }
        if let Some(h) = self.spec.batch_hint {
            pairs.push(("batch_hint", Json::num(h as f64)));
        }
        Json::obj(pairs)
    }

    /// Parse a value produced by [`Self::to_json`] (or hand-written by any
    /// HTTP client). Rejects requests carrying both a class and a
    /// deadline, non-finite deadlines, and non-numeric inputs.
    pub fn from_json(v: &Json) -> Result<InferRequest, String> {
        let input = v
            .get("input")
            .and_then(Json::as_arr)
            .ok_or("infer request: missing 'input' array")?
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| "infer request: 'input' entries must be numbers".to_string())
            })
            .collect::<Result<Vec<f32>, String>>()?;
        let budget = match (v.get("budget"), v.get("deadline_ms")) {
            (Some(_), Some(_)) => {
                return Err(
                    "infer request: give either 'budget' or 'deadline_ms', not both".to_string()
                )
            }
            (Some(b), None) => BudgetSpec::Class(Budget::parse(
                b.as_str().ok_or("infer request: 'budget' must be a string")?,
            )?),
            (None, Some(d)) => {
                let ms = d.as_f64().ok_or("infer request: 'deadline_ms' must be a number")?;
                if !(ms.is_finite() && ms > 0.0 && ms <= MAX_DEADLINE_MS) {
                    return Err(format!(
                        "infer request: 'deadline_ms' must be in (0, {MAX_DEADLINE_MS}]"
                    ));
                }
                BudgetSpec::Deadline(Duration::from_secs_f64(ms / 1e3))
            }
            (None, None) => BudgetSpec::Class(Budget::High),
        };
        let priority = match v.get("priority") {
            None => Priority::Normal,
            Some(p) => Priority::parse(
                p.as_str().ok_or("infer request: 'priority' must be a string")?,
            )?,
        };
        let batch_hint = match v.get("batch_hint") {
            None => None,
            Some(h) => Some(
                h.as_i64()
                    .filter(|&n| n >= 1)
                    .ok_or("infer request: 'batch_hint' must be an integer >= 1")?
                    as u64,
            ),
        };
        Ok(InferRequest { input, spec: RequestSpec { budget, priority, batch_hint } })
    }
}

/// Serialize a coordinator [`Response`] to the `/infer` reply body.
pub fn response_to_json(r: &Response) -> Json {
    Json::obj([
        ("logits", Json::arr(r.logits.iter().map(|&x| Json::num(x as f64)))),
        ("config", Json::str(r.config.clone())),
        ("batch", Json::num(r.batch as f64)),
        ("latency_s", Json::num(r.latency_s)),
        ("target_s", Json::num(r.target_s)),
        ("met_deadline", Json::Bool(r.met_deadline)),
    ])
}

/// Parse an `/infer` reply body back into a [`Response`] (client side).
pub fn response_from_json(v: &Json) -> Result<Response, String> {
    Ok(Response {
        logits: v
            .get("logits")
            .and_then(Json::as_arr)
            .ok_or("infer reply: missing 'logits' array")?
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| "infer reply: 'logits' entries must be numbers".to_string())
            })
            .collect::<Result<Vec<f32>, String>>()?,
        config: v
            .get("config")
            .and_then(Json::as_str)
            .ok_or("infer reply: missing 'config'")?
            .to_string(),
        batch: v
            .get("batch")
            .and_then(Json::as_i64)
            .filter(|&b| b >= 1)
            .ok_or("infer reply: missing 'batch'")? as u64,
        latency_s: v
            .get("latency_s")
            .and_then(Json::as_f64)
            .ok_or("infer reply: missing 'latency_s'")?,
        target_s: v
            .get("target_s")
            .and_then(Json::as_f64)
            .ok_or("infer reply: missing 'target_s'")?,
        met_deadline: v
            .get("met_deadline")
            .and_then(Json::as_bool)
            .ok_or("infer reply: missing 'met_deadline'")?,
    })
}

/// A running serving front end: a TCP listener routing `/infer`,
/// `/healthz`, and `/stats` onto a [`Coordinator`], one handler thread per
/// connection (the coordinator handle is cheap to clone; its worker thread
/// serializes execution).
///
/// ```no_run
/// use bf_imna::coordinator::{Coordinator, CoordinatorConfig, ServingServer};
///
/// let coord = Coordinator::start_sim(CoordinatorConfig::default(), 0.0).unwrap();
/// let server = ServingServer::spawn("127.0.0.1:0", coord).unwrap();
/// println!("serving on {}", server.addr());
/// server.shutdown();
/// ```
#[derive(Debug)]
pub struct ServingServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl ServingServer {
    /// Bind `addr` (port `0` picks an ephemeral port) and serve until
    /// dropped or [`Self::shutdown`], with the default connection budget
    /// ([`ServeOpts::default`]).
    pub fn spawn(addr: &str, coordinator: Coordinator) -> io::Result<ServingServer> {
        Self::spawn_with(addr, coordinator, ServeOpts::default())
    }

    /// [`Self::spawn`] with an explicit connection budget.
    pub fn spawn_with(
        addr: &str,
        coordinator: Coordinator,
        opts: ServeOpts,
    ) -> io::Result<ServingServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(AdmissionGate::new(opts.max_concurrent_requests, 0));
        let reject_gate = Arc::new(AdmissionGate::new(REJECT_POOL, 0));
        let handle = {
            let stop = Arc::clone(&stop);
            thread::spawn(move || accept_loop(listener, coordinator, stop, gate, reject_gate))
        };
        Ok(ServingServer { addr, stop, handle: Some(handle) })
    }

    /// The bound socket address (with the real port for `:0` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections, drop the listener, and join the accept
    /// loop; in-flight requests still complete.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// Block until the accept loop exits — i.e. forever, for a CLI server.
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    fn stop_and_join(&mut self) {
        if self.handle.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so a blocking accept() observes the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServingServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    coordinator: Coordinator,
    stop: Arc<AtomicBool>,
    gate: Arc<AdmissionGate>,
    reject_gate: Arc<AdmissionGate>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // Connection budget: over the cap, hand the connection to a
        // short-deadline rejection handler instead of a full one — no
        // coordinator work, no long-lived exchange deadline. The
        // rejection handlers are themselves pooled: past REJECT_POOL of
        // them, the connection is simply dropped — under a genuine flood,
        // a TCP-level refusal is the only honest (and bounded) signal
        // left, and total thread count stays capped either way.
        let Some(permit) = AdmissionGate::admit(&gate) else {
            if let Some(reject_permit) = AdmissionGate::admit(&reject_gate) {
                thread::spawn(move || {
                    let _permit = reject_permit;
                    reject_busy(stream);
                });
            }
            continue;
        };
        let coordinator = coordinator.clone();
        thread::spawn(move || {
            // The permit rides the handler thread; dropping it (normal
            // return or panic) frees the slot.
            let _permit = permit;
            handle_connection(stream, &coordinator);
        });
    }
}

/// Tight deadline for over-budget connections: long enough for a
/// well-behaved client's request/response exchange, short enough that a
/// flood's rejection handlers cannot accumulate.
const REJECT_DEADLINE: Duration = Duration::from_secs(5);

/// Concurrent rejection handlers allowed; connections arriving past both
/// the main budget and this pool are dropped without a reply.
const REJECT_POOL: usize = 32;

/// Answer one over-budget connection: read the (size-capped) request
/// under the short deadline — closing with unread bytes in flight could
/// RST the reply off the wire — then answer `503` + [`CODE_SERVER_BUSY`].
fn reject_busy(stream: TcpStream) {
    let reader = match stream.try_clone() {
        Ok(s) => DeadlineStream::new(s, REJECT_DEADLINE),
        Err(_) => return,
    };
    let _ = read_request(&mut BufReader::new(reader));
    let mut writer = DeadlineStream::new(stream, REJECT_DEADLINE);
    let reply = Json::obj([
        ("code", Json::str(CODE_SERVER_BUSY)),
        ("error", Json::str("serving front end at connection capacity")),
    ]);
    let _ = write_response(&mut writer, 503, reply.to_string().as_bytes());
}

/// One request, one response, close — the same exchange discipline (and
/// slowloris protection) as the sweep worker.
fn handle_connection(stream: TcpStream, coordinator: &Coordinator) {
    let reader = match stream.try_clone() {
        Ok(s) => DeadlineStream::new(s, SERVE_EXCHANGE_DEADLINE),
        Err(_) => return,
    };
    let (status, reply) = match read_request(&mut BufReader::new(reader)) {
        Ok(req) => route(&req, coordinator),
        Err(e) => (e.status, err_doc(e.message)),
    };
    let mut writer = DeadlineStream::new(stream, SERVE_EXCHANGE_DEADLINE);
    let _ = write_response(&mut writer, status, reply.to_string().as_bytes());
}

fn route(req: &Request, coordinator: &Coordinator) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, health_doc(coordinator)),
        ("GET", "/stats") => {
            (200, coordinator.metrics().to_json(coordinator.uptime_s()))
        }
        ("POST", "/infer") => handle_infer(&req.body, coordinator),
        ("GET", _) | ("POST", _) => (404, err_doc(format!("no such endpoint {:?}", req.path))),
        _ => (405, err_doc(format!("method {:?} not allowed", req.method))),
    }
}

fn health_doc(coordinator: &Coordinator) -> Json {
    Json::obj([
        ("ok", Json::Bool(true)),
        ("sample_elems", Json::num(coordinator.sample_elems() as f64)),
        ("num_classes", Json::num(coordinator.num_classes() as f64)),
        (
            "configs",
            Json::arr(coordinator.configs().iter().map(|c| Json::str(c.clone()))),
        ),
    ])
}

fn handle_infer(body: &[u8], coordinator: &Coordinator) -> (u16, Json) {
    let req = match Json::parse_bytes(body)
        .map_err(|e| format!("bad infer request: {e}"))
        .and_then(|v| InferRequest::from_json(&v))
    {
        Ok(req) => req,
        Err(e) => return (400, err_doc(e)),
    };
    let pending = match coordinator.submit_spec(req.input, req.spec) {
        Ok(p) => p,
        // Submission rejections (wrong input size, shut-down coordinator)
        // are the client's fault or a dead server, respectively — but the
        // input-size case dominates, so reply 400 with the exact message.
        Err(e) => return (400, err_doc(e.to_string())),
    };
    match pending.wait_timeout(REPLY_DEADLINE) {
        Ok(r) => (200, response_to_json(&r)),
        Err(e) => (500, err_doc(e.to_string())),
    }
}

// ---------------------------------------------------------------------
// Client half — what `bf-imna infer` drives.
// ---------------------------------------------------------------------

/// Send one inference request to a serving front end and parse the reply.
pub fn infer_remote(
    addr: &str,
    req: &InferRequest,
    timeout: Duration,
) -> Result<Response, String> {
    let (status, doc) =
        http_request_json(addr, "POST", "/infer", req.to_json().to_string().as_bytes(), timeout)?;
    if status != 200 {
        let detail = doc.get("error").and_then(Json::as_str).unwrap_or("unknown error");
        return Err(format!("{addr}: HTTP {status}: {detail}"));
    }
    response_from_json(&doc).map_err(|e| format!("{addr}: invalid infer reply: {e}"))
}

/// Fetch a serving front end's `/stats` document.
pub fn fetch_stats(addr: &str, timeout: Duration) -> Result<Json, String> {
    let (status, doc) = http_request_json(addr, "GET", "/stats", b"", timeout)?;
    if status != 200 {
        return Err(format!("{addr}: GET /stats returned HTTP {status}"));
    }
    Ok(doc)
}

/// Fetch a serving front end's `/healthz` document (the model contract:
/// `sample_elems`, `num_classes`, `configs`).
pub fn fetch_health(addr: &str, timeout: Duration) -> Result<Json, String> {
    let (status, doc) = http_request_json(addr, "GET", "/healthz", b"", timeout)?;
    if status != 200 {
        return Err(format!("{addr}: GET /healthz returned HTTP {status}"));
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_request_round_trips_every_budget_shape() {
        let shapes = [
            RequestSpec::default(),
            RequestSpec { budget: BudgetSpec::Class(Budget::Low), ..RequestSpec::default() },
            RequestSpec {
                budget: BudgetSpec::Deadline(Duration::from_millis(12)),
                priority: Priority::High,
                batch_hint: Some(4),
            },
        ];
        for spec in shapes {
            let req = InferRequest { input: vec![0.25, -1.0, 0.5], spec: spec.clone() };
            let text = req.to_json().to_string();
            let back = InferRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.input, req.input);
            assert_eq!(back.spec.budget, spec.budget);
            assert_eq!(back.spec.priority, spec.priority);
            assert_eq!(back.spec.batch_hint, spec.batch_hint);
        }
    }

    #[test]
    fn infer_request_rejects_contradictions_and_garbage() {
        let both = r#"{"input":[1.0],"budget":"low","deadline_ms":5}"#;
        assert!(InferRequest::from_json(&Json::parse(both).unwrap())
            .unwrap_err()
            .contains("not both"));
        for bad in [
            r#"{"budget":"low"}"#,
            r#"{"input":[1.0],"budget":"urgent"}"#,
            r#"{"input":[1.0],"deadline_ms":-3}"#,
            // A deadline past the 24h cap would overflow Duration (panic)
            // if it were not rejected here.
            r#"{"input":[1.0],"deadline_ms":1e300}"#,
            r#"{"input":[1.0],"priority":"asap"}"#,
            r#"{"input":[1.0],"batch_hint":0}"#,
            r#"{"input":["x"]}"#,
        ] {
            assert!(InferRequest::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
        // No budget at all defaults to the loosest class.
        let plain = InferRequest::from_json(&Json::parse(r#"{"input":[1.0]}"#).unwrap()).unwrap();
        assert_eq!(plain.spec.budget, BudgetSpec::Class(Budget::High));
    }

    #[test]
    fn response_round_trips() {
        let r = Response {
            logits: vec![0.1, -2.5, 3.0],
            config: "mixed".to_string(),
            batch: 4,
            latency_s: 0.012,
            target_s: 0.03,
            met_deadline: true,
        };
        let back = response_from_json(&response_to_json(&r)).unwrap();
        assert_eq!(back.logits, r.logits);
        assert_eq!(back.config, r.config);
        assert_eq!(back.batch, r.batch);
        assert!(back.met_deadline);
        assert!((back.latency_s - r.latency_s).abs() < 1e-12);
        assert!((back.target_s - r.target_s).abs() < 1e-12);
    }

    // Live server round trips (spawn + POST /infer over real sockets) are
    // in rust/tests/serving.rs — they need the sim-backed coordinator.
}
