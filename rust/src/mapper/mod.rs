//! Layer-to-chip mapping (paper §III-A, Fig. 4).
//!
//! Every network layer is lowered onto the chip's CAPs:
//!
//! * conv / fc -> im2col GEMM, **weight-stationary**: each cluster keeps a
//!   resident copy of the kernel matrix `K_i` and computes a slice of the
//!   output columns; when the chip cannot hold all `i·j·u` product rows the
//!   GEMM folds in time (`steps > 1`), streaming new input-patch columns
//!   from the MAP each step. Contractions longer than one CAP
//!   (`j > 4800`) additionally fold across CAPs with a partial-sum combine.
//! * max/avg pooling -> the Table IV / Eq. (9)–(14) pooling operations over
//!   `S·K` words, folded in time when capacity is exceeded.
//! * residual add -> in-place vector addition; fused ReLUs run as an extra
//!   pass group on the produced words.
//!
//! The mapper emits *structural* costs: per-phase event counts on the
//! per-CAP critical path (for latency) and per-phase total cell activity
//! (for energy), plus mesh traffic and MAP activity. The simulator
//! ([`crate::sim`]) converts these to seconds and joules under a
//! [`crate::ap::tech::Tech`].

pub mod cache;

pub use cache::{CacheSnapshot, CacheStats, PlanCache, PlanKey};

use std::sync::Arc;

use crate::ap::runtime_model as rt;
use crate::ap::{clog2, ApKind, CellEvents, Events};
use crate::arch::ChipConfig;
use crate::model::{Layer, LayerKind, Network};
use crate::precision::{LayerPrec, PrecisionConfig};

/// Per-phase table of some cost type (Fig. 8's breakdown axes).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTable<T> {
    /// Data population / input streaming writes.
    pub populate: T,
    /// Bit-serial multiplication passes.
    pub multiply: T,
    /// Vertical reduction passes (+ cross-CAP combines).
    pub reduce: T,
    /// Bit-sequential result read-out.
    pub readout: T,
    /// Auxiliary passes: ReLU, pooling LUTs, residual adds, flag resets.
    pub aux: T,
}

impl<T: Copy + std::ops::Add<Output = T>> PhaseTable<T> {
    /// Sum of all phases.
    pub fn total(&self) -> T {
        self.populate + self.multiply + self.reduce + self.readout + self.aux
    }
}

impl PhaseTable<Events> {
    /// Map each phase through an event->seconds conversion.
    pub fn map_f64(&self, f: impl Fn(&Events) -> f64) -> PhaseTable<f64> {
        PhaseTable {
            populate: f(&self.populate),
            multiply: f(&self.multiply),
            reduce: f(&self.reduce),
            readout: f(&self.readout),
            aux: f(&self.aux),
        }
    }
}

impl PhaseTable<CellEvents> {
    /// Map each phase through a cells->joules conversion.
    pub fn map_f64(&self, f: impl Fn(&CellEvents) -> f64) -> PhaseTable<f64> {
        PhaseTable {
            populate: f(&self.populate),
            multiply: f(&self.multiply),
            reduce: f(&self.reduce),
            readout: f(&self.readout),
            aux: f(&self.aux),
        }
    }
}

impl PhaseTable<f64> {
    /// Elementwise sum with another table.
    pub fn add(&self, o: &PhaseTable<f64>) -> PhaseTable<f64> {
        PhaseTable {
            populate: self.populate + o.populate,
            multiply: self.multiply + o.multiply,
            reduce: self.reduce + o.reduce,
            readout: self.readout + o.readout,
            aux: self.aux + o.aux,
        }
    }
}

/// What kind of work a mapped layer performs (Fig. 8a energy categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkKind {
    /// im2col GEMM (conv / fc).
    Gemm,
    /// Max / average pooling.
    Pooling,
    /// Residual element-wise addition.
    Residual,
    /// Standalone ReLU pass.
    Relu,
}

impl WorkKind {
    /// Category label for breakdown tables.
    pub fn label(&self) -> &'static str {
        match self {
            WorkKind::Gemm => "GEMM",
            WorkKind::Pooling => "Pooling",
            WorkKind::Residual => "Residual",
            WorkKind::Relu => "ReLU",
        }
    }
}

/// Structural cost of one mapped layer.
///
/// Cloning is cheap by design — every field is `Copy` except the interned
/// `Arc<str>` name — which is what makes [`PlanCache`] hits nearly free.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// Layer name (interned, shared with the model).
    pub name: Arc<str>,
    /// What kind of work the layer performs.
    pub kind: WorkKind,
    /// Time-folding steps (1 in IR for every paper workload).
    pub steps: u64,
    /// CAPs active in a full step.
    pub caps_used: u64,
    /// Critical-path events per phase, already multiplied by `steps`.
    pub latency_events: PhaseTable<Events>,
    /// Total cell activity per phase across all CAPs and steps.
    pub energy_cells: PhaseTable<CellEvents>,
    /// Bits moved over the on-chip mesh (inputs + weights + outputs),
    /// summed across all clusters — the energy-side traffic.
    pub mesh_bits: u64,
    /// Mesh bits on the *critical path*: each cluster streams its own
    /// slice (and its own weight copy) in parallel from its private MAP,
    /// so latency sees per-cluster traffic, not the chip total.
    pub mesh_bits_critical: u64,
    /// MAP activity: output buffering + input re-reads (reshape traffic).
    pub map_cells: CellEvents,
}

/// A whole network mapped onto a chip under a precision configuration.
#[derive(Debug, Clone)]
pub struct NetworkPlan {
    /// Network name.
    pub net_name: String,
    /// Per-layer plans, in execution order.
    pub layers: Vec<LayerPlan>,
}

impl NetworkPlan {
    /// Maximum time-folding factor across layers.
    pub fn max_steps(&self) -> u64 {
        self.layers.iter().map(|l| l.steps).max().unwrap_or(1)
    }
}

/// Map every layer of `net` onto `chip` under `cfg`.
pub fn map_network(net: &Network, chip: &ChipConfig, cfg: &PrecisionConfig) -> NetworkPlan {
    let per_layer = cfg.for_network(net);
    let layers = net
        .layers
        .iter()
        .zip(per_layer)
        .map(|(layer, prec)| map_layer(layer, prec, chip))
        .collect();
    NetworkPlan { net_name: net.name.clone(), layers }
}

/// Map one layer.
pub fn map_layer(layer: &Layer, prec: LayerPrec, chip: &ChipConfig) -> LayerPlan {
    match &layer.kind {
        LayerKind::Conv { .. } | LayerKind::Fc { .. } => map_gemm(layer, prec, chip),
        LayerKind::MaxPool { win, .. } => map_pool(layer, prec, chip, win * win, true),
        LayerKind::AvgPool { win, .. } => map_pool(layer, prec, chip, win * win, false),
        LayerKind::ResidualAdd { relu, .. } => map_residual(layer, prec, chip, *relu),
    }
}

/// GEMM (conv / fc) mapping — the heart of the simulator.
fn map_gemm(layer: &Layer, prec: LayerPrec, chip: &ChipConfig) -> LayerPlan {
    let g = layer.gemm_dims().expect("gemm layer");
    let (i, j, u) = (g.i, g.j, g.u);
    let (ma, mw) = (prec.a.max(1) as u64, prec.w.max(1) as u64);
    let cap_rows = chip.cluster.cap.gemm_rows();

    // Cross-CAP contraction folding: j_sub rows per sub-contraction.
    let j_fold = j.div_ceil(cap_rows).max(1);
    let j_sub = j.div_ceil(j_fold);
    // Groups (sub-contractions) per CAP and chip-level capacity.
    let groups_per_cap = (cap_rows / j_sub).max(1);
    let rows_per_cap = groups_per_cap * j_sub;
    let groups_total = i * u * j_fold;
    let caps_needed = groups_total.div_ceil(groups_per_cap);
    let total_caps = chip.total_caps();
    let steps = caps_needed.div_ceil(total_caps).max(1);
    let caps_used = caps_needed.min(total_caps);

    let words_total = i * j * u;
    let prod_bits = ma + mw;
    let out_bits = prod_bits + clog2(j) as u64;

    // ---- Latency: per-CAP critical path per step, x steps. ----
    let mult_passes = 4 * ma * mw;
    // Populate: activations streamed every step; weights resident after the
    // first step (weight-stationary), charged once.
    let lat_populate = Events::new(0, steps * ma + mw, 0);
    let lat_multiply = Events::new(steps * mult_passes, steps * mult_passes, 0);
    // Vertical adds per CAP per step: sequential within the CAP.
    let adds_per_cap = rows_per_cap.saturating_sub(groups_per_cap) as u64;
    // Cross-CAP partial-sum combine: log2(j_fold) add rounds over out_bits
    // column pairs (through the MAP), charged per step.
    let combine = if j_fold > 1 { 8 * clog2(j_fold) as u64 * out_bits } else { 0 };
    let lat_reduce =
        Events::new(steps * (4 * adds_per_cap + combine), steps * (4 * adds_per_cap + combine), 0);
    let lat_readout = Events::new(0, 0, steps * out_bits);

    // ---- Energy: total cell activity over all CAPs/steps. ----
    let resident_weight_cells = mw * rows_per_cap * caps_used;
    let en_populate = CellEvents {
        populate_write_cells: (ma * words_total + resident_weight_cells) as f64,
        ..Default::default()
    };
    let en_multiply = CellEvents {
        compare_senses: (mult_passes * words_total) as f64,
        lut_write_cells: mult_passes as f64 * rt::MATCH_PROB_4BIT * words_total as f64 * 1.5,
        ..Default::default()
    };
    let adds_total = i * u * (j - 1) + i * u * (j_fold - 1);
    let en_reduce = CellEvents {
        compare_senses: (4 * adds_total * out_bits) as f64,
        lut_write_cells: 4.0 * adds_total as f64 * rt::MATCH_PROB_3BIT * out_bits as f64 * 1.5,
        ..Default::default()
    };
    let en_readout = CellEvents { read_senses: (out_bits * i * u) as f64, ..Default::default() };

    // ---- Fused ReLU on the i*u outputs. ----
    let relu = matches!(
        layer.kind,
        LayerKind::Conv { relu: true, .. } | LayerKind::Fc { relu: true, .. }
    );
    let (lat_aux, en_aux) = if relu {
        let c = rt::relu(out_bits as u32, i * u, ApKind::TwoD);
        (c.events, c.cells)
    } else {
        (Events::default(), CellEvents::default())
    };

    // ---- Mesh traffic + MAP buffering (reshape overheads, §III-A). ----
    let act_bits = j * u * ma; // unique patch elements streamed in
    let clusters_used = chip.clusters().min(caps_needed.div_ceil(chip.cluster.caps()).max(1));
    let weight_bits = clusters_used * i * j * mw; // one resident copy per cluster
    let out_bits_total = i * u * out_bits; // written back to MAP
    let mesh_bits = act_bits + weight_bits + out_bits_total;
    // Latency side: clusters stream their slices concurrently over private
    // meshes (Fig. 3 — "clusters operate independently and in parallel").
    // Two work splits exist and the mapper picks the cheaper one per layer:
    // * u-split (the paper's conv mapping): every cluster keeps a full copy
    //   of K_i and computes different output columns — activations and
    //   outputs divide across clusters, weights replicate;
    // * i-split (the natural fc mapping, u = 1): clusters own disjoint
    //   kernel rows — weights divide, activations broadcast.
    let cu = clusters_used.min(u).max(1);
    let ci = clusters_used.min(i).max(1);
    let u_split = (act_bits + out_bits_total).div_ceil(cu) + i * j * mw;
    let i_split = act_bits + out_bits_total.div_ceil(ci) + (i.div_ceil(ci)) * j * mw;
    let mesh_bits_critical = u_split.min(i_split);
    let map_cells = CellEvents {
        // Outputs buffered word-sequentially in the MAP, then re-read for
        // the next layer's patch streaming.
        populate_write_cells: out_bits_total as f64,
        read_senses: (j * u) as f64, // word-sense reads feeding this layer
        ..Default::default()
    };

    LayerPlan {
        name: layer.name.clone(),
        kind: WorkKind::Gemm,
        steps,
        caps_used,
        latency_events: PhaseTable {
            populate: lat_populate,
            multiply: lat_multiply,
            reduce: lat_reduce,
            readout: lat_readout,
            aux: lat_aux,
        },
        energy_cells: PhaseTable {
            populate: en_populate,
            multiply: en_multiply,
            reduce: en_reduce,
            readout: en_readout,
            aux: en_aux,
        },
        mesh_bits,
        mesh_bits_critical,
        map_cells,
    }
}

/// Pooling mapping (max or average).
fn map_pool(layer: &Layer, prec: LayerPrec, chip: &ChipConfig, s: u64, is_max: bool) -> LayerPlan {
    let m = prec.a.max(1);
    let out = layer.output();
    let k_total = out.elems();
    let words_total = s * k_total;
    let cap_words = chip.cluster.cap.word_capacity();
    let k_per_cap = (cap_words / s).max(1);
    let caps_needed = k_total.div_ceil(k_per_cap);
    let total_caps = chip.total_caps();
    let steps = caps_needed.div_ceil(total_caps).max(1);
    let caps_used = caps_needed.min(total_caps);

    let per_cap = if is_max {
        rt::maxpool(m, s, k_per_cap.min(k_total), ApKind::TwoD)
    } else {
        rt::avgpool(m, s, k_per_cap.min(k_total), ApKind::TwoD)
    };
    let total = if is_max {
        rt::maxpool(m, s, k_total, ApKind::TwoD)
    } else {
        rt::avgpool(m, s, k_total, ApKind::TwoD)
    };

    let mesh_bits = words_total * m as u64 + k_total * m as u64;
    let mesh_bits_critical = mesh_bits.div_ceil(chip.clusters());
    LayerPlan {
        name: layer.name.clone(),
        kind: WorkKind::Pooling,
        steps,
        caps_used,
        latency_events: PhaseTable {
            aux: per_cap.events.scale(steps),
            ..Default::default()
        },
        energy_cells: PhaseTable { aux: total.cells, ..Default::default() },
        mesh_bits,
        mesh_bits_critical,
        map_cells: CellEvents {
            populate_write_cells: (k_total * m as u64) as f64,
            read_senses: words_total as f64,
            ..Default::default()
        },
    }
}

/// Residual element-wise addition (+ optional ReLU).
fn map_residual(layer: &Layer, prec: LayerPrec, chip: &ChipConfig, relu: bool) -> LayerPlan {
    let m = prec.a.max(1);
    let elems = layer.input.elems();
    let pairs_capacity = chip.total_word_capacity() / 2;
    let steps = elems.div_ceil(pairs_capacity).max(1);
    let caps_used = elems.div_ceil(chip.cluster.cap.word_capacity() / 2).min(chip.total_caps());

    let add = rt::add(m, 2 * elems, ApKind::TwoD);
    let mut lat_aux = add.events.scale(steps);
    let mut en_aux = add.cells;
    if relu {
        let r = rt::relu(add.result_bits, elems, ApKind::TwoD);
        lat_aux = lat_aux + r.events;
        en_aux = en_aux + r.cells;
    }
    // Note: add latency is column-serial (independent of rows), so steps
    // only multiply the populate portion in hardware; we conservatively
    // multiply the whole op (a documented over-estimate, negligible at
    // network scale).
    let mesh_bits = (2 * elems + elems) * m as u64;
    let mesh_bits_critical = mesh_bits.div_ceil(chip.clusters());
    LayerPlan {
        name: layer.name.clone(),
        kind: WorkKind::Residual,
        steps,
        caps_used: caps_used.max(1),
        latency_events: PhaseTable { aux: lat_aux, ..Default::default() },
        energy_cells: PhaseTable { aux: en_aux, ..Default::default() },
        mesh_bits,
        mesh_bits_critical,
        map_cells: CellEvents {
            populate_write_cells: (elems * m as u64) as f64,
            read_senses: (2 * elems) as f64,
            ..Default::default()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwConfig;
    use crate::model::zoo;
    use crate::precision::PrecisionConfig;

    fn lr_plan(net: &crate::model::Network, bits: u32) -> NetworkPlan {
        let chip = ChipConfig::lr();
        let cfg = PrecisionConfig::fixed(bits, net.weight_layers());
        map_network(net, &chip, &cfg)
    }

    #[test]
    fn every_layer_gets_a_plan() {
        let net = zoo::alexnet();
        let plan = lr_plan(&net, 8);
        assert_eq!(plan.layers.len(), net.layers.len());
    }

    #[test]
    fn lr_folds_large_layers_in_time() {
        let net = zoo::vgg16();
        let plan = lr_plan(&net, 8);
        // VGG16's big convs cannot fit 4096 CAPs in one step.
        assert!(plan.max_steps() > 1, "expected time folding, got {}", plan.max_steps());
    }

    #[test]
    fn ir_never_folds() {
        let net = zoo::vgg16();
        let chip = ChipConfig::for_network(HwConfig::Ir, &net);
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let plan = map_network(&net, &chip, &cfg);
        for l in plan.layers.iter().filter(|l| l.kind == WorkKind::Gemm) {
            assert_eq!(l.steps, 1, "layer {} folded on IR", l.name);
        }
    }

    #[test]
    fn caps_used_bounded_by_chip() {
        let net = zoo::resnet50();
        let chip = ChipConfig::lr();
        let plan = lr_plan(&net, 8);
        for l in &plan.layers {
            assert!(l.caps_used <= chip.total_caps(), "{} uses {}", l.name, l.caps_used);
            assert!(l.caps_used >= 1);
        }
    }

    #[test]
    fn gemm_latency_dominated_by_reduction() {
        // Fig. 8b: the GEMM latency bottleneck is reduction, not multiply.
        let net = zoo::vgg16();
        let plan = lr_plan(&net, 8);
        let gemm_layers: Vec<&LayerPlan> =
            plan.layers.iter().filter(|l| l.kind == WorkKind::Gemm).collect();
        let mult: u64 = gemm_layers.iter().map(|l| l.latency_events.multiply.time_units()).sum();
        let red: u64 = gemm_layers.iter().map(|l| l.latency_events.reduce.time_units()).sum();
        assert!(red > 5 * mult, "reduce {red} vs mult {mult}");
    }

    #[test]
    fn lower_precision_reduces_energy_not_latency() {
        let net = zoo::resnet18();
        let p8 = lr_plan(&net, 8);
        let p2 = lr_plan(&net, 2);
        let e8: f64 = p8.layers.iter().map(|l| l.energy_cells.total().compare_senses).sum();
        let e2: f64 = p2.layers.iter().map(|l| l.energy_cells.total().compare_senses).sum();
        assert!(e8 > 4.0 * e2, "compare senses 8b {e8} vs 2b {e2}");
        // Latency is reduction-bound, so precision barely moves it (Fig 7b).
        let l8: u64 = p8.layers.iter().map(|l| l.latency_events.total().time_units()).sum();
        let l2: u64 = p2.layers.iter().map(|l| l.latency_events.total().time_units()).sum();
        let ratio = l8 as f64 / l2 as f64;
        assert!(ratio < 2.0, "latency ratio 8b/2b = {ratio}");
    }

    #[test]
    fn fc_layer_with_long_contraction_folds_across_caps() {
        // AlexNet fc6: j = 9216 > 4800 rows -> cross-CAP combine.
        let net = zoo::alexnet();
        let plan = lr_plan(&net, 8);
        let fc6 = plan.layers.iter().find(|l| &*l.name == "fc6").unwrap();
        assert_eq!(fc6.kind, WorkKind::Gemm);
        assert!(fc6.latency_events.reduce.time_units() > 0);
    }

    #[test]
    fn mesh_traffic_positive_everywhere() {
        let net = zoo::resnet18();
        let plan = lr_plan(&net, 4);
        for l in &plan.layers {
            assert!(l.mesh_bits > 0, "{} has no mesh traffic", l.name);
        }
    }

    #[test]
    fn pooling_layers_present_and_costed() {
        let net = zoo::vgg16();
        let plan = lr_plan(&net, 8);
        let pools: Vec<&LayerPlan> =
            plan.layers.iter().filter(|l| l.kind == WorkKind::Pooling).collect();
        assert_eq!(pools.len(), 5);
        for p in pools {
            assert!(p.latency_events.aux.time_units() > 0);
            assert!(p.energy_cells.aux.compare_senses > 0.0);
        }
    }

    #[test]
    fn residual_layers_costed_on_resnet() {
        let net = zoo::resnet18();
        let plan = lr_plan(&net, 8);
        let res: Vec<&LayerPlan> =
            plan.layers.iter().filter(|l| l.kind == WorkKind::Residual).collect();
        assert_eq!(res.len(), 8);
    }

    #[test]
    fn phase_table_total_sums() {
        let t = PhaseTable::<f64> { populate: 1.0, multiply: 2.0, reduce: 3.0, readout: 4.0, aux: 5.0 };
        assert_eq!(t.total(), 15.0);
        let s = t.add(&t);
        assert_eq!(s.total(), 30.0);
    }
}
