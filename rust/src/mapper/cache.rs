//! Memoizing plan cache — the heart of the sweep engine's speedup.
//!
//! [`crate::mapper::map_layer`] is a pure function of (layer structure,
//! [`LayerPrec`], chip geometry): the layer *name* only labels the result
//! and the network context never enters the math. A Fig. 7-style sweep
//! that varies per-layer bits therefore recomputes the same small set of
//! plans over and over — with 7 candidate bitwidths per layer, an entire
//! sweep needs at most `7 × layers` distinct plans per chip, while the
//! uncached path pays `configs × layers` mappings.
//!
//! [`PlanCache`] memoizes plans under a [`PlanKey`] capturing exactly the
//! inputs `map_layer` reads. A hit clones the stored plan (cheap: every
//! field is `Copy` except the `Arc<str>` name) and relabels it with the
//! requesting layer's name, so cached and uncached paths produce
//! **bit-identical** results — the invariant `tests/sweep_engine.rs`
//! asserts property-style.
//!
//! The cache is `Sync` (an `RwLock`'d map + atomic hit/miss counters) so
//! [`crate::sim::SweepEngine`] can share one instance across its worker
//! threads: concurrent sweeps populate it cooperatively.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use super::{map_layer, LayerPlan, NetworkPlan};
use crate::arch::{ChipConfig, ChipKey};
use crate::model::{Layer, LayerKind, Network, Shape};
use crate::precision::{LayerPrec, PrecisionConfig};

/// Everything [`map_layer`] reads, as a hashable value.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    input: Shape,
    kind: LayerKind,
    prec: LayerPrec,
    chip: ChipKey,
}

impl PlanKey {
    /// Key for mapping `layer` at `prec` onto `chip`.
    pub fn new(layer: &Layer, prec: LayerPrec, chip: &ChipConfig) -> Self {
        Self { input: layer.input, kind: layer.kind.clone(), prec, chip: chip.cache_key() }
    }
}

/// Hit/miss counters of a [`PlanCache`] (diagnostics + perf reporting).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Distinct plans currently stored.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A thread-safe memo table for [`map_layer`] results.
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: RwLock<HashMap<PlanKey, LayerPlan>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cached [`map_layer`]: returns the memoized plan when one exists,
    /// computing and storing it otherwise. The returned plan always
    /// carries `layer`'s own name.
    pub fn map_layer(&self, layer: &Layer, prec: LayerPrec, chip: &ChipConfig) -> LayerPlan {
        let key = PlanKey::new(layer, prec, chip);
        if let Some(hit) = self.plans.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            let mut plan = hit.clone();
            plan.name = layer.name.clone();
            return plan;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = map_layer(layer, prec, chip);
        // A racing worker may have inserted the same key meanwhile; both
        // computed identical values, so last-write-wins is harmless.
        self.plans.write().unwrap().insert(key, plan.clone());
        plan
    }

    /// Cached [`crate::mapper::map_network`]: one lookup per layer.
    pub fn map_network(
        &self,
        net: &Network,
        chip: &ChipConfig,
        cfg: &PrecisionConfig,
    ) -> NetworkPlan {
        let per_layer = cfg.for_network(net);
        let layers = net
            .layers
            .iter()
            .zip(per_layer)
            .map(|(layer, prec)| self.map_layer(layer, prec, chip))
            .collect();
        NetworkPlan { net_name: net.name.clone(), layers }
    }

    /// Snapshot of the hit/miss counters and stored-entry count.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.plans.read().unwrap().len(),
        }
    }

    /// Number of distinct plans stored.
    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every stored plan and reset the counters.
    pub fn clear(&self) {
        self.plans.write().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::map_network;
    use crate::model::zoo;

    fn assert_plans_identical(a: &LayerPlan, b: &LayerPlan) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.caps_used, b.caps_used);
        assert_eq!(a.latency_events, b.latency_events);
        assert_eq!(a.energy_cells, b.energy_cells);
        assert_eq!(a.mesh_bits, b.mesh_bits);
        assert_eq!(a.mesh_bits_critical, b.mesh_bits_critical);
        assert_eq!(a.map_cells, b.map_cells);
    }

    #[test]
    fn cached_plans_match_direct_mapping_exactly() {
        let net = zoo::resnet18();
        let chip = ChipConfig::lr();
        let cache = PlanCache::new();
        for bits in [2u32, 5, 8] {
            let cfg = PrecisionConfig::fixed(bits, net.weight_layers());
            let direct = map_network(&net, &chip, &cfg);
            let cached = cache.map_network(&net, &chip, &cfg);
            assert_eq!(direct.layers.len(), cached.layers.len());
            for (d, c) in direct.layers.iter().zip(&cached.layers) {
                assert_plans_identical(d, c);
            }
            // Second pass must hit for every layer and stay identical.
            let again = cache.map_network(&net, &chip, &cfg);
            for (d, c) in direct.layers.iter().zip(&again.layers) {
                assert_plans_identical(d, c);
            }
        }
        let stats = cache.stats();
        assert!(stats.hits > 0, "{stats:?}");
        assert!(stats.hit_rate() > 0.0 && stats.hit_rate() < 1.0);
    }

    #[test]
    fn sweep_stores_at_most_unique_layer_bits_plans() {
        // The tentpole claim: a whole per-layer bits sweep needs only
        // O(unique layer × bits) plans, not O(configs × layers).
        let net = zoo::alexnet();
        let chip = ChipConfig::lr();
        let cache = PlanCache::new();
        let mut rng = crate::util::rng::Rng::new(11);
        for _ in 0..40 {
            let bits: Vec<u32> =
                (0..net.weight_layers()).map(|_| 2 + rng.below(7) as u32).collect();
            let cfg = PrecisionConfig::from_bits("r", &bits);
            cache.map_network(&net, &chip, &cfg);
        }
        // 7 candidate widths per layer bounds the cache (structurally
        // identical layers shrink it further).
        assert!(
            cache.len() <= 7 * net.layers.len(),
            "cache holds {} > {}",
            cache.len(),
            7 * net.layers.len()
        );
        let stats = cache.stats();
        assert!(stats.hit_rate() > 0.5, "hit rate {:.2}", stats.hit_rate());
    }

    #[test]
    fn different_chips_do_not_share_plans() {
        let net = zoo::alexnet();
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let lr = ChipConfig::lr();
        let ir = ChipConfig::ir_for(&net);
        let cache = PlanCache::new();
        let on_lr = cache.map_network(&net, &lr, &cfg);
        let on_ir = cache.map_network(&net, &ir, &cfg);
        let direct_ir = map_network(&net, &ir, &cfg);
        for (c, d) in on_ir.layers.iter().zip(&direct_ir.layers) {
            assert_plans_identical(c, d);
        }
        // IR never time-folds, LR does on at least one AlexNet layer — the
        // cache must have kept them apart.
        assert!(on_lr.layers.iter().any(|l| l.steps > 1));
        assert!(on_ir.layers.iter().filter(|l| l.kind == crate::mapper::WorkKind::Gemm).all(|l| l.steps == 1));
    }

    #[test]
    fn clear_resets_everything() {
        let net = zoo::alexnet();
        let chip = ChipConfig::lr();
        let cache = PlanCache::new();
        let cfg = PrecisionConfig::fixed(4, net.weight_layers());
        cache.map_network(&net, &chip, &cfg);
        assert!(!cache.is_empty());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats(), CacheStats::default());
    }
}
