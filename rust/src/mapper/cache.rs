//! Memoizing plan cache — the heart of the sweep engine's speedup.
//!
//! [`crate::mapper::map_layer`] is a pure function of (layer structure,
//! [`LayerPrec`], chip geometry): the layer *name* only labels the result
//! and the network context never enters the math. A Fig. 7-style sweep
//! that varies per-layer bits therefore recomputes the same small set of
//! plans over and over — with 7 candidate bitwidths per layer, an entire
//! sweep needs at most `7 × layers` distinct plans per chip, while the
//! uncached path pays `configs × layers` mappings.
//!
//! [`PlanCache`] memoizes plans under a [`PlanKey`] capturing exactly the
//! inputs `map_layer` reads. A hit clones the stored plan (cheap: every
//! field is `Copy` except the `Arc<str>` name) and relabels it with the
//! requesting layer's name, so cached and uncached paths produce
//! **bit-identical** results — the invariant `tests/sweep_engine.rs`
//! asserts property-style.
//!
//! The cache is `Sync` (an `RwLock`'d map + atomic hit/miss counters) so
//! [`crate::sim::SweepEngine`] can share one instance across its worker
//! threads: concurrent sweeps populate it cooperatively.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use super::{map_layer, LayerPlan, NetworkPlan, PhaseTable, WorkKind};
use crate::ap::{CellEvents, Events};
use crate::arch::{ChipConfig, ChipKey};
use crate::model::{Layer, LayerKind, Network, Shape};
use crate::precision::{LayerPrec, PrecisionConfig};
use crate::util::json::Json;

/// Everything [`map_layer`] reads, as a hashable value.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    input: Shape,
    kind: LayerKind,
    prec: LayerPrec,
    chip: ChipKey,
}

impl PlanKey {
    /// Key for mapping `layer` at `prec` onto `chip`.
    pub fn new(layer: &Layer, prec: LayerPrec, chip: &ChipConfig) -> Self {
        Self { input: layer.input, kind: layer.kind.clone(), prec, chip: chip.cache_key() }
    }
}

/// Hit/miss counters of a [`PlanCache`] (diagnostics + perf reporting).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the memo table.
    pub hits: u64,
    /// Lookups that had to run [`map_layer`].
    pub misses: u64,
    /// Distinct plans currently stored.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A thread-safe memo table for [`map_layer`] results.
///
/// ```
/// use bf_imna::arch::ChipConfig;
/// use bf_imna::mapper::{map_network, PlanCache};
/// use bf_imna::model::zoo;
/// use bf_imna::precision::PrecisionConfig;
///
/// let net = zoo::serve_cnn();
/// let chip = ChipConfig::lr();
/// let cfg = PrecisionConfig::fixed(8, net.weight_layers());
/// let cache = PlanCache::new();
/// // Cached mapping is bit-identical to the direct one...
/// let cached = cache.map_network(&net, &chip, &cfg);
/// let direct = map_network(&net, &chip, &cfg);
/// assert_eq!(cached.layers.len(), direct.layers.len());
/// // ...and a second pass hits the memo table for every layer.
/// cache.map_network(&net, &chip, &cfg);
/// assert!(cache.stats().hits >= net.layers.len() as u64);
/// ```
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: RwLock<HashMap<PlanKey, LayerPlan>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cached [`map_layer`]: returns the memoized plan when one exists,
    /// computing and storing it otherwise. The returned plan always
    /// carries `layer`'s own name.
    pub fn map_layer(&self, layer: &Layer, prec: LayerPrec, chip: &ChipConfig) -> LayerPlan {
        let key = PlanKey::new(layer, prec, chip);
        if let Some(hit) = self.plans.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            let mut plan = hit.clone();
            plan.name = layer.name.clone();
            return plan;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = map_layer(layer, prec, chip);
        // A racing worker may have inserted the same key meanwhile; both
        // computed identical values, so last-write-wins is harmless.
        self.plans.write().unwrap().insert(key, plan.clone());
        plan
    }

    /// Cached [`crate::mapper::map_network`]: one lookup per layer.
    pub fn map_network(
        &self,
        net: &Network,
        chip: &ChipConfig,
        cfg: &PrecisionConfig,
    ) -> NetworkPlan {
        let per_layer = cfg.for_network(net);
        let layers = net
            .layers
            .iter()
            .zip(per_layer)
            .map(|(layer, prec)| self.map_layer(layer, prec, chip))
            .collect();
        NetworkPlan { net_name: net.name.clone(), layers }
    }

    /// Snapshot of the hit/miss counters and stored-entry count.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.plans.read().unwrap().len(),
        }
    }

    /// Number of distinct plans stored.
    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every stored plan and reset the counters.
    pub fn clear(&self) {
        self.plans.write().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    /// Batch-level prewarm: map every layer of `net` at `cfg` on `chip`,
    /// populating the memo table, and return the number of *new* plans
    /// stored. After a prewarm, a parallel sweep over the same coordinates
    /// never maps cold — without it, workers that race on the same cold key
    /// each pay the `map_layer` (both results are identical; only the work
    /// is duplicated). The prewarm lookups count toward [`Self::stats`]
    /// like any other.
    pub fn prewarm(&self, net: &Network, chip: &ChipConfig, cfg: &PrecisionConfig) -> usize {
        let before = self.len();
        self.map_network(net, chip, cfg);
        self.len() - before
    }

    /// Copy every stored plan into a shippable [`CacheSnapshot`].
    pub fn snapshot(&self) -> CacheSnapshot {
        let plans = self.plans.read().unwrap();
        CacheSnapshot {
            entries: plans.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
        }
    }

    /// Insert every snapshot entry that is not already present, returning
    /// how many were added. Counters are untouched: snapshot loads are not
    /// lookups, so a subsequent sweep's hit rate still measures real reuse.
    pub fn absorb(&self, snap: &CacheSnapshot) -> usize {
        let mut plans = self.plans.write().unwrap();
        let mut added = 0;
        for (k, v) in &snap.entries {
            if !plans.contains_key(k) {
                plans.insert(k.clone(), v.clone());
                added += 1;
            }
        }
        added
    }
}

/// A serializable copy of a [`PlanCache`]'s contents — the "shippable"
/// half of the prewarm story. A sweep coordinator prewarms one cache,
/// [`PlanCache::snapshot`]s it, writes the JSON to disk (or a wire), and
/// every shard worker [`PlanCache::absorb`]s it to skip all cold mapping.
///
/// The encoding is lossless: `u64`s serialize as decimal strings (JSON
/// numbers are `f64` and cannot carry all 64 bits) and `f64`s as the
/// decimal form of their IEEE-754 bit patterns, so an absorbed snapshot
/// reproduces the donor cache's plans **bit for bit** — the sweep-level
/// determinism invariant survives the round trip through disk.
///
/// Snapshots additionally carry the donor's [`mapper_fingerprint`] — a
/// hash of the mapper's structural outputs on a fixed probe workload —
/// and [`CacheSnapshot::from_json`] rejects documents whose fingerprint
/// does not match the running binary. A snapshot written before a
/// mapper / chip-geometry change therefore fails loudly instead of
/// silently injecting stale plans and breaking the "snapshots are never a
/// correctness dependency" invariant.
#[derive(Debug, Clone, Default)]
pub struct CacheSnapshot {
    entries: Vec<(PlanKey, LayerPlan)>,
}

impl CacheSnapshot {
    /// Number of plans in the snapshot.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the snapshot carries no plans.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize to a JSON document. Entries are sorted by their canonical
    /// encoding so the output is deterministic regardless of the donor
    /// cache's hash-map iteration order, and a content checksum over the
    /// encoded entries rides along for corruption detection.
    pub fn to_json(&self) -> Json {
        let (items, checksum) = entries_digest(&self.entries);
        Json::obj([
            ("version", Json::num(1.0)),
            ("fingerprint", Json::str(mapper_fingerprint())),
            ("checksum", Json::str(checksum)),
            ("entries", Json::arr(items.into_iter().map(|(_, v)| v))),
        ])
    }

    /// Parse a document produced by [`Self::to_json`]. Rejects snapshots
    /// from a binary whose mapper behaves differently (see
    /// [`mapper_fingerprint`]) and snapshots whose entries fail the
    /// content checksum (bit rot / hand edits) — corruption is detected,
    /// not authenticated; the snapshot format is not a security boundary.
    pub fn from_json(v: &Json) -> Result<CacheSnapshot, String> {
        match v.get("version").and_then(Json::as_i64) {
            Some(1) => {}
            other => return Err(format!("unsupported snapshot version {other:?}")),
        }
        let expected = mapper_fingerprint();
        match v.get("fingerprint").and_then(Json::as_str) {
            Some(fp) if fp == expected => {}
            Some(fp) => {
                return Err(format!(
                    "snapshot fingerprint {fp} does not match this binary's mapper \
                     ({expected}): it was produced by a different mapper/cost-model \
                     build — recreate it with --cache-out"
                ))
            }
            None => return Err("snapshot: missing 'fingerprint'".to_string()),
        }
        let raw = v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("snapshot: missing 'entries' array")?;
        let mut entries = Vec::with_capacity(raw.len());
        for e in raw {
            let key = key_from_json(e.get("key").ok_or("snapshot entry: missing 'key'")?)?;
            let plan = plan_from_json(e.get("plan").ok_or("snapshot entry: missing 'plan'")?)?;
            entries.push((key, plan));
        }
        let (_, recomputed) = entries_digest(&entries);
        match v.get("checksum").and_then(Json::as_str) {
            Some(c) if c == recomputed => {}
            Some(_) => {
                return Err(
                    "snapshot checksum mismatch: the entries are corrupted — recreate the \
                     snapshot with --cache-out"
                        .to_string(),
                )
            }
            None => return Err("snapshot: missing 'checksum'".to_string()),
        }
        Ok(CacheSnapshot { entries })
    }
}

/// Canonically encode every entry, sorted, plus an FNV-1a checksum over
/// the encoded text. Shared by [`CacheSnapshot::to_json`] (to emit) and
/// [`CacheSnapshot::from_json`] (to verify after re-parsing): because the
/// entry encoding is lossless and the writer canonical, any bit-level
/// change to a stored plan or key changes the checksum.
fn entries_digest(entries: &[(PlanKey, LayerPlan)]) -> (Vec<(String, Json)>, String) {
    let mut items: Vec<(String, Json)> = entries
        .iter()
        .map(|(k, v)| {
            let entry = Json::obj([("key", key_to_json(k)), ("plan", plan_to_json(v))]);
            (entry.to_string(), entry)
        })
        .collect();
    items.sort_by(|a, b| a.0.cmp(&b.0));
    let mut h = FNV_OFFSET;
    for (text, _) in &items {
        h = fnv1a(h, text.as_bytes());
    }
    (items, format!("{h:016x}"))
}

/// Behavioral fingerprint of the mapper: map a fixed synthetic probe
/// workload (one layer of each kind at two precisions on the Table V LR
/// chip) and hash every structural output bit plus the chip key, then
/// fold in the default cost table's
/// [`cost_version`](crate::costs::CostTable::cost_version). Any change to
/// `map_layer`'s math, the pass/LUT cost constants it consumes, the
/// default chip geometry, *or any default cost-table row* changes this
/// value — no manual version bump required. Used to guard
/// [`CacheSnapshot`] exchange between processes and every shard / fleet
/// handshake: a snapshot only loads into (and a peer only talks to) a
/// binary whose mapper and cost model would have produced the same
/// numbers.
///
/// Plans themselves are structural — independent of the energy values a
/// [`CostTable`](crate::costs::CostTable) declares — so a `--costs`
/// what-if sweep still runs under this (default-table) fingerprint: the
/// alternative table travels inside the spec, while the fingerprint pins
/// the *binary's* semantics.
pub fn mapper_fingerprint() -> String {
    use std::sync::OnceLock;
    // Pure function of the binary: memoized (the probe mapping is not
    // free and serving hot paths stamp the fingerprint per handshake).
    static FP: OnceLock<String> = OnceLock::new();
    FP.get_or_init(|| mapper_fingerprint_with(crate::costs::default_table())).clone()
}

/// [`mapper_fingerprint`] parameterized over the cost table whose
/// `cost_version` is folded in — exposed so tests (and tools that reason
/// about cross-binary compatibility) can compute the fingerprint a binary
/// with a *different* default cost model would advertise.
pub fn mapper_fingerprint_with(table: &crate::costs::CostTable) -> String {
    let chip = ChipConfig::lr();
    let probes = [
        Layer {
            name: "probe_conv".into(),
            input: Shape::new(16, 16, 8),
            kind: LayerKind::Conv { k: 3, out_c: 16, stride: 1, pad: 1, groups: 1, relu: true },
            from: None,
        },
        Layer {
            name: "probe_pool".into(),
            input: Shape::new(16, 16, 16),
            kind: LayerKind::MaxPool { win: 2, stride: 2 },
            from: None,
        },
        Layer {
            name: "probe_gap".into(),
            input: Shape::new(8, 8, 16),
            kind: LayerKind::AvgPool { win: 8, stride: 8 },
            from: None,
        },
        Layer {
            name: "probe_fc".into(),
            input: Shape::new(1, 1, 256),
            kind: LayerKind::Fc { out_features: 64, relu: false },
            from: None,
        },
        Layer {
            name: "probe_res".into(),
            input: Shape::new(8, 8, 16),
            kind: LayerKind::ResidualAdd { from: 0, relu: true },
            from: None,
        },
    ];
    let mut words: Vec<u64> = Vec::new();
    for layer in &probes {
        for bits in [2u32, 8] {
            let p = map_layer(layer, LayerPrec::uniform(bits), &chip);
            words.push(p.steps);
            words.push(p.caps_used);
            for ev in [
                p.latency_events.populate,
                p.latency_events.multiply,
                p.latency_events.reduce,
                p.latency_events.readout,
                p.latency_events.aux,
            ] {
                words.extend([ev.compares, ev.writes, ev.reads]);
            }
            for ce in [
                p.energy_cells.populate,
                p.energy_cells.multiply,
                p.energy_cells.reduce,
                p.energy_cells.readout,
                p.energy_cells.aux,
                p.map_cells,
            ] {
                words.extend([
                    ce.compare_senses.to_bits(),
                    ce.lut_write_cells.to_bits(),
                    ce.populate_write_cells.to_bits(),
                    ce.read_senses.to_bits(),
                ]);
            }
            words.push(p.mesh_bits);
            words.push(p.mesh_bits_critical);
        }
    }
    words.extend(chip.cache_key().to_words());
    let mut h = FNV_OFFSET;
    for w in &words {
        h = fnv1a(h, &w.to_le_bytes());
    }
    h = fnv1a(h, table.cost_version().as_bytes());
    format!("{h:016x}")
}

/// FNV-1a offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into a running FNV-1a state.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---- Lossless JSON encoding of keys and plans. --------------------------
//
// `u64` -> decimal string; `f64` -> decimal string of its bit pattern.
// Everything here is internal: the only public surface is `CacheSnapshot`
// and the `mapper_fingerprint` guard above.

fn ju64(x: u64) -> Json {
    Json::str(x.to_string())
}

fn pu64(v: Option<&Json>, what: &str) -> Result<u64, String> {
    v.and_then(Json::as_str)
        .ok_or_else(|| format!("{what}: expected a decimal string"))?
        .parse::<u64>()
        .map_err(|e| format!("{what}: {e}"))
}

fn jf64(x: f64) -> Json {
    ju64(x.to_bits())
}

fn pf64(v: Option<&Json>, what: &str) -> Result<f64, String> {
    Ok(f64::from_bits(pu64(v, what)?))
}

fn events_to_json(e: &Events) -> Json {
    Json::obj([("c", ju64(e.compares)), ("w", ju64(e.writes)), ("r", ju64(e.reads))])
}

fn events_from_json(v: &Json) -> Result<Events, String> {
    Ok(Events::new(
        pu64(v.get("c"), "events.c")?,
        pu64(v.get("w"), "events.w")?,
        pu64(v.get("r"), "events.r")?,
    ))
}

fn cells_to_json(c: &CellEvents) -> Json {
    Json::obj([
        ("cs", jf64(c.compare_senses)),
        ("lw", jf64(c.lut_write_cells)),
        ("pw", jf64(c.populate_write_cells)),
        ("rs", jf64(c.read_senses)),
    ])
}

fn cells_from_json(v: &Json) -> Result<CellEvents, String> {
    Ok(CellEvents {
        compare_senses: pf64(v.get("cs"), "cells.cs")?,
        lut_write_cells: pf64(v.get("lw"), "cells.lw")?,
        populate_write_cells: pf64(v.get("pw"), "cells.pw")?,
        read_senses: pf64(v.get("rs"), "cells.rs")?,
    })
}

fn phases_to_json<T>(t: &PhaseTable<T>, f: impl Fn(&T) -> Json) -> Json {
    Json::obj([
        ("populate", f(&t.populate)),
        ("multiply", f(&t.multiply)),
        ("reduce", f(&t.reduce)),
        ("readout", f(&t.readout)),
        ("aux", f(&t.aux)),
    ])
}

fn phases_from_json<T: Default + Copy>(
    v: &Json,
    f: impl Fn(&Json) -> Result<T, String>,
) -> Result<PhaseTable<T>, String> {
    let phase = |name: &str| -> Result<T, String> {
        f(v.get(name).ok_or_else(|| format!("phases: missing '{name}'"))?)
    };
    Ok(PhaseTable {
        populate: phase("populate")?,
        multiply: phase("multiply")?,
        reduce: phase("reduce")?,
        readout: phase("readout")?,
        aux: phase("aux")?,
    })
}

fn layer_kind_to_json(k: &LayerKind) -> Json {
    match k {
        LayerKind::Conv { k, out_c, stride, pad, groups, relu } => Json::obj([
            ("op", Json::str("conv")),
            ("k", ju64(*k)),
            ("out_c", ju64(*out_c)),
            ("stride", ju64(*stride)),
            ("pad", ju64(*pad)),
            ("groups", ju64(*groups)),
            ("relu", Json::Bool(*relu)),
        ]),
        LayerKind::Fc { out_features, relu } => Json::obj([
            ("op", Json::str("fc")),
            ("out_features", ju64(*out_features)),
            ("relu", Json::Bool(*relu)),
        ]),
        LayerKind::MaxPool { win, stride } => Json::obj([
            ("op", Json::str("maxpool")),
            ("win", ju64(*win)),
            ("stride", ju64(*stride)),
        ]),
        LayerKind::AvgPool { win, stride } => Json::obj([
            ("op", Json::str("avgpool")),
            ("win", ju64(*win)),
            ("stride", ju64(*stride)),
        ]),
        LayerKind::ResidualAdd { from, relu } => Json::obj([
            ("op", Json::str("residual")),
            ("from", ju64(*from as u64)),
            ("relu", Json::Bool(*relu)),
        ]),
    }
}

fn layer_kind_from_json(v: &Json) -> Result<LayerKind, String> {
    let relu = || -> Result<bool, String> {
        v.get("relu").and_then(Json::as_bool).ok_or("kind: missing 'relu'".to_string())
    };
    match v.get("op").and_then(Json::as_str) {
        Some("conv") => Ok(LayerKind::Conv {
            k: pu64(v.get("k"), "conv.k")?,
            out_c: pu64(v.get("out_c"), "conv.out_c")?,
            stride: pu64(v.get("stride"), "conv.stride")?,
            pad: pu64(v.get("pad"), "conv.pad")?,
            groups: pu64(v.get("groups"), "conv.groups")?,
            relu: relu()?,
        }),
        Some("fc") => Ok(LayerKind::Fc {
            out_features: pu64(v.get("out_features"), "fc.out_features")?,
            relu: relu()?,
        }),
        Some("maxpool") => Ok(LayerKind::MaxPool {
            win: pu64(v.get("win"), "maxpool.win")?,
            stride: pu64(v.get("stride"), "maxpool.stride")?,
        }),
        Some("avgpool") => Ok(LayerKind::AvgPool {
            win: pu64(v.get("win"), "avgpool.win")?,
            stride: pu64(v.get("stride"), "avgpool.stride")?,
        }),
        Some("residual") => Ok(LayerKind::ResidualAdd {
            from: pu64(v.get("from"), "residual.from")? as usize,
            relu: relu()?,
        }),
        other => Err(format!("kind: unknown op {other:?}")),
    }
}

fn work_kind_name(k: WorkKind) -> &'static str {
    match k {
        WorkKind::Gemm => "gemm",
        WorkKind::Pooling => "pooling",
        WorkKind::Residual => "residual",
        WorkKind::Relu => "relu",
    }
}

fn work_kind_from_name(s: &str) -> Result<WorkKind, String> {
    match s {
        "gemm" => Ok(WorkKind::Gemm),
        "pooling" => Ok(WorkKind::Pooling),
        "residual" => Ok(WorkKind::Residual),
        "relu" => Ok(WorkKind::Relu),
        other => Err(format!("unknown work kind '{other}'")),
    }
}

fn key_to_json(k: &PlanKey) -> Json {
    Json::obj([
        (
            "input",
            Json::obj([
                ("h", ju64(k.input.h)),
                ("w", ju64(k.input.w)),
                ("c", ju64(k.input.c)),
            ]),
        ),
        ("kind", layer_kind_to_json(&k.kind)),
        ("prec", Json::obj([("w", ju64(k.prec.w as u64)), ("a", ju64(k.prec.a as u64))])),
        ("chip", Json::arr(k.chip.to_words().iter().map(|&w| ju64(w)))),
    ])
}

fn key_from_json(v: &Json) -> Result<PlanKey, String> {
    let input = v.get("input").ok_or("key: missing 'input'")?;
    let input = Shape::new(
        pu64(input.get("h"), "input.h")?,
        pu64(input.get("w"), "input.w")?,
        pu64(input.get("c"), "input.c")?,
    );
    let kind = layer_kind_from_json(v.get("kind").ok_or("key: missing 'kind'")?)?;
    let prec = v.get("prec").ok_or("key: missing 'prec'")?;
    let prec = LayerPrec {
        w: pu64(prec.get("w"), "prec.w")? as u32,
        a: pu64(prec.get("a"), "prec.a")? as u32,
    };
    let words = v
        .get("chip")
        .and_then(Json::as_arr)
        .ok_or("key: missing 'chip' words")?
        .iter()
        .map(|w| pu64(Some(w), "chip word"))
        .collect::<Result<Vec<u64>, String>>()?;
    let chip = ChipKey::from_words(&words).ok_or("key: malformed chip words")?;
    Ok(PlanKey { input, kind, prec, chip })
}

fn plan_to_json(p: &LayerPlan) -> Json {
    Json::obj([
        ("name", Json::str(p.name.as_ref())),
        ("kind", Json::str(work_kind_name(p.kind))),
        ("steps", ju64(p.steps)),
        ("caps_used", ju64(p.caps_used)),
        ("latency", phases_to_json(&p.latency_events, events_to_json)),
        ("energy", phases_to_json(&p.energy_cells, cells_to_json)),
        ("mesh_bits", ju64(p.mesh_bits)),
        ("mesh_bits_critical", ju64(p.mesh_bits_critical)),
        ("map_cells", cells_to_json(&p.map_cells)),
    ])
}

fn plan_from_json(v: &Json) -> Result<LayerPlan, String> {
    Ok(LayerPlan {
        name: v.get("name").and_then(Json::as_str).ok_or("plan: missing 'name'")?.into(),
        kind: work_kind_from_name(
            v.get("kind").and_then(Json::as_str).ok_or("plan: missing 'kind'")?,
        )?,
        steps: pu64(v.get("steps"), "plan.steps")?,
        caps_used: pu64(v.get("caps_used"), "plan.caps_used")?,
        latency_events: phases_from_json(
            v.get("latency").ok_or("plan: missing 'latency'")?,
            events_from_json,
        )?,
        energy_cells: phases_from_json(
            v.get("energy").ok_or("plan: missing 'energy'")?,
            cells_from_json,
        )?,
        mesh_bits: pu64(v.get("mesh_bits"), "plan.mesh_bits")?,
        mesh_bits_critical: pu64(v.get("mesh_bits_critical"), "plan.mesh_bits_critical")?,
        map_cells: cells_from_json(v.get("map_cells").ok_or("plan: missing 'map_cells'")?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::map_network;
    use crate::model::zoo;

    fn assert_plans_identical(a: &LayerPlan, b: &LayerPlan) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.caps_used, b.caps_used);
        assert_eq!(a.latency_events, b.latency_events);
        assert_eq!(a.energy_cells, b.energy_cells);
        assert_eq!(a.mesh_bits, b.mesh_bits);
        assert_eq!(a.mesh_bits_critical, b.mesh_bits_critical);
        assert_eq!(a.map_cells, b.map_cells);
    }

    #[test]
    fn cached_plans_match_direct_mapping_exactly() {
        let net = zoo::resnet18();
        let chip = ChipConfig::lr();
        let cache = PlanCache::new();
        for bits in [2u32, 5, 8] {
            let cfg = PrecisionConfig::fixed(bits, net.weight_layers());
            let direct = map_network(&net, &chip, &cfg);
            let cached = cache.map_network(&net, &chip, &cfg);
            assert_eq!(direct.layers.len(), cached.layers.len());
            for (d, c) in direct.layers.iter().zip(&cached.layers) {
                assert_plans_identical(d, c);
            }
            // Second pass must hit for every layer and stay identical.
            let again = cache.map_network(&net, &chip, &cfg);
            for (d, c) in direct.layers.iter().zip(&again.layers) {
                assert_plans_identical(d, c);
            }
        }
        let stats = cache.stats();
        assert!(stats.hits > 0, "{stats:?}");
        assert!(stats.hit_rate() > 0.0 && stats.hit_rate() < 1.0);
    }

    #[test]
    fn sweep_stores_at_most_unique_layer_bits_plans() {
        // The tentpole claim: a whole per-layer bits sweep needs only
        // O(unique layer × bits) plans, not O(configs × layers).
        let net = zoo::alexnet();
        let chip = ChipConfig::lr();
        let cache = PlanCache::new();
        let mut rng = crate::util::rng::Rng::new(11);
        for _ in 0..40 {
            let bits: Vec<u32> =
                (0..net.weight_layers()).map(|_| 2 + rng.below(7) as u32).collect();
            let cfg = PrecisionConfig::from_bits("r", &bits);
            cache.map_network(&net, &chip, &cfg);
        }
        // 7 candidate widths per layer bounds the cache (structurally
        // identical layers shrink it further).
        assert!(
            cache.len() <= 7 * net.layers.len(),
            "cache holds {} > {}",
            cache.len(),
            7 * net.layers.len()
        );
        let stats = cache.stats();
        assert!(stats.hit_rate() > 0.5, "hit rate {:.2}", stats.hit_rate());
    }

    #[test]
    fn different_chips_do_not_share_plans() {
        let net = zoo::alexnet();
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let lr = ChipConfig::lr();
        let ir = ChipConfig::ir_for(&net);
        let cache = PlanCache::new();
        let on_lr = cache.map_network(&net, &lr, &cfg);
        let on_ir = cache.map_network(&net, &ir, &cfg);
        let direct_ir = map_network(&net, &ir, &cfg);
        for (c, d) in on_ir.layers.iter().zip(&direct_ir.layers) {
            assert_plans_identical(c, d);
        }
        // IR never time-folds, LR does on at least one AlexNet layer — the
        // cache must have kept them apart.
        assert!(on_lr.layers.iter().any(|l| l.steps > 1));
        assert!(on_ir.layers.iter().filter(|l| l.kind == crate::mapper::WorkKind::Gemm).all(|l| l.steps == 1));
    }

    #[test]
    fn snapshot_round_trips_bit_for_bit() {
        let net = zoo::resnet18();
        let chip = ChipConfig::lr();
        let donor = PlanCache::new();
        for bits in [2u32, 4, 8] {
            donor.prewarm(&net, &chip, &PrecisionConfig::fixed(bits, net.weight_layers()));
        }
        let snap = donor.snapshot();
        assert_eq!(snap.len(), donor.len());

        // JSON round trip: value-identical, and the writer is deterministic.
        let text = snap.to_json().to_string();
        let parsed = CacheSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed.to_json().to_string(), text);

        // Absorbing into a fresh cache reproduces the donor's plans exactly:
        // a full re-mapping misses on nothing and matches bit for bit.
        let fresh = PlanCache::new();
        assert_eq!(fresh.absorb(&parsed), snap.len());
        for bits in [2u32, 4, 8] {
            let cfg = PrecisionConfig::fixed(bits, net.weight_layers());
            let from_snapshot = fresh.map_network(&net, &chip, &cfg);
            let direct = map_network(&net, &chip, &cfg);
            for (s, d) in from_snapshot.layers.iter().zip(&direct.layers) {
                assert_plans_identical(s, d);
            }
        }
        assert_eq!(fresh.stats().misses, 0, "snapshot should cover every lookup");
        // Absorbing twice adds nothing.
        assert_eq!(fresh.absorb(&parsed), 0);
    }

    #[test]
    fn prewarm_reports_new_plans() {
        let net = zoo::alexnet();
        let chip = ChipConfig::lr();
        let cache = PlanCache::new();
        let cfg = PrecisionConfig::fixed(6, net.weight_layers());
        let added = cache.prewarm(&net, &chip, &cfg);
        assert!(added > 0);
        assert_eq!(added, cache.len());
        // Same coordinates again: nothing new.
        assert_eq!(cache.prewarm(&net, &chip, &cfg), 0);
    }

    #[test]
    fn snapshot_rejects_malformed_documents() {
        assert!(CacheSnapshot::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad_version = Json::parse(r#"{"version": 99, "entries": []}"#).unwrap();
        assert!(CacheSnapshot::from_json(&bad_version).is_err());
        // Fingerprint is mandatory...
        let no_fp = Json::parse(r#"{"version": 1, "entries": []}"#).unwrap();
        assert!(CacheSnapshot::from_json(&no_fp).is_err());
        // A well-formed empty snapshot round-trips.
        let empty = CacheSnapshot::default().to_json();
        assert!(CacheSnapshot::from_json(&empty).unwrap().is_empty());
        // A snapshot from a different mapper build is rejected.
        let mut stale = match CacheSnapshot::default().to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("snapshots serialize to objects"),
        };
        stale.insert("fingerprint".to_string(), Json::str("0000000000000000"));
        let err = CacheSnapshot::from_json(&Json::Obj(stale)).unwrap_err();
        assert!(err.contains("different mapper"), "{err}");
    }

    #[test]
    fn snapshot_rejects_corrupted_entries() {
        let net = zoo::alexnet();
        let chip = ChipConfig::lr();
        let donor = PlanCache::new();
        donor.prewarm(&net, &chip, &PrecisionConfig::fixed(8, net.weight_layers()));
        let mut doc = donor.snapshot().to_json();
        // Sanity: the untampered document loads.
        assert!(CacheSnapshot::from_json(&doc).is_ok());
        // Flip one stored value (a parseable-but-wrong edit): the content
        // checksum must catch it even though the fingerprint is intact.
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Arr(entries)) = m.get_mut("entries") {
                if let Json::Obj(entry) = &mut entries[0] {
                    if let Some(Json::Obj(plan)) = entry.get_mut("plan") {
                        plan.insert("steps".to_string(), Json::str("999999"));
                    }
                }
            }
        }
        let err = CacheSnapshot::from_json(&doc).unwrap_err();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn mapper_fingerprint_is_stable_within_a_build() {
        let fp = mapper_fingerprint();
        assert_eq!(fp.len(), 16, "{fp}");
        assert_eq!(fp, mapper_fingerprint(), "fingerprint must be deterministic");
        // And equals the parameterized form at the default table.
        assert_eq!(fp, mapper_fingerprint_with(crate::costs::default_table()));
    }

    #[test]
    fn mutated_cost_table_changes_fingerprint_and_rejects_snapshots() {
        // A binary whose default cost model drifted by one bit of one row
        // advertises a different fingerprint...
        let mut mutated = crate::costs::default_table().clone();
        mutated.rows[0].compare.energy_j *= 1.0 + 1e-9;
        let drifted = mapper_fingerprint_with(&mutated);
        assert_ne!(drifted, mapper_fingerprint());

        // ...so its snapshots are rejected by this binary (the stale
        // CacheSnapshot path of the cost-version contract).
        let mut doc = match CacheSnapshot::default().to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("snapshots serialize to objects"),
        };
        doc.insert("fingerprint".to_string(), Json::str(drifted));
        let err = CacheSnapshot::from_json(&Json::Obj(doc)).unwrap_err();
        assert!(err.contains("different mapper"), "{err}");

        // Cycle shapes are fingerprinted too, not just energies.
        let mut cycles = crate::costs::default_table().clone();
        cycles.rows[0].write.cycles += 1.0;
        assert_ne!(mapper_fingerprint_with(&cycles), mapper_fingerprint());
    }

    #[test]
    fn clear_resets_everything() {
        let net = zoo::alexnet();
        let chip = ChipConfig::lr();
        let cache = PlanCache::new();
        let cfg = PrecisionConfig::fixed(4, net.weight_layers());
        cache.map_network(&net, &chip, &cfg);
        assert!(!cache.is_empty());
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats(), CacheStats::default());
    }
}
