//! CNN model intermediate representation.
//!
//! A [`Network`] is an ordered list of [`Layer`]s with explicit input
//! shapes. The mapper lowers convolutional and fully-connected layers to
//! GEMM via im2col ([`gemm`]), exactly as §II-C describes; pooling and ReLU
//! map to the corresponding AP CNN functions. The [`zoo`] module provides
//! AlexNet, VGG16, ResNet18 and ResNet50 with ImageNet shapes (the paper's
//! benchmarks) plus the small serving CNN used by the end-to-end example.

pub mod gemm;
pub mod zoo;

use std::sync::Arc;

/// A 3-D feature-map shape (height, width, channels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    /// Height.
    pub h: u64,
    /// Width.
    pub w: u64,
    /// Channels.
    pub c: u64,
}

impl Shape {
    /// Convenience constructor.
    pub fn new(h: u64, w: u64, c: u64) -> Self {
        Self { h, w, c }
    }

    /// Total element count.
    pub fn elems(&self) -> u64 {
        self.h * self.w * self.c
    }
}

/// One network layer. Each layer carries its input shape; chain consistency
/// is validated by [`Network::validate`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// 2-D convolution with `out_c` kernels of `k x k x (in.c / groups)`,
    /// given stride and symmetric zero padding (`groups > 1` models
    /// AlexNet's two-tower grouped convolutions). A ReLU may be fused
    /// behind it (`relu`).
    Conv { k: u64, out_c: u64, stride: u64, pad: u64, groups: u64, relu: bool },
    /// Fully-connected layer: `out_features x in_features` weights.
    Fc { out_features: u64, relu: bool },
    /// Max pooling with window `win x win` and the given stride.
    MaxPool { win: u64, stride: u64 },
    /// Average pooling with window `win x win` and the given stride
    /// (`win == in.h` gives global average pooling).
    AvgPool { win: u64, stride: u64 },
    /// Residual element-wise addition with the output of layer `from`
    /// (index into the network's layer list), followed by optional ReLU.
    ResidualAdd { from: usize, relu: bool },
}

/// A named layer with its input shape. `from` names the layer whose output
/// feeds this one (`None` = the immediately preceding layer), allowing the
/// branch-and-merge topology of residual networks while keeping a flat
/// layer list.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    /// Interned layer name: an `Arc<str>` so the mapper and simulator can
    /// label per-layer results without re-allocating a `String` per
    /// simulation point (the DSE hot path maps every layer thousands of
    /// times per sweep).
    pub name: Arc<str>,
    /// Input feature-map shape.
    pub input: Shape,
    /// What the layer computes.
    pub kind: LayerKind,
    /// Index of the layer feeding this one (`None` = the previous layer).
    pub from: Option<usize>,
}

impl Layer {
    /// Output shape of this layer.
    pub fn output(&self) -> Shape {
        match &self.kind {
            LayerKind::Conv { k, out_c, stride, pad, .. } => {
                let h = (self.input.h + 2 * pad - k) / stride + 1;
                let w = (self.input.w + 2 * pad - k) / stride + 1;
                Shape::new(h, w, *out_c)
            }
            LayerKind::Fc { out_features, .. } => Shape::new(1, 1, *out_features),
            LayerKind::MaxPool { win, stride } | LayerKind::AvgPool { win, stride } => {
                let h = (self.input.h - win) / stride + 1;
                let w = (self.input.w - win) / stride + 1;
                Shape::new(h, w, self.input.c)
            }
            LayerKind::ResidualAdd { .. } => self.input,
        }
    }

    /// Multiply-accumulate count (the paper's MACs metric; 0 for layers
    /// without multiplications).
    pub fn macs(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv { k, groups, .. } => {
                let out = self.output();
                out.h * out.w * out.c * k * k * self.input.c / groups
            }
            LayerKind::Fc { out_features, .. } => self.input.elems() * out_features,
            _ => 0,
        }
    }

    /// Weight parameter count (0 for weight-less layers).
    pub fn params(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv { k, out_c, groups, .. } => k * k * self.input.c * out_c / groups,
            LayerKind::Fc { out_features, .. } => self.input.elems() * out_features,
            _ => 0,
        }
    }

    /// True for layers that carry quantizable weights (conv / fc) — the
    /// layers a per-layer mixed-precision configuration assigns bits to.
    pub fn has_weights(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. } | LayerKind::Fc { .. })
    }

    /// im2col GEMM dimensions for conv / fc layers, `None` otherwise.
    pub fn gemm_dims(&self) -> Option<gemm::GemmDims> {
        match &self.kind {
            LayerKind::Conv { k, out_c, groups, .. } => {
                // A grouped conv is `groups` independent GEMMs; for cost
                // purposes we model one GEMM with the contraction shortened
                // by the group count (identical total MACs and words).
                let out = self.output();
                Some(gemm::GemmDims {
                    i: *out_c,
                    j: k * k * self.input.c / groups,
                    u: out.h * out.w,
                })
            }
            LayerKind::Fc { out_features, .. } => {
                Some(gemm::GemmDims { i: *out_features, j: self.input.elems(), u: 1 })
            }
            _ => None,
        }
    }
}

/// A whole network: named, with an ImageNet-style input.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    /// Model name (zoo name).
    pub name: String,
    /// Input shape (e.g. 224x224x3 for the ImageNet models).
    pub input: Shape,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
}

impl Network {
    /// Total MACs across all layers (the paper quotes 0.72G / 15.5G / 4.14G
    /// for AlexNet / VGG16 / ResNet50).
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Total weight parameters.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(Layer::params).sum()
    }

    /// Number of weight-carrying (quantizable) layers.
    pub fn weight_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.has_weights()).count()
    }

    /// Indices of the weight-carrying layers, in execution order.
    pub fn weight_layer_indices(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.has_weights())
            .map(|(i, _)| i)
            .collect()
    }

    /// Largest conv layer by MACs — sizes the IR (maximum-parallelism)
    /// configuration (§III-A: "Configuring the accelerator size is based on
    /// the dimensions of the convolutional layer with the highest number of
    /// MACs").
    pub fn largest_conv_macs(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv { .. }))
            .map(Layer::macs)
            .max()
            .unwrap_or(0)
    }

    /// Validate shape chaining: each layer's recorded input must equal the
    /// previous layer's output (residual adds must reference an earlier
    /// layer with a matching output shape).
    pub fn validate(&self) -> Result<(), String> {
        let mut prev = self.input;
        for (idx, layer) in self.layers.iter().enumerate() {
            let feeding = match layer.from {
                None => prev,
                Some(src) => {
                    if src >= idx {
                        return Err(format!(
                            "layer {idx} '{}': feeds from {src}, not an earlier layer",
                            layer.name
                        ));
                    }
                    self.layers[src].output()
                }
            };
            if layer.input != feeding {
                return Err(format!(
                    "layer {idx} '{}': recorded input {:?} != feeding output {feeding:?}",
                    layer.name, layer.input
                ));
            }
            if let LayerKind::ResidualAdd { from, .. } = layer.kind {
                if from >= idx {
                    return Err(format!(
                        "layer {idx} '{}': residual source {from} is not an earlier layer",
                        layer.name
                    ));
                }
                let src_out = self.layers[from].output();
                if src_out != layer.input {
                    return Err(format!(
                        "layer {idx} '{}': residual source shape {src_out:?} != input {:?}",
                        layer.name, layer.input
                    ));
                }
            }
            prev = layer.output();
        }
        Ok(())
    }

    /// Output shape of the final layer.
    pub fn output(&self) -> Shape {
        self.layers.last().map(Layer::output).unwrap_or(self.input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_layer() -> Layer {
        Layer {
            name: "c1".into(),
            input: Shape::new(224, 224, 3),
            kind: LayerKind::Conv { k: 11, out_c: 96, stride: 4, pad: 2, groups: 1, relu: true },
            from: None,
        }
    }

    #[test]
    fn conv_output_shape() {
        // AlexNet conv1: (224 + 4 - 11)/4 + 1 = 55.
        assert_eq!(conv_layer().output(), Shape::new(55, 55, 96));
    }

    #[test]
    fn conv_macs_and_params() {
        let l = conv_layer();
        assert_eq!(l.macs(), 55 * 55 * 96 * 11 * 11 * 3);
        assert_eq!(l.params(), 11 * 11 * 3 * 96);
    }

    #[test]
    fn conv_gemm_dims_match_im2col() {
        let g = conv_layer().gemm_dims().unwrap();
        assert_eq!(g.i, 96);
        assert_eq!(g.j, 11 * 11 * 3);
        assert_eq!(g.u, 55 * 55);
        // GEMM MACs == conv MACs.
        assert_eq!(g.i * g.j * g.u, conv_layer().macs());
    }

    #[test]
    fn pool_output_shape() {
        let l = Layer {
            name: "p".into(),
            input: Shape::new(55, 55, 96),
            kind: LayerKind::MaxPool { win: 3, stride: 2 },
            from: None,
        };
        assert_eq!(l.output(), Shape::new(27, 27, 96));
        assert_eq!(l.macs(), 0);
    }

    #[test]
    fn fc_is_gemm_with_u1() {
        let l = Layer {
            name: "fc".into(),
            input: Shape::new(1, 1, 4096),
            kind: LayerKind::Fc { out_features: 1000, relu: false },
            from: None,
        };
        let g = l.gemm_dims().unwrap();
        assert_eq!((g.i, g.j, g.u), (1000, 4096, 1));
        assert_eq!(l.macs(), 4096 * 1000);
    }

    #[test]
    fn validate_catches_shape_breaks() {
        let mut net = Network {
            name: "bad".into(),
            input: Shape::new(224, 224, 3),
            layers: vec![conv_layer()],
        };
        assert!(net.validate().is_ok());
        net.layers.push(Layer {
            name: "bad_next".into(),
            input: Shape::new(10, 10, 10), // wrong: conv1 outputs 55x55x96
            kind: LayerKind::MaxPool { win: 2, stride: 2 },
            from: None,
        });
        assert!(net.validate().is_err());
    }

    #[test]
    fn validate_checks_residual_sources() {
        let shape = Shape::new(8, 8, 4);
        let id_conv = Layer {
            name: "c".into(),
            input: shape,
            kind: LayerKind::Conv { k: 3, out_c: 4, stride: 1, pad: 1, groups: 1, relu: true },
            from: None,
        };
        let net = Network {
            name: "res".into(),
            input: shape,
            layers: vec![
                id_conv.clone(),
                Layer { name: "r".into(), input: shape, kind: LayerKind::ResidualAdd { from: 0, relu: true }, from: None },
            ],
        };
        assert!(net.validate().is_ok());
        let bad = Network {
            name: "res_bad".into(),
            input: shape,
            layers: vec![Layer {
                name: "r".into(),
                input: shape,
                kind: LayerKind::ResidualAdd { from: 0, relu: true },
                from: None,
            }],
        };
        assert!(bad.validate().is_err());
    }
}
