//! Model zoo — the paper's ImageNet benchmarks (AlexNet, VGG16, ResNet18,
//! ResNet50) plus the small CNN served by the end-to-end example.
//!
//! MAC counts are pinned by tests to the figures the paper quotes in §V-A:
//! AlexNet 0.72 G (the grouped two-tower variant), VGG16 15.5 G and
//! ResNet50 4.14 G (±5%), and ResNet18 ≈ 1.8 G.

use super::{Layer, LayerKind, Network, Shape};

/// Incremental network builder that chains shapes automatically.
struct Builder {
    layers: Vec<Layer>,
    input: Shape,
    cur: Shape,
}

impl Builder {
    fn new(input: Shape) -> Self {
        Self { layers: Vec::new(), input, cur: input }
    }

    /// Index of the most recently added layer (panics on empty).
    fn last(&self) -> usize {
        self.layers.len() - 1
    }

    fn push(&mut self, name: std::sync::Arc<str>, kind: LayerKind, from: Option<usize>) -> usize {
        let input = match from {
            None => self.cur,
            Some(src) => self.layers[src].output(),
        };
        let layer = Layer { name, input, kind, from };
        self.cur = layer.output();
        self.layers.push(layer);
        self.layers.len() - 1
    }

    fn conv(&mut self, name: &str, k: u64, out_c: u64, stride: u64, pad: u64, relu: bool) -> usize {
        self.push(name.into(), LayerKind::Conv { k, out_c, stride, pad, groups: 1, relu }, None)
    }

    fn conv_from(
        &mut self,
        name: &str,
        from: usize,
        k: u64,
        out_c: u64,
        stride: u64,
        pad: u64,
        relu: bool,
    ) -> usize {
        self.push(name.into(), LayerKind::Conv { k, out_c, stride, pad, groups: 1, relu }, Some(from))
    }

    fn conv_grouped(&mut self, name: &str, k: u64, out_c: u64, stride: u64, pad: u64, groups: u64) -> usize {
        self.push(name.into(), LayerKind::Conv { k, out_c, stride, pad, groups, relu: true }, None)
    }

    fn maxpool(&mut self, name: &str, win: u64, stride: u64) -> usize {
        self.push(name.into(), LayerKind::MaxPool { win, stride }, None)
    }

    fn avgpool(&mut self, name: &str, win: u64, stride: u64) -> usize {
        self.push(name.into(), LayerKind::AvgPool { win, stride }, None)
    }

    fn fc(&mut self, name: &str, out_features: u64, relu: bool) -> usize {
        self.push(name.into(), LayerKind::Fc { out_features, relu }, None)
    }

    fn residual(&mut self, name: &str, skip_from: usize, relu: bool) -> usize {
        self.push(name.into(), LayerKind::ResidualAdd { from: skip_from, relu }, None)
    }

    fn build(self, name: &str) -> Network {
        let net = Network { name: name.into(), input: self.input, layers: self.layers };
        net.validate().unwrap_or_else(|e| panic!("zoo network '{name}' invalid: {e}"));
        net
    }
}

/// AlexNet (Krizhevsky et al.) — the grouped two-tower ImageNet variant
/// (conv2/4/5 with groups = 2), 0.72 G MACs as quoted by the paper.
pub fn alexnet() -> Network {
    let mut b = Builder::new(Shape::new(224, 224, 3));
    b.conv("conv1", 11, 96, 4, 2, true);
    b.maxpool("pool1", 3, 2);
    b.conv_grouped("conv2", 5, 256, 1, 2, 2);
    b.maxpool("pool2", 3, 2);
    b.conv("conv3", 3, 384, 1, 1, true);
    b.conv_grouped("conv4", 3, 384, 1, 1, 2);
    b.conv_grouped("conv5", 3, 256, 1, 1, 2);
    b.maxpool("pool5", 3, 2);
    b.fc("fc6", 4096, true);
    b.fc("fc7", 4096, true);
    b.fc("fc8", 1000, false);
    b.build("alexnet")
}

/// VGG16 (Simonyan & Zisserman), 15.5 G MACs.
pub fn vgg16() -> Network {
    let mut b = Builder::new(Shape::new(224, 224, 3));
    let cfg: &[&[u64]] = &[&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]];
    for (s, widths) in cfg.iter().enumerate() {
        for (i, &w) in widths.iter().enumerate() {
            b.conv(&format!("conv{}_{}", s + 1, i + 1), 3, w, 1, 1, true);
        }
        b.maxpool(&format!("pool{}", s + 1), 2, 2);
    }
    b.fc("fc6", 4096, true);
    b.fc("fc7", 4096, true);
    b.fc("fc8", 1000, false);
    b.build("vgg16")
}

/// One ResNet *basic* block (two 3x3 convs). `downsample` adds the 1x1
/// strided projection on the skip path (first block of stages 2–4).
fn basic_block(b: &mut Builder, name: &str, out_c: u64, stride: u64, downsample: bool) {
    let pre = b.last();
    let skip = if downsample {
        b.conv_from(&format!("{name}.ds"), pre, 1, out_c, stride, 0, false)
    } else {
        pre
    };
    b.conv_from(&format!("{name}.conv1"), pre, 3, out_c, stride, 1, true);
    b.conv(&format!("{name}.conv2"), 3, out_c, 1, 1, false);
    b.residual(&format!("{name}.add"), skip, true);
}

/// One ResNet *bottleneck* block (1x1 down, 3x3, 1x1 up x4).
fn bottleneck_block(b: &mut Builder, name: &str, mid_c: u64, stride: u64, downsample: bool) {
    let out_c = 4 * mid_c;
    let pre = b.last();
    let skip = if downsample {
        b.conv_from(&format!("{name}.ds"), pre, 1, out_c, stride, 0, false)
    } else {
        pre
    };
    b.conv_from(&format!("{name}.conv1"), pre, 1, mid_c, 1, 0, true);
    b.conv(&format!("{name}.conv2"), 3, mid_c, stride, 1, true);
    b.conv(&format!("{name}.conv3"), 1, out_c, 1, 0, false);
    b.residual(&format!("{name}.add"), skip, true);
}

/// ResNet18 (He et al.), ≈1.8 G MACs — the HAWQ-V3 bit-fluidity benchmark.
pub fn resnet18() -> Network {
    let mut b = Builder::new(Shape::new(224, 224, 3));
    b.conv("conv1", 7, 64, 2, 3, true);
    b.maxpool("pool1", 3, 2);
    let stages: &[(u64, u64)] = &[(64, 1), (128, 2), (256, 2), (512, 2)];
    for (s, &(c, stride)) in stages.iter().enumerate() {
        basic_block(&mut b, &format!("layer{}.0", s + 1), c, stride, stride != 1);
        basic_block(&mut b, &format!("layer{}.1", s + 1), c, 1, false);
    }
    b.avgpool("gap", 7, 7);
    b.fc("fc", 1000, false);
    b.build("resnet18")
}

/// ResNet50 (He et al.), 4.14 G MACs as quoted by the paper.
pub fn resnet50() -> Network {
    let mut b = Builder::new(Shape::new(224, 224, 3));
    b.conv("conv1", 7, 64, 2, 3, true);
    b.maxpool("pool1", 3, 2);
    let stages: &[(u64, u64, usize)] = &[(64, 1, 3), (128, 2, 4), (256, 2, 6), (512, 2, 3)];
    for (s, &(c, stride, blocks)) in stages.iter().enumerate() {
        // The first bottleneck of every stage projects the skip path (the
        // channel count changes 64 -> 256 even at stride 1 in stage 1).
        bottleneck_block(&mut b, &format!("layer{}.0", s + 1), c, stride, true);
        for blk in 1..blocks {
            bottleneck_block(&mut b, &format!("layer{}.{}", s + 1, blk), c, 1, false);
        }
    }
    b.avgpool("gap", 7, 7);
    b.fc("fc", 1000, false);
    b.build("resnet50")
}

/// The small CNN trained at build time and served by `examples/e2e_serving`
/// (matches `python/compile/model.py::SERVE_CNN` layer for layer): 32x32x3
/// input, 3 conv stages, global average pooling, 10-way classifier.
pub fn serve_cnn() -> Network {
    let mut b = Builder::new(Shape::new(32, 32, 3));
    b.conv("conv1", 3, 16, 1, 1, true);
    b.conv("conv2", 3, 16, 1, 1, true);
    b.maxpool("pool1", 2, 2);
    b.conv("conv3", 3, 32, 1, 1, true);
    b.conv("conv4", 3, 32, 1, 1, true);
    b.maxpool("pool2", 2, 2);
    b.conv("conv5", 3, 64, 1, 1, true);
    b.avgpool("gap", 8, 8);
    b.fc("fc", 10, false);
    b.build("serve_cnn")
}

/// All ImageNet benchmark networks the paper evaluates (Fig. 7 order).
pub fn imagenet_benchmarks() -> Vec<Network> {
    vec![alexnet(), vgg16(), resnet50()]
}

/// One transformer block's *weight* GEMMs (§V-D "Supported Workloads"):
/// QKV projection, attention output projection, and the two FFN matmuls,
/// expressed as 1x1 convolutions over a `seq x 1 x d_model` activation map
/// (token-parallel GEMMs — exactly how they land on the AP). The
/// activation-activation attention matmuls (QKᵀ, AV) carry no weights and
/// are omitted; they add ~`2·seq²·d` MACs (< 10% at seq << d) and map to
/// the same AP GEMM primitive. Used to quantify the paper's §V-D claim
/// that matrix multiplications dominate LLM inference energy on BF-IMNA.
pub fn llm_block(seq: u64, d_model: u64) -> Network {
    let mut b = Builder::new(Shape::new(seq, 1, d_model));
    // Token embedding projection — also anchors the residual stream (the
    // IR's ResidualAdd references an earlier *layer*).
    let stream = b.conv("embed", 1, d_model, 1, 0, false);
    b.conv("attn.qkv", 1, 3 * d_model, 1, 0, false);
    // Attention output projection back to the residual width (the
    // activation-activation QKᵀ/AV matmuls carry no weights; see docs).
    b.conv("attn.out", 1, d_model, 1, 0, false);
    b.residual("attn.add", stream, false);
    let post_attn = b.last();
    b.conv("ffn.up", 1, 4 * d_model, 1, 0, true);
    b.conv("ffn.down", 1, d_model, 1, 0, false);
    b.residual("ffn.add", post_attn, false);
    b.build(&format!("llm_block_s{seq}_d{d_model}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn giga(x: u64) -> f64 {
        x as f64 / 1e9
    }

    #[test]
    fn alexnet_macs_match_paper() {
        let net = alexnet();
        net.validate().unwrap();
        let g = giga(net.total_macs());
        assert!((g - 0.72).abs() < 0.72 * 0.05, "AlexNet MACs {g:.3} G != 0.72 G");
    }

    #[test]
    fn vgg16_macs_match_paper() {
        let net = vgg16();
        net.validate().unwrap();
        let g = giga(net.total_macs());
        assert!((g - 15.5).abs() < 15.5 * 0.05, "VGG16 MACs {g:.2} G != 15.5 G");
    }

    #[test]
    fn resnet50_macs_match_paper() {
        let net = resnet50();
        net.validate().unwrap();
        let g = giga(net.total_macs());
        assert!((g - 4.14).abs() < 4.14 * 0.05, "ResNet50 MACs {g:.2} G != 4.14 G");
    }

    #[test]
    fn resnet18_macs_standard() {
        let net = resnet18();
        net.validate().unwrap();
        let g = giga(net.total_macs());
        assert!((g - 1.82).abs() < 1.82 * 0.06, "ResNet18 MACs {g:.2} G != 1.82 G");
    }

    #[test]
    fn vgg16_params_standard() {
        // VGG16 has ~138 M parameters.
        let p = vgg16().total_params() as f64 / 1e6;
        assert!((p - 138.0).abs() < 3.0, "VGG16 params {p:.1} M");
    }

    #[test]
    fn resnet18_weight_layer_count() {
        // conv1 + 16 block convs + 3 downsample convs + fc = 21 weight
        // layers; HAWQ-V3's 19-entry config maps onto these via
        // `precision::hawq` (downsample convs inherit their block).
        assert_eq!(resnet18().weight_layers(), 21);
    }

    #[test]
    fn resnet50_layer_structure() {
        let net = resnet50();
        // 1 stem + (3+4+6+3) blocks x 3 convs + 4 downsamples + fc = 53
        // weight layers.
        assert_eq!(net.weight_layers(), 1 + 16 * 3 + 4 + 1);
        assert_eq!(net.output(), Shape::new(1, 1, 1000));
    }

    #[test]
    fn all_networks_validate_and_classify() {
        for net in [alexnet(), vgg16(), resnet18(), resnet50()] {
            net.validate().unwrap();
            assert_eq!(net.output(), Shape::new(1, 1, 1000), "{}", net.name);
        }
        let s = serve_cnn();
        s.validate().unwrap();
        assert_eq!(s.output(), Shape::new(1, 1, 10));
    }

    #[test]
    fn paper_mac_ordering_vgg_gt_resnet_gt_alexnet() {
        // §V-A: "the number of MAC operations of VGG16 (15.5G) exceeds
        // ResNet50 (4.14G) which exceeds AlexNet (0.72G)".
        let (a, v, r) = (alexnet().total_macs(), vgg16().total_macs(), resnet50().total_macs());
        assert!(v > r && r > a);
    }

    #[test]
    fn llm_block_is_gemm_dominated() {
        // §V-D: "matrix-multiplications constitute more than 99% of LLM
        // operations" — the block's MACs must be entirely in the GEMMs.
        let net = llm_block(128, 768);
        net.validate().unwrap();
        let gemm_macs: u64 = net
            .layers
            .iter()
            .filter(|l| l.has_weights())
            .map(Layer::macs)
            .sum();
        assert_eq!(gemm_macs, net.total_macs());
        // GPT-2-small scale: embed d² + qkv 3d² + out 3d² (projects the 3d
        // QKV tensor) + ffn 8d² = 15·d²·seq.
        assert_eq!(net.total_macs(), 15 * 768 * 768 * 128);
        assert_eq!(net.output(), Shape::new(128, 1, 768));
        assert_eq!(net.weight_layers(), 5);
    }

    #[test]
    fn llm_block_simulates_with_gemm_energy_dominance() {
        // The §V-D energy claim, end to end through the simulator.
        use crate::precision::PrecisionConfig;
        use crate::sim::{breakdown, simulate, SimParams};
        let net = llm_block(64, 512);
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let r = simulate(&net, &cfg, &SimParams::lr_sram());
        let shares = breakdown::energy_by_kind(&r);
        let gemm = breakdown::fraction_of(&shares, "GEMM");
        // §V-D: matmuls are "BF-IMNA's energy bottleneck" and dominate LLM
        // work; the remainder here is interconnect streaming.
        assert!(gemm > 0.75, "GEMM energy share {gemm:.3}");
        let residual = breakdown::fraction_of(&shares, "Residual/ReLU");
        assert!(residual < 0.05, "residual share {residual:.3}");
    }

    #[test]
    fn largest_conv_sizes_ir_config() {
        let net = vgg16();
        let largest = net.largest_conv_macs();
        // VGG16's largest conv layer is conv1_2 / conv2_x scale: ~1.85 G.
        assert!(largest > 1_000_000_000, "largest conv {largest}");
        assert!(largest < net.total_macs());
    }
}
