//! im2col GEMM lowering (paper §II-C, Fig. 2).
//!
//! A convolution over an `{H_I, W_I, C_I}` input with `C_K` kernels of
//! `{H_K, W_K, C_I}` becomes `K x P = O`:
//!
//! * `P` ("input-patch" / Toeplitz matrix): `(H_K·W_K·C_I) x (H_O·W_O)`,
//! * `K` ("kernel-patch" matrix): `C_K x (H_K·W_K·C_I)`,
//! * `O`: `C_K x (H_O·W_O)`.
//!
//! In the crate's `i x j` by `j x u` GEMM vocabulary: `i = C_K`,
//! `j = H_K·W_K·C_I`, `u = H_O·W_O`.

/// GEMM problem dimensions: an `i x j` (kernel) by `j x u` (input-patch)
/// product.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmDims {
    /// Output channels `C_K` (rows of the kernel matrix).
    pub i: u64,
    /// Contraction length `H_K·W_K·C_I`.
    pub j: u64,
    /// Output pixels `H_O·W_O` (columns of the patch matrix).
    pub u: u64,
}

impl GemmDims {
    /// Total MACs of the product.
    pub fn macs(&self) -> u64 {
        self.i * self.j * self.u
    }

    /// Words (product rows) an AP mapping materializes: one per (i, j, u)
    /// product triple (§III-B: "the number of rows needed in the AP ... is
    /// i*j*u").
    pub fn ap_words(&self) -> u64 {
        self.i * self.j * self.u
    }

    /// Elements of the input-patch matrix P (streamed per inference).
    pub fn patch_elems(&self) -> u64 {
        self.j * self.u
    }

    /// Elements of the kernel matrix K (resident weights).
    pub fn kernel_elems(&self) -> u64 {
        self.i * self.j
    }

    /// Elements of the output matrix O.
    pub fn output_elems(&self) -> u64 {
        self.i * self.u
    }
}

/// im2col expansion of an input feature map: (input shape, kernel, stride,
/// padding) -> P-matrix dimensions. Mirrors §II-C's formulas.
pub fn im2col_patch_dims(
    h_i: u64,
    w_i: u64,
    c_i: u64,
    h_k: u64,
    w_k: u64,
    stride: u64,
    pad: u64,
) -> (u64, u64) {
    let h_o = (h_i + 2 * pad - h_k) / stride + 1;
    let w_o = (w_i + 2 * pad - w_k) / stride + 1;
    (h_k * w_k * c_i, h_o * w_o)
}

/// Build the actual im2col patch matrix of a (row-major, HWC) input — used
/// by tests to prove the lowering is value-exact, and by the runtime to
/// prepare GEMM-artifact inputs. Out-of-range taps read zero (zero padding).
/// Returns a `(h_k*w_k*c_i) x (h_o*w_o)` matrix in row-major order.
pub fn im2col<T: Copy + Default>(
    input: &[T],
    h_i: usize,
    w_i: usize,
    c_i: usize,
    h_k: usize,
    w_k: usize,
    stride: usize,
    pad: usize,
) -> Vec<T> {
    assert_eq!(input.len(), h_i * w_i * c_i, "input length mismatch");
    let h_o = (h_i + 2 * pad - h_k) / stride + 1;
    let w_o = (w_i + 2 * pad - w_k) / stride + 1;
    let rows = h_k * w_k * c_i;
    let cols = h_o * w_o;
    let mut out = vec![T::default(); rows * cols];
    for oy in 0..h_o {
        for ox in 0..w_o {
            let col = oy * w_o + ox;
            for ky in 0..h_k {
                for kx in 0..w_k {
                    for ch in 0..c_i {
                        let row = (ky * w_k + kx) * c_i + ch;
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if iy >= 0 && (iy as usize) < h_i && ix >= 0 && (ix as usize) < w_i {
                            out[row * cols + col] =
                                input[(iy as usize * w_i + ix as usize) * c_i + ch];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Reference dense GEMM `C = A(i x j) * B(j x u)` over i64 (row-major), the
/// oracle for im2col-lowered convolution tests.
pub fn matmul_i64(a: &[i64], b: &[i64], i: usize, j: usize, u: usize) -> Vec<i64> {
    assert_eq!(a.len(), i * j);
    assert_eq!(b.len(), j * u);
    let mut c = vec![0i64; i * u];
    for ii in 0..i {
        for jj in 0..j {
            let av = a[ii * j + jj];
            if av == 0 {
                continue;
            }
            for uu in 0..u {
                c[ii * u + uu] += av * b[jj * u + uu];
            }
        }
    }
    c
}

/// Direct (nested-loop) convolution oracle over i64, HWC layout, returning
/// HWC output. Used to prove im2col + GEMM == convolution.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i64(
    input: &[i64],
    weights: &[i64], // [out_c][k][k][c_i]
    h_i: usize,
    w_i: usize,
    c_i: usize,
    k: usize,
    out_c: usize,
    stride: usize,
    pad: usize,
) -> Vec<i64> {
    let h_o = (h_i + 2 * pad - k) / stride + 1;
    let w_o = (w_i + 2 * pad - k) / stride + 1;
    let mut out = vec![0i64; h_o * w_o * out_c];
    for oc in 0..out_c {
        for oy in 0..h_o {
            for ox in 0..w_o {
                let mut acc = 0i64;
                for ky in 0..k {
                    for kx in 0..k {
                        for ch in 0..c_i {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if iy >= 0 && (iy as usize) < h_i && ix >= 0 && (ix as usize) < w_i {
                                acc += weights[((oc * k + ky) * k + kx) * c_i + ch]
                                    * input[(iy as usize * w_i + ix as usize) * c_i + ch];
                            }
                        }
                    }
                }
                out[(oy * w_o + ox) * out_c + oc] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn patch_dims_match_paper_formulas() {
        // Fig. 2's example: 2x2x2 input, 2x2x2x2 filter, stride 1, no pad.
        let (rows, cols) = im2col_patch_dims(2, 2, 2, 2, 2, 1, 0);
        assert_eq!(rows, 2 * 2 * 2);
        assert_eq!(cols, 1);
    }

    #[test]
    fn fig2_example_gemm() {
        // The Fig. 2 shapes: K is 2x8, P is 8x1, O is 2x1.
        let g = GemmDims { i: 2, j: 8, u: 1 };
        assert_eq!(g.macs(), 16);
        assert_eq!(g.kernel_elems(), 16);
        assert_eq!(g.output_elems(), 2);
    }

    /// im2col + GEMM must equal direct convolution on random cases
    /// (including stride > 1 and zero padding).
    #[test]
    fn im2col_gemm_equals_direct_conv() {
        check("im2col+gemm == conv", 32, |rng| {
            let h = rng.range(3, 8);
            let w = rng.range(3, 8);
            let c = rng.range(1, 4);
            let k = rng.range(1, 3.min(h).min(w));
            let oc = rng.range(1, 4);
            let stride = rng.range(1, 2);
            let pad = rng.range(0, 1);
            let input: Vec<i64> = (0..h * w * c).map(|_| rng.range_i64(-8, 8)).collect();
            let weights: Vec<i64> = (0..oc * k * k * c).map(|_| rng.range_i64(-8, 8)).collect();

            let direct = conv2d_i64(&input, &weights, h, w, c, k, oc, stride, pad);

            let p = im2col(&input, h, w, c, k, k, stride, pad);
            let j = k * k * c;
            let h_o = (h + 2 * pad - k) / stride + 1;
            let w_o = (w + 2 * pad - k) / stride + 1;
            let u = h_o * w_o;
            // Kernel matrix rows are [k][k][c] unrolled — same order im2col
            // unrolls patch rows.
            let gemm_out = matmul_i64(&weights, &p, oc, j, u);
            for ocx in 0..oc {
                for px in 0..u {
                    let got = gemm_out[ocx * u + px];
                    let want = direct[px * oc + ocx];
                    if got != want {
                        return Err(format!("mismatch at oc={ocx} pixel={px}: {got} != {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(5);
        let n = 4;
        let a: Vec<i64> = (0..n * n).map(|_| rng.range_i64(-5, 5)).collect();
        let mut eye = vec![0i64; n * n];
        for d in 0..n {
            eye[d * n + d] = 1;
        }
        assert_eq!(matmul_i64(&a, &eye, n, n, n), a);
        assert_eq!(matmul_i64(&eye, &a, n, n, n), a);
    }
}
