//! The sweep engine — batch simulation with plan caching and parallel
//! fan-out (the DSE hot path's execution substrate).
//!
//! Every paper-level result (Figs. 6–8, Table VII, the HAWQ bit-fluid
//! study) is a sweep: thousands of independent `simulate()` points over
//! precision/hardware coordinates. [`SweepEngine`] runs such sweeps
//!
//! * **memoized** — all points share one [`PlanCache`], so mapping work is
//!   `O(unique layer × bits × chip)` instead of `O(points × layers)`;
//! * **parallel** — points fan out across `std::thread::scope` workers
//!   (an atomic work queue, no work item ever computed twice);
//! * **deterministic** — results come back in input order, and every
//!   report is bit-identical to a direct [`crate::sim::simulate`] call:
//!   workers run the same pure function on the same inputs, so neither
//!   thread count nor cache state can change a single bit of the output.
//!
//! Chip configurations are resolved once per (hardware config, network)
//! and shared across that network's points, removing the per-point
//! `ChipConfig::for_network` scan *and* guaranteeing all points of a
//! network agree on their cache keys.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;

use super::{simulate_with_cache, InferenceReport, SimParams};
use crate::arch::{ChipConfig, HwConfig};
use crate::mapper::{CacheStats, PlanCache};
use crate::model::Network;
use crate::precision::PrecisionConfig;

/// One independent simulation point of a sweep.
#[derive(Clone, Copy)]
pub struct SweepPoint<'a> {
    /// Network to simulate.
    pub net: &'a Network,
    /// Per-layer precision configuration.
    pub cfg: &'a PrecisionConfig,
    /// Hardware point (chip family, cell technology, batch).
    pub params: SimParams,
    /// Explicit chip override (geometry ablations); `None` derives the
    /// chip from `params.hw` + `net`, memoized per network.
    pub chip: Option<&'a ChipConfig>,
}

impl<'a> SweepPoint<'a> {
    /// A point on the standard chip for `params.hw`.
    pub fn new(net: &'a Network, cfg: &'a PrecisionConfig, params: &SimParams) -> Self {
        Self { net, cfg, params: *params, chip: None }
    }

    /// A point on an explicit chip (ablations that vary geometry).
    pub fn on_chip(
        net: &'a Network,
        cfg: &'a PrecisionConfig,
        params: &SimParams,
        chip: &'a ChipConfig,
    ) -> Self {
        Self { net, cfg, params: *params, chip: Some(chip) }
    }
}

/// A reusable sweep runner: one plan cache + a worker-thread budget.
///
/// Reuse one engine across related sweeps (e.g. all of Fig. 7's series):
/// the cache carries over, so later sweeps start warm.
///
/// ```
/// use bf_imna::model::zoo;
/// use bf_imna::precision::PrecisionConfig;
/// use bf_imna::sim::{simulate, SimParams, SweepEngine, SweepPoint};
///
/// let net = zoo::serve_cnn();
/// let params = SimParams::lr_sram();
/// let cfgs: Vec<_> =
///     (2..=8).map(|b| PrecisionConfig::fixed(b, net.weight_layers())).collect();
/// let points: Vec<_> = cfgs.iter().map(|c| SweepPoint::new(&net, c, &params)).collect();
///
/// let engine = SweepEngine::new();
/// let reports = engine.run(&points);
/// // Input order, one report per point, bit-identical to direct simulate().
/// assert_eq!(reports.len(), points.len());
/// let direct = simulate(&net, &cfgs[0], &params);
/// assert_eq!(reports[0].energy_j().to_bits(), direct.energy_j().to_bits());
/// ```
#[derive(Debug)]
pub struct SweepEngine {
    cache: PlanCache,
    threads: usize,
}

impl Default for SweepEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl SweepEngine {
    /// Engine with one worker per available CPU.
    pub fn new() -> Self {
        Self::with_threads(default_threads())
    }

    /// Engine that runs points on the calling thread only (still cached).
    pub fn serial() -> Self {
        Self::with_threads(1)
    }

    /// Engine with an explicit worker count (clamped to ≥ 1).
    pub fn with_threads(threads: usize) -> Self {
        Self { cache: PlanCache::new(), threads: threads.max(1) }
    }

    /// Worker-thread budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The shared plan cache (for stats or pre-warming).
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Shorthand for `self.cache().stats()`.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Batch-level cache prewarm: map every `points` coordinate into the
    /// plan cache serially, returning how many new plans were stored. A
    /// subsequent [`Self::run`] over the same points never maps cold — in
    /// particular, parallel workers can no longer race on a cold key and
    /// duplicate its `map_layer` work. Results are unaffected either way
    /// (cached and uncached mapping are bit-identical); prewarming is
    /// purely a work-scheduling optimization, and the engine's cache can
    /// afterwards be [`PlanCache::snapshot`]ted and shipped to other
    /// processes ([`crate::sim::shard`] does exactly that).
    pub fn prewarm(&self, points: &[SweepPoint]) -> usize {
        let chips = self.resolve_chips(points);
        let before = self.cache.len();
        for (p, chip) in points.iter().zip(&chips) {
            self.cache.map_network(p.net, chip, p.cfg);
        }
        self.cache.len() - before
    }

    /// Simulate every point, returning reports **in input order**. Points
    /// are independent; each is computed exactly once, on whichever worker
    /// pulls it first.
    pub fn run(&self, points: &[SweepPoint]) -> Vec<InferenceReport> {
        let chips = self.resolve_chips(points);
        let n = points.len();
        let workers = self.threads.min(n);
        if workers <= 1 {
            return points
                .iter()
                .zip(&chips)
                .map(|(p, chip)| simulate_with_cache(p.net, p.cfg, &p.params, chip, &self.cache))
                .collect();
        }

        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, InferenceReport)>();
        thread::scope(|s| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let chips = &chips;
                let cache = &self.cache;
                s.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let p = &points[i];
                    let report = simulate_with_cache(p.net, p.cfg, &p.params, &chips[i], cache);
                    if tx.send((i, report)).is_err() {
                        break;
                    }
                });
            }
        });
        drop(tx);

        let mut slots: Vec<Option<InferenceReport>> = (0..n).map(|_| None).collect();
        for (i, report) in rx {
            slots[i] = Some(report);
        }
        slots
            .into_iter()
            .map(|r| r.expect("every sweep point produces exactly one report"))
            .collect()
    }

    /// Convenience: one (net, cfg) pair per point at a common `params`.
    pub fn run_configs(
        &self,
        net: &Network,
        cfgs: &[PrecisionConfig],
        params: &SimParams,
    ) -> Vec<InferenceReport> {
        let points: Vec<SweepPoint> =
            cfgs.iter().map(|c| SweepPoint::new(net, c, params)).collect();
        self.run(&points)
    }

    /// Resolve each point's chip, building `ChipConfig::for_network` at
    /// most once per (hw, network) so same-network points share one chip.
    fn resolve_chips(&self, points: &[SweepPoint]) -> Vec<ChipConfig> {
        let mut memo: HashMap<(HwConfig, usize), ChipConfig> = HashMap::new();
        points
            .iter()
            .map(|p| match p.chip {
                Some(chip) => *chip,
                None => *memo
                    .entry((p.params.hw, p.net as *const Network as usize))
                    .or_insert_with(|| ChipConfig::for_network(p.params.hw, p.net)),
            })
            .collect()
    }
}

/// Simulate a batch of points with a fresh default engine.
pub fn simulate_many(points: &[SweepPoint]) -> Vec<InferenceReport> {
    SweepEngine::new().run(points)
}

fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ap::tech::Tech;
    use crate::model::zoo;
    use crate::sim::simulate;

    fn points_for<'a>(
        net: &'a Network,
        cfgs: &'a [PrecisionConfig],
        params: &SimParams,
    ) -> Vec<SweepPoint<'a>> {
        cfgs.iter().map(|c| SweepPoint::new(net, c, params)).collect()
    }

    #[test]
    fn engine_matches_direct_simulate_bit_for_bit() {
        let net = zoo::alexnet();
        let params = SimParams::lr_sram();
        let cfgs: Vec<PrecisionConfig> =
            (2..=8).map(|b| PrecisionConfig::fixed(b, net.weight_layers())).collect();
        let points = points_for(&net, &cfgs, &params);
        let engine = SweepEngine::new();
        let reports = engine.run(&points);
        assert_eq!(reports.len(), cfgs.len());
        for (r, cfg) in reports.iter().zip(&cfgs) {
            let direct = simulate(&net, cfg, &params);
            assert_eq!(r.energy_j().to_bits(), direct.energy_j().to_bits());
            assert_eq!(r.latency_s().to_bits(), direct.latency_s().to_bits());
            assert_eq!(r.cfg_name, direct.cfg_name);
        }
    }

    #[test]
    fn parallel_and_serial_orders_agree() {
        let nets = [zoo::alexnet(), zoo::resnet18()];
        let params = SimParams::new(HwConfig::Lr, Tech::reram());
        let cfgs: Vec<PrecisionConfig> =
            (2..=8).map(|b| PrecisionConfig::fixed(b, nets[0].weight_layers())).collect();
        let mut points = Vec::new();
        for net in &nets {
            for cfg in &cfgs {
                points.push(SweepPoint::new(net, cfg, &params));
            }
        }
        let serial = SweepEngine::serial().run(&points);
        let parallel = SweepEngine::with_threads(4).run(&points);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.net_name, p.net_name);
            assert_eq!(s.cfg_name, p.cfg_name);
            assert_eq!(s.energy_j().to_bits(), p.energy_j().to_bits());
            assert_eq!(s.latency_s().to_bits(), p.latency_s().to_bits());
        }
    }

    #[test]
    fn repeated_runs_hit_the_cache() {
        let net = zoo::resnet18();
        let params = SimParams::lr_sram();
        let cfgs: Vec<PrecisionConfig> =
            (2..=8).map(|b| PrecisionConfig::fixed(b, net.weight_layers())).collect();
        let engine = SweepEngine::new();
        engine.run(&points_for(&net, &cfgs, &params));
        let after_first = engine.cache_stats();
        engine.run(&points_for(&net, &cfgs, &params));
        let after_second = engine.cache_stats();
        assert_eq!(after_first.entries, after_second.entries, "no new plans on rerun");
        assert!(
            after_second.hits >= after_first.hits + (net.layers.len() * cfgs.len()) as u64,
            "{after_first:?} -> {after_second:?}"
        );
    }

    #[test]
    fn chip_override_is_respected() {
        let net = zoo::alexnet();
        let cfg = PrecisionConfig::fixed(8, net.weight_layers());
        let params = SimParams::lr_sram();
        let mut chip = ChipConfig::lr();
        chip.mesh.e_bit_mm *= 4.0;
        let points = [
            SweepPoint::new(&net, &cfg, &params),
            SweepPoint::on_chip(&net, &cfg, &params, &chip),
        ];
        let reports = SweepEngine::new().run(&points);
        assert!(reports[1].energy_j() > reports[0].energy_j());
    }

    #[test]
    fn empty_sweep_is_fine() {
        assert!(SweepEngine::new().run(&[]).is_empty());
    }

    #[test]
    fn prewarmed_run_never_misses() {
        let net = zoo::alexnet();
        let params = SimParams::lr_sram();
        let cfgs: Vec<PrecisionConfig> =
            (2..=8).map(|b| PrecisionConfig::fixed(b, net.weight_layers())).collect();
        let points = points_for(&net, &cfgs, &params);
        let engine = SweepEngine::with_threads(4);
        let added = engine.prewarm(&points);
        assert!(added > 0);
        // Prewarming the same batch again adds nothing.
        assert_eq!(engine.prewarm(&points), 0);
        let misses_before = engine.cache_stats().misses;
        let reports = engine.run(&points);
        assert_eq!(engine.cache_stats().misses, misses_before, "run after prewarm mapped cold");
        // Still bit-identical to the cold path.
        let cold = SweepEngine::serial().run(&points);
        for (w, c) in reports.iter().zip(&cold) {
            assert_eq!(w.energy_j().to_bits(), c.energy_j().to_bits());
        }
    }
}
